"""Property tests (hypothesis) for the logical-axis sharding system."""
import jax
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st
from jax.sharding import Mesh, PartitionSpec as P

from repro.parallel.sharding import (DEFAULT_RULES, LogicalRules,
                                     activation_rules, rules_for_mesh,
                                     spec_for, spec_for_shape, batch_spec)


def fake_mesh(shape=(2, 2), names=("data", "model")):
    devs = np.array(jax.devices() * int(np.prod(shape)))[:int(np.prod(shape))]
    return Mesh(devs.reshape(shape), names)


def test_spec_for_basic():
    rules = LogicalRules({"a": "data", "b": "model", "c": None})
    assert spec_for(("a", "b"), rules) == P("data", "model")
    assert spec_for(("c", None, "a"), rules) == P(None, None, "data")


def test_spec_for_no_duplicate_axis():
    rules = LogicalRules({"a": "data", "b": "data"})
    s = spec_for(("a", "b"), rules)
    used = [x for x in s if x is not None]
    assert len(used) == len(set(used)) == 1


def test_spec_for_shape_drops_nondividing():
    mesh = fake_mesh((2, 2))
    rules = LogicalRules({"kv": "model", "d": "data"})
    # 3 is not divisible by 2 -> replicated
    s = spec_for_shape(("kv", "d"), (3, 8), rules, mesh)
    assert s == P(None, "data")


@settings(max_examples=50, deadline=None)
@given(dims=st.lists(st.integers(1, 64), min_size=1, max_size=4),
       axes=st.lists(st.sampled_from(["batch", "embed", "heads", "ff", None]),
                     min_size=1, max_size=4))
def test_spec_for_shape_always_divides(dims, axes):
    """Property: every sharded dim is divisible by its mesh-axes product."""
    n = min(len(dims), len(axes))
    dims, axes = dims[:n], axes[:n]
    mesh = fake_mesh((2, 2))
    rules = rules_for_mesh(mesh, DEFAULT_RULES)
    spec = spec_for_shape(tuple(axes), tuple(dims), rules, mesh)
    for dim, s in zip(dims, spec):
        if s is None:
            continue
        ax = (s,) if isinstance(s, str) else s
        prod = int(np.prod([mesh.shape[a] for a in ax]))
        assert dim % prod == 0


@settings(max_examples=50, deadline=None)
@given(gb=st.integers(1, 512))
def test_activation_rules_batch_always_divisible(gb):
    mesh = fake_mesh((2, 2), ("data", "model"))
    rules = rules_for_mesh(mesh, DEFAULT_RULES)
    out, seq_sharded = activation_rules(rules, gb, mesh)
    b = out.mesh_axes("batch")
    baxes = (b,) if isinstance(b, str) else (b or ())
    dp = int(np.prod([mesh.shape[a] for a in baxes])) if baxes else 1
    assert gb % dp == 0
    if gb % 2 != 0:               # cannot use the data axis for batch
        assert seq_sharded


def test_rules_for_mesh_strips_missing_axes():
    mesh = fake_mesh((4,), ("data",))
    rules = rules_for_mesh(mesh, DEFAULT_RULES)
    assert rules.mesh_axes("heads") is None          # no 'model' axis
    assert rules.mesh_axes("batch") == ("data",)


def test_batch_spec_no_axis_collision():
    rules = LogicalRules({"batch": ("pod", "data"), "seq_shard": "data"})
    s = batch_spec(rules, seq_sharded=True)
    flat = []
    for e in s:
        if e is None:
            continue
        flat.extend((e,) if isinstance(e, str) else e)
    assert len(flat) == len(set(flat))
