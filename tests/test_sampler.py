"""Shared sampler: greedy equivalence, top-k/top-p filtering, per-request
seeded determinism, and end-to-end determinism through the engines."""
import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.models import model as M
from repro.runtime.sampler import GREEDY, Sampler, SamplingParams
from repro.runtime.serving import PagedServingEngine, ServingEngine


def test_greedy_default_is_argmax():
    rng = np.random.default_rng(0)
    s = Sampler()
    for _ in range(5):
        logits = rng.normal(size=(32,))
        assert s.sample(logits) == int(np.argmax(logits))
        assert s.sample(logits, GREEDY, rid=3, step=9) == int(np.argmax(logits))
        assert s.sample(logits, SamplingParams(temperature=0.0, seed=1)) \
            == int(np.argmax(logits))


def test_top_k_one_is_argmax_even_with_temperature():
    rng = np.random.default_rng(1)
    s = Sampler()
    logits = rng.normal(size=(64,))
    sp = SamplingParams(temperature=2.0, top_k=1, seed=5)
    for step in range(10):
        assert s.sample(logits, sp, rid=0, step=step) == int(np.argmax(logits))


def test_top_k_filters_to_top_tokens():
    rng = np.random.default_rng(2)
    s = Sampler()
    logits = rng.normal(size=(100,))
    topk = set(np.argsort(-logits)[:5])
    sp = SamplingParams(temperature=1.5, top_k=5, seed=0)
    drawn = {s.sample(logits, sp, rid=0, step=t) for t in range(60)}
    assert drawn <= topk
    assert len(drawn) > 1                     # actually stochastic


def test_top_p_nucleus_excludes_tail():
    s = Sampler()
    logits = np.full(50, -10.0)
    logits[7] = 10.0                          # p(7) ~ 1.0 > any top_p
    sp = SamplingParams(temperature=1.0, top_p=0.5, seed=3)
    for step in range(20):
        assert s.sample(logits, sp, rid=1, step=step) == 7
    # two dominant tokens covering ~1.0: top_p=0.6 keeps only the larger
    logits[9] = 9.0
    drawn = {s.sample(logits, SamplingParams(temperature=1.0, top_p=0.6,
                                             seed=3), rid=1, step=t)
             for t in range(40)}
    assert drawn == {7}


def test_deterministic_per_seed_rid_step():
    rng = np.random.default_rng(4)
    s = Sampler()
    logits = rng.normal(size=(200,))
    sp = SamplingParams(temperature=1.0, seed=11)
    seq_a = [s.sample(logits, sp, rid=2, step=t) for t in range(30)]
    seq_b = [s.sample(logits, sp, rid=2, step=t) for t in range(30)]
    assert seq_a == seq_b                     # replay-exact
    assert len(set(seq_a)) > 1                # the stream is not constant
    seq_other_rid = [s.sample(logits, sp, rid=3, step=t) for t in range(30)]
    assert seq_a != seq_other_rid             # streams differ across requests
    seq_other_seed = [s.sample(logits, SamplingParams(temperature=1.0, seed=12),
                               rid=2, step=t) for t in range(30)]
    assert seq_a != seq_other_seed


def test_sampling_params_validation():
    with pytest.raises(ValueError):
        SamplingParams(temperature=-0.1)
    with pytest.raises(ValueError):
        SamplingParams(top_k=-1)
    with pytest.raises(ValueError):
        SamplingParams(top_p=0.0)
    with pytest.raises(ValueError):
        SamplingParams(top_p=1.5)
    with pytest.raises(ValueError):
        SamplingParams(seed=-1)


# -- through the engines ------------------------------------------------------

@pytest.fixture(scope="module")
def engine_setup():
    cfg = reduced_config(get_config("qwen3-1.7b"))
    params = M.init_params(M.param_specs(cfg), jax.random.PRNGKey(0))
    return cfg, params


def test_paged_engine_sampled_run_is_deterministic(engine_setup):
    cfg, params = engine_setup
    sp = SamplingParams(temperature=0.9, top_k=20, seed=7)
    runs = []
    for _ in range(2):
        eng = PagedServingEngine(cfg, params, page_size=8, num_pages=16,
                                 max_seats=2, max_seq_len=32, prefill_chunk=8)
        for i in range(3):
            eng.submit((np.arange(5 + i, dtype=np.int32) * 3) % cfg.vocab_size,
                       max_new_tokens=4, sampling=sp)
        done = eng.run()
        runs.append({r.rid: r.generated for r in done})
    assert runs[0] == runs[1]
    # greedy requests in the same batch stay greedy
    eng = PagedServingEngine(cfg, params, page_size=8, num_pages=16,
                             max_seats=2, max_seq_len=32, prefill_chunk=8)
    eng.submit(np.arange(5, dtype=np.int32), max_new_tokens=4, sampling=sp)
    eng.submit(np.arange(7, dtype=np.int32), max_new_tokens=4)
    mixed = {r.rid: r.generated for r in eng.run()}
    solo = PagedServingEngine(cfg, params, page_size=8, num_pages=16,
                              max_seats=2, max_seq_len=32, prefill_chunk=8)
    solo.submit(np.arange(7, dtype=np.int32), max_new_tokens=4)
    assert mixed[1] == solo.run()[0].generated


def test_fixed_engine_sampled_run_is_deterministic(engine_setup):
    cfg, params = engine_setup
    sp = SamplingParams(temperature=1.1, top_p=0.9, seed=13)
    runs = []
    for _ in range(2):
        eng = ServingEngine(cfg, params, slots=2, max_len=32)
        for i in range(3):
            eng.submit((np.arange(4 + i, dtype=np.int32) * 7) % cfg.vocab_size,
                       max_new_tokens=3, sampling=sp)
        done = eng.run()
        runs.append({r.rid: r.generated for r in done})
    assert runs[0] == runs[1]


def test_sampler_key_is_seed_rid_step_only():
    """SLO scheduling must never change tokens: the sampling key is
    (seed, rid, step) and nothing else — Sampler.sample has no notion
    of priority/deadline/admission, so two requests that differ only
    in SLO class draw identical streams (the engine-level half of this
    invariant lives in tests/test_slo_scheduling.py)."""
    import inspect
    sig = inspect.signature(Sampler.sample)
    assert set(sig.parameters) == {"self", "logits", "params",
                                   "rid", "step"}
    rng = np.random.default_rng(4)
    logits = rng.normal(size=(64,))
    sp = SamplingParams(temperature=0.9, top_p=0.8, seed=5)
    s = Sampler()
    draws = [s.sample(logits, sp, rid=2, step=7) for _ in range(3)]
    assert len(set(draws)) == 1
