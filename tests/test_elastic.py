"""Fault-tolerance unit + property tests: heartbeats, stragglers, remesh."""
import pytest
from _hypothesis_compat import given, settings, st

from repro.runtime.elastic import (ElasticCoordinator, HeartbeatMonitor,
                                   StragglerDetector, plan_remesh)


def test_heartbeat_death_detection():
    hb = HeartbeatMonitor(timeout_s=10)
    hb.register("a", now=0.0)
    hb.register("b", now=0.0)
    hb.beat("a", now=8.0)
    assert hb.dead(now=12.0) == ["b"]
    assert hb.alive(now=12.0) == ["a"]


def test_straggler_detection():
    sd = StragglerDetector(ratio=1.5, min_samples=3)
    for _ in range(5):
        for h in ("a", "b", "c", "d"):
            sd.record(h, 1.0)
        sd.record("slow", 3.0)
    assert sd.stragglers() == ["slow"]


def test_straggler_needs_samples():
    sd = StragglerDetector(min_samples=3)
    sd.record("a", 1.0)
    sd.record("slow", 100.0)
    assert sd.stragglers() == []


def test_plan_remesh_drops_whole_model_groups():
    # 10 hosts × 8 devices, model=16 => 2 hosts per model group
    plan = plan_remesh([f"h{i}" for i in range(9)], 8, 16, num_pods=2)
    # 72 devices -> 4 whole groups of 16 -> (2, 2, 16)
    assert plan.mesh_shape == (2, 2, 16)
    assert plan.dropped_capacity_frac == pytest.approx(1 - 64 / 72)


def test_plan_remesh_single_pod_collapse():
    plan = plan_remesh(["h0", "h1"], 8, 16, num_pods=2)
    assert plan.mesh_shape == (1, 16)
    assert plan.axis_names == ("data", "model")


def test_plan_remesh_insufficient_raises():
    with pytest.raises(RuntimeError):
        plan_remesh(["h0"], 4, 16)


@settings(max_examples=60, deadline=None)
@given(n_hosts=st.integers(2, 200), dph=st.sampled_from([4, 8]),
       mp=st.sampled_from([4, 8, 16]))
def test_plan_remesh_properties(n_hosts, dph, mp):
    hosts = [f"h{i}" for i in range(n_hosts)]
    if n_hosts * dph < mp:
        with pytest.raises(RuntimeError):
            plan_remesh(hosts, dph, mp)
        return
    plan = plan_remesh(hosts, dph, mp)
    shape = plan.mesh_shape
    # model axis always whole
    assert shape[-1] == mp
    used = 1
    for s in shape:
        used *= s
    # never uses more than available; wastes less than one model group per pod
    total = n_hosts * dph
    assert used <= total
    assert total - used < mp * (2 if len(shape) == 3 else 1) + dph


def test_coordinator_full_cycle():
    c = ElasticCoordinator([f"h{i}" for i in range(8)], 8, 16,
                           timeout_s=5, num_pods=2)
    for h in (f"h{i}" for i in range(8)):
        c.hb.beat(h, now=0.0)
    assert c.check(step=1, now=1.0) is None
    # h7 dies
    for i in range(7):
        c.hb.beat(f"h{i}", now=10.0)
    plan = c.check(step=2, now=10.0)
    assert plan is not None
    assert "h7" not in plan.hosts_used
    assert c.events[-1].kind == "dead"
    # after eviction the cluster is healthy again
    assert c.check(step=3, now=10.5) is None
