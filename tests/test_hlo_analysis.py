"""Loop-aware HLO analyzer: unit tests on hand-built HLO + an end-to-end
check that trip counts multiply a real scanned program's dot FLOPs."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_analysis import analyze, parse_computations

HLO = """
HloModule test

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8] get-tuple-element(%p), index=1
  %d = f32[8,8] dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,8] all-reduce(%d), replica_groups=[2,4]<=[8], to_apply=%add
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,8]) tuple(%ni, %ar)
}

%cond (p2: (s32[], f32[8,8])) -> pred[] {
  %p2 = (s32[], f32[8,8]) parameter(0)
  %i2 = s32[] get-tuple-element(%p2), index=0
  %n = s32[] constant(10)
  ROOT %lt = pred[] compare(%i2, %n), direction=LT
}

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main (arg: f32[8,8]) -> (s32[], f32[8,8]) {
  %arg = f32[8,8] parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[8,8]) tuple(%zero, %arg)
  %ag = f32[16,8] all-gather(%arg), replica_groups=[4,2]<=[8], dimensions={0}
  %big = f32[16,8] dot(%ag, %arg), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %w = (s32[], f32[8,8]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"10"}}
}
"""


def test_parse_computations():
    comps = parse_computations(HLO)
    assert set(comps) == {"body", "cond", "add", "main"}
    assert any(i.op == "dot" for i in comps["body"])


def test_loop_multiplier_applied_to_flops():
    r = analyze(HLO)
    # body dot: 2*8*8*8 = 1024 flops × 10 trips; entry dot: 2*16*8*8 = 2048
    assert r["flops"] == pytest.approx(1024 * 10 + 2048)


def test_loop_multiplier_applied_to_collectives():
    r = analyze(HLO)
    # all-reduce in body: 8*8*4 bytes × 10; all-gather result 16*8*4 /
    # group 2 = 256 bytes operand
    assert r["collective_bytes"]["all-reduce"] == pytest.approx(256 * 10)
    assert r["collective_bytes"]["all-gather"] == pytest.approx(16 * 8 * 4 / 2)
    assert r["collective_counts"]["all-reduce"] == 10


def test_real_program_trip_count_scaling():
    """A jitted scan with N iterations must report ≈N× the dot flops of a
    single iteration (the exact bug in cost_analysis this module fixes)."""
    def f(x, n):
        def body(c, _):
            return c @ c, None
        y, _ = jax.lax.scan(body, x, None, length=n)
        return y

    x = jnp.eye(16)
    txt5 = jax.jit(lambda v: f(v, 5)).lower(x).compile().as_text()
    txt10 = jax.jit(lambda v: f(v, 10)).lower(x).compile().as_text()
    f5 = analyze(txt5)["flops"]
    f10 = analyze(txt10)["flops"]
    assert f5 > 0
    assert f10 == pytest.approx(2 * f5, rel=0.05)
