"""Deterministic scheduler-trace tests for the paged serving engine:
continuous admission, chunked-prefill interleaving, and exact TTFT /
throughput accounting in the engine metrics."""
import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.models import model as M
from repro.runtime.serving import PagedServingEngine


@pytest.fixture(scope="module")
def setup():
    cfg = reduced_config(get_config("qwen3-1.7b"))
    params = M.init_params(M.param_specs(cfg), jax.random.PRNGKey(0))
    return cfg, params


def events(eng, kind, rid):
    return [t for (t, k, r) in eng.trace if k == kind and r == rid]


def test_short_request_admitted_while_long_mid_generation(setup):
    """Continuous admission: a short request submitted while a long one is
    mid-generation is admitted immediately (pages are free), decodes
    alongside it, and finishes first."""
    cfg, params = setup
    eng = PagedServingEngine(cfg, params, page_size=8, num_pages=16,
                             max_seats=2, max_seq_len=48, prefill_chunk=8)
    rid_long = eng.submit(np.arange(16, dtype=np.int32), max_new_tokens=20)
    for _ in range(6):
        eng.step()
    # long is admitted, fully prefilled, and several tokens into decode
    assert events(eng, "first_token", rid_long)
    assert not events(eng, "finish", rid_long)
    rid_short = eng.submit(np.arange(4, dtype=np.int32), max_new_tokens=3)
    eng.run()

    t_admit_short = events(eng, "admit", rid_short)[0]
    assert t_admit_short > events(eng, "first_token", rid_long)[0]
    assert t_admit_short < events(eng, "finish", rid_long)[0]
    # short overtakes: fewer tokens to generate, same decode cadence
    assert events(eng, "finish", rid_short)[0] \
        < events(eng, "finish", rid_long)[0]
    # both decoded in the same ticks at least once (continuous batching)
    long_decode_ticks = set(events(eng, "decode", rid_long))
    short_decode_ticks = set(events(eng, "decode", rid_short))
    assert long_decode_ticks & short_decode_ticks


def test_chunked_prefill_interleaves_with_decode(setup):
    """A long prompt prefills in chunks; an already-running short request
    keeps producing a token in the SAME ticks (no prefill stall)."""
    cfg, params = setup
    eng = PagedServingEngine(cfg, params, page_size=8, num_pages=16,
                             max_seats=2, max_seq_len=48, prefill_chunk=8)
    rid_short = eng.submit(np.arange(4, dtype=np.int32), max_new_tokens=10)
    eng.step()                       # short: admit + full prefill + decode
    rid_long = eng.submit(np.arange(24, dtype=np.int32), max_new_tokens=4)
    eng.run()

    long_chunks = events(eng, "prefill_chunk", rid_long)
    assert len(long_chunks) == 3     # 24-token prompt / 8-token chunks
    short_decodes = set(events(eng, "decode", rid_short))
    # every one of the long request's prefill ticks also decoded the short
    assert set(long_chunks) <= short_decodes


def test_metrics_accounting_exact(setup):
    """Counter identities the dashboards rely on, on a deterministic run."""
    cfg, params = setup
    eng = PagedServingEngine(cfg, params, page_size=8, num_pages=24,
                             max_seats=3, max_seq_len=40, prefill_chunk=8)
    rng = np.random.default_rng(11)
    plens, gens = [5, 17, 9, 12], [4, 6, 2, 5]
    for plen, gen in zip(plens, gens):
        eng.submit(rng.integers(0, cfg.vocab_size, plen).astype(np.int32),
                   max_new_tokens=gen)
    done = eng.run()
    m = eng.metrics.snapshot()

    assert m["submitted"] == m["admitted"] == m["completed"] == 4
    assert m["queued"] == m["active"] == 0
    assert m["prefill_tokens"] == sum(plens)
    total_generated = sum(len(r.generated) for r in done)
    assert total_generated == sum(gens)
    assert m["generated_tokens"] == total_generated
    assert m["decode_tokens"] == total_generated - 4   # one TTFT token each
    assert len(eng.metrics.ttft_s) == 4
    assert all(t > 0 for t in eng.metrics.ttft_s)
    assert m["ttft_max_s"] >= m["ttft_avg_s"] > 0
    assert m["tokens_per_s"] > 0
    assert 0 < m["peak_page_utilization"] <= 1.0
    assert m["pages_in_use"] == 0 and m["page_utilization"] == 0.0
    # every request observed TTFT before completion
    for r in done:
        assert r.t_submit < r.t_first_token < r.t_done
