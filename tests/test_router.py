"""Multi-model fleet router: HostBudget carving, replica selection,
session affinity, fleet-global rid namespacing (cross-engine sampler
isolation + routing-invariant token streams), metrics aggregation, and
the --models CLI spec.

The load-bearing claims pinned here:
  - two engines with the same seed and overlapping raw rids produce
    IDENTICAL stochastic streams for identical logits (the collision the
    fleet exists to prevent) — and fleet-global rids make them
    independent yet replay-stable;
  - a routed request's tokens are bit-identical to the same request on
    a dedicated solo engine given the same rid, for ANY routing
    schedule (fuzzed with random replica selection);
  - the shared HostBudget lets a busy model borrow an idle model's
    pages beyond its own floor, while a static zero-surplus split caps
    it at the floor.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced_config, resolve_arch
from repro.models import model as M
from repro.runtime.paged_kv import BlockManager, EngineMetrics
from repro.runtime.router import (FleetModel, HostBudget, LeastLoaded,
                                  ModelFleet, RoundRobin, _make_selection,
                                  parse_models_spec)
from repro.runtime.sampler import SamplingParams
from repro.runtime.serving import PagedServingEngine, SchedulerStallError


@pytest.fixture(scope="module")
def qwen():
    cfg = reduced_config(get_config("qwen3-1.7b"))
    params = M.init_params(M.param_specs(cfg), jax.random.PRNGKey(0))
    return cfg, params


@pytest.fixture(scope="module")
def llama():
    cfg = reduced_config(get_config("llama3-8b"))
    params = M.init_params(M.param_specs(cfg), jax.random.PRNGKey(1))
    return cfg, params


KW = dict(page_size=4, max_seats=2, max_seq_len=16, prefill_chunk=4)
N_TABLES = 4            # ceil(max_seq_len / page_size)


def prompt_for(cfg, i, n=6):
    return ((np.arange(n, dtype=np.int32) * (2 * i + 3) + i)
            % cfg.vocab_size).astype(np.int32)


# ---------------------------------------------------------------------------
# HostBudget + BlockManager gate (no models involved)
# ---------------------------------------------------------------------------

class TestHostBudget:
    def make(self, total=10, floors=(3, 3)):
        budget = HostBudget(total)
        bms = []
        for i, floor in enumerate(floors):
            bm = BlockManager(total + 1, 4, prefix_cache=False)
            budget.register(f"m{i}", bm, floor)
            bms.append(bm)
        return budget, bms

    def test_surplus_and_floor_accounting(self):
        budget, (a, b) = self.make(10, (3, 3))
        assert budget.surplus == 4
        # A takes its floor, then borrows the whole surplus
        floor_pages = a.alloc(3, rid=0)
        assert floor_pages is not None and budget.borrowed("m0") == 0
        assert a.can_alloc(4) and not a.can_alloc(5)
        borrowed_pages = a.alloc(4, rid=0)
        assert borrowed_pages is not None and budget.borrowed("m0") == 4
        # B is squeezed down to its guaranteed floor, no further
        assert b.can_alloc(3) and not b.can_alloc(4)
        assert b.alloc(3, rid=1) is not None
        assert not b.can_alloc(1) and b.alloc(1, rid=1) is None
        # A hands surplus back -> B may borrow again
        a.free(borrowed_pages[:2])
        assert b.can_alloc(2) and not b.can_alloc(3)

    def test_usage_snapshot(self):
        budget, (a, b) = self.make(10, (3, 3))
        a.alloc(5, rid=0)
        u = budget.usage()
        assert u["total_pages"] == 10 and u["surplus_pages"] == 4
        m0 = u["engines"]["m0"]
        assert (m0["floor"], m0["in_use"], m0["borrowed"]) == (3, 5, 2)
        # byte-denominated fields ride along (page_bytes defaults to 1)
        assert (m0["page_bytes"], m0["bytes_in_use"],
                m0["borrowed_bytes"]) == (1, 5, 2)
        assert u["engines"]["m1"]["in_use"] == 0

    def test_register_validation(self):
        budget = HostBudget(6)
        bm = BlockManager(8, 4)
        budget.register("a", bm, 3)
        with pytest.raises(ValueError, match="already registered"):
            budget.register("a", BlockManager(8, 4), 1)
        with pytest.raises(ValueError, match="floor must be"):
            budget.register("b", BlockManager(8, 4), 0)
        with pytest.raises(ValueError, match="exceed the host budget"):
            budget.register("c", BlockManager(8, 4), 4)
        with pytest.raises(ValueError, match="total_pages"):
            HostBudget(0)

    def test_attach_requires_pristine_manager(self):
        budget = HostBudget(6)
        bm = BlockManager(8, 4)
        bm.alloc(1, rid=0)
        with pytest.raises(ValueError, match="pristine"):
            budget.register("a", bm, 2)
        clean = BlockManager(8, 4)
        budget.register("a", clean, 2)
        with pytest.raises(ValueError, match="already answers"):
            HostBudget(6).register("b", clean, 2)

    def test_cross_engine_version_invalidation(self):
        """Freeing pages in one engine must bump its siblings' versions:
        the paged admission path caches a failed attempt against
        bm.version, and the pages that un-starve it can free ANYWHERE
        in the fleet."""
        budget, (a, b) = self.make(8, (2, 2))
        pages = a.alloc(6, rid=0)           # floor 2 + all 4 surplus
        assert not b.can_alloc(3)
        v = b.version
        a.free(pages[:2])
        assert b.version > v                # sibling invalidated
        assert b.can_alloc(3)

    def test_reclaimable_pages_do_not_count_against_budget(self):
        budget = HostBudget(8)
        a = BlockManager(9, 4, prefix_cache=True)
        b = BlockManager(9, 4, prefix_cache=True)
        budget.register("a", a, 2)
        budget.register("b", b, 2)
        pages = a.alloc(6, rid=0)
        a.register_prefix(list(range(4)), pages[0])
        a.free(pages)                       # page parks reclaimable
        assert a.cached == 1 and a.in_use == 0
        # B may use the full surplus: A's cached page is evictable, not
        # a live commitment
        assert b.can_alloc(6)


def test_engine_metrics_merged():
    a = EngineMetrics(page_capacity=4)
    b = EngineMetrics(page_capacity=6)
    a.note_first_token("premium", 0.1, deadlined=True, missed=True)
    b.note_first_token("batch", 0.3)
    a.note_completion("premium")
    b.note_completion("batch")
    b.note_preemption("batch")
    a.decode_tokens, b.decode_tokens = 5, 7
    a.tick(queued=1, active=2, pages_in_use=3)
    b.tick(queued=0, active=1, pages_in_use=4)
    m = EngineMetrics.merged([a, b])
    s = m.snapshot()
    assert s["page_capacity"] == 10
    assert s["completed"] == 2 and s["decode_tokens"] == 12
    assert s["preemptions"] == 1
    assert sorted(s["classes"]) == ["batch", "premium"]
    assert s["classes"]["premium"]["deadline_misses"] == 1
    assert m.ttft_s == [0.1, 0.3]
    # parts are untouched
    assert a.completed == 1 and b.completed == 1


# ---------------------------------------------------------------------------
# CLI spec + selection plumbing (no models involved)
# ---------------------------------------------------------------------------

def test_parse_models_spec():
    assert parse_models_spec("llama3-8b:2,qwen3-1.7b") == \
        [("llama3-8b", 2, None), ("qwen3-1.7b", 1, None)]
    assert parse_models_spec(" a:1 , b:3 ") == \
        [("a", 1, None), ("b", 3, None)]
    assert parse_models_spec("a:2:fp8,b:1:f32,c") == \
        [("a", 2, "fp8"), ("b", 1, "f32"), ("c", 1, None)]
    assert parse_models_spec("a::int8") == [("a", 1, "int8")]
    for bad, msg in (("", "empty"), ("a,,b", "empty entry"),
                     (":2", "missing model name"), ("a:x", "bad replica"),
                     ("a:0", ">= 1"), ("a,a", "twice"),
                     ("a:2:fp7", "unknown kv dtype"),
                     ("a:2:fp8:x", "too many")):
        with pytest.raises(ValueError, match=msg):
            parse_models_spec(bad)


def test_resolve_arch_aliases():
    assert resolve_arch("llama3-8b") == "llama3-8b"
    assert resolve_arch("llama3_8b") == "llama3-8b"
    assert resolve_arch("qwen3_1_7b") == "qwen3-1.7b"
    with pytest.raises(KeyError, match="unknown model"):
        resolve_arch("gpt5")


def test_make_selection():
    assert isinstance(_make_selection("least-loaded"), LeastLoaded)
    assert isinstance(_make_selection("round-robin"), RoundRobin)
    with pytest.raises(ValueError, match="unknown replica selection"):
        _make_selection("random")
    with pytest.raises(TypeError, match="no select"):
        _make_selection(42)
    sentinel = RoundRobin()
    assert _make_selection(sentinel) is sentinel


def test_fleet_constructor_validation(qwen):
    cfg, params = qwen
    fm = FleetModel("m", cfg, params)
    with pytest.raises(ValueError, match="at least one model"):
        ModelFleet([], total_pages=16, **KW)
    with pytest.raises(ValueError, match="duplicate model names"):
        ModelFleet([fm, FleetModel("m", cfg, params)], total_pages=32, **KW)
    with pytest.raises(ValueError, match="replicas must be"):
        ModelFleet([FleetModel("m", cfg, params, replicas=0)],
                   total_pages=16, **KW)
    with pytest.raises(ValueError, match="cannot hold"):
        ModelFleet([FleetModel("m", cfg, params, floor=N_TABLES - 1)],
                   total_pages=16, **KW)
    with pytest.raises(ValueError, match="floors need"):
        ModelFleet([FleetModel("m", cfg, params, replicas=2)],
                   total_pages=2 * N_TABLES - 1, **KW)


# ---------------------------------------------------------------------------
# Routing: selection policies + session affinity
# ---------------------------------------------------------------------------

def test_round_robin_rotation(qwen):
    cfg, params = qwen
    fleet = ModelFleet([FleetModel("q", cfg, params, replicas=2)],
                       total_pages=4 * N_TABLES, selection="round-robin",
                       **KW)
    rids = [fleet.submit(model="q", prompt=prompt_for(cfg, i),
                         max_new_tokens=2) for i in range(4)]
    assert [fleet.route(r) for r in rids] == \
        [("q", 0), ("q", 1), ("q", 0), ("q", 1)]
    fleet.run()


def test_least_loaded_spreads_and_unknown_model_raises(qwen):
    cfg, params = qwen
    fleet = ModelFleet([FleetModel("q", cfg, params, replicas=2)],
                       total_pages=4 * N_TABLES, **KW)
    # without stepping, queued work counts as load -> submissions spread
    r0 = fleet.submit(model="q", prompt=prompt_for(cfg, 0),
                      max_new_tokens=2)
    r1 = fleet.submit(model="q", prompt=prompt_for(cfg, 1),
                      max_new_tokens=2)
    assert {fleet.route(r0)[1], fleet.route(r1)[1]} == {0, 1}
    with pytest.raises(ValueError, match="unknown model 'x'"):
        fleet.submit(model="x", prompt=prompt_for(cfg, 0))
    with pytest.raises(ValueError, match="unknown model"):
        fleet.home_replica("x", "s")
    fleet.run()


def test_session_affinity_and_home_replica_prefix_hits(qwen):
    """Turn 2 of a session must land on the replica that served turn 1
    and hit that replica's prefix cache (the multi-turn prefix is only
    warm there)."""
    cfg, params = qwen
    fleet = ModelFleet([FleetModel("q", cfg, params, replicas=2)],
                       total_pages=6 * N_TABLES, **KW)
    # two sessions -> least-loaded spreads them across both replicas
    t1 = {}
    for s in range(2):
        prompt = prompt_for(cfg, s, n=6)    # > page_size: full page cached
        t1[s] = (fleet.submit(model="q", prompt=prompt, max_new_tokens=3,
                              session_id=f"s{s}"), prompt)
    done = fleet.run()
    homes = {s: fleet.home_replica("q", f"s{s}") for s in range(2)}
    assert set(homes.values()) == {0, 1}
    for s in range(2):
        rid1, prompt = t1[s]
        follow = np.concatenate(
            [prompt, np.asarray(done[rid1].generated, np.int32),
             prompt_for(cfg, 9 + s, n=2)])
        rid2 = fleet.submit(model="q", prompt=follow, max_new_tokens=2,
                            session_id=f"s{s}")
        assert fleet.route(rid2) == ("q", homes[s])   # affinity held
    done = fleet.run()
    for s in range(2):
        home = fleet.group("q").engines[homes[s]]
        hits = [r for (_, k, r) in home.trace if k == "prefix_hit"]
        assert hits, f"session s{s}: no prefix hit on its home replica"
        assert home.metrics.cached_prompt_tokens > 0
    m = fleet.metrics_snapshot()
    assert m["models"]["q"]["prefix_hit_rate"] > 0


# ---------------------------------------------------------------------------
# rid namespacing: sampler isolation, replay stability, routing invariance
# ---------------------------------------------------------------------------

STOCH = SamplingParams(temperature=0.9, seed=11)


def _solo_outputs(cfg, params, submits):
    """Run [(rid, prompt, gen)] on one dedicated engine with explicit
    rids; returns rid -> generated."""
    eng = PagedServingEngine(cfg, params, num_pages=65, **KW)
    for rid, p, g in submits:
        eng.submit(p, max_new_tokens=g, sampling=STOCH, rid=rid)
    eng.run()
    return {r.rid: r.generated for r in eng.finished}


def test_raw_rid_collision_vs_fleet_namespacing(qwen):
    """Two same-seed engines with overlapping raw rids emit the SAME
    stochastic stream for the same prompt — the collision.  Routed
    through a fleet, the same two submissions get distinct fleet-global
    rids: independent streams, yet each replays bit-identically on a
    solo engine given its fleet rid."""
    cfg, params = qwen
    p = prompt_for(cfg, 0)
    # the collision: dedicated engines both auto-assign rid 0
    a = PagedServingEngine(cfg, params, num_pages=17, **KW)
    b = PagedServingEngine(cfg, params, num_pages=17, **KW)
    a.submit(p, max_new_tokens=5, sampling=STOCH)
    b.submit(p, max_new_tokens=5, sampling=STOCH)
    a.run(), b.run()
    assert a.finished[0].rid == b.finished[0].rid == 0
    assert a.finished[0].generated == b.finished[0].generated

    def fleet_outputs():
        fleet = ModelFleet([FleetModel("q", cfg, params, replicas=2)],
                           total_pages=4 * N_TABLES,
                           selection="round-robin", **KW)
        r0 = fleet.submit(model="q", prompt=p, max_new_tokens=5,
                          sampling=STOCH)
        r1 = fleet.submit(model="q", prompt=p, max_new_tokens=5,
                          sampling=STOCH)
        done = fleet.run()
        assert {fleet.route(r0)[1], fleet.route(r1)[1]} == {0, 1}
        return r0, r1, done

    r0, r1, done = fleet_outputs()
    assert (r0, r1) == (0, 1)               # fleet-global, never colliding
    assert done[r0].generated != done[r1].generated   # independent streams
    # replay-stable: a fresh fleet reproduces both streams exactly
    _, _, again = fleet_outputs()
    assert {r: q.generated for r, q in again.items()} == \
        {r: q.generated for r, q in done.items()}
    # and each stream is bit-identical on a dedicated solo engine
    solo = _solo_outputs(cfg, params, [(0, p, 5), (1, p, 5)])
    assert solo == {r: q.generated for r, q in done.items()}


class SeededSelection:
    """Deterministic 'random' replica selection for the fuzz test."""

    def __init__(self, seed):
        self.rng = np.random.default_rng(seed)

    def select(self, group):
        return int(self.rng.integers(0, len(group.engines)))


def test_fuzz_random_routing_token_identical_to_solo(qwen, llama):
    """Any routing schedule yields the same per-rid stochastic streams
    as dedicated solo engines fed the same (rid, prompt) pairs: routing
    decides where a request runs, never which tokens it produces."""
    cfg_q, params_q = qwen
    cfg_l, params_l = llama
    rng = np.random.default_rng(3)
    stream = []                             # (model, prompt, gen)
    for i in range(8):
        model = "q" if rng.random() < 0.7 else "l"
        cfg = cfg_q if model == "q" else cfg_l
        stream.append((model,
                       prompt_for(cfg, i, n=int(rng.integers(3, 9))),
                       int(rng.integers(2, 6))))

    per_schedule = []
    for schedule_seed in (0, 1):
        fleet = ModelFleet(
            [FleetModel("q", cfg_q, params_q, replicas=2),
             FleetModel("l", cfg_l, params_l)],
            total_pages=6 * N_TABLES,
            selection=SeededSelection(schedule_seed), **KW)
        rids = [fleet.submit(model=m, prompt=p, max_new_tokens=g,
                             sampling=STOCH) for m, p, g in stream]
        done = fleet.run()
        per_schedule.append({r: done[r].generated for r in rids})
    # different schedules, identical streams
    assert per_schedule[0] == per_schedule[1]
    # and identical to dedicated solo engines with the same rids
    solo = {}
    for model, cfg, params in (("q", cfg_q, params_q),
                               ("l", cfg_l, params_l)):
        submits = [(rid, p, g) for rid, (m, p, g)
                   in zip(range(len(stream)), stream) if m == model]
        solo.update(_solo_outputs(cfg, params, submits))
    assert per_schedule[0] == solo


def test_explicit_rid_must_stay_monotonic(qwen):
    cfg, params = qwen
    eng = PagedServingEngine(cfg, params, num_pages=17, **KW)
    assert eng.submit(prompt_for(cfg, 0), max_new_tokens=2, rid=5) == 5
    with pytest.raises(ValueError, match="not monotonic"):
        eng.submit(prompt_for(cfg, 1), max_new_tokens=2, rid=3)
    assert eng.submit(prompt_for(cfg, 1), max_new_tokens=2) == 6
    eng.run()


# ---------------------------------------------------------------------------
# Shared budget at fleet level + observability
# ---------------------------------------------------------------------------

def test_surplus_borrowing_vs_static_split(qwen, llama):
    """With minimal floors the busy model's engine climbs past its
    floor into the surplus; a zero-surplus static split pins it at the
    floor — same total budget, same tokens either way."""
    cfg_q, params_q = qwen
    cfg_l, params_l = llama
    total = 4 * N_TABLES                    # 16 pages
    reqs = [(prompt_for(cfg_q, i), 8) for i in range(5)]

    def run(floors):
        fleet = ModelFleet(
            [FleetModel("q", cfg_q, params_q, floor=floors[0]),
             FleetModel("l", cfg_l, params_l, floor=floors[1])],
            total_pages=total, **KW)
        for p, g in reqs:                   # all load on one model
            fleet.submit(model="q", prompt=p, max_new_tokens=g)
        done = fleet.run()
        eng = fleet.group("q").engines[0]
        return eng.metrics.peak_pages_in_use, \
            {r: q.generated for r, q in done.items()}

    shared_peak, shared_out = run((N_TABLES, N_TABLES))
    static_peak, static_out = run((total // 2, total // 2))
    assert shared_peak > N_TABLES           # borrowed surplus
    assert static_peak <= total // 2        # capped at the static floor
    assert shared_out == static_out         # budget never changes tokens


def test_fleet_metrics_snapshot_and_budget_block(qwen, llama):
    cfg_q, params_q = qwen
    cfg_l, params_l = llama
    fleet = ModelFleet([FleetModel("q", cfg_q, params_q, replicas=2),
                        FleetModel("l", cfg_l, params_l)],
                       total_pages=6 * N_TABLES, **KW)
    for i in range(4):
        fleet.submit(model=("q" if i % 2 else "l"),
                     prompt=prompt_for(cfg_q if i % 2 else cfg_l, i),
                     max_new_tokens=3)
    fleet.run()
    m = fleet.metrics_snapshot()
    assert set(m["models"]) == {"q", "l"}
    assert m["fleet"]["completed"] == 4
    assert m["models"]["q"]["completed"] + m["models"]["l"]["completed"] == 4
    assert len(m["models"]["q"]["replicas"]) == 2
    assert m["budget"]["total_pages"] == 6 * N_TABLES
    assert set(m["budget"]["engines"]) == \
        {"('q', 0)", "('q', 1)", "('l', 0)"}
    assert m["fleet"]["tokens_per_s"] > 0


def test_fleet_stall_names_model_and_replica(qwen):
    cfg, params = qwen
    fleet = ModelFleet([FleetModel("q", cfg, params)],
                       total_pages=2 * N_TABLES, **KW)
    fleet.submit(model="q", prompt=prompt_for(cfg, 0), max_new_tokens=4)
    with pytest.raises(SchedulerStallError, match=r"q/0:0\(standard\)"):
        fleet.run(max_ticks=1)
    fleet.run()                             # and it can still finish
    assert fleet.finished()[0].done
