"""Quantized paged KV (fp8/int8 pages with per-(token, head) scales):
kernel-vs-oracle tolerance, cache layout and byte accounting, exactness
of CoW / preemption replay within a precision, per-class precision
floors, and byte-denominated fleet budgeting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.core import mixed_precision as mp
from repro.kernels import ops
from repro.kernels.decode_attention import (
    paged_decode_attention_pallas, quantized_paged_decode_attention_pallas)
from repro.kernels.ref import (decode_attention_ref,
                               paged_decode_attention_ref,
                               quantized_paged_decode_attention_ref)
from repro.models import model as M
from repro.runtime.paged_kv import BlockManager
from repro.runtime.router import FleetModel, HostBudget, ModelFleet
from repro.runtime.serving import PagedServingEngine


# -- quantization helpers -----------------------------------------------------

@pytest.mark.parametrize("kv_dtype", mp.KV_QUANTIZED)
def test_quantize_kv_page_shapes_and_dtypes(kv_dtype):
    x = jnp.asarray(np.random.default_rng(0).normal(size=(5, 8, 3, 16)),
                    jnp.float32)
    q, s = mp.quantize_kv_page(x, kv_dtype)
    assert q.shape == x.shape and s.shape == x.shape[:-1]
    assert q.dtype == mp.kv_storage_dtype(kv_dtype)
    assert s.dtype == jnp.float32
    back = mp.dequantize_kv_page(q, s)
    assert back.shape == x.shape and back.dtype == jnp.float32


def test_quantize_kv_page_rejects_unquantized_dtypes():
    x = jnp.ones((2, 4))
    for dt in ("f32", "bf16"):
        with pytest.raises(ValueError, match="quantized"):
            mp.quantize_kv_page(x, dt)
    with pytest.raises(ValueError, match="unknown kv_dtype"):
        mp.quantize_kv_page(x, "fp4")


def test_quantize_kv_page_write_order_independence():
    """A vector's quantized bytes depend only on its own values — the
    invariant CoW and preemption replay lean on."""
    rng = np.random.default_rng(3)
    page = jnp.asarray(rng.normal(size=(8, 16)), jnp.float32)
    for dt in mp.KV_QUANTIZED:
        q_full, s_full = mp.quantize_kv_page(page, dt)
        q_row, s_row = mp.quantize_kv_page(page[3], dt)
        np.testing.assert_array_equal(
            np.asarray(q_full[3]).view(np.uint8),
            np.asarray(q_row).view(np.uint8))
        np.testing.assert_array_equal(np.asarray(s_full[3]),
                                      np.asarray(s_row))


def test_kv_token_bytes_and_precision_bits():
    assert mp.kv_token_bytes("f32", 64) == 256
    assert mp.kv_token_bytes("bf16", 64) == 128
    assert mp.kv_token_bytes("fp8", 64) == 64 + 4      # values + f32 scale
    assert mp.kv_token_bytes("int8", 64) == 64 + 4
    bits = [mp.kv_precision_bits(d) for d in ("f32", "bf16", "fp8", "int8")]
    assert bits == [32, 16, 8, 8]
    with pytest.raises(ValueError):
        mp.kv_precision_bits("fp4")


# -- quantized kernel vs references -------------------------------------------

def _paged_problem(seed, BH=6, d=32, P=16, page=8, n=4):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(BH, d)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(P, page, d)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(P, page, d)), jnp.float32)
    pt = np.zeros((BH, n), np.int32)
    lengths = rng.integers(1, n * page, size=(BH,)).astype(np.int32)
    avail = list(range(1, P))
    for b in range(BH):
        for i in range(-(-int(lengths[b]) // page)):
            pt[b, i] = avail.pop()
    return q, kp, vp, jnp.asarray(pt), jnp.asarray(lengths)


@pytest.mark.parametrize("kv_dtype", mp.KV_QUANTIZED)
def test_quantized_kernel_matches_oracle(kv_dtype):
    """The Pallas kernel dequantizing in VMEM must match the jnp oracle
    that dequantizes the whole pool first — same math, tight tolerance."""
    q, kp, vp, pt, lengths = _paged_problem(0)
    kq, ks = mp.quantize_kv_page(kp, kv_dtype)
    vq, vs = mp.quantize_kv_page(vp, kv_dtype)
    out = quantized_paged_decode_attention_pallas(q, kq, vq, ks, vs, pt,
                                                  lengths, interpret=True)
    want = quantized_paged_decode_attention_ref(q, kq, vq, ks, vs, pt,
                                                lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=5e-6)


@pytest.mark.parametrize("kv_dtype", mp.KV_QUANTIZED)
def test_quantized_kernel_close_to_full_precision(kv_dtype):
    """Quantized attention output stays within the storage format's
    error envelope of the full-precision kernel (~2^-4 relative for
    e4m3's 3 mantissa bits; int8 is finer)."""
    q, kp, vp, pt, lengths = _paged_problem(1)
    kq, ks = mp.quantize_kv_page(kp, kv_dtype)
    vq, vs = mp.quantize_kv_page(vp, kv_dtype)
    out = quantized_paged_decode_attention_pallas(q, kq, vq, ks, vs, pt,
                                                  lengths, interpret=True)
    full = paged_decode_attention_pallas(q, kp, vp, pt, lengths,
                                         interpret=True)
    # outputs are convex combinations of unit-scale v rows: abs error
    # bounded by the per-element quantization error plus softmax shift
    tol = 0.25 if kv_dtype == "fp8" else 0.08
    assert float(jnp.max(jnp.abs(out - full))) < tol
    # and the quantized ref equals dense decode on the dequantized pool
    kd = mp.dequantize_kv_page(kq, ks)
    vd = mp.dequantize_kv_page(vq, vs)
    dense = paged_decode_attention_ref(q, kd, vd, pt, lengths)
    want = quantized_paged_decode_attention_ref(q, kq, vq, ks, vs, pt,
                                                lengths)
    np.testing.assert_allclose(np.asarray(want), np.asarray(dense),
                               atol=1e-6)


def test_quantized_ops_wrapper_gqa_expansion():
    rng = np.random.default_rng(2)
    B, H, KVH, d, P, page, n = 3, 4, 2, 16, 12, 8, 3
    q = jnp.asarray(rng.normal(size=(B, 1, H, d)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(P, page, KVH, d)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(P, page, KVH, d)), jnp.float32)
    pt = np.zeros((B, n), np.int32)
    lengths = rng.integers(1, n * page, size=(B,)).astype(np.int32)
    avail = list(range(1, P))
    for b in range(B):
        for i in range(-(-int(lengths[b]) // page)):
            pt[b, i] = avail.pop()
    kq, ks = mp.quantize_kv_page(kp, "fp8")
    vq, vs = mp.quantize_kv_page(vp, "fp8")
    out = ops.paged_decode_attention(q, kq, vq, jnp.asarray(pt),
                                     jnp.asarray(lengths),
                                     k_scale=ks, v_scale=vs)
    kd, vd = mp.dequantize_kv_page(kq, ks), mp.dequantize_kv_page(vq, vs)
    rep = H // KVH
    for h in range(H):
        kk = np.asarray(kd)[:, :, h // rep][pt].reshape(B, -1, d)
        vv = np.asarray(vd)[:, :, h // rep][pt].reshape(B, -1, d)
        ref = decode_attention_ref(q[:, 0, h], jnp.asarray(kk),
                                   jnp.asarray(vv), jnp.asarray(lengths))
        np.testing.assert_allclose(np.asarray(out[:, 0, h]),
                                   np.asarray(ref), atol=2e-5)


def test_ops_wrapper_requires_scale_pair():
    q, kp, vp, pt, lengths = _paged_problem(4)
    kq, ks = mp.quantize_kv_page(kp, "fp8")
    with pytest.raises(ValueError, match="together"):
        ops.paged_decode_attention(q[:, None, :, None].transpose(0, 1, 3, 2),
                                   kq[:, :, None], vp[:, :, None],
                                   pt, lengths, k_scale=ks[:, :, None])


# -- cache layout and byte accounting -----------------------------------------

@pytest.fixture(scope="module")
def setup():
    cfg = reduced_config(get_config("qwen3-1.7b"))
    params = M.init_params(M.param_specs(cfg), jax.random.PRNGKey(0))
    return cfg, params


def test_init_paged_cache_layouts(setup):
    cfg, _ = setup
    plain = M.init_paged_cache(cfg, 8, 4)
    ent = plain["pos0"]
    assert set(ent) == {"k", "v"}               # pre-quantization layout
    assert ent["k"].dtype == jnp.dtype(cfg.compute_dtype)
    for dt in mp.KV_QUANTIZED:
        c = M.init_paged_cache(cfg, 8, 4, kv_dtype=dt)
        e = c["pos0"]
        assert set(e) == {"k", "v", "ks", "vs"}
        assert e["k"].dtype == mp.kv_storage_dtype(dt)
        assert e["ks"].dtype == jnp.float32
        assert e["ks"].shape == e["k"].shape[:-1]
    # f32/bf16 as explicit kv_dtype: plain layout at that precision
    c = M.init_paged_cache(cfg, 8, 4, kv_dtype="f32")
    assert set(c["pos0"]) == {"k", "v"}
    assert c["pos0"]["k"].dtype == jnp.float32


def test_paged_page_bytes_arithmetic(setup):
    cfg, _ = setup
    kvh, hd, L = cfg.padded_kv_heads, cfg.resolved_head_dim, cfg.num_layers
    page = 4
    assert (M.paged_page_bytes(cfg, page) ==
            L * page * kvh * hd * jnp.dtype(cfg.compute_dtype).itemsize * 2)
    assert (M.paged_page_bytes(cfg, page, "fp8") ==
            L * page * kvh * (hd + 4) * 2)
    assert (M.paged_page_bytes(cfg, page, "f32") ==
            L * page * kvh * hd * 4 * 2)
    # the effective-capacity win: fp8 pages cost under half of f32 ones
    assert (M.paged_page_bytes(cfg, page, "fp8") * 2 <
            M.paged_page_bytes(cfg, page, "f32"))


def test_engine_metrics_expose_kv_bytes(setup):
    cfg, params = setup
    eng = PagedServingEngine(cfg, params, page_size=4, num_pages=12,
                             max_seats=2, max_seq_len=20, prefill_chunk=8,
                             kv_dtype="fp8")
    assert eng.kv_dtype == "fp8"
    eng.submit(np.arange(6, dtype=np.int32) % cfg.vocab_size,
               max_new_tokens=3)
    eng.run()
    snap = eng.metrics.snapshot()
    assert snap["kv_dtype"] == "fp8"
    assert snap["page_bytes"] == M.paged_page_bytes(cfg, 4, "fp8")
    assert snap["kv_bytes_in_use"] == 0         # drained pool
    assert eng.policy.bm.page_bytes == snap["page_bytes"]


# -- exactness within a precision ---------------------------------------------

@pytest.mark.parametrize("kv_dtype", mp.KV_QUANTIZED)
def test_quantized_prefix_cache_token_identical_on_vs_off(setup, kv_dtype):
    """CoW over quantized pages: heavy prefix overlap generates the same
    tokens with sharing on and off — per-(token, head) scales make the
    stored bytes write-order independent."""
    cfg, params = setup
    rng = np.random.default_rng(11)
    base = rng.integers(0, cfg.vocab_size, 11).astype(np.int32)
    reqs = [(base, 5), (base.copy(), 5),
            (np.concatenate([base[:6],
                             rng.integers(0, cfg.vocab_size,
                                          3).astype(np.int32)]), 4)]
    kw = dict(page_size=4, num_pages=24, max_seats=3, max_seq_len=24,
              prefill_chunk=4, kv_dtype=kv_dtype)

    def run(prefix_cache):
        eng = PagedServingEngine(cfg, params, prefix_cache=prefix_cache,
                                 **kw)
        for p, g in reqs:
            eng.submit(p, max_new_tokens=g)
            for _ in range(3):
                eng.step()
        return eng, {r.rid: r.generated for r in eng.run()}

    eng_on, on = run(True)
    _, off = run(False)
    assert on == off
    assert eng_on.metrics.snapshot()["cached_prompt_tokens"] > 0


@pytest.mark.parametrize("kv_dtype", mp.KV_QUANTIZED)
def test_quantized_preemption_replay_token_identical(setup, kv_dtype):
    """Preempt-and-recompute on a quantized pool replays to the same
    token stream as an uncontended run at the same precision."""
    cfg, params = setup
    reqs = [((np.arange(8, dtype=np.int32) * 3) % cfg.vocab_size, 10),
            ((np.arange(8, dtype=np.int32) * 7) % cfg.vocab_size, 10)]
    kw = dict(page_size=4, max_seats=2, max_seq_len=24, prefill_chunk=8,
              kv_dtype=kv_dtype)

    def run(num_pages):
        eng = PagedServingEngine(cfg, params, num_pages=num_pages, **kw)
        for p, g in reqs:
            eng.submit(p, max_new_tokens=g)
        return eng, {r.rid: r.generated for r in eng.run()}

    _, ref = run(32)
    tight, out = run(7)
    assert tight.metrics.preemptions >= 1
    assert out == ref


def test_full_precision_pool_unchanged_by_quantization_plumbing(setup):
    """kv_dtype=None threads through the same code paths but keeps the
    plain two-leaf cache and page-count budget arithmetic."""
    cfg, params = setup
    eng = PagedServingEngine(cfg, params, page_size=4, num_pages=16,
                             max_seats=2, max_seq_len=20, prefill_chunk=8)
    leaves = eng.cache["pos0"]
    assert set(leaves) == {"k", "v"}
    assert leaves["k"].dtype == jnp.dtype(cfg.compute_dtype)
    assert eng.kv_dtype in ("f32", "bf16")
    assert eng.metrics.page_bytes == M.paged_page_bytes(cfg, 4)


# -- per-class precision floors -----------------------------------------------

def test_class_precision_floor_rejects_at_submit(setup):
    cfg, params = setup
    eng = PagedServingEngine(cfg, params, page_size=4, num_pages=12,
                             max_seats=2, max_seq_len=20, prefill_chunk=8,
                             kv_dtype="fp8",
                             class_precision={"premium": "bf16"})
    with pytest.raises(ValueError, match="premium"):
        eng.submit(np.arange(4, dtype=np.int32), max_new_tokens=2,
                   priority="premium")
    eng.submit(np.arange(4, dtype=np.int32) % cfg.vocab_size,
               max_new_tokens=2, priority="standard")
    eng.run()


def test_class_precision_validation(setup):
    cfg, params = setup
    kw = dict(page_size=4, num_pages=12, max_seats=2, max_seq_len=20,
              prefill_chunk=8)
    with pytest.raises(ValueError):
        PagedServingEngine(cfg, params,
                           class_precision={"vip": "f32"}, **kw)
    with pytest.raises(ValueError):
        PagedServingEngine(cfg, params,
                           class_precision={"premium": "fp4"}, **kw)
    with pytest.raises(ValueError):
        PagedServingEngine(cfg, params, kv_dtype="fp7", **kw)


# -- byte-denominated host budget ---------------------------------------------

def test_host_budget_weighs_engines_by_page_bytes():
    budget = HostBudget(8, page_bytes=4)        # 32 bytes total
    assert budget.total_bytes == 32
    exp = BlockManager(num_pages=9, page_size=4, page_bytes=4)
    cheap = BlockManager(num_pages=25, page_size=4, page_bytes=1)
    budget.register("exp", exp, floor=2)        # 8 bytes guaranteed
    budget.register("cheap", cheap, floor=4)    # 4 bytes guaranteed
    assert budget.surplus_bytes == 20
    assert budget.surplus == 5                  # in 4-byte reference pages
    # the cheap engine can borrow 4x as many pages from the same surplus
    got = cheap.alloc(24, rid=0)                # floor 4 + 20 borrowed
    assert got is not None
    assert budget.borrowed_bytes("cheap") == 20
    assert not budget.allows("exp", 3)          # surplus is spoken for
    assert budget.allows("exp", 2)              # floor is always grantable
    cheap.free(got[:20])
    assert budget.allows("exp", 7)              # 5 surplus pages freed up


def test_fleet_mixed_precision_routing_and_budget(setup):
    cfg, params = setup
    fleet = ModelFleet(
        [FleetModel("q", cfg, params, replicas=2, kv_dtype=[None, "fp8"])],
        total_pages=64, page_size=4, max_seats=2, max_seq_len=32,
        prefill_chunk=8, class_precision={"premium": "bf16"})
    e_full, e_q = fleet.group("q").engines
    assert (e_full.kv_dtype, e_q.kv_dtype) == ("bf16", "fp8")
    # same byte surplus buys the quantized replica more physical pages
    assert e_q.policy.bm.capacity > e_full.policy.bm.capacity
    rids = [fleet.submit(model="q", prompt=[1, 2, 3], max_new_tokens=2,
                         priority="premium") for _ in range(3)]
    assert all(fleet.route(r) == ("q", 0) for r in rids)
    rid_b = fleet.submit(model="q", prompt=[4, 5], max_new_tokens=2,
                         priority="batch")
    done = fleet.run()
    assert set(done) == set(rids) | {rid_b}
    u = fleet.budget.usage()
    assert u["total_bytes"] == 64 * M.paged_page_bytes(cfg, 4)
    assert all(e["bytes_in_use"] == 0 for e in u["engines"].values())


def test_fleet_rejects_unmeetable_class_floor(setup):
    cfg, params = setup
    with pytest.raises(ValueError, match="premium"):
        ModelFleet([FleetModel("q", cfg, params, kv_dtype="fp8")],
                   total_pages=32, page_size=4, max_seq_len=32,
                   class_precision={"premium": "bf16"})


def test_fleet_precision_floor_overrides_session_affinity(setup):
    cfg, params = setup
    fleet = ModelFleet(
        [FleetModel("q", cfg, params, replicas=2, kv_dtype=["fp8", None])],
        total_pages=64, page_size=4, max_seats=2, max_seq_len=32,
        prefill_chunk=8, class_precision={"premium": "bf16"},
        selection="round-robin")
    a = fleet.submit(model="q", prompt=[1, 2], max_new_tokens=1,
                     session_id="s1")
    assert fleet.route(a) == ("q", 0)           # pinned to the fp8 replica
    b = fleet.submit(model="q", prompt=[1, 2], max_new_tokens=1,
                     session_id="s1", priority="premium")
    assert fleet.route(b) == ("q", 1)           # floor beats the pin
    fleet.run()
