"""Preemption-and-recompute: when on-demand growth fails, the Scheduler
evicts the youngest decoding request (``preempt`` trace event +
``EngineMetrics.preemptions``), its pages return to the pool (registered
prompt pages park reclaimable in the prefix index), it requeues at the
queue head, and re-admission replays ``prompt + generated`` with outputs
token-identical to an uncontended run — with prefix caching on or off,
greedy or stochastic sampling."""
import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.models import model as M
from repro.runtime.sampler import SamplingParams
from repro.runtime.serving import PagedServingEngine, ServingEngine


@pytest.fixture(scope="module")
def setup():
    cfg = reduced_config(get_config("qwen3-1.7b"))
    params = M.init_params(M.param_specs(cfg), jax.random.PRNGKey(0))
    return cfg, params


KW = dict(page_size=4, max_seats=2, max_seq_len=24, prefill_chunk=8)


def _reqs(cfg):
    return [((np.arange(8, dtype=np.int32) * 3) % cfg.vocab_size, 10),
            ((np.arange(8, dtype=np.int32) * 7) % cfg.vocab_size, 10)]


def _run(cfg, params, num_pages, *, sampling=None, **over):
    eng = PagedServingEngine(cfg, params, num_pages=num_pages,
                             **{**KW, **over})
    for p, g in _reqs(cfg):
        eng.submit(p, max_new_tokens=g, sampling=sampling)
    eng.run()
    return eng, {r.rid: r.generated for r in eng.finished}


def events(eng, kind, rid):
    return [t for (t, k, r) in eng.trace if k == kind and r == rid]


def test_growth_failure_preempts_youngest_and_replays_exactly(setup):
    cfg, params = setup
    big, ref = _run(cfg, params, 32)
    assert big.metrics.preemptions == 0

    # capacity 6: two 2-page prompts decode concurrently, each growing
    # toward 5 pages — the second boundary crossing cannot be satisfied
    tight, out = _run(cfg, params, 7)
    assert out == ref                          # token-identical replay
    assert tight.metrics.preemptions >= 1
    assert tight.metrics.snapshot()["preemptions"] == \
        tight.metrics.preemptions
    preempted = {r for (_, k, r) in tight.trace if k == "preempt"}
    assert preempted == {1}                    # youngest decoding request
    for rid in preempted:
        req = next(r for r in tight.finished if r.rid == rid)
        assert req.times_preempted >= 1
        assert len(req.generated) == req.max_new_tokens
        # re-admitted after the preemption (queue head, so next chance)
        admits = events(tight, "admit", rid)
        assert len(admits) == req.times_preempted + 1
        assert min(events(tight, "preempt", rid)) >= admits[0]
        # exactly one TTFT emission despite the replayed prefill
        assert len(events(tight, "first_token", rid)) == 1
    # pool fully drained afterwards
    assert tight.bm.in_use == 0
    assert tight.bm.available == tight.bm.capacity


def test_preempted_readmission_recomputes_through_prefix_hits(setup):
    cfg, params = setup
    tight, _ = _run(cfg, params, 7)
    (rid,) = {r for (_, k, r) in tight.trace if k == "preempt"}
    t_pre = events(tight, "preempt", rid)[0]
    hits = events(tight, "prefix_hit", rid)
    # the victim's full prompt pages stayed registered, so its replay
    # starts from the cache instead of re-prefilling from scratch
    assert any(t >= t_pre for t in hits)
    req = next(r for r in tight.finished if r.rid == rid)
    assert req.resume_tokens is not None
    assert len(req.resume_tokens) > len(req.prompt)    # generated replayed


def test_preemption_exact_without_prefix_cache(setup):
    cfg, params = setup
    _, ref = _run(cfg, params, 32)
    tight, out = _run(cfg, params, 7, prefix_cache=False)
    assert tight.metrics.preemptions >= 1
    assert out == ref


def test_preemption_exact_with_stochastic_sampling(setup):
    """The sampler is deterministic per (seed, rid, step): replayed
    requests resume at their step counter, so even temperature > 0 runs
    are preemption-invariant."""
    cfg, params = setup
    sp = SamplingParams(temperature=0.8, top_p=0.9, seed=11)
    _, ref = _run(cfg, params, 32, sampling=sp)
    tight, out = _run(cfg, params, 7, sampling=sp)
    assert tight.metrics.preemptions >= 1
    assert out == ref


def test_preempt_rejects_mid_prefill_requests(setup):
    """Only decoding requests are preemptible: a request with no tokens
    yet has nothing to replay, so preempting it must fail loudly."""
    cfg, params = setup
    eng = PagedServingEngine(cfg, params, page_size=4, num_pages=16,
                             max_seats=1, max_seq_len=24, prefill_chunk=4)
    eng.submit(np.arange(12, dtype=np.int32), max_new_tokens=4)
    eng.step()                                # one 4-token chunk of 12
    req = eng.seats[0]
    assert req.prefill_pos < len(req.prompt) and not req.generated
    with pytest.raises(ValueError, match="preempt"):
        eng.preempt(req)
    assert eng.seats[0] is req                # untouched, still seated
    assert len(eng.run()) == 1


def test_scheduler_preempt_hook_works_on_fixed_slot(setup):
    """`Scheduler.preempt` is policy-agnostic: the fixed-slot engine
    never preempts on its own, but an explicit preemption mid-decode
    parks the slot on scratch, requeues the request, and the replay
    reproduces the solo run exactly."""
    cfg, params = setup
    solo = ServingEngine(cfg, params, slots=1, max_len=32)
    solo.submit(np.arange(6, dtype=np.int32), max_new_tokens=8)
    ref = solo.run()[0].generated

    eng = ServingEngine(cfg, params, slots=1, max_len=32)
    eng.submit(np.arange(6, dtype=np.int32), max_new_tokens=8)
    for _ in range(3):
        eng.step()
    req = eng.seats[0]
    assert 1 < len(req.generated) < 8
    eng.preempt(req)
    assert not eng.seats and eng.queue[0] is req
    assert int(np.asarray(eng.pos)[0]) == 32           # slot on scratch
    done = eng.run()
    assert done[0].generated == ref
    assert eng.metrics.preemptions == 1
    assert req.times_preempted == 1
