"""Cross-cutting property tests (hypothesis) on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.checkpoint.manager import CheckpointManager
from repro.core.mixed_precision import (quantize_fp8, quantize_kv_page,
                                        dequantize_kv_page, F8_MAX)
from repro.core.topology import (RailTopology, hierarchical_allreduce_cost,
                                 flat_allreduce_cost, roofline)
from repro.launch.hlo_analysis import analyze


# -- fp8 quantization ---------------------------------------------------------

@settings(max_examples=50, deadline=None)
@given(scale=st.floats(1e-3, 1e3), seed=st.integers(0, 2**16))
def test_fp8_quantization_relative_error_bound(scale, seed):
    """Property: e4m3 round-trip relative error < 2^-2 on the max element
    and the quantized representation never overflows the format."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (64,)) * scale
    q, s = quantize_fp8(x)
    assert float(jnp.max(jnp.abs(q.astype(jnp.float32)))) <= F8_MAX
    err = jnp.abs(q.astype(jnp.float32) * s - x)
    assert float(jnp.max(err)) <= float(jnp.max(jnp.abs(x))) * 0.25 + 1e-9


@settings(max_examples=50, deadline=None)
@given(scale=st.floats(1e-3, 1e3), seed=st.integers(0, 2**16),
       axis=st.sampled_from([None, 0, 1, -1]))
def test_fp8_quantization_keepdims_contract(scale, seed, axis):
    """Property: ``axis=None`` yields a 0-d scalar scale; any explicit
    axis keeps the reduced dimension, so ``q.astype(f32) * scale``
    reconstructs x elementwise without reshaping in either case."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (8, 16)) * scale
    q, s = quantize_fp8(x, axis=axis)
    if axis is None:
        assert s.shape == ()
    else:
        want = list(x.shape)
        want[axis] = 1
        assert s.shape == tuple(want)
    err = jnp.abs(q.astype(jnp.float32) * s - x)
    assert float(jnp.max(err)) <= float(jnp.max(jnp.abs(x))) * 0.25 + 1e-9


@settings(max_examples=50, deadline=None)
@given(scale=st.floats(1e-3, 1e3), seed=st.integers(0, 2**16))
def test_kv_page_int8_roundtrip_within_half_step(scale, seed):
    """Property: int8 KV round-trip error is at most half a quantization
    step per element — |x - q·s| <= s/2 with s the (token, head) scale."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (4, 8, 16)) * scale
    q, s = quantize_kv_page(x, "int8")
    assert int(jnp.max(jnp.abs(q.astype(jnp.int32)))) <= 127
    err = jnp.abs(dequantize_kv_page(q, s) - x)
    assert bool(jnp.all(err <= s[..., None] * 0.5 + 1e-9))


@settings(max_examples=50, deadline=None)
@given(scale=st.floats(1e-3, 1e3), seed=st.integers(0, 2**16))
def test_kv_page_fp8_roundtrip_relative_bound(scale, seed):
    """Property: fp8 (e4m3, 3 mantissa bits) KV round-trip error is
    *relative* — bounded per element by |x|·2^-3 plus one denormal step
    (448·s/2^10), never by the int8-style s/2 absolute bound."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (4, 8, 16)) * scale
    q, s = quantize_kv_page(x, "fp8")
    assert q.dtype == jnp.uint8      # e4m3 bit patterns (storage dtype)
    vals = jax.lax.bitcast_convert_type(q, jnp.float8_e4m3fn)
    assert float(jnp.max(jnp.abs(vals.astype(jnp.float32)))) <= F8_MAX
    err = jnp.abs(dequantize_kv_page(q, s) - x)
    bound = jnp.abs(x) * 0.125 + s[..., None] * (F8_MAX / 1024.0)
    assert bool(jnp.all(err <= bound + 1e-9))


# -- topology cost model -------------------------------------------------------

@settings(max_examples=50, deadline=None)
@given(gb=st.floats(1e6, 1e11), in_pod=st.sampled_from([2, 4, 8, 16]),
       pods=st.sampled_from([2, 4]))
def test_hierarchical_never_worse_than_flat(gb, in_pod, pods):
    hier, _ = hierarchical_allreduce_cost(gb, in_pod, pods)
    flat = flat_allreduce_cost(gb, in_pod, pods)
    assert hier <= flat * (1 + 1e-9)


@settings(max_examples=30, deadline=None)
@given(src=st.integers(0, 799), dst=st.integers(0, 799))
def test_rail_hops_valid(src, dst):
    t = RailTopology()
    h = t.hops(src, dst)
    assert h in (0, 1, 3)
    assert t.hops(src, src) == 0
    assert t.hops(src, dst) == t.hops(dst, src)


@settings(max_examples=30, deadline=None)
@given(f=st.floats(1e9, 1e18), b=st.floats(1e6, 1e15),
       c=st.floats(0, 1e14), n=st.sampled_from([1, 16, 256, 512]))
def test_roofline_dominant_is_max(f, b, c, n):
    rt = roofline(f, b, c, n)
    terms = {"compute": rt.compute_s, "memory": rt.memory_s,
             "collective": rt.collective_s}
    assert terms[rt.dominant] == max(terms.values())


# -- checkpoint round trip -----------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(shapes=st.lists(
    st.tuples(st.integers(1, 7), st.integers(1, 9)), min_size=1, max_size=4),
    stripes=st.integers(1, 5), seed=st.integers(0, 100))
def test_checkpoint_roundtrip_random_trees(tmp_path_factory, shapes, stripes,
                                           seed):
    rng = np.random.default_rng(seed)
    tree = {f"leaf{i}": jnp.asarray(rng.normal(size=s).astype(
        rng.choice(["float32", "float16"]))) for i, s in enumerate(shapes)}
    root = tmp_path_factory.mktemp("ck")
    mgr = CheckpointManager(str(root), stripes=stripes)
    mgr.save(1, tree)
    _, got = mgr.restore(tree)
    for k in tree:
        assert np.array_equal(np.asarray(tree[k]), np.asarray(got[k]))
        assert got[k].dtype == np.asarray(tree[k]).dtype


# -- loop-aware HLO analyzer ---------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(n1=st.integers(2, 6), n2=st.integers(2, 6))
def test_hlo_flops_scale_linearly_with_trip_count(n1, n2):
    def f(x, n):
        def body(c, _):
            return c @ c, None
        y, _ = jax.lax.scan(body, x, None, length=n)
        return y
    x = jnp.eye(8)
    f1 = analyze(jax.jit(lambda v: f(v, n1)).lower(x).compile().as_text())["flops"]
    f2 = analyze(jax.jit(lambda v: f(v, n2)).lower(x).compile().as_text())["flops"]
    assert f1 > 0 and f2 > 0
    assert f2 / f1 == pytest.approx(n2 / n1, rel=0.05)
