"""Lazy on-demand KV page growth: admission reserves only the prompt's
pages, decode grows one page per boundary crossing via
``BlockManager.try_grow``, the low-watermark gate keeps growth headroom,
``validate`` bounds requests by ``max_seq_len`` alone, and lazy /
reserved greedy outputs are token-identical.  Also covers the
copy-on-write source pinning fix (the source can no longer be evicted
by the admission alloc and handed back as its own copy target) and the
``serve_paged`` ``max_seq_len`` / ``prompt_len`` plumbing."""
import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.launch.serve import serve_paged
from repro.models import model as M
from repro.runtime.paged_kv import BlockManager
from repro.runtime.serving import PagedServingEngine


@pytest.fixture(scope="module")
def setup():
    cfg = reduced_config(get_config("qwen3-1.7b"))
    params = M.init_params(M.param_specs(cfg), jax.random.PRNGKey(0))
    return cfg, params


# -- BlockManager.try_grow ----------------------------------------------------

def test_try_grow_hands_out_single_pages_until_pressure():
    bm = BlockManager(num_pages=4, page_size=2)
    got = [bm.try_grow(rid=0) for _ in range(3)]
    assert sorted(got) == [1, 2, 3]
    assert bm.grows == 3 and bm.in_use == 3
    assert bm.try_grow(rid=0) is None          # pool exhausted, no crash
    assert bm.grows == 3                       # failed grow not counted
    bm.free([got[0]])
    assert bm.try_grow(rid=1) == got[0]        # freed page grows again
    assert bm.refcount(got[0]) == 1


def test_try_grow_evicts_reclaimable_cached_pages():
    bm = BlockManager(num_pages=3, page_size=2)
    a, b = bm.alloc(2, rid=0)
    bm.register_prefix([7, 8], a)
    bm.free([a])                               # a parks reclaimable
    bm.free([b])                               # b returns to the free list
    assert bm.try_grow(rid=1) == b             # free before eviction
    assert bm.try_grow(rid=1) == a             # then the LRU cached page
    assert bm.evictions == 1
    assert bm.match_prefix([7, 8, 0]).pages == []


# -- lazy admission / growth --------------------------------------------------

def test_lazy_admission_reserves_prompt_pages_only(setup):
    cfg, params = setup
    kw = dict(page_size=4, num_pages=32, max_seats=2, max_seq_len=32,
              prefill_chunk=8)
    prompt = np.arange(10, dtype=np.int32)
    lazy = PagedServingEngine(cfg, params, lazy_pages=True, **kw)
    lazy.submit(prompt, max_new_tokens=12)
    lazy.step()
    assert len(lazy.seats[0].pages) == 3       # ceil(10 / 4): prompt only
    reserved = PagedServingEngine(cfg, params, lazy_pages=False, **kw)
    reserved.submit(prompt, max_new_tokens=12)
    reserved.step()
    assert len(reserved.seats[0].pages) == 6   # ceil((10 + 12) / 4): all


def test_decode_grows_across_page_boundaries_token_identical(setup):
    cfg, params = setup
    kw = dict(page_size=4, num_pages=32, max_seats=2, max_seq_len=32,
              prefill_chunk=8)
    # page-aligned prompt: the very first decode write crosses a boundary
    prompt = (np.arange(8, dtype=np.int32) * 3) % cfg.vocab_size
    outs = {}
    for lazy in (False, True):
        eng = PagedServingEngine(cfg, params, lazy_pages=lazy, **kw)
        eng.submit(prompt, max_new_tokens=9)
        outs[lazy] = eng.run()[0].generated
        if lazy:
            # 8 prompt tokens = 2 pages at admission; 9 generated tokens
            # reach position 16 -> two boundary crossings
            assert eng.bm.grows == 2
            assert eng.metrics.preemptions == 0    # ample pool
    assert outs[True] == outs[False]


def test_watermark_gate_defers_admission_until_headroom(setup):
    """With a decoding request live, admission must leave watermark
    headroom; with watermark=0 the gate is off and the same submission
    is admitted a tick earlier."""
    cfg, params = setup
    kw = dict(page_size=4, num_pages=7, max_seats=2, max_seq_len=16,
              prefill_chunk=8)      # capacity 6
    admit_ticks = {}
    for wm in (0.25, 0.0):
        eng = PagedServingEngine(cfg, params, watermark=wm, **kw)
        eng.submit(np.arange(8, dtype=np.int32), max_new_tokens=4)
        eng.step()                  # r0: 2 prompt pages + 1 grown, decoding
        r1 = eng.submit(np.arange(5, dtype=np.int32) + 40,
                        max_new_tokens=2)
        eng.run()
        admit_ticks[wm] = next(t for t, k, r in eng.trace
                               if k == "admit" and r == r1)
        assert eng.metrics.completed == 2
    # ungated: 3 free pages cover the 2-page prompt -> admitted on tick
    # 2 alongside r0; gated: 2 + ceil(0.25 * 6) > 3 -> waits for r0 to
    # finish and the pool to go idle
    assert admit_ticks[0.0] == 2
    assert admit_ticks[0.25] > admit_ticks[0.0]


def test_lazy_pool_must_cover_one_max_length_request(setup):
    cfg, params = setup
    with pytest.raises(ValueError, match="lazy_pages"):
        PagedServingEngine(cfg, params, page_size=4, num_pages=4,
                           max_seats=1, max_seq_len=32)  # 8 tables > cap 3
    # reserved mode still allows the config; the per-request reservation
    # check applies at submit instead
    eng = PagedServingEngine(cfg, params, page_size=4, num_pages=4,
                             max_seats=1, max_seq_len=32, lazy_pages=False)
    with pytest.raises(ValueError, match="pool capacity"):
        eng.submit(np.arange(20, dtype=np.int32), max_new_tokens=4)
    eng.submit(np.arange(6, dtype=np.int32), max_new_tokens=4)
    assert len(eng.run()) == 1


def test_lazy_validate_is_bounded_by_max_seq_len_only(setup):
    """Two requests whose combined full reservation (14 pages) exceeds
    the pool (7) are both accepted and completed — lazy mode's
    feasibility bound is per-request max_seq_len, not the up-front
    reservation."""
    cfg, params = setup
    eng = PagedServingEngine(cfg, params, page_size=4, num_pages=8,
                             max_seats=2, max_seq_len=28, prefill_chunk=8)
    for k in range(2):
        eng.submit((np.arange(8, dtype=np.int32) * (3 + 4 * k))
                   % cfg.vocab_size, max_new_tokens=20)
    done = eng.run()
    assert len(done) == 2
    assert all(len(r.generated) == 20 for r in done)
    assert eng.bm.in_use == 0
    with pytest.raises(ValueError, match="max_seq_len"):
        eng.submit(np.arange(10, dtype=np.int32), max_new_tokens=20)


# -- CoW source pinning (admission must not evict its own copy source) --------

@pytest.fixture(scope="module")
def cow_prompts(setup):
    cfg, _ = setup
    pa = (np.arange(11, dtype=np.int32) * 5 + 3) % cfg.vocab_size
    # shares page 0 in full and the first 2 tokens of page 1
    pb = np.concatenate([pa[:6],
                         np.arange(3, dtype=np.int32) + 90]).astype(np.int32)
    return pa, pb


def _cow_scenario(cfg, params, num_pages, pa, pb):
    """Warm the prefix index with ``pa`` (pages park reclaimable), then
    admit ``pb`` whose match carries a reclaimable CoW source."""
    eng = PagedServingEngine(cfg, params, page_size=4, num_pages=num_pages,
                             max_seats=2, max_seq_len=12, prefill_chunk=4)
    eng.submit(pa, max_new_tokens=1)
    eng.run()
    eng.submit(pb, max_new_tokens=3)
    return eng, eng.run()[-1]


def test_cow_source_pinned_then_released(setup, cow_prompts):
    cfg, params = setup
    pa, pb = cow_prompts
    ref_eng = PagedServingEngine(cfg, params, page_size=4, num_pages=64,
                                 max_seats=2, max_seq_len=12, prefill_chunk=4)
    ref_eng.submit(pb, max_new_tokens=3)
    ref = ref_eng.run()[0].generated

    # capacity 4: the pin holds the reclaimable source alive through the
    # alloc (which takes free pages), the copy lands elsewhere, and the
    # pin is dropped after the copy — the source parks reclaimable again
    eng, req = _cow_scenario(cfg, params, 5, pa, pb)
    assert req.cached_tokens == 6              # full page + 2-token CoW
    assert req.generated == ref
    assert eng.bm.evictions == 0               # source never evicted
    assert eng.bm.in_use == 0 and eng.bm.available == eng.bm.capacity


def test_cow_transient_too_tight_forgoes_partial_match(setup, cow_prompts):
    """Capacity 3: source + copy cannot be live at once, so admission
    drops the partial-page match (keeping full-page shares) instead of
    deferring forever; the old code would have let alloc evict the
    source and hand it back as its own copy target."""
    cfg, params = setup
    pa, pb = cow_prompts
    ref_eng = PagedServingEngine(cfg, params, page_size=4, num_pages=64,
                                 max_seats=2, max_seq_len=12, prefill_chunk=4)
    ref_eng.submit(pb, max_new_tokens=3)
    ref = ref_eng.run()[0].generated

    eng, req = _cow_scenario(cfg, params, 4, pa, pb)
    assert req.cached_tokens == 4              # page-aligned share only
    assert req.generated == ref
    assert eng.bm.in_use == 0 and eng.bm.available == eng.bm.capacity


# -- serve_paged CLI plumbing -------------------------------------------------

def test_serve_paged_honors_prompt_len_and_max_seq_len():
    r = serve_paged("qwen3-1.7b", requests=2, gen=4, page_size=4,
                    num_pages=16, max_seats=2, prefill_chunk=8,
                    prompt_len=10, max_seq_len=16)
    assert len(r["finished"]) == 2
    assert all(len(q.prompt) == 10 for q in r["finished"])


def test_serve_paged_small_page_size_defaults_are_feasible():
    # --page-size 4 used to crash at submit against the hardcoded
    # 3 * page_size + gen bound
    r = serve_paged("qwen3-1.7b", requests=2, gen=3, page_size=4,
                    num_pages=16, max_seats=2, prefill_chunk=8)
    assert len(r["finished"]) == 2


def test_serve_paged_rejects_infeasible_flag_combos():
    with pytest.raises(ValueError, match="max-seq-len"):
        serve_paged("qwen3-1.7b", requests=1, gen=8, prompt_len=10,
                    max_seq_len=12)
    with pytest.raises(ValueError, match="room for prompts"):
        serve_paged("qwen3-1.7b", requests=1, gen=8, max_seq_len=9)
