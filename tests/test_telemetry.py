"""Telemetry plane: flight recorder, postmortems, Perfetto export,
mergeable histograms, burn-rate alerting, Prometheus exposition.

The load-bearing claims pinned here:
  - a forced scheduler stall writes a postmortem whose flight recorder
    names the stalled rid and whose BlockManager snapshot is
    partition-consistent (free + reclaimable + live cover every page
    exactly once);
  - the Perfetto export of a run with preemptions is schema-valid
    Chrome trace JSON, contains preempt instants and replay spans, and
    round-trips through scripts/trace_view.py;
  - Histogram.merge is associative and commutative, and
    quantile_bucket agrees bucket-for-bucket with the exact
    nearest-rank sample quantile (EngineMetrics' _quantile);
  - telemetry is free when on: the flat (tick, event, rid) trace and
    the generated tokens are identical with telemetry on vs off.
"""
import json
import sys
import urllib.request
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent))
from _hypothesis_compat import given, settings, st  # noqa: E402

from repro.runtime.paged_kv import _quantile  # noqa: E402
from repro.runtime.serving import SchedulerStallError  # noqa: E402
from repro.runtime.telemetry import (  # noqa: E402
    ZERO_BUCKET, BurnRateMonitor, FlightRecorder, Histogram, MetricsRegistry,
    MetricsServer, Telemetry, TickProfiler, TraceEvent, block_manager_state,
    build_spans, event_from_dict, perfetto_trace, prometheus_text,
    validate_chrome_trace, write_perfetto)
from repro.runtime.workload import (  # noqa: E402
    VirtualClock, generate_workload, oracle_fleet, spec_from_args)

SCRIPTS = Path(__file__).resolve().parent.parent / "scripts"
sys.path.insert(0, str(SCRIPTS))
import trace_view  # noqa: E402


def _spec(requests=60, seed=0):
    import argparse

    from repro.runtime.workload import add_workload_args
    p = argparse.ArgumentParser()
    add_workload_args(p)
    return spec_from_args(p.parse_args([]), requests=requests)


def _drive(spec, *, total_pages=64, telemetry=None, record_trace=False,
           seed=0):
    from benchmarks.load_harness import drive_workload
    clock = VirtualClock()
    fleet = oracle_fleet(spec, replicas=1, total_pages=total_pages,
                         clock=clock, telemetry=telemetry,
                         record_trace=record_trace)
    res = drive_workload(fleet, generate_workload(spec, seed), clock)
    return fleet, res


# ---------------------------------------------------------------------------
# Flight recorder ring
# ---------------------------------------------------------------------------

class TestFlightRecorder:
    def test_ring_bounds_and_drop_count(self):
        fr = FlightRecorder(capacity=4)
        for i in range(10):
            fr.append(TraceEvent(i, float(i), "e", i, "decode", None))
        evs = fr.events()
        assert len(evs) == 4
        assert [e.tick for e in evs] == [6, 7, 8, 9]   # oldest dropped
        assert fr.total == 10 and fr.dropped == 6
        snap = fr.snapshot()
        assert snap["capacity"] == 4 and snap["dropped"] == 6
        assert [d["tick"] for d in snap["events"]] == [6, 7, 8, 9]

    def test_event_dict_round_trip(self):
        ev = TraceEvent(3, 1.5, "m0/0", 7, "admit", {"seat": 2})
        assert event_from_dict(ev.to_dict()) == ev
        bare = TraceEvent(0, 0.0, "e", 1, "finish", None)
        assert "attrs" not in bare.to_dict()
        assert event_from_dict(bare.to_dict()) == bare

    def test_capacity_validated(self):
        with pytest.raises(ValueError):
            FlightRecorder(capacity=0)


# ---------------------------------------------------------------------------
# Forced stall → postmortem
# ---------------------------------------------------------------------------

class TestPostmortem:
    def test_stall_writes_postmortem_with_stalled_rid(self, tmp_path):
        pm_path = tmp_path / "pm.json"
        tel = Telemetry(ring=256, postmortem_path=str(pm_path))
        spec = _spec(requests=4)
        clock = VirtualClock()
        fleet = oracle_fleet(spec, replicas=1, total_pages=32,
                             clock=clock, telemetry=tel)
        model = next(iter(spec.models))
        import numpy as np
        rid = fleet.submit(model=model,
                           prompt=np.arange(4, dtype=np.int32),
                           max_new_tokens=8)
        with pytest.raises(SchedulerStallError):
            fleet.run(max_ticks=1)

        assert pm_path.exists()
        pm = json.loads(pm_path.read_text())
        assert pm["reason"].startswith("SchedulerStallError")
        # the stalled rid appears in the engine snapshot
        eng_state = pm["engines"][f"{model}/0"]
        seated = [int(r) for r in eng_state["seats"]]
        queued = [r["rid"] for r in eng_state["queue"]]
        assert rid in seated + queued
        # and in the flight-recorder events (it was admitted)
        rids = {d["rid"] for d in pm["flight_recorder"]["events"]}
        assert rid in rids
        # fleet postmortem carries the budget snapshot
        assert "budget" in pm
        assert tel.last_postmortem is pm or tel.last_postmortem["reason"] \
            == pm["reason"]

    def test_block_manager_snapshot_partition_consistent(self, tmp_path):
        tel = Telemetry(ring=256, postmortem_path=str(tmp_path / "p.json"))
        spec = _spec(requests=4)
        clock = VirtualClock()
        fleet = oracle_fleet(spec, replicas=1, total_pages=32,
                             clock=clock, telemetry=tel)
        model = next(iter(spec.models))
        import numpy as np
        fleet.submit(model=model, prompt=np.arange(6, dtype=np.int32),
                     max_new_tokens=8)
        with pytest.raises(SchedulerStallError):
            fleet.run(max_ticks=1)
        bm = tel.last_postmortem["engines"][f"{model}/0"]["block_manager"]
        assert bm["partition_ok"] is True
        covered = (len(bm["free"]) + len(bm["reclaimable"])
                   + len(bm["live_refcounts"]))
        assert covered == bm["capacity"]

    def test_block_manager_state_direct(self):
        from repro.runtime.paged_kv import BlockManager
        bm = BlockManager(num_pages=8, page_size=4)
        pages = bm.alloc(3, rid=1)
        st_ = block_manager_state(bm)
        assert st_["partition_ok"] is True
        assert st_["capacity"] == 7          # page 0 is scratch
        assert st_["in_use"] == 3
        assert sorted(int(k) for k in st_["live_refcounts"]) == \
            sorted(pages)


# ---------------------------------------------------------------------------
# Perfetto export + trace_view round trip
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def preemption_run():
    """A tight-pages oracle run that preempts and replays requests."""
    tel = Telemetry(ring=8192)
    spec = _spec(requests=80)
    fleet, res = _drive(spec, total_pages=24, telemetry=tel)
    events = tel.events()
    kinds = {e.kind for e in events}
    assert "preempt" in kinds, "fixture must produce preemptions"
    return tel, events


class TestPerfetto:
    def test_chrome_trace_validates(self, preemption_run, tmp_path):
        _, events = preemption_run
        doc = perfetto_trace(events)
        assert validate_chrome_trace(doc) == []
        assert doc["displayTimeUnit"] == "ms"
        path = tmp_path / "trace.json"
        write_perfetto(str(path), events)
        assert validate_chrome_trace(json.loads(path.read_text())) == []

    def test_preempt_and_replay_visible(self, preemption_run):
        _, events = preemption_run
        built = build_spans(events)
        names = {sp["name"] for sp in built["spans"]}
        assert {"queued", "prefill", "decode", "replay"} <= names
        preempts = [i for i in built["instants"] if i["kind"] == "preempt"]
        assert preempts
        # every preempted rid later gets a replay span
        replay_rids = {sp["rid"] for sp in built["spans"]
                       if sp["name"] == "replay"}
        assert {i["rid"] for i in preempts} <= replay_rids
        doc = perfetto_trace(events)
        x_names = {e["name"] for e in doc["traceEvents"] if e["ph"] == "X"}
        i_names = {e["name"] for e in doc["traceEvents"] if e["ph"] == "i"}
        assert "replay" in x_names and "preempt" in i_names

    def test_span_tracks_one_per_seat(self, preemption_run):
        _, events = preemption_run
        doc = perfetto_trace(events)
        tids = {e["tid"] for e in doc["traceEvents"] if e["ph"] == "X"}
        assert 0 in tids            # queue track
        assert any(t > 0 for t in tids)     # seat tracks
        thread_names = {(e["pid"], e["tid"]): e["args"]["name"]
                        for e in doc["traceEvents"]
                        if e["ph"] == "M" and e["name"] == "thread_name"}
        for (pid, tid), name in thread_names.items():
            assert name == "queue" if tid == 0 else name.startswith("seat")

    def test_trace_view_round_trip(self, preemption_run, tmp_path):
        tel, events = preemption_run
        # Perfetto input
        ptrace = tmp_path / "trace.json"
        write_perfetto(str(ptrace), events)
        spans_p, inst_p = trace_view.load_trace(str(ptrace))
        # flight-recorder / postmortem input
        pm = tmp_path / "pm.json"
        pm.write_text(json.dumps(tel.postmortem("round trip"), default=str))
        spans_f, inst_f = trace_view.load_trace(str(pm))
        assert len(spans_p) == len(spans_f)
        assert len(inst_p) == len(inst_f)
        out = trace_view.render(spans_f, inst_f)
        assert "replay" in out and "preempt" in out
        rid = next(i["rid"] for i in inst_f if i["kind"] == "preempt")
        md = trace_view.render(spans_p, inst_p, rid=rid, fmt="md")
        assert f"### rid {rid}" in md and "| replay |" in md

    def test_trace_view_rejects_garbage(self, tmp_path):
        bad = tmp_path / "bad.json"
        bad.write_text(json.dumps({"nope": 1}))
        with pytest.raises(SystemExit):
            trace_view.load_trace(str(bad))


# ---------------------------------------------------------------------------
# Histograms: merge laws + quantile contract vs EngineMetrics._quantile
# ---------------------------------------------------------------------------

pos_floats = st.floats(min_value=1e-6, max_value=1e6,
                       allow_nan=False, allow_infinity=False)


class TestHistogram:
    def test_bucket_edges(self):
        h = Histogram(base=2.0)
        assert h.bucket_index(0.0) == ZERO_BUCKET
        assert h.bucket_index(-1.0) == ZERO_BUCKET
        assert h.bucket_index(1.0) == 0          # (0.5, 1] -> 2^0
        assert h.bucket_index(1.5) == 1
        assert h.bucket_index(2.0) == 1          # boundary goes low
        assert h.bucket_le(ZERO_BUCKET) == 0.0
        assert h.bucket_le(3) == 8.0

    def test_merge_base_mismatch(self):
        with pytest.raises(ValueError):
            Histogram(base=2.0).merge(Histogram(base=10.0))

    @settings(max_examples=60, deadline=None)
    @given(st.lists(pos_floats, min_size=1, max_size=40),
           st.lists(pos_floats, min_size=0, max_size=40),
           st.lists(pos_floats, min_size=0, max_size=40))
    def test_merge_associative_commutative(self, xs, ys, zs):
        def mk(vals):
            h = Histogram()
            for v in vals:
                h.observe(v)
            return h
        a, b, c = mk(xs), mk(ys), mk(zs)
        ab_c = a.merge(b).merge(c)
        a_bc = a.merge(b.merge(c))
        ba = b.merge(a)
        assert ab_c.counts == a_bc.counts
        assert a.merge(b).counts == ba.counts
        assert ab_c.count == len(xs) + len(ys) + len(zs)
        assert ab_c.sum == pytest.approx(sum(xs) + sum(ys) + sum(zs))
        # merge is pure: operands unchanged
        assert a.count == len(xs) and b.count == len(ys)

    @settings(max_examples=60, deadline=None)
    @given(st.lists(pos_floats, min_size=1, max_size=50),
           st.sampled_from([0.5, 0.9, 0.95, 0.99, 1.0]))
    def test_quantile_bucket_matches_exact_quantile(self, xs, q):
        h = Histogram()
        for x in xs:
            h.observe(x)
        exact = _quantile(xs, q)
        assert h.quantile_bucket(q) == h.bucket_index(exact)
        assert h.quantile_bound(q) >= exact or \
            h.quantile_bucket(q) == ZERO_BUCKET

    def test_quantile_empty(self):
        assert Histogram().quantile_bucket(0.5) is None

    def test_json_round_trip(self):
        h = Histogram()
        for v in (0.001, 0.5, 3.0, 3.0, 100.0):
            h.observe(v)
        h2 = Histogram.from_dict(h.to_dict())
        assert h2.counts == h.counts and h2.count == h.count
        assert h2.sum == h.sum and h2.base == h.base


# ---------------------------------------------------------------------------
# Burn-rate monitor: window boundary + edge triggering
# ---------------------------------------------------------------------------

class TestBurnRate:
    def test_window_boundary_strict_eviction(self):
        m = BurnRateMonitor(window_s=1.0, threshold=0.5, min_samples=2)
        m.observe(0.0, "rt", "ttft", True)
        m.observe(0.0, "rt", "ttft", True)
        # at now = 0.999 the t=0 samples are still inside the window
        rates = m.rates(0.999)
        assert rates["rt/ttft"]["samples"] == 2
        # at now = 1.0 the boundary is exclusive: t <= now - window evicts
        rates = m.rates(1.0)
        assert "rt/ttft" not in rates or rates["rt/ttft"]["samples"] == 0

    def test_edge_triggered_fire_then_clear(self):
        m = BurnRateMonitor(window_s=10.0, threshold=0.5, min_samples=2)
        assert m.observe(0.0, "rt", "tbt", True) is None   # n=1 < min
        alert = m.observe(0.1, "rt", "tbt", True)          # rate 1.0 fires
        assert alert and alert["state"] == "fire"
        assert alert["class"] == "rt" and alert["kind"] == "tbt"
        assert alert["miss_rate"] == 1.0
        # still burning: no repeat alert
        assert m.observe(0.2, "rt", "tbt", True) is None
        # recover: hits push the rate under threshold -> one clear
        cleared = None
        t = 0.3
        while cleared is None and t < 5.0:
            cleared = m.observe(t, "rt", "tbt", False)
            t += 0.1
        assert cleared and cleared["state"] == "clear"
        assert m.observe(t, "rt", "tbt", False) is None    # stays clear

    def test_classes_independent(self):
        m = BurnRateMonitor(window_s=10.0, threshold=0.5, min_samples=2)
        m.observe(0.0, "rt", "ttft", True)
        m.observe(0.0, "batch", "ttft", False)
        m.observe(0.1, "batch", "ttft", False)
        alert = m.observe(0.1, "rt", "ttft", True)
        assert alert and alert["class"] == "rt"
        assert m.rates(0.2)["batch/ttft"]["miss_rate"] == 0.0

    def test_validation(self):
        with pytest.raises(ValueError):
            BurnRateMonitor(window_s=0.0)
        with pytest.raises(ValueError):
            BurnRateMonitor(threshold=1.5)

    def test_observe_slo_emits_burn_events(self):
        tel = Telemetry(ring=64, burn_window_s=10.0, burn_threshold=0.5,
                        burn_min_samples=2)
        tel.observe_slo(0.0, 1, "e", "rt", "ttft", True)
        tel.observe_slo(0.1, 2, "e", "rt", "ttft", True)
        kinds = [e.kind for e in tel.events()]
        assert kinds == ["slo_burn"]
        ev = tel.events()[0]
        assert ev.rid == -1 and ev.attrs["class"] == "rt"
        assert "state" not in ev.attrs            # popped into the kind


# ---------------------------------------------------------------------------
# Metrics registry + Prometheus exposition + HTTP server
# ---------------------------------------------------------------------------

class TestMetrics:
    def test_registry_renders_prometheus_text(self):
        reg = MetricsRegistry()
        reg.counter("repro_ticks_total", 5, {"engine": "e0"}, help="ticks")
        reg.gauge("repro_pages_in_use", 7.0, {"engine": "e0"})
        h = Histogram()
        for v in (0.1, 0.2, 0.4):
            h.observe(v)
        reg.histogram("repro_ttft_seconds", h, {"engine": "e0"})
        text = reg.render()
        assert "# TYPE repro_ticks_total counter" in text
        assert 'repro_ticks_total{engine="e0"} 5' in text
        assert "# TYPE repro_ttft_seconds histogram" in text
        assert 'le="+Inf"' in text
        assert "repro_ttft_seconds_count" in text
        # cumulative buckets: last finite bucket == count
        inf_line = [l for l in text.splitlines() if 'le="+Inf"' in l][0]
        assert inf_line.endswith(" 3")
        with pytest.raises(ValueError):
            reg.gauge("repro_ticks_total", 1.0)   # type collision

    def test_exposition_from_real_run(self):
        spec = _spec(requests=40)
        fleet, _ = _drive(spec, telemetry=Telemetry(ring=256))
        text = prometheus_text(
            {f"{n}/{i}": e.metrics for n, i, e in fleet._engines()})
        assert "repro_requests_completed_total" in text
        assert 'repro_ttft_seconds_bucket{class=' in text
        assert "repro_slo_misses_total" in text or True  # only if misses

    def test_metrics_server_serves_and_404s(self):
        reg_text = ["# boot\n"]
        srv = MetricsServer(lambda: reg_text[0], port=0)
        try:
            with urllib.request.urlopen(srv.url) as resp:
                assert resp.status == 200
                assert "version=0.0.4" in resp.headers["Content-Type"]
                assert resp.read().decode() == "# boot\n"
            reg_text[0] = "repro_ticks_total 9\n"
            with urllib.request.urlopen(srv.url) as resp:
                assert b"repro_ticks_total 9" in resp.read()
            bad = srv.url.replace("/metrics", "/nope")
            with pytest.raises(urllib.error.HTTPError) as ei:
                urllib.request.urlopen(bad)
            assert ei.value.code == 404
        finally:
            srv.close()


# ---------------------------------------------------------------------------
# Tick profiler
# ---------------------------------------------------------------------------

class TestTickProfiler:
    def test_snapshot_shares_over_top_level_phases(self):
        """decode/* re-slices wall already counted under decode, so the
        share denominator is the top-level sum only — no dilution."""
        p = TickProfiler()
        p.add("admission", 0.25)
        p.add("decode", 0.75)
        p.add("decode/dispatch", 0.5)
        p.add("decode/host", 0.25)
        p.note_tick()
        snap = p.snapshot()
        assert snap["ticks"] == 1
        top = sum(ph["share"] for name, ph in snap["phases"].items()
                  if "/" not in name)
        assert top == pytest.approx(1.0)
        assert snap["phases"]["decode"]["share"] == pytest.approx(0.75)
        assert snap["phases"]["decode/dispatch"]["share"] == \
            pytest.approx(0.5)

    def test_profiled_step_records_phases(self):
        tel = Telemetry(ring=256, profile=True)
        spec = _spec(requests=20)
        _drive(spec, telemetry=tel)
        snap = tel.profiler.snapshot()
        assert snap["ticks"] > 0
        assert "admission" in snap["phases"]
        assert "bookkeeping" in snap["phases"]


# ---------------------------------------------------------------------------
# Telemetry must be free: identical flat trace + tokens, on vs off
# ---------------------------------------------------------------------------

class TestZeroIntrusion:
    def test_flat_trace_and_tokens_identical_on_vs_off(self):
        spec = _spec(requests=40)
        fleet_off, _ = _drive(spec, record_trace=True, telemetry=None)
        fleet_on, _ = _drive(spec, record_trace=True,
                             telemetry=Telemetry(ring=4096, profile=True))
        engs_off = [e for _, _, e in fleet_off._engines()]
        engs_on = [e for _, _, e in fleet_on._engines()]
        for a, b in zip(engs_off, engs_on):
            assert a.trace == b.trace
        toks_off = {rid: r.generated
                    for rid, r in fleet_off.finished().items()}
        toks_on = {rid: r.generated
                   for rid, r in fleet_on.finished().items()}
        assert toks_off == toks_on

    def test_submit_event_is_telemetry_only(self):
        """`submit` must never appear in the flat trace (its tuple shape
        is pinned by parity tests) — telemetry ring only."""
        tel = Telemetry(ring=4096)
        spec = _spec(requests=20)
        fleet, _ = _drive(spec, record_trace=True, telemetry=tel)
        for _, _, eng in fleet._engines():
            assert all(ev[1] != "submit" for ev in eng.trace)
        assert any(e.kind == "submit" for e in tel.events())
