"""Workload model + oracle stub unit contracts.

Pins the pieces the load harness's fidelity rests on: the numpy
batched sampler is token-for-token identical to both the scalar host
sampler and the jnp device sampler; the workload generator is
seed-deterministic, validates its spec, and produces the advertised
mixture shapes; the oracle model's logits are pure functions of
(rid, step, last_token) so token streams replay exactly under any
schedule.  See docs/benchmarks.md §"Workload 8"."""
import dataclasses

import numpy as np
import pytest

from repro.runtime.sampler import (Sampler, SamplingParams,
                                   sample_tokens, sample_tokens_np)
from repro.runtime.workload import (OracleModel, VirtualClock,
                                    WorkloadSpec, generate_workload)
from repro.runtime.serving import PRIORITIES


# -- sample_tokens_np equivalence -------------------------------------------

def _random_batch(seed, B=24, V=96):
    rng = np.random.default_rng(seed)
    logits = rng.normal(0, 4, (B, V)).astype(np.float32)
    # mixed rows: greedy / temperature-only / top-k / top-p / both
    temperature = np.where(rng.random(B) < 0.3, 0.0,
                           rng.uniform(0.2, 1.5, B)).astype(np.float32)
    top_k = np.where(rng.random(B) < 0.5, 0,
                     rng.integers(1, V, B)).astype(np.int32)
    top_p = np.where(rng.random(B) < 0.5, 1.0,
                     rng.uniform(0.3, 0.99, B)).astype(np.float32)
    # uint32 per the sample_tokens key contract (int64 would demote to
    # int32 on the device and hash differently)
    seeds = rng.integers(0, 2**31, B).astype(np.uint32)
    rids = rng.integers(0, 10_000, B).astype(np.uint32)
    steps = rng.integers(0, 512, B).astype(np.uint32)
    return logits, temperature, top_k, top_p, seeds, rids, steps


@pytest.mark.parametrize("case", range(3))
def test_sample_tokens_np_matches_scalar_sampler(case):
    """Every row of the batched numpy sampler equals the scalar
    Sampler.sample call with the same (seed, rid, step) key — across
    greedy, temperature, top-k and top-p rows."""
    logits, temp, top_k, top_p, seeds, rids, steps = _random_batch(case)
    got = sample_tokens_np(logits, temp, top_k, top_p,
                           seeds, rids, steps)
    s = Sampler()
    for i in range(logits.shape[0]):
        params = SamplingParams(temperature=float(temp[i]),
                                top_k=int(top_k[i]),
                                top_p=float(top_p[i]),
                                seed=int(seeds[i]))
        want = s.sample(logits[i], params, rid=int(rids[i]),
                        step=int(steps[i]))
        assert got[i] == want, f"row {i}: {got[i]} != {want}"


def test_sample_tokens_np_matches_device_sampler():
    """The numpy twin and the jnp device sampler agree on the same
    batch — the oracle engine's streams are the streams a real engine
    would sample from identical logits."""
    logits, temp, top_k, top_p, seeds, rids, steps = _random_batch(7)
    host = sample_tokens_np(logits, temp, top_k, top_p,
                            seeds, rids, steps)
    dev = np.asarray(sample_tokens(logits, temp, top_k, top_p,
                                   seeds, rids, steps))
    np.testing.assert_array_equal(host, dev)


def test_sample_tokens_np_subset_invariant():
    """Sampling a row subset returns the same tokens as the full
    batch — per-row keys are (seed, rid, step), never batch position
    (the mixed-batch fast path and the oracle's per-seat batching
    both rely on this)."""
    logits, temp, top_k, top_p, seeds, rids, steps = _random_batch(11)
    full = sample_tokens_np(logits, temp, top_k, top_p,
                            seeds, rids, steps)
    idx = np.array([3, 0, 17, 9, 21])
    sub = sample_tokens_np(logits[idx], temp[idx], top_k[idx],
                           top_p[idx], seeds[idx], rids[idx], steps[idx])
    np.testing.assert_array_equal(sub, full[idx])


# -- oracle model -----------------------------------------------------------

def test_oracle_logits_pure_and_schedule_free():
    """Logit rows depend only on (rid, step, last) — batch shape,
    call order and batch companions never change them."""
    m = OracleModel(vocab=32)
    row = m.logits_row(5, 3, 17)
    batch = m.logits_batch(np.array([9, 5, 2], np.uint32),
                           np.array([1, 3, 0], np.uint32),
                           np.array([4, 17, 30], np.uint32))
    np.testing.assert_array_equal(batch[1], row)
    np.testing.assert_array_equal(m.logits_row(5, 3, 17), row)
    assert row.shape == (32,) and row.dtype == np.float32
    # distinct keys decorrelate
    assert not np.array_equal(m.logits_row(5, 3, 18), row)
    with pytest.raises(ValueError):
        OracleModel(vocab=1)


# -- virtual clock ----------------------------------------------------------

def test_virtual_clock_monotone():
    c = VirtualClock()
    assert c() == 0.0
    c.advance(1.5)
    c.advance(0.0)
    assert c() == 1.5
    with pytest.raises(ValueError):
        c.advance(-0.1)


# -- workload generator -----------------------------------------------------

def _event_key(e):
    return (e.t, e.model, e.session_id, tuple(e.prompt),
            e.max_new_tokens, e.priority, e.deadline_ms,
            e.tbt_deadline_ms, e.sampling)


def test_generate_workload_deterministic_and_sorted():
    spec = WorkloadSpec(requests=500)
    a = generate_workload(spec, seed=3)
    b = generate_workload(spec, seed=3)
    assert [_event_key(e) for e in a] == [_event_key(e) for e in b]
    assert len(a) == 500
    assert all(x.t <= y.t for x, y in zip(a, a[1:]))
    c = generate_workload(spec, seed=4)
    assert [_event_key(e) for e in c] != [_event_key(e) for e in a]


def test_generate_workload_mixture_shapes():
    """Class mix lands near the spec, prompts/outputs respect bounds,
    session turns reuse the session id with growing context."""
    spec = WorkloadSpec(requests=3000, class_mix=(0.5, 0.3, 0.2))
    ev = generate_workload(spec, seed=0)
    frac = {c: sum(1 for e in ev if e.priority == c) / len(ev)
            for c in PRIORITIES}
    assert abs(frac["premium"] - 0.5) < 0.05
    assert abs(frac["batch"] - 0.2) < 0.05
    for e in ev:
        assert 1 <= e.max_new_tokens
        assert len(e.prompt) + e.max_new_tokens <= spec.max_total_len
    sessions = {}
    for e in ev:
        if e.session_id is not None:
            sessions.setdefault(e.session_id, []).append(e)
    multi = [v for v in sessions.values() if len(v) > 1]
    assert multi, "no multi-turn sessions generated"
    grew = 0
    for turns in multi:
        for a, b in zip(turns, turns[1:]):
            assert b.t > a.t                       # think time elapsed
            # context grows turn over turn, except across a
            # context-window truncation (reset to the shared prefix)
            if len(b.prompt) > len(a.prompt):
                grew += 1
                np.testing.assert_array_equal(
                    b.prompt[:len(a.prompt)], a.prompt)
    assert grew > len(multi) // 2


def test_workload_spec_validation():
    with pytest.raises(ValueError, match="requests"):
        WorkloadSpec(requests=0)
    with pytest.raises(ValueError, match="class_mix"):
        WorkloadSpec(class_mix=(0.9, 0.2, 0.2))
    with pytest.raises(ValueError, match="zipf"):
        WorkloadSpec(prefix_zipf=1.0)
    with pytest.raises(ValueError, match="max_total_len"):
        WorkloadSpec(max_total_len=10, prefix_len=24)


def test_workload_diurnal_envelope_modulates_rate():
    """With a strong diurnal swing, arrival density varies across the
    period — the first half-period (rate above base) packs more
    arrivals than the second (rate below base)."""
    spec = WorkloadSpec(requests=4000, arrival_rate=50.0,
                        diurnal_amplitude=0.9, diurnal_period_s=100.0,
                        session_extra_turns=0.0)
    ev = generate_workload(spec, seed=1)
    in_phase = [e.t % 100.0 for e in ev]
    first_half = sum(1 for t in in_phase if t < 50.0)
    assert first_half / len(ev) > 0.6
