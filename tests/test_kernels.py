"""Per-kernel allclose vs pure-jnp oracles, swept over shapes & dtypes
(interpret=True executes the Pallas kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.fp8_matmul import fp8_matmul_pallas
from repro.core.mixed_precision import F8_MAX


@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (256, 384, 128),
                                   (100, 70, 50), (33, 128, 257)])
def test_fp8_matmul_vs_oracle(m, k, n):
    """Kernel output == oracle on identical quantized inputs (bit-level
    fp8 path), swept over aligned and ragged shapes."""
    key = jax.random.PRNGKey(m * 1000 + k + n)
    a = jax.random.normal(key, (m, k), jnp.float32)
    b = jax.random.normal(jax.random.fold_in(key, 1), (k, n), jnp.float32)
    out = ops.fp8_matmul(a, b, interpret=True)
    # Oracle path: same padding/quantization as the wrapper
    bm = bn = bk = 128
    pad = lambda x, mult, ax: jnp.pad(
        x, [(0, (-x.shape[0]) % mult if ax == 0 else 0),
            (0, (-x.shape[1]) % mult if ax == 1 else 0)])
    ap = pad(pad(a, bm, 0), bk, 1)
    bp = pad(pad(b, bk, 0), bn, 1)
    mm, kk = ap.shape
    nn = bp.shape[1]
    sa = jnp.maximum(jnp.max(jnp.abs(ap.reshape(mm // bm, bm, kk)), axis=(1, 2)), 1e-12) / F8_MAX
    sb = jnp.maximum(jnp.max(jnp.abs(bp.reshape(kk, nn // bn, bn)), axis=(0, 2)), 1e-12) / F8_MAX
    aq = (ap / jnp.repeat(sa, bm)[:, None]).astype(jnp.float8_e4m3fn)
    bq = (bp / jnp.repeat(sb, bn)[None, :]).astype(jnp.float8_e4m3fn)
    want = ref.fp8_matmul_ref(aq, bq, sa, sb, bm=bm, bn=bn)[:m, :n]
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("m,k,n", [(128, 256, 128), (512, 128, 256)])
def test_fp8_matmul_quant_error_bounded(m, k, n):
    """End-to-end fp8 error vs exact f32 matmul stays within e4m3 bounds."""
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (m, k), jnp.float32)
    b = jax.random.normal(jax.random.fold_in(key, 7), (k, n), jnp.float32)
    out = ops.fp8_matmul(a, b, interpret=True)
    exact = a @ b
    rel = float(jnp.linalg.norm(out - exact) / jnp.linalg.norm(exact))
    assert rel < 0.08, rel                     # e4m3 ~2 mantissa bits


def test_fp8_matmul_pallas_rejects_ragged_shapes():
    """Regression: a non-multiple dimension used to be a bare assert (a
    silent grid truncation with asserts stripped); it must raise a
    ValueError naming the offending dimension and block."""
    q = lambda shape: jnp.zeros(shape, jnp.float8_e4m3fn)
    s = lambda n: jnp.ones((n,), jnp.float32)
    with pytest.raises(ValueError, match=r"M=100 is not a multiple of bm=128"):
        fp8_matmul_pallas(q((100, 128)), q((128, 128)), s(1), s(1),
                          interpret=True)
    with pytest.raises(ValueError, match=r"N=257 is not a multiple of bn=128"):
        fp8_matmul_pallas(q((128, 128)), q((128, 257)), s(1), s(3),
                          interpret=True)
    with pytest.raises(ValueError, match=r"K=70 is not a multiple of bk=128"):
        fp8_matmul_pallas(q((128, 70)), q((70, 128)), s(1), s(1),
                          interpret=True)
    with pytest.raises(ValueError, match="contraction mismatch"):
        fp8_matmul_pallas(q((128, 128)), q((256, 128)), s(1), s(1),
                          interpret=True)
    with pytest.raises(ValueError, match=r"M=100"):
        ref.fp8_matmul_ref(q((100, 128)), q((128, 128)), s(1), s(1))
    # the padding wrapper still accepts the same ragged shape
    a = jax.random.normal(jax.random.PRNGKey(0), (100, 70), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(1), (70, 50), jnp.float32)
    assert ops.fp8_matmul(a, b, interpret=True).shape == (100, 50)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("sq,skv,h,kvh,d", [
    (64, 64, 4, 4, 32), (128, 128, 8, 2, 64), (96, 200, 4, 1, 32),
])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 32),
                                           (False, None)])
def test_flash_attention_sweep(dtype, sq, skv, h, kvh, d, causal, window):
    if not causal and sq != skv:
        pass  # cross-attention case — exercised below too
    key = jax.random.PRNGKey(sq + skv + h)
    q = jax.random.normal(key, (2, sq, h, d), dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (2, skv, kvh, d), dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (2, skv, kvh, d), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              bq=32, bk=32, interpret=True)
    rep = h // kvh
    kf = jnp.repeat(k, rep, axis=2).transpose(0, 2, 1, 3).reshape(2 * h, skv, d)
    vf = jnp.repeat(v, rep, axis=2).transpose(0, 2, 1, 3).reshape(2 * h, skv, d)
    qf = q.transpose(0, 2, 1, 3).reshape(2 * h, sq, d)
    want = ref.attention_ref(qf, kf, vf, causal=causal, window=window)
    want = want.reshape(2, h, sq, d).transpose(0, 2, 1, 3)
    atol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), atol=atol)


@pytest.mark.parametrize("rows,d", [(64, 64), (100, 128), (256, 96)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(rows, d, dtype):
    key = jax.random.PRNGKey(rows + d)
    x = jax.random.normal(key, (rows, d), dtype) * 3.0
    w = jax.random.normal(jax.random.fold_in(key, 1), (d,), jnp.float32) * 0.2
    out = ops.rmsnorm(x, w, bm=32, interpret=True)
    want = ref.rmsnorm_ref(x, w)
    atol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), atol=atol)


def test_flash_matches_model_attention_path():
    """The kernel agrees with the model's chunked-jnp attention module."""
    from repro.configs.base import ModelConfig
    from repro.models.attention import attention, attn_specs
    from repro.models.modules import init_params

    cfg = ModelConfig(name="t", family="dense", num_layers=1, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=64,
                      head_dim=16)
    params = init_params(attn_specs(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 64), jnp.float32)
    out_model, (k, v) = attention(params, cfg, x, q_chunk=32)
    # reproduce with the kernel on the same projected q/k/v
    import repro.models.attention as A
    q, k2, v2 = A._project_qkv(params, cfg, x, x,
                               jnp.broadcast_to(jnp.arange(64), (2, 64)),
                               jnp.broadcast_to(jnp.arange(64), (2, 64)))
    out_kernel = ops.flash_attention(q, k2, v2, causal=True, bq=32, bk=32,
                                     interpret=True)
    proj = jnp.einsum("bshd,hdD->bsD", out_kernel, params["wo"])
    np.testing.assert_allclose(np.asarray(proj), np.asarray(out_model),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("t,h,kvh,d", [(64, 4, 4, 32), (160, 8, 2, 64),
                                       (96, 4, 1, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(t, h, kvh, d, dtype):
    from repro.kernels.decode_attention import decode_attention_pallas
    key = jax.random.PRNGKey(t + h)
    B = 3
    q = jax.random.normal(key, (B, 1, h, d), dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, t, kvh, d), dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, t, kvh, d), dtype)
    lengths = jnp.array([t, t // 2, 1], jnp.int32)
    out = ops.decode_attention(q, k, v, lengths, bk=32, interpret=True)
    rep = h // kvh
    kf = jnp.repeat(k, rep, axis=2).transpose(0, 2, 1, 3).reshape(B * h, t, d)
    vf = jnp.repeat(v, rep, axis=2).transpose(0, 2, 1, 3).reshape(B * h, t, d)
    qf = q[:, 0].reshape(B * h, d)
    want = ref.decode_attention_ref(qf, kf, vf, jnp.repeat(lengths, h))
    want = want.reshape(B, 1, h, d)
    atol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=atol)


@pytest.mark.parametrize("t", [37, 65, 100, 192, 255])
def test_decode_attention_odd_lengths(t):
    """Regression: cache lengths not divisible by the key tile used to
    hit a hard ``t % bk == 0`` assert (e.g. fixed-slot ``max_len=192``
    configs, or any ``max_len + 1`` scratch layout).  The kernel now
    clamps ``bk`` and zero-pads the cache to the tile multiple; padded
    keys sit beyond every row's length so results are unchanged."""
    from repro.kernels.decode_attention import decode_attention_pallas
    key = jax.random.PRNGKey(t)
    BH, d = 4, 32
    q = jax.random.normal(key, (BH, d), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (BH, t, d), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 2), (BH, t, d), jnp.float32)
    lengths = jnp.array([t, max(1, t // 2), max(1, t - 1), 1], jnp.int32)
    out = decode_attention_pallas(q, k, v, lengths, bk=64, interpret=True)
    want = ref.decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


def test_decode_attention_matches_model_decode_path():
    """Kernel agrees with the model's decode_attention (cache semantics)."""
    from repro.configs.base import ModelConfig
    from repro.models.attention import decode_attention as model_decode
    from repro.models.modules import init_params
    from repro.models.attention import attn_specs

    cfg = ModelConfig(name="t", family="dense", num_layers=1, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=64,
                      head_dim=16, rope_theta=1e4)
    params = init_params(attn_specs(cfg), jax.random.PRNGKey(0))
    B, T = 2, 64
    x = jax.random.normal(jax.random.PRNGKey(1), (B, 1, 64), jnp.float32)
    ck = jax.random.normal(jax.random.PRNGKey(2), (B, T, 2, 16)) * 0.5
    cv = jax.random.normal(jax.random.PRNGKey(3), (B, T, 2, 16)) * 0.5
    pos = jnp.array([10, 31], jnp.int32)
    out_model, k_new, v_new = model_decode(params, cfg, x, ck, cv, pos)
    # reproduce via kernel on the updated cache
    import repro.models.attention as A
    q, _, _ = A._project_qkv(params, cfg, x, x, pos[:, None], pos[:, None])
    out_k = ops.decode_attention(q, k_new, v_new, pos + 1, bk=32,
                                 interpret=True)
    proj = jnp.einsum("bshd,hdD->bsD", out_k, params["wo"])
    np.testing.assert_allclose(np.asarray(proj), np.asarray(out_model),
                               rtol=2e-4, atol=2e-4)
