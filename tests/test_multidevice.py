"""Multi-device integration tests, run in subprocesses so the main test
session keeps seeing exactly ONE device (the dry-run is the only 512-device
context; these use 8)."""
import os
import subprocess
import sys

import pytest

pytestmark = [pytest.mark.multidevice, pytest.mark.slow]

HERE = os.path.dirname(__file__)


def run_script(name, timeout=900):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, os.path.join(HERE, "subproc", name)],
        capture_output=True, text=True, timeout=timeout, env=env)
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "ALL_OK" in proc.stdout, proc.stdout
    return proc.stdout


def test_hierarchical_and_compressed_collectives():
    out = run_script("check_collectives.py")
    assert "OK hierarchical==flat" in out
    assert "OK compressed" in out
    assert "OK single-pod fallback" in out


def test_sharded_train_matches_single_device_and_elastic_restore():
    out = run_script("check_sharded_train.py")
    assert "OK sharded==single" in out
    assert "OK elastic-restore" in out
    assert "OK sharded-decode" in out


def test_distributed_hpl_matches_reference():
    out = run_script("check_collectives.py")
    assert "OK distributed-hpl" in out


def test_pipeline_parallel_matches_reference():
    out = run_script("check_pipeline.py")
    assert "OK pipeline==reference" in out
