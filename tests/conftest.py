import os
import sys

# Tests run single-device (the dry-run is the ONLY place with 512 virtual
# devices); multi-device collective/sharding tests spawn subprocesses that
# set XLA_FLAGS themselves (see tests/subproc/).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)


def pytest_configure(config):
    # Tier-1 runs everything; `-m "not slow"` (scripts/run_tests.sh FAST=1)
    # keeps the quick inner loop for contributors.
    config.addinivalue_line(
        "markers", "slow: long-running test (minutes-scale model loops)")
    config.addinivalue_line(
        "markers",
        "multidevice: spawns XLA_FLAGS multi-device subprocesses")
