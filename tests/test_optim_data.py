"""Optimizer, schedules, and data-pipeline tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.data.pipeline import TokenPipeline, _tokens_for_slice
from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state, global_norm
from repro.optim.schedules import wsd_schedule, cosine_schedule


def test_adamw_minimizes_quadratic():
    params = {"x": jnp.array([5.0, -3.0])}
    opt = init_opt_state(params)
    target = jnp.array([1.0, 2.0])
    cfg = AdamWConfig(weight_decay=0.0, clip_norm=None)
    for _ in range(300):
        g = {"x": 2 * (params["x"] - target)}
        params, opt, _ = adamw_update(g, opt, params, 0.05, cfg)
    np.testing.assert_allclose(np.asarray(params["x"]), np.asarray(target),
                               atol=1e-2)


def test_grad_clipping_bounds_update():
    params = {"x": jnp.zeros(4)}
    opt = init_opt_state(params)
    g = {"x": jnp.full((4,), 1e6)}
    cfg = AdamWConfig(clip_norm=1.0, weight_decay=0.0)
    _, _, m = adamw_update(g, opt, params, 1e-3, cfg)
    assert float(m["grad_norm"]) == pytest.approx(2e6, rel=1e-3)


def test_weight_decay_shrinks_params():
    params = {"x": jnp.full((4,), 10.0)}
    opt = init_opt_state(params)
    g = {"x": jnp.zeros(4)}
    p2, _, _ = adamw_update(g, opt, params, 0.1,
                            AdamWConfig(weight_decay=0.1, clip_norm=None))
    assert float(p2["x"][0]) < 10.0


def test_wsd_schedule_phases():
    kw = dict(peak=1.0, warmup_steps=100, total_steps=1000)
    assert float(wsd_schedule(0, **kw)) == 0.0
    assert float(wsd_schedule(50, **kw)) == pytest.approx(0.5)
    assert float(wsd_schedule(500, **kw)) == pytest.approx(1.0)   # stable
    assert float(wsd_schedule(899, **kw)) == pytest.approx(1.0)   # stable end
    assert float(wsd_schedule(1000, **kw)) == pytest.approx(0.01, abs=1e-6)
    # decay is monotonic
    vals = [float(wsd_schedule(s, **kw)) for s in range(900, 1001, 10)]
    assert all(a >= b for a, b in zip(vals, vals[1:]))


@settings(max_examples=30, deadline=None)
@given(step=st.integers(0, 2000))
def test_schedules_bounded(step):
    kw = dict(peak=3e-4, warmup_steps=20, total_steps=1000)
    for sched in (wsd_schedule, cosine_schedule):
        v = float(sched(step, **kw))
        assert 0.0 <= v <= 3e-4 + 1e-9


def test_pipeline_determinism_and_label_shift():
    pipe = TokenPipeline(vocab_size=100, seq_len=16, global_batch=4)
    b1 = pipe.get_batch(7)
    b2 = pipe.get_batch(7)
    assert np.array_equal(b1["tokens"], b2["tokens"])
    # labels are tokens shifted by one
    raw = _tokens_for_slice(7, 0, 4, 16, 100)
    assert np.array_equal(np.asarray(b1["tokens"]), raw[:, :-1])
    assert np.array_equal(np.asarray(b1["labels"]), raw[:, 1:])
    # different steps differ
    b3 = pipe.get_batch(8)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_pipeline_slice_consistency():
    """Per-shard generation equals slicing the global batch (elastic replay
    across different shardings depends on this)."""
    full = _tokens_for_slice(3, 0, 8, 12, 50)
    part = _tokens_for_slice(3, 2, 5, 12, 50)
    assert np.array_equal(full[2:5], part)
