"""Continuous-batching engine: correctness vs the plain serve path,
slot reuse, mixed-length scheduling, and loud stall failures."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.models import model as M
from repro.parallel.sharding import SINGLE_DEVICE_RULES
from repro.runtime.serving import (PagedServingEngine, SchedulerStallError,
                                   ServingEngine)


@pytest.fixture(scope="module")
def engine_setup():
    cfg = reduced_config(get_config("qwen3-1.7b"))
    params = M.init_params(M.param_specs(cfg), jax.random.PRNGKey(0))
    return cfg, params


def greedy_reference(cfg, params, prompt, n):
    """Naive reference: full re-prefill per generated token."""
    toks = list(np.asarray(prompt))
    opts = M.RunOptions(q_chunk=512)
    out = []
    for _ in range(n):
        batch = {"tokens": jnp.asarray(toks, jnp.int32)[None]}
        logits, _ = M.prefill(params, cfg, batch, SINGLE_DEVICE_RULES, opts)
        nxt = int(jnp.argmax(logits[0, -1]))
        out.append(nxt)
        toks.append(nxt)
    return out


def test_engine_matches_naive_greedy(engine_setup):
    cfg, params = engine_setup
    prompt = np.arange(7, dtype=np.int32) % cfg.vocab_size
    eng = ServingEngine(cfg, params, slots=2, max_len=32)
    eng.submit(prompt, max_new_tokens=5)
    done = eng.run()
    assert len(done) == 1
    want = greedy_reference(cfg, params, prompt, 5)
    assert done[0].generated == want


def test_continuous_batching_slot_reuse(engine_setup):
    cfg, params = engine_setup
    eng = ServingEngine(cfg, params, slots=2, max_len=48)
    # 5 requests, 2 slots: scheduling must reuse slots as requests finish
    rids = [eng.submit(np.arange(3 + i, dtype=np.int32),
                       max_new_tokens=2 + (i % 3)) for i in range(5)]
    done = eng.run()
    assert sorted(r.rid for r in done) == sorted(rids)
    for r in done:
        assert len(r.generated) == r.max_new_tokens
        assert r.t_first_token is not None and r.t_done is not None


def test_mixed_lengths_isolated(engine_setup):
    """Requests sharing a decode batch must not contaminate each other:
    the same prompt gives the same tokens whether run alone or alongside
    other requests."""
    cfg, params = engine_setup
    prompt = (np.arange(9, dtype=np.int32) * 3) % cfg.vocab_size
    solo = ServingEngine(cfg, params, slots=2, max_len=40)
    solo.submit(prompt, max_new_tokens=6)
    ref = solo.run()[0].generated

    busy = ServingEngine(cfg, params, slots=2, max_len=40)
    busy.submit((np.arange(5, dtype=np.int32) * 7) % cfg.vocab_size,
                max_new_tokens=9)
    busy.submit(prompt, max_new_tokens=6)
    busy.submit((np.arange(4, dtype=np.int32) * 11) % cfg.vocab_size,
                max_new_tokens=3)
    done = busy.run()
    got = next(r for r in done if len(r.prompt) == 9).generated
    assert got == ref


def test_fixed_engine_rejects_cache_overflow(engine_setup):
    """prompt + max_new_tokens > max_len must raise at submit (decode
    would otherwise clamp writes into the last slot and corrupt KV)."""
    cfg, params = engine_setup
    eng = ServingEngine(cfg, params, slots=1, max_len=16)
    with pytest.raises(ValueError):
        eng.submit(np.arange(10, dtype=np.int32), max_new_tokens=8)
    with pytest.raises(ValueError):
        eng.submit(np.arange(16, dtype=np.int32), max_new_tokens=1)
    with pytest.raises(ValueError):
        eng.submit(np.asarray([], np.int32), max_new_tokens=2)
    eng.submit(np.arange(10, dtype=np.int32), max_new_tokens=6)  # == max_len
    assert len(eng.run()) == 1


def test_run_raises_on_stall_fixed(engine_setup):
    """Exhausting max_ticks with unfinished requests raises instead of
    silently returning a partial result."""
    cfg, params = engine_setup
    eng = ServingEngine(cfg, params, slots=1, max_len=32)
    eng.submit(np.arange(4, dtype=np.int32), max_new_tokens=8)
    with pytest.raises(SchedulerStallError):
        eng.run(max_ticks=2)
    # the same workload completes with enough ticks
    eng2 = ServingEngine(cfg, params, slots=1, max_len=32)
    eng2.submit(np.arange(4, dtype=np.int32), max_new_tokens=8)
    assert len(eng2.run()) == 1


def test_run_raises_on_stall_paged(engine_setup):
    cfg, params = engine_setup
    eng = PagedServingEngine(cfg, params, page_size=8, num_pages=8,
                             max_seats=1, max_seq_len=24, prefill_chunk=8)
    eng.submit(np.arange(4, dtype=np.int32), max_new_tokens=6)
    eng.submit(np.arange(4, dtype=np.int32), max_new_tokens=6)
    with pytest.raises(SchedulerStallError) as ei:
        eng.run(max_ticks=1)
    assert "queued" in str(ei.value)


def test_fixed_slot_release_parks_pos_on_scratch(engine_setup):
    """release() must reset the slot's write position to the scratch
    index (max_len); it used to stay wherever the finished request left
    it, so idle slots kept rewriting KV at stale positions."""
    cfg, params = engine_setup
    eng = ServingEngine(cfg, params, slots=2, max_len=32)
    eng.submit(np.arange(6, dtype=np.int32), max_new_tokens=3)
    eng.run()
    assert [int(p) for p in np.asarray(eng.pos)] == [32, 32]
    # a slot that finishes mid-tick is also parked (the tick's position
    # advance must not clobber the release reset)
    eng.submit(np.arange(4, dtype=np.int32), max_new_tokens=2)
    eng.submit(np.arange(5, dtype=np.int32), max_new_tokens=6)
    eng.run()
    assert [int(p) for p in np.asarray(eng.pos)] == [32, 32]


def test_fixed_slot_idle_writes_go_to_scratch_position(engine_setup):
    """A slot whose request finished holds stale KV while its sibling
    decodes on; its live region [0:max_len] must stay byte-identical —
    the idle slot's batched-decode writes are routed to the scratch
    position at index max_len instead of its stale write position."""
    cfg, params = engine_setup
    eng = ServingEngine(cfg, params, slots=2, max_len=32)
    eng.submit(np.arange(6, dtype=np.int32), max_new_tokens=2)       # slot 0
    eng.submit(np.arange(9, dtype=np.int32) + 20, max_new_tokens=10)  # slot 1
    eng.step()
    # the short request prefilled + decoded to completion in tick 1:
    # slot 0 is now idle with its KV and (pre-fix) stale position intact
    assert 0 not in eng.seats and 1 in eng.seats
    snap = {pos: {k: np.asarray(eng.cache[pos][k])[:, 0, :32].copy()
                  for k in ("k", "v") if k in eng.cache[pos]}
            for pos in eng.cache}
    for _ in range(4):
        eng.step()
    for pos, ent in snap.items():
        for k, before in ent.items():
            after = np.asarray(eng.cache[pos][k])[:, 0, :32]
            assert np.array_equal(before, after), (pos, k)
