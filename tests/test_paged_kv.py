"""Paged KV serving subsystem: block-manager invariants, the paged
decode-attention kernel vs its references, and token-exact equivalence of
the paged engine against the dense fixed-slot engine."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.kernels import ops
from repro.kernels.decode_attention import paged_decode_attention_pallas
from repro.kernels.ref import decode_attention_ref, paged_decode_attention_ref
from repro.models import model as M
from repro.runtime.paged_kv import BlockManager
from repro.runtime.serving import PagedServingEngine, ServingEngine


# -- block manager -----------------------------------------------------------

def test_block_manager_no_page_shared_and_scratch_reserved():
    bm = BlockManager(num_pages=8, page_size=16)
    a = bm.alloc(3, rid=0)
    b = bm.alloc(4, rid=1)
    assert a is not None and b is not None
    assert 0 not in a + b                       # scratch page never allocated
    assert len(set(a) | set(b)) == 7            # disjoint ownership
    assert bm.owner(a[0]) == 0 and bm.owner(b[0]) == 1
    assert bm.available == 0


def test_block_manager_alloc_failure_returns_none():
    bm = BlockManager(num_pages=4, page_size=16)
    assert bm.alloc(4, rid=0) is None           # only 3 usable pages
    assert bm.available == 3                    # failed alloc takes nothing
    got = bm.alloc(3, rid=0)
    assert got is not None and bm.alloc(1, rid=1) is None


def test_block_manager_free_cycle_and_double_free():
    bm = BlockManager(num_pages=6, page_size=8)
    pages = bm.alloc(5, rid=7)
    bm.free(pages)
    assert bm.available == bm.capacity == 5
    with pytest.raises(ValueError):
        bm.free(pages[:1])                      # double free
    assert bm.peak_in_use == 5


def test_pages_needed_rounding():
    bm = BlockManager(num_pages=8, page_size=16)
    assert bm.pages_needed(1) == 1
    assert bm.pages_needed(16) == 1
    assert bm.pages_needed(17) == 2


# -- kernel vs references ----------------------------------------------------

def test_paged_kernel_matches_refs():
    rng = np.random.default_rng(0)
    BH, d, P, page, n = 6, 32, 16, 8, 4
    q = jnp.asarray(rng.normal(size=(BH, d)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(P, page, d)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(P, page, d)), jnp.float32)
    pt = np.zeros((BH, n), np.int32)
    lengths = rng.integers(1, n * page, size=(BH,)).astype(np.int32)
    avail = list(range(1, P))
    for b in range(BH):
        for i in range(-(-int(lengths[b]) // page)):
            pt[b, i] = avail.pop()
    out = paged_decode_attention_pallas(q, kp, vp, jnp.asarray(pt),
                                        jnp.asarray(lengths), interpret=True)
    ref = paged_decode_attention_ref(q, kp, vp, jnp.asarray(pt),
                                     jnp.asarray(lengths))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-6)
    # the paged ref itself must equal dense decode on the gathered cache
    k = np.asarray(kp)[pt].reshape(BH, -1, d)
    v = np.asarray(vp)[pt].reshape(BH, -1, d)
    dense = decode_attention_ref(q, jnp.asarray(k), jnp.asarray(v),
                                 jnp.asarray(lengths))
    np.testing.assert_allclose(np.asarray(ref), np.asarray(dense), atol=1e-6)


def test_paged_ops_wrapper_gqa_expansion():
    rng = np.random.default_rng(1)
    B, H, KVH, d, P, page, n = 3, 4, 2, 16, 12, 8, 3
    q = jnp.asarray(rng.normal(size=(B, 1, H, d)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(P, page, KVH, d)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(P, page, KVH, d)), jnp.float32)
    pt = np.zeros((B, n), np.int32)
    lengths = rng.integers(1, n * page, size=(B,)).astype(np.int32)
    avail = list(range(1, P))
    for b in range(B):
        for i in range(-(-int(lengths[b]) // page)):
            pt[b, i] = avail.pop()
    out = ops.paged_decode_attention(q, kp, vp, jnp.asarray(pt),
                                     jnp.asarray(lengths))
    rep = H // KVH
    for h in range(H):
        kk = np.asarray(kp)[:, :, h // rep][pt].reshape(B, -1, d)
        vv = np.asarray(vp)[:, :, h // rep][pt].reshape(B, -1, d)
        ref = decode_attention_ref(q[:, 0, h], jnp.asarray(kk),
                                   jnp.asarray(vv), jnp.asarray(lengths))
        np.testing.assert_allclose(np.asarray(out[:, 0, h]), np.asarray(ref),
                                   atol=2e-6)


# -- engine equivalence ------------------------------------------------------

@pytest.fixture(scope="module")
def engine_setup():
    cfg = reduced_config(get_config("qwen3-1.7b"))
    params = M.init_params(M.param_specs(cfg), jax.random.PRNGKey(0))
    return cfg, params


def test_paged_matches_fixed_slot_tokens(engine_setup):
    """Paged decode (through chunked prefill + page-table gather) must be
    token-identical to the dense fixed-slot engine on the same request."""
    cfg, params = engine_setup
    prompt = np.arange(7, dtype=np.int32) % cfg.vocab_size
    fixed = ServingEngine(cfg, params, slots=2, max_len=32)
    fixed.submit(prompt, max_new_tokens=5)
    want = fixed.run()[0].generated

    eng = PagedServingEngine(cfg, params, page_size=8, num_pages=16,
                             max_seats=2, max_seq_len=32, prefill_chunk=4)
    eng.submit(prompt, max_new_tokens=5)
    done = eng.run()
    assert len(done) == 1
    assert done[0].generated == want


def test_paged_random_prompts_match_fixed(engine_setup):
    """Token-exact equivalence on a batch of random prompts served
    concurrently (mixed lengths, seat contention)."""
    cfg, params = engine_setup
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab_size, int(n)).astype(np.int32)
               for n in rng.integers(3, 20, size=5)]
    gens = [int(g) for g in rng.integers(2, 7, size=5)]

    want = {}
    for i, (p, g) in enumerate(zip(prompts, gens)):
        solo = ServingEngine(cfg, params, slots=1, max_len=32)
        solo.submit(p, max_new_tokens=g)
        want[i] = solo.run()[0].generated

    eng = PagedServingEngine(cfg, params, page_size=8, num_pages=24,
                             max_seats=3, max_seq_len=32, prefill_chunk=8)
    rid_to_i = {eng.submit(p, max_new_tokens=g): i
                for i, (p, g) in enumerate(zip(prompts, gens))}
    done = eng.run()
    assert len(done) == 5
    for r in done:
        assert r.generated == want[rid_to_i[r.rid]], r.rid


def test_engine_pallas_impl_matches_jnp(engine_setup):
    """The kernel decode path (interpret mode on CPU) produces the same
    greedy tokens as the jnp gather path through the full engine."""
    cfg, params = engine_setup
    prompt = np.arange(5, dtype=np.int32) % cfg.vocab_size
    outs = {}
    for impl in ("jnp", "pallas"):
        eng = PagedServingEngine(
            cfg, params, page_size=8, num_pages=8, max_seats=1,
            max_seq_len=16, prefill_chunk=8,
            opts=M.RunOptions(q_chunk=16, paged_attn_impl=impl))
        eng.submit(prompt, max_new_tokens=3)
        outs[impl] = eng.run()[0].generated
    assert outs["pallas"] == outs["jnp"]


def test_no_page_shared_across_live_requests(engine_setup):
    """While requests are in flight, page-table rows of distinct seats
    never name the same physical page (and never the scratch page)."""
    cfg, params = engine_setup
    eng = PagedServingEngine(cfg, params, page_size=8, num_pages=12,
                             max_seats=3, max_seq_len=32, prefill_chunk=8)
    rng = np.random.default_rng(5)
    for i in range(6):
        eng.submit(rng.integers(0, cfg.vocab_size, 5 + i).astype(np.int32),
                   max_new_tokens=3)
    saw_live = False
    while eng.queue or eng.seats:
        eng.step()
        live = [pg for r in eng.seats.values() for pg in r.pages]
        assert 0 not in live
        assert len(live) == len(set(live)), "page shared across requests"
        saw_live = saw_live or len(eng.seats) > 1
    assert saw_live                       # the assertion above actually bit


def test_pages_freed_on_completion_and_queueing_not_crashing(engine_setup):
    """A pool too small for the whole workload queues requests (no crash),
    serves everyone eventually, and ends with every page back in the pool."""
    cfg, params = engine_setup
    eng = PagedServingEngine(cfg, params, page_size=8, num_pages=7,
                             max_seats=4, max_seq_len=32, prefill_chunk=8)
    rng = np.random.default_rng(7)
    rids = [eng.submit(rng.integers(0, cfg.vocab_size, 12).astype(np.int32),
                       max_new_tokens=4) for _ in range(5)]
    # 5 requests x 2 pages each > 6 usable pages: someone must wait
    waited = False
    while eng.queue or eng.seats:
        eng.step()
        waited = waited or (len(eng.queue) > 0 and len(eng.seats) > 0)
    assert waited
    assert sorted(r.rid for r in eng.finished) == sorted(rids)
    assert eng.bm.in_use == 0
    assert eng.bm.available == eng.bm.capacity
    assert np.all(eng.page_table == 0)


def test_oversized_request_rejected(engine_setup):
    cfg, params = engine_setup
    # up-front reservation: per-request max_seq_len bound AND pool check
    eng = PagedServingEngine(cfg, params, page_size=8, num_pages=4,
                             max_seats=2, max_seq_len=40, lazy_pages=False)
    with pytest.raises(ValueError):
        eng.submit(np.arange(44, dtype=np.int32), max_new_tokens=4)  # > max_seq_len
    with pytest.raises(ValueError):
        eng.submit(np.arange(28, dtype=np.int32), max_new_tokens=4)  # > pool
    # lazy growth: max_seq_len is the only per-request bound — a pool too
    # small to cover one max-length request is rejected at construction
    with pytest.raises(ValueError):
        PagedServingEngine(cfg, params, page_size=8, num_pages=4,
                           max_seats=2, max_seq_len=40)
    lazy = PagedServingEngine(cfg, params, page_size=8, num_pages=6,
                              max_seats=2, max_seq_len=40)
    with pytest.raises(ValueError):
        lazy.submit(np.arange(44, dtype=np.int32), max_new_tokens=4)
    with pytest.raises(ValueError):
        PagedServingEngine(reduced_config(get_config("mamba2-130m")),
                           params, page_size=8, num_pages=4)  # ssm: unsupported
