"""Launcher env detection + bootstrap guard tests."""
import os

import pytest

from repro.runtime.launcher import ClusterEnv, bootstrap, detect_cluster


@pytest.fixture
def clean_env(monkeypatch):
    for k in ("SLURM_JOB_ID", "SLURM_NTASKS", "SLURM_PROCID",
              "SLURM_NODELIST", "JAX_COORDINATOR", "JAX_NUM_PROCESSES",
              "JAX_PROCESS_ID"):
        monkeypatch.delenv(k, raising=False)
    return monkeypatch


def test_detect_local(clean_env):
    c = detect_cluster()
    assert not c.is_distributed
    assert c.num_processes == 1


def test_detect_slurm(clean_env):
    clean_env.setenv("SLURM_JOB_ID", "42")
    clean_env.setenv("SLURM_NTASKS", "64")
    clean_env.setenv("SLURM_PROCID", "7")
    clean_env.setenv("SLURM_NODELIST", "node001,node002")
    c = detect_cluster()
    assert c.is_distributed and c.num_processes == 64 and c.process_id == 7
    assert c.coordinator.startswith("node001")


def test_detect_jax_env(clean_env):
    clean_env.setenv("JAX_COORDINATOR", "10.0.0.1:1234")
    clean_env.setenv("JAX_NUM_PROCESSES", "4")
    clean_env.setenv("JAX_PROCESS_ID", "2")
    c = detect_cluster()
    assert c.coordinator == "10.0.0.1:1234"
    assert (c.num_processes, c.process_id) == (4, 2)


def test_bootstrap_local_mesh(clean_env):
    mesh, cluster = bootstrap()
    assert not cluster.is_distributed
    assert mesh.size >= 1


def test_bootstrap_fleet_guard(clean_env):
    with pytest.raises(RuntimeError, match="elastic"):
        bootstrap(require_chips=512)
