"""Refcounted prefix-aware BlockManager + engine-level prefix caching:
refcount invariants and double-free protection over random admit/release
schedules, prefix match/register semantics, LRU eviction, live page
sharing across seats, copy-on-write token-exactness (caching on vs off),
and fuzzed admit/grow/preempt/finish schedules under a tiny pool."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import get_config, reduced_config
from repro.models import model as M
from repro.runtime.paged_kv import BlockManager
from repro.runtime.serving import PagedServingEngine


# -- refcounting --------------------------------------------------------------

def test_refcount_share_release_and_double_free():
    bm = BlockManager(num_pages=6, page_size=4)
    (pg,) = bm.alloc(1, rid=0)
    bm.acquire(pg, rid=1)
    bm.acquire(pg, rid=2)
    assert bm.refcount(pg) == 3
    bm.free([pg])
    bm.free([pg])
    assert bm.refcount(pg) == 1 and bm.in_use == 1
    bm.free([pg])
    assert bm.refcount(pg) == 0 and bm.in_use == 0
    with pytest.raises(ValueError):
        bm.free([pg])                           # double free
    with pytest.raises(ValueError):
        bm.acquire(pg)                          # not live, not cached


def test_registered_page_parks_reclaimable_and_revives():
    bm = BlockManager(num_pages=4, page_size=2)
    (pg,) = bm.alloc(1, rid=0)
    bm.register_prefix([5, 6], pg)
    bm.free([pg])
    # refcount 0 but registered: reclaimable, still allocatable capacity
    assert bm.in_use == 0 and bm.cached == 1
    assert bm.available == bm.capacity == 3
    m = bm.match_prefix([5, 6, 7])
    assert m.pages == [pg] and m.n_cached == 2
    bm.acquire(pg, rid=1)                       # prefix hit revives it
    assert bm.refcount(pg) == 1 and bm.cached == 0


def test_lru_eviction_under_pressure_unregisters():
    bm = BlockManager(num_pages=4, page_size=2)
    pages = bm.alloc(3, rid=0)
    for i, pg in enumerate(pages):
        bm.register_prefix([10 + i] * 2, pg)    # three distinct 1-page chains
    bm.free([pages[1]])                         # reclaim order: 1, 0, 2
    bm.free([pages[0]])
    bm.free([pages[2]])
    got = bm.alloc(2, rid=1)                    # evicts LRU pages 1 then 0
    assert got == [pages[1], pages[0]]
    assert bm.evictions == 2
    assert bm.match_prefix([11, 11, 0]).pages == []      # evicted chain gone
    assert bm.match_prefix([12, 12, 0]).pages == [pages[2]]  # survivor intact


def test_match_prefix_full_partial_and_last_token_cap():
    bm = BlockManager(num_pages=8, page_size=4)
    p0, p1 = bm.alloc(2, rid=0)
    prompt = list(range(100, 108))              # two full pages
    bm.register_prefix(prompt[:4], p0)
    bm.register_prefix(prompt[:8], p1)

    # full-page match capped at len-1: an identical prompt reuses page 0
    # fully but page 1 only as a copy-on-write partial (last token always
    # recomputed so admission has logits to sample from)
    m = bm.match_prefix(prompt)
    assert m.pages == [p0] and m.cow_src == p1 and m.n_cached == 7
    # longer prompt: both pages shared outright
    m = bm.match_prefix(prompt + [9, 9, 9])
    assert m.pages == [p0, p1] and m.cow_src is None and m.n_cached == 8
    # divergence mid-page-2: partial cow match of the common run
    m = bm.match_prefix(prompt[:6] + [55, 55, 55])
    assert m.pages == [p0] and m.cow_src == p1 and m.n_cached == 6
    # divergence in page 1: only the chain head matches
    m = bm.match_prefix(prompt[:4] + [55, 55, 55, 55, 55])
    assert m.pages == [p0] and m.cow_src is None and m.n_cached == 4
    # cold prompt: nothing
    m = bm.match_prefix([1, 2, 3, 4, 5])
    assert m.pages == [] and m.cow_src is None and m.n_cached == 0


def test_register_is_idempotent_and_one_chain_per_page():
    bm = BlockManager(num_pages=4, page_size=2)
    a, b = bm.alloc(2, rid=0)
    bm.register_prefix([1, 2], a)
    bm.register_prefix([1, 2], b)               # chain slot taken: no-op
    assert bm.match_prefix([1, 2, 0]).pages == [a]
    bm.register_prefix([3, 4], a)               # page already indexed: no-op
    assert bm.match_prefix([3, 4, 0]).pages == []


def test_random_schedules_refcount_invariants():
    """Property-style: random interleavings of alloc/grow/acquire/release
    with registration never violate the page-conservation invariants."""
    for seed in range(8):
        rng = np.random.default_rng(seed)
        bm = BlockManager(num_pages=10, page_size=2)
        shadow = {}                              # page -> expected refcount
        next_tok = [0]
        for _ in range(300):
            op = rng.choice(["alloc", "grow", "acquire", "release",
                             "register"])
            if op == "grow":
                pg = bm.try_grow(rid=0)
                if pg is None:
                    assert bm.available == 0
                else:
                    assert shadow.get(pg, 0) == 0
                    shadow[pg] = 1
            elif op == "alloc":
                n = int(rng.integers(1, 4))
                pages = bm.alloc(n, rid=0)
                if pages is None:
                    assert bm.available < n
                else:
                    for pg in pages:
                        assert shadow.get(pg, 0) == 0
                        shadow[pg] = 1
            elif op == "acquire" and bm.in_use:
                live = [p for p, r in shadow.items() if r > 0]
                pg = int(rng.choice(live))
                bm.acquire(pg)
                shadow[pg] += 1
            elif op == "release" and bm.in_use:
                live = [p for p, r in shadow.items() if r > 0]
                pg = int(rng.choice(live))
                bm.free([pg])
                shadow[pg] -= 1
            elif op == "register" and bm.in_use:
                live = [p for p, r in shadow.items() if r > 0]
                pg = int(rng.choice(live))
                next_tok[0] += 2
                bm.register_prefix([next_tok[0], next_tok[0] + 1], pg)
            # conservation: live + free + reclaimable == capacity
            assert bm.in_use + bm.available == bm.capacity
            assert bm.in_use == sum(1 for r in shadow.values() if r > 0)
            for pg, r in shadow.items():
                assert bm.refcount(pg) == r
            # releasing a dead page always raises
            dead = [p for p, r in shadow.items() if r == 0]
            if dead:
                with pytest.raises(ValueError):
                    bm.free([int(rng.choice(dead))])
        # drain: everything returns to allocatable state
        for pg in [p for p, r in shadow.items() if r > 0]:
            for _ in range(shadow[pg]):
                bm.free([pg])
        assert bm.in_use == 0 and bm.available == bm.capacity


# -- engine-level prefix caching ----------------------------------------------

@pytest.fixture(scope="module")
def engine_setup():
    cfg = reduced_config(get_config("qwen3-1.7b"))
    params = M.init_params(M.param_specs(cfg), jax.random.PRNGKey(0))
    return cfg, params


def _run_workload(cfg, params, reqs, *, prefix_cache, stagger=True, **kw):
    eng = PagedServingEngine(cfg, params, prefix_cache=prefix_cache, **kw)
    outs = {}
    for prompt, gen in reqs:
        rid = eng.submit(prompt, max_new_tokens=gen)
        outs[rid] = None
        if stagger:                 # let earlier requests publish pages
            for _ in range(3):
                eng.step()
    done = eng.run()
    for r in done:
        outs[r.rid] = r.generated
    return eng, outs


def test_shared_prefix_pages_shared_live_and_cow(engine_setup):
    """A request whose prompt repeats an already-prefilled prompt shares
    the full prefix pages (same physical pages, refcount 2) and owns a
    copy-on-write page for the final partial page."""
    cfg, params = engine_setup
    eng = PagedServingEngine(cfg, params, page_size=4, num_pages=16,
                             max_seats=2, max_seq_len=24, prefill_chunk=4)
    prompt = (np.arange(12, dtype=np.int32) * 5) % cfg.vocab_size
    eng.submit(prompt, max_new_tokens=8)
    for _ in range(4):                  # prefill all 12 tokens -> 3 pages
        eng.step()
    a = eng.seats[0]
    assert a.prefill_pos == 12 and a.registered_pages == 3

    eng.submit(prompt, max_new_tokens=8)
    eng.step()                          # admit the twin
    b = eng.seats[1]
    # full pages 0,1 shared; page 2 is a CoW copy (last token recomputed)
    assert b.pages[:2] == a.pages[:2]
    assert b.pages[2] != a.pages[2]
    assert b.cached_tokens == 11
    for pg in a.pages[:2]:
        assert eng.bm.refcount(pg) == 2
    assert eng.bm.refcount(a.pages[2]) == 1
    assert ("prefix_hit" in {k for (_, k, r) in eng.trace if r == b.rid})

    done = eng.run()
    assert eng.bm.in_use == 0
    assert eng.bm.available == eng.bm.capacity
    # identical prompts + greedy => identical outputs, via different pages
    gens = {r.rid: r.generated for r in done}
    assert gens[0] == gens[1]


def test_prefix_cache_token_identical_on_vs_off(engine_setup):
    """Copy-on-write correctness: a workload with heavy prefix overlap
    (including a full-prompt repeat) generates token-identical outputs
    with caching on and off."""
    cfg, params = engine_setup
    rng = np.random.default_rng(17)
    base = rng.integers(0, cfg.vocab_size, 16).astype(np.int32)
    reqs = [
        (base, 5),
        (np.concatenate([base, rng.integers(0, cfg.vocab_size, 5).astype(np.int32)]), 4),
        (base.copy(), 5),                         # exact repeat
        (np.concatenate([base[:8], rng.integers(0, cfg.vocab_size, 3).astype(np.int32)]), 3),
        (rng.integers(0, cfg.vocab_size, 7).astype(np.int32), 4),  # cold
    ]
    kw = dict(page_size=8, num_pages=24, max_seats=3, max_seq_len=32,
              prefill_chunk=8)
    eng_on, on = _run_workload(cfg, params, reqs, prefix_cache=True, **kw)
    _, off = _run_workload(cfg, params, reqs, prefix_cache=False, **kw)
    assert on == off
    m = eng_on.metrics.snapshot()
    assert m["cached_prompt_tokens"] > 0
    assert 0 < m["prefix_hit_rate"] < 1
    # every prompt token was either prefilled or served from cache
    total_prompt = sum(len(p) for p, _ in reqs)
    assert m["prefill_tokens"] + m["cached_prompt_tokens"] == total_prompt


def test_prefix_cache_skips_prefill_work(engine_setup):
    """The cached run prefills strictly fewer tokens and emits
    prefix_hit trace events for the repeat requests."""
    cfg, params = engine_setup
    prompt = (np.arange(17, dtype=np.int32) * 3) % cfg.vocab_size
    reqs = [(prompt, 3)] * 4
    kw = dict(page_size=8, num_pages=32, max_seats=2, max_seq_len=32,
              prefill_chunk=8)
    eng_on, _ = _run_workload(cfg, params, reqs, prefix_cache=True, **kw)
    eng_off, _ = _run_workload(cfg, params, reqs, prefix_cache=False, **kw)
    assert eng_on.metrics.prefill_tokens < eng_off.metrics.prefill_tokens
    hits = [r for (_, k, r) in eng_on.trace if k == "prefix_hit"]
    assert len(hits) == 3                        # every repeat after the first


def test_eviction_pressure_keeps_outputs_exact(engine_setup):
    """A pool too small to retain every cached prefix evicts LRU cached
    pages, still completes everyone, and outputs match caching-off."""
    cfg, params = engine_setup
    rng = np.random.default_rng(23)
    prompts = [rng.integers(0, cfg.vocab_size, 12).astype(np.int32)
               for _ in range(3)]
    # revisit each prompt twice, interleaved, under a tiny page budget
    reqs = [(prompts[i % 3], 3) for i in range(6)]
    kw = dict(page_size=4, num_pages=9, max_seats=2, max_seq_len=20,
              prefill_chunk=4)
    eng_on, on = _run_workload(cfg, params, reqs, prefix_cache=True, **kw)
    _, off = _run_workload(cfg, params, reqs, prefix_cache=False, **kw)
    assert on == off
    assert eng_on.bm.in_use == 0
    assert eng_on.bm.available == eng_on.bm.capacity
    m = eng_on.metrics.snapshot()
    assert m["evictions"] == eng_on.bm.evictions > 0   # pressure surfaced
    assert m["kv_occupancy"] >= m["page_utilization"]
    # failed admissions must not inflate the live-page high-water mark
    assert eng_on.bm.peak_in_use <= eng_on.bm.capacity


# -- copy-on-write page copy --------------------------------------------------

def test_copy_paged_page_guards_self_copy():
    """src == dst must be a no-op (callers jit with the pool donated; an
    aliased self-copy must not read the buffer it overwrites)."""
    cache = {"pos0": {"k": jnp.arange(48.0).reshape(2, 3, 2, 2, 2),
                      "v": jnp.arange(48.0).reshape(2, 3, 2, 2, 2) + 100}}
    same = M.copy_paged_page(cache, 1, 1)
    assert all(np.array_equal(same["pos0"][k], cache["pos0"][k])
               for k in ("k", "v"))
    out = M.copy_paged_page(cache, 1, 2)
    for k in ("k", "v"):
        got = np.asarray(out["pos0"][k])
        want = np.asarray(cache["pos0"][k])
        assert np.array_equal(got[:, 2], want[:, 1])     # copied
        assert np.array_equal(got[:, :2], want[:, :2])   # rest untouched


# -- fuzzed admit/grow/preempt/finish schedules -------------------------------

def _assert_block_invariants(eng):
    """Page conservation under the fuzz: every usable page is in exactly
    one of {live, reclaimable, free}, the scratch page is never handed
    out, and each page's refcount equals the number of live page-table
    references to it."""
    bm = eng.bm
    live, reclaim, free = set(bm._ref), set(bm._reclaim), set(bm._free)
    assert not (live & reclaim) and not (live & free) and not (reclaim & free)
    assert 0 not in (live | reclaim | free)
    assert len(live) + len(reclaim) + len(free) == bm.capacity
    refs = {}
    for r in eng.seats.values():
        for pg in r.pages:
            refs[pg] = refs.get(pg, 0) + 1
    assert refs == dict(bm._ref)
    for r in eng.seats.values():                 # table rows name the pages
        row = eng.page_table[r.slot]
        assert list(row[:len(r.pages)]) == r.pages


def _fuzz_requests(cfg, seed, n=6):
    """Mixed stream with prefix overlap: some prompts repeat a base run
    (exercising shares + CoW under churn), some are cold."""
    rng = np.random.default_rng(seed)
    bases = [((np.arange(12, dtype=np.int32) * m + 1) % cfg.vocab_size)
             for m in (3, 7)]
    reqs = []
    for _ in range(n):
        plen = int(rng.integers(2, 13))
        if rng.random() < 0.5:
            prompt = bases[int(rng.integers(0, 2))][:plen].copy()
        else:
            prompt = rng.integers(0, cfg.vocab_size, plen).astype(np.int32)
        reqs.append((prompt, int(rng.integers(1, 9))))
    return reqs


def _fuzz_one(cfg, params, seed):
    reqs = _fuzz_requests(cfg, seed)
    big = PagedServingEngine(cfg, params, page_size=4, num_pages=64,
                             max_seats=len(reqs), max_seq_len=24,
                             prefill_chunk=4)
    for p, g in reqs:
        big.submit(p, max_new_tokens=g)
    ref = {r.rid: r.generated for r in big.run()}

    eng = PagedServingEngine(cfg, params, page_size=4, num_pages=8,
                             max_seats=3, max_seq_len=24, prefill_chunk=4)
    rng = np.random.default_rng(seed ^ 0x5EED)
    pending = list(reqs)
    steps = 0
    while pending or eng.queue or eng.seats:
        if pending and rng.random() < 0.4:
            p, g = pending.pop(0)
            eng.submit(p, max_new_tokens=g)
        eng.step()
        _assert_block_invariants(eng)
        steps += 1
        assert steps < 2000, "fuzz schedule failed to drain"
    out = {r.rid: r.generated for r in eng.finished}
    # every request — preempted ones included — matches the uncontended
    # run token for token
    assert out == ref
    assert eng.bm.in_use == 0 and eng.bm.available == eng.bm.capacity
    return eng


@pytest.fixture(scope="module")
def fuzz_setup(engine_setup):
    return engine_setup


@pytest.mark.parametrize("seed", [0, 4])
def test_fuzz_schedules_fixed_seeds(fuzz_setup, seed):
    cfg, params = fuzz_setup
    eng = _fuzz_one(cfg, params, seed)
    if seed == 4:                # deterministic: this schedule preempts
        assert eng.metrics.preemptions >= 1


@pytest.mark.slow
@settings(max_examples=3, deadline=None)
@given(seed=st.integers(0, 2 ** 20))
def test_fuzz_schedules_hypothesis(fuzz_setup, seed):
    cfg, params = fuzz_setup
    _fuzz_one(cfg, params, seed)
