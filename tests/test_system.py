"""End-to-end system tests: the train driver trains (loss ↓), checkpoints
restart exactly, the serve driver generates, mixed-precision training path
runs, and the paper's headline claims hold in miniature."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.train import train
from repro.launch.serve import serve


@pytest.fixture(scope="module")
def trained(tmp_path_factory):
    ck = str(tmp_path_factory.mktemp("ckpt"))
    losses = train("qwen3-1.7b", steps=30, batch=8, seq=64, reduced=True,
                   ckpt_dir=ck, ckpt_every=10, log_every=1000,
                   lr_peak=3e-3, total_steps=300)
    return ck, losses


def test_training_reduces_loss(trained):
    _, losses = trained
    first = np.mean(losses[:5])
    last = np.mean(losses[-5:])
    assert last < first - 0.05, (first, last)


def test_restart_continues_from_checkpoint(trained):
    ck, losses = trained
    more = train("qwen3-1.7b", steps=33, batch=8, seq=64, reduced=True,
                 ckpt_dir=ck, ckpt_every=100, log_every=1000,
                 lr_peak=3e-3, total_steps=300)
    # resumed run only covers steps 30..32
    assert len(more) == 3
    assert np.isfinite(more).all()
    assert np.mean(more) < np.mean(losses[:5])


def test_injected_failure_then_recovery(tmp_path):
    """Crash mid-run, restart, and the stream replays deterministically."""
    ck = str(tmp_path / "ck")
    with pytest.raises(RuntimeError, match="injected failure"):
        train("mamba2-130m", steps=20, batch=4, seq=64, reduced=True,
              ckpt_dir=ck, ckpt_every=5, fail_at_step=12, log_every=1000)
    # recovery resumes from the last committed step (10), not zero
    losses = train("mamba2-130m", steps=14, batch=4, seq=64, reduced=True,
                   ckpt_dir=ck, ckpt_every=100, log_every=1000)
    assert len(losses) == 4                     # steps 10..13
    assert np.isfinite(losses).all()


def test_serve_generates_tokens():
    r = serve("qwen3-1.7b", batch=2, prompt_len=16, gen=8)
    gen = np.asarray(r["generated"])
    assert gen.shape == (2, 8)
    assert (gen >= 0).all()
    assert r["tokens_per_s"] > 0


def test_serve_greedy_deterministic():
    r1 = serve("llama3-8b", batch=2, prompt_len=12, gen=6, seed=3)
    r2 = serve("llama3-8b", batch=2, prompt_len=12, gen=6, seed=3)
    assert np.array_equal(np.asarray(r1["generated"]),
                          np.asarray(r2["generated"]))


def test_tuning_preset_env(tmp_path):
    """build_tuning_env is pure, idempotent, and append-only: tcmalloc
    joins (never clobbers) LD_PRELOAD, XLA flags join XLA_FLAGS, a
    missing tcmalloc library degrades to the XLA flags alone, and an
    already-tuned environment gets no additions."""
    from repro.launch.serve import build_tuning_env
    lib = tmp_path / "libtcmalloc.so.4"
    lib.write_bytes(b"")

    assert build_tuning_env("off", {}) == {}
    with pytest.raises(ValueError, match="preset"):
        build_tuning_env("warp-speed", {})

    add = build_tuning_env("alloc", {}, tcmalloc_path=str(lib))
    assert add["LD_PRELOAD"] == str(lib)
    assert "XLA_FLAGS" not in add

    add = build_tuning_env("full", {"LD_PRELOAD": "/other.so",
                                    "XLA_FLAGS": "--xla_foo=2"},
                           tcmalloc_path=str(lib))
    assert add["LD_PRELOAD"] == f"/other.so:{lib}"
    assert "--xla_foo=2" in add["XLA_FLAGS"]
    assert ("--xla_step_marker_location=STEP_MARK_AT_TOP_LEVEL_WHILE_LOOP"
            in add["XLA_FLAGS"])
    assert "--xla_force_host_platform_device_count=1" in add["XLA_FLAGS"]

    # no tcmalloc on disk: alloc adds nothing, full still tunes XLA
    assert build_tuning_env("alloc", {},
                            tcmalloc_path=str(tmp_path / "nope.so")) == {}
    add = build_tuning_env("full", {},
                           tcmalloc_path=str(tmp_path / "nope.so"))
    assert set(add) == {"XLA_FLAGS"}

    # idempotent against an environment the preset already shaped
    tuned = {"LD_PRELOAD": str(lib),
             "TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD": "60000000000",
             "XLA_FLAGS": ("--xla_step_marker_location="
                           "STEP_MARK_AT_TOP_LEVEL_WHILE_LOOP "
                           "--xla_force_host_platform_device_count=1")}
    assert build_tuning_env("full", tuned, tcmalloc_path=str(lib)) == {}


def test_paper_headline_lowprec_claim():
    """Table 9's structural claim in miniature: the FP8/bf16 LU does the
    same O(n³) factor work at lower precision and IR recovers an answer
    that passes the same validation gate as full-precision HPL.  (The
    paper's 10× wall-clock win needs FP8 compute units; timing is NOT
    asserted on CPU — see benchmarks/run.py table9 note.)"""
    from repro.core.hplmxp import run_hplmxp
    from repro.core.hpl import run_hpl
    hpl = run_hpl(256, 64)
    mxp = run_hplmxp(256, 64, lowprec="bf16", ir_iters=6)
    assert hpl["passed"] and mxp["passed"]
    # refinement monotone-ish: final residual <= first
    assert mxp["ir_history"][-1] <= mxp["ir_history"][0]
    # IR work is O(n²)/iter vs O(n³) factorization: at the paper's scale
    # (Table 9, N=2,989,056) refinement is noise — structural check
    n_paper = 2_989_056
    ir_flops = 6 * 3 * 2 * n_paper ** 2   # iters × (matvec + 2 tri-solves)
    assert ir_flops < (2 / 3) * n_paper ** 3 * 1e-3
