"""Optional-`hypothesis` shim.

Test modules do ``from _hypothesis_compat import given, settings, st``
instead of importing hypothesis directly.  When hypothesis is installed
these are the real objects; when it is not, ``@given(...)`` marks the
test skipped (and ``st``/``settings`` become inert stand-ins), so the
module still collects and its non-property tests still run.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    import pytest

    HAVE_HYPOTHESIS = False

    class _Inert:
        """Absorbs any strategy-building expression (st.integers(0, 5)...)."""

        def __getattr__(self, name):
            return _Inert()

        def __call__(self, *args, **kwargs):
            return _Inert()

    st = _Inert()

    def given(*args, **kwargs):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)
        return deco

    def settings(*args, **kwargs):
        def deco(fn):
            return fn
        return deco
