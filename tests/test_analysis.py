"""repro-lint (src/repro/analysis): per-rule fixtures + repo self-check.

Each rule gets a positive fixture (fires), a negative fixture (stays
quiet on the idiomatic pattern), and a suppressed fixture (inline
pragma silences it).  The self-check at the bottom runs the real
analyzer over the real repo with the committed manifest and baseline —
tier-1 itself enforces lint-cleanliness, not just the CI lint job.
"""
import json
import textwrap
from pathlib import Path

import pytest

from repro.analysis import baseline as baseline_mod
from repro.analysis.docscheck import check_docs
from repro.analysis.engine import analyze_source, analyze_paths
from repro.analysis.manifest import (Manifest, ModuleDecl, load_manifest,
                                     parse_toml_subset)
from repro.analysis.rules import get_rules, rule_ids


def make_manifest(hot=(), traced=(), host_state=(), producers=()):
    decl = ModuleDecl(file="fix.py", hot=tuple(hot), traced=tuple(traced),
                      host_state=tuple(host_state))
    return Manifest(modules={"fix.py": decl},
                    device_producers=tuple(producers))


def run(src, manifest=None, rules=None):
    src = textwrap.dedent(src)
    manifest = manifest or make_manifest()
    only = get_rules(set(rules)) if rules else None
    return analyze_source(src, "fix.py", manifest, rules=only)


def rules_of(result):
    return [f.rule for f in result.findings]


# -- RL001: implicit transfers in hot paths ----------------------------------

class TestRL001:
    HOT = make_manifest(hot=["tick"], producers=["self._step"])

    def test_np_asarray_on_device_value_fires(self):
        res = run("""
            import jax.numpy as jnp
            import numpy as np
            def tick(self):
                logits = jnp.ones((4, 32000))
                host = np.asarray(logits)
                return host
        """, self.HOT)
        assert rules_of(res) == ["RL001"]
        assert "device->host" in res.findings[0].message

    def test_pr6_sample_decode_batch_full_matrix_pull_is_caught(self):
        # the exact PR 6 regression: _sample_decode_batch pulling the
        # whole (max_seats, vocab) logits matrix to host before
        # reducing, instead of gathering active rows on device
        res = run("""
            import numpy as np
            class Scheduler:
                def _sample_decode_batch(self, last_logits, seat_ids):
                    rows = np.asarray(last_logits)
                    return {s: int(np.argmax(rows[s])) for s in seat_ids}
        """, make_manifest(hot=["Scheduler._sample_decode_batch"]))
        assert rules_of(res) == ["RL001"]
        assert "np.asarray" in res.findings[0].message

    def test_int_on_device_scalar_fires(self):
        res = run("""
            import jax.numpy as jnp
            def tick(self):
                s = jnp.sum(jnp.ones(8))
                return int(s)
        """, self.HOT)
        assert rules_of(res) == ["RL001"]

    def test_item_and_iteration_fire(self):
        res = run("""
            import jax.numpy as jnp
            def tick(self):
                xs = jnp.arange(8)
                out = [xs.item()]
                for x in xs:
                    out.append(x)
                return out
        """, self.HOT)
        assert rules_of(res) == ["RL001", "RL001"]

    def test_per_call_host_to_device_upload_fires(self):
        res = run("""
            import jax.numpy as jnp
            import numpy as np
            def tick(self):
                tok = np.zeros((4, 1), np.int32)
                return self._step(jnp.asarray(tok))
        """, self.HOT)
        assert rules_of(res) == ["RL001"]
        assert "host->device" in res.findings[0].message

    def test_host_state_attr_upload_fires(self):
        res = run("""
            import jax.numpy as jnp
            def tick(self):
                return self._step(jnp.asarray(self.page_table))
        """, make_manifest(hot=["tick"], producers=["self._step"],
                           host_state=["self.page_table"]))
        assert rules_of(res) == ["RL001"]

    def test_host_to_host_asarray_is_quiet(self):
        res = run("""
            import numpy as np
            def tick(self):
                xs = np.zeros(8)
                return np.asarray(xs), int(xs[0]), [x for x in xs]
        """, self.HOT)
        assert rules_of(res) == []

    def test_outside_hot_path_is_quiet(self):
        res = run("""
            import jax.numpy as jnp
            import numpy as np
            def cold():
                return np.asarray(jnp.ones(8))
        """, self.HOT)
        assert rules_of(res) == []

    def test_suppression_pragma_silences(self):
        res = run("""
            import jax.numpy as jnp
            import numpy as np
            def tick(self):
                logits = jnp.ones((4, 8))
                return np.asarray(logits)  # repro-lint: disable=RL001
        """, self.HOT)
        assert rules_of(res) == []
        assert res.suppressed == 1


# -- RL002: retrace hazards --------------------------------------------------

class TestRL002:
    def test_scalar_into_jit_without_statics_fires(self):
        res = run("""
            import jax
            def compute(x): return x
            step = jax.jit(compute)
            def drive(xs):
                return step(xs, 3)
        """)
        assert "RL002" in rules_of(res)

    def test_shape_dependent_arg_fires(self):
        res = run("""
            import jax
            step = jax.jit(lambda x, n: x)
            def drive(xs):
                return step(xs, xs.shape[0])
        """)
        assert "RL002" in rules_of(res)

    def test_static_argnums_is_quiet(self):
        res = run("""
            import jax
            step = jax.jit(lambda x, n: x, static_argnums=(1,))
            def drive(xs):
                return step(xs, 3)
        """)
        assert rules_of(res) == []

    def test_partial_jit_static_argnames_is_quiet(self):
        res = run("""
            import functools
            import jax
            @functools.partial(jax.jit, static_argnames=("bk",))
            def kernel(x, bk=256):
                return x
            def drive(xs):
                return kernel(xs, bk=128)
        """)
        assert rules_of(res) == []

    def test_array_args_are_quiet(self):
        res = run("""
            import jax
            import jax.numpy as jnp
            step = jax.jit(lambda x, n: x)
            def drive(xs):
                return step(xs, jnp.asarray([3]))
        """)
        assert rules_of(res) == []

    def test_suppressed(self):
        res = run("""
            import jax
            step = jax.jit(lambda x, n: x)
            def drive(xs):
                return step(xs, 3)  # repro-lint: disable=RL002
        """)
        assert rules_of(res) == []


# -- RL003: donation-after-use -----------------------------------------------

class TestRL003:
    def test_read_after_donation_fires(self):
        res = run("""
            import jax
            cow = jax.jit(lambda pool, s, d: pool, donate_argnums=(0,))
            def grow(self, pool, s, d):
                fresh = cow(pool, s, d)
                return pool.sum() + fresh.sum()
        """)
        assert rules_of(res) == ["RL003"]
        assert "donated" in res.findings[0].message

    def test_rebind_before_use_is_quiet(self):
        res = run("""
            import jax
            cow = jax.jit(lambda pool, s, d: pool, donate_argnums=(0,))
            def grow(self, pool, s, d):
                pool = cow(pool, s, d)
                return pool.sum()
        """)
        assert rules_of(res) == []

    def test_self_attr_rebound_on_call_statement_is_quiet(self):
        # the serving idiom: self.cache = self._cow_fn(self.cache, ...)
        res = run("""
            import jax
            class P:
                def __init__(self, M):
                    self._cow_fn = jax.jit(M.copy, donate_argnums=(0,))
                def grow(self):
                    self.cache = self._cow_fn(self.cache, 0, 1)
                    return self.cache
        """, rules=["RL003"])
        assert rules_of(res) == []

    def test_conditional_donation_still_analyzed(self):
        # donate = (0,) if backend != "cpu" else () — must analyze
        # as-if-donated (the code has to be safe where donation is on)
        res = run("""
            import jax
            donate = (0,) if jax.default_backend() != "cpu" else ()
            cow = jax.jit(lambda pool: pool, donate_argnums=donate)
            def grow(pool):
                fresh = cow(pool)
                return pool.sum()
        """)
        assert rules_of(res) == ["RL003"]

    def test_suppressed(self):
        res = run("""
            import jax
            cow = jax.jit(lambda pool: pool, donate_argnums=(0,))
            def grow(pool):
                fresh = cow(pool)
                return pool.sum()  # repro-lint: disable=RL003
        """)
        assert rules_of(res) == []


# -- RL004: PRNG key reuse ---------------------------------------------------

class TestRL004:
    def test_same_key_two_consumers_fires(self):
        res = run("""
            import jax
            def init(key):
                a = jax.random.normal(key, (4,))
                b = jax.random.normal(key, (4,))
                return a + b
        """)
        assert rules_of(res) == ["RL004"]
        assert "reusing a key" in res.findings[0].message

    def test_split_keys_are_quiet(self):
        res = run("""
            import jax
            def init(key):
                k1, k2 = jax.random.split(key)
                a = jax.random.normal(k1, (4,))
                b = jax.random.normal(k2, (4,))
                return a + b
        """)
        assert rules_of(res) == []

    def test_split_subscripts_distinct_quiet_same_fires(self):
        res = run("""
            import jax
            def init(key):
                ks = jax.random.split(key, 3)
                a = jax.random.normal(ks[0], (4,))
                b = jax.random.normal(ks[1], (4,))
                c = jax.random.uniform(ks[1], (4,))
                return a + b + c
        """)
        assert rules_of(res) == ["RL004"]

    def test_fold_in_rebind_resets_lineage(self):
        res = run("""
            import jax
            def init(key):
                a = jax.random.normal(key, (4,))
                key = jax.random.fold_in(key, 1)
                b = jax.random.normal(key, (4,))
                return a + b
        """)
        assert rules_of(res) == []

    def test_loop_rebound_key_is_quiet(self):
        # the modules.py idiom: one key per layer from a split
        res = run("""
            import jax
            def init(key, shapes):
                out = []
                for k in jax.random.split(key, 4):
                    out.append(jax.random.normal(k, (4,)))
                return out
        """)
        assert rules_of(res) == []

    def test_suppressed(self):
        res = run("""
            import jax
            def init(key):
                a = jax.random.normal(key, (4,))
                b = jax.random.normal(key, (4,))  # repro-lint: disable=RL004
                return a + b
        """)
        assert rules_of(res) == []


# -- RL005: side effects under trace -----------------------------------------

class TestRL005:
    TRACED = make_manifest(traced=["step"])

    def test_print_in_manifest_traced_fn_fires(self):
        res = run("""
            def step(x):
                print("x =", x)
                return x * 2
        """, self.TRACED)
        assert rules_of(res) == ["RL005"]
        assert "jax.debug.print" in res.findings[0].message

    def test_print_in_jit_decorated_fn_fires(self):
        res = run("""
            import jax
            @jax.jit
            def step(x):
                print(x)
                return x
        """)
        assert rules_of(res) == ["RL005"]

    def test_clock_in_partial_jit_fn_fires(self):
        res = run("""
            import time
            from functools import partial
            import jax
            @partial(jax.jit, static_argnames=("n",))
            def step(x, n=1):
                t0 = time.perf_counter()
                return x, t0
        """)
        assert rules_of(res) == ["RL005"]

    def test_print_in_untraced_fn_is_quiet(self):
        res = run("""
            def host_loop(x):
                print("tick", x)
                return x
        """, self.TRACED)
        assert rules_of(res) == []

    def test_jax_debug_print_is_quiet(self):
        res = run("""
            import jax
            def step(x):
                jax.debug.print("x={}", x)
                return x
        """, self.TRACED)
        assert rules_of(res) == []

    def test_suppressed(self):
        res = run("""
            def step(x):
                print(x)  # repro-lint: disable=RL005
                return x
        """, self.TRACED)
        assert rules_of(res) == []


# -- RL006: structural ops on float8 -----------------------------------------

class TestRL006:
    def test_dynamic_gather_on_fp8_fires(self):
        res = run("""
            import jax.numpy as jnp
            def attend(pool, page_table):
                kq = pool.astype(jnp.float8_e4m3fn)
                return kq[page_table]
        """)
        assert rules_of(res) == ["RL006"]
        assert "uint8" in res.findings[0].message

    def test_dynamic_scatter_on_fp8_fires(self):
        res = run("""
            import jax.numpy as jnp
            def write(pool, idx, v):
                kq = pool.astype(jnp.float8_e4m3fn)
                return kq.at[idx].set(v)
        """)
        assert rules_of(res) == ["RL006"]

    def test_take_and_scan_carry_fire(self):
        res = run("""
            import jax
            import jax.numpy as jnp
            def roll(pool, idx, f):
                kq = jnp.zeros((4, 8), jnp.float8_e4m3fn)
                a = jnp.take(kq, idx, axis=0)
                out, _ = jax.lax.scan(f, kq, jnp.arange(4))
                return a, out
        """)
        assert rules_of(res) == ["RL006", "RL006"]

    def test_uint8_bit_pattern_idiom_is_quiet(self):
        # the PR 7 fix: bitcast to uint8, gather, bitcast back
        res = run("""
            import jax
            import jax.numpy as jnp
            def attend(pool, page_table):
                kq = pool.astype(jnp.float8_e4m3fn)
                bits = jax.lax.bitcast_convert_type(kq, jnp.uint8)
                sel = bits[page_table]
                return jax.lax.bitcast_convert_type(sel, jnp.float8_e4m3fn)
        """)
        assert rules_of(res) == []

    def test_dequantized_gather_is_quiet(self):
        # kernels/ref.py idiom: dequantize to f32 before the gather
        res = run("""
            import jax.numpy as jnp
            def attend(kq, scale, page_table):
                k = kq.astype(jnp.float32) * scale
                return k[page_table]
        """)
        assert rules_of(res) == []

    def test_static_slice_on_fp8_is_quiet(self):
        res = run("""
            import jax.numpy as jnp
            def peek(pool):
                kq = pool.astype(jnp.float8_e4m3fn)
                return kq[0], kq[:, 1:]
        """)
        assert rules_of(res) == []

    def test_suppressed(self):
        res = run("""
            import jax.numpy as jnp
            def attend(pool, idx):
                kq = pool.astype(jnp.float8_e4m3fn)
                return kq[idx]  # repro-lint: disable=RL006
        """)
        assert rules_of(res) == []


# -- suppression / baseline machinery ----------------------------------------

class TestMachinery:
    def test_disable_file_pragma(self):
        res = run("""
            # repro-lint: disable-file=RL004
            import jax
            def init(key):
                a = jax.random.normal(key, (4,))
                b = jax.random.normal(key, (4,))
                return a + b
        """)
        assert rules_of(res) == []

    def test_bare_disable_silences_all_rules_on_line(self):
        res = run("""
            import jax.numpy as jnp
            import numpy as np
            def tick(self):
                x = jnp.ones(8)
                return np.asarray(x)  # repro-lint: disable
        """, make_manifest(hot=["tick"]))
        assert rules_of(res) == []

    def test_baseline_roundtrip_and_multiset(self, tmp_path):
        src = """
            import jax
            def init(key):
                a = jax.random.normal(key, (4,))
                b = jax.random.normal(key, (4,))
                c = jax.random.normal(key, (4,))
                return a + b + c
        """
        res = run(src)
        assert rules_of(res) == ["RL004", "RL004"]
        path = tmp_path / "baseline.json"
        baseline_mod.write_baseline(path, res.findings)
        known = baseline_mod.load_baseline(path)
        new, old = baseline_mod.split_baselined(res.findings, known)
        assert not new and len(old) == 2
        # multiset semantics: one entry absolves one finding only
        one = baseline_mod.load_baseline(path)
        one.subtract([res.findings[0].baseline_key()])
        new, old = baseline_mod.split_baselined(res.findings, +one)
        assert len(new) == 1 and len(old) == 1

    def test_baseline_keys_survive_line_shifts(self):
        a = run("""
            import jax
            def init(key):
                a = jax.random.normal(key, (4,))
                b = jax.random.normal(key, (4,))
                return a + b
        """)
        b = run("""
            import jax
            # a comment pushing everything down


            def init(key):
                a = jax.random.normal(key, (4,))
                b = jax.random.normal(key, (4,))
                return a + b
        """)
        assert a.findings[0].baseline_key() == b.findings[0].baseline_key()
        assert a.findings[0].line != b.findings[0].line

    def test_mini_toml_parser_matches_manifest_shape(self):
        data = parse_toml_subset("""
            [scan]
            paths = ["src/repro"]
            [device_producers]
            patterns = ["self._step_fn",
                        "self._fused_fn"]
            [[module]]
            file = "a.py"               # trailing comment
            hot = ["tick", "step"]
            [[module]]
            file = "b.py"
            traced = []
        """)
        assert data["scan"]["paths"] == ["src/repro"]
        assert data["device_producers"]["patterns"] == [
            "self._step_fn", "self._fused_fn"]
        assert [m["file"] for m in data["module"]] == ["a.py", "b.py"]
        assert data["module"][0]["hot"] == ["tick", "step"]
        assert data["module"][1]["traced"] == []

    def test_rule_filter_rejects_unknown(self):
        with pytest.raises(ValueError):
            get_rules({"RL999"})


# -- the repo itself ----------------------------------------------------------

ROOT = Path(__file__).resolve().parent.parent


class TestRepoClean:
    def test_repo_is_clean_under_committed_baseline(self):
        manifest = load_manifest()
        result = analyze_paths(ROOT, manifest)
        known = baseline_mod.load_baseline(
            baseline_mod.default_baseline_path())
        new, _ = baseline_mod.split_baselined(result.findings, known)
        assert not new, "\n".join(
            f"{f.file}:{f.line} {f.rule} {f.message}" for f in new)
        assert result.files_scanned > 50

    def test_committed_baseline_is_empty(self):
        # the ratchet starts at zero: all seed findings were fixed or
        # given rationale-bearing inline suppressions in this repo
        doc = json.loads(baseline_mod.default_baseline_path().read_text())
        assert doc["findings"] == []

    def test_manifest_names_real_functions(self):
        manifest = load_manifest()
        assert manifest.modules, "empty manifest"
        for relpath, decl in manifest.modules.items():
            src = (ROOT / relpath).read_text()
            import ast as ast_mod
            from repro.analysis.engine import ModuleContext
            ctx = ModuleContext(ROOT / relpath, relpath, src,
                                ast_mod.parse(src), manifest)
            quals = {q for q, _ in ctx.functions}
            for qual in decl.hot + decl.traced:
                assert qual in quals, (
                    f"{relpath}: manifest names {qual!r} but the file "
                    f"defines no such function — fix hotpaths.toml")

    def test_doc_links_green(self):
        assert check_docs(ROOT) == []
