"""Load-harness integration contracts (tier 1).

The guarantees the 10⁵-request CI gate stands on, pinned at 10⁴ and
below so they run in tier-1 time:

- soak: random workloads through a ModelFleet of OraclePolicy engines
  lose no request, duplicate no rid, keep every BlockManager page in
  exactly one of {live, free, reclaimable} with refcounts equal to the
  seated tables' references, and never over-grant HostBudget bytes;
- determinism: two same-seed runs produce identical per-rid token
  streams, tick counts and metrics;
- trace parity: the oracle-stub engine and the real tiny-model engine
  schedule a fixed workload through the SAME trace event sequence —
  the oracle exercises the real machinery, not a simplification of it;
- the nearest-rank quantile contract EngineMetrics reports with.

See docs/benchmarks.md §"Workload 8" for the methodology.
"""
import dataclasses

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from benchmarks.load_harness import (check_conservation, check_invariants,
                                     drive_workload)
from repro.runtime.paged_kv import _quantile
from repro.runtime.serving import PagedServingEngine
from repro.runtime.workload import (OraclePolicy, VirtualClock,
                                    WorkloadSpec, generate_workload,
                                    oracle_fleet, tiny_paged_cfg)


def _drive(spec, seed, *, replicas=2, total_pages=192, max_seats=8,
           admission="slo", selection="slo-aware"):
    clock = VirtualClock()
    fleet = oracle_fleet(spec, replicas=replicas, total_pages=total_pages,
                         page_size=8, max_seats=max_seats,
                         prefill_chunk=32, admission=admission,
                         selection=selection, clock=clock)
    res = drive_workload(fleet, generate_workload(spec, seed), clock,
                         invariant_interval=64)
    return fleet, res


# -- the 1e4 soak -----------------------------------------------------------

def test_soak_10k_invariants_and_conservation():
    """10⁴ requests through a 2-replica fleet under slo admission and
    slo-aware routing: zero invariant violations at every checked tick
    and at the end, every submitted rid finished exactly once."""
    spec = WorkloadSpec(requests=10_000)
    fleet, res = _drive(spec, seed=0)
    assert res.invariant_violations == []
    done = fleet.finished()
    assert len(done) == 10_000
    assert sorted(done) == list(range(10_000))     # rids 0..N-1, no gaps
    for rid, req in done.items():
        assert 1 <= len(req.generated) <= req.max_new_tokens


def test_soak_same_seed_streams_identical():
    """Two same-seed runs: identical per-rid token streams, tick
    count, virtual span and per-class metrics — the reproducibility
    contract BENCH_capacity.json's determinism self-check gates on."""
    spec = WorkloadSpec(requests=2_000)
    fleet_a, a = _drive(spec, seed=42)
    fleet_b, b = _drive(spec, seed=42)
    sa = {rid: r.generated for rid, r in fleet_a.finished().items()}
    sb = {rid: r.generated for rid, r in fleet_b.finished().items()}
    assert sa == sb
    assert (a.ticks, a.virtual_s) == (b.ticks, b.virtual_s)
    assert a.classes == b.classes
    assert a.token_digest == b.token_digest
    # a different seed actually changes the streams
    fleet_c, c = _drive(spec, seed=43)
    assert c.token_digest != a.token_digest


def test_streams_replay_exactly_under_preemption():
    """A page-starved fleet preempts and replays; the oracle's hash
    logits depend only on (rid, step, last token), so every stream
    still matches the uncontended run token for token."""
    spec = WorkloadSpec(requests=300)
    ample, res_a = _drive(spec, seed=7, total_pages=512)
    tight, res_t = _drive(spec, seed=7, total_pages=48, max_seats=6)
    assert res_t.invariant_violations == []
    preempted = sum(m["preemptions"] for m in res_t.classes.values())
    assert preempted >= 1, "workload never preempted; tighten pages"
    sa = {rid: r.generated for rid, r in ample.finished().items()}
    st_ = {rid: r.generated for rid, r in tight.finished().items()}
    assert sa == st_


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       pages=st.integers(64, 256),
       replicas=st.integers(1, 3),
       mix=st.sampled_from([(0.2, 0.5, 0.3), (1.0, 0.0, 0.0),
                            (0.0, 0.0, 1.0), (0.34, 0.33, 0.33)]))
def test_property_no_request_lost_under_any_workload(seed, pages,
                                                     replicas, mix):
    """Hypothesis sweep over seeds, page budgets, replica counts and
    class mixes: conservation and the structural invariants hold."""
    spec = WorkloadSpec(requests=400, class_mix=mix)
    fleet, res = _drive(spec, seed=seed, replicas=replicas,
                        total_pages=pages, max_seats=4)
    assert res.invariant_violations == []
    assert len(fleet.finished()) == 400
    assert check_invariants(fleet) == []
    assert check_conservation(fleet, list(range(400))) == []


# -- oracle / real-engine trace parity --------------------------------------

@pytest.mark.slow
def test_trace_parity_oracle_vs_real_engine():
    """The oracle-stub engine and the real tiny-model engine emit the
    SAME trace event sequence (admit / prefix_hit / prefill_chunk /
    first_token / decode / preempt / finish order) for a fixed
    30-request workload — scheduling never observes token values under
    greedy sampling with no eos, so the oracle drives the admission /
    placement / growth machinery exactly as the real model does."""
    import jax
    from repro.models import model as M

    cfg = tiny_paged_cfg()
    params = M.init_params(M.param_specs(cfg), jax.random.PRNGKey(0))
    spec = WorkloadSpec(requests=30, max_total_len=64, prefix_len=16,
                        prompt_median=12, out_median=6,
                        stochastic_fraction=0.0)
    events = generate_workload(spec, seed=5)

    def run(policy_cls, params_):
        eng = PagedServingEngine(cfg, params_, page_size=8,
                                 num_pages=128, max_seats=4,
                                 max_seq_len=64, prefill_chunk=16,
                                 admission="slo", clock=VirtualClock(),
                                 policy_cls=policy_cls)
        for e in events:
            eng.submit(e.prompt, max_new_tokens=e.max_new_tokens,
                       priority=e.priority, deadline_ms=e.deadline_ms,
                       tbt_deadline_ms=e.tbt_deadline_ms,
                       sampling=e.sampling)
        eng.run()
        return eng

    real = run(None, params)
    oracle = run(OraclePolicy, None)
    assert oracle.trace == real.trace


# -- EngineMetrics._quantile nearest-rank contract --------------------------

def test_quantile_single_element_and_duplicates():
    """Nearest-rank on the degenerate samples that used to misreport:
    a 1-element sample returns that element at every q, and duplicate
    values return the duplicate, order-insensitively."""
    assert _quantile([7.0], 0.5) == 7.0
    assert _quantile([7.0], 0.95) == 7.0
    assert _quantile([7.0], 0.0) == 7.0
    assert _quantile([3.0, 3.0, 3.0, 3.0], 0.95) == 3.0
    assert _quantile([2.0, 1.0], 0.5) == _quantile([1.0, 2.0], 0.5)


def test_quantile_nearest_rank_reference():
    """Matches the ceil(q*n)-th order statistic (nearest-rank method)
    including the float-overshoot case q*n == 19.000000000000004."""
    import math
    s = list(range(1, 21))                        # n = 20
    for q in (0.05, 0.5, 0.75, 0.95, 0.99, 1.0):
        rank = max(1, min(20, math.ceil(round(q * 20, 9))))
        assert _quantile(s, q) == float(rank)
    assert _quantile(list(range(1, 21)), 0.95) == 19.0   # not 20
    rev = list(reversed(range(1, 21)))
    assert _quantile(rev, 0.95) == 19.0                  # order-insensitive
    assert _quantile([], 0.95) == 0.0
