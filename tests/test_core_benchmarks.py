"""Paper-benchmark correctness: HPL, HPL-MxP, HPCG, IO500, topology model."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hpl import (blocked_lu, lu_solve, make_test_matrix,
                            hpl_residual, hpl_flops, run_hpl)
from repro.core.hplmxp import run_hplmxp
from repro.core.hpcg import run_hpcg, stencil_apply
from repro.core.io500 import run_io500
from repro.core import topology
from repro.core.mixed_precision import (quantize_fp8, fp8_matmul,
                                        iterative_refinement)


def test_blocked_lu_factors_correctly():
    a, b = make_test_matrix(256)
    lu = blocked_lu(a, nb=64)
    n = a.shape[0]
    l = jnp.tril(lu, -1) + jnp.eye(n)
    u = jnp.triu(lu)
    np.testing.assert_allclose(np.asarray(l @ u), np.asarray(a),
                               rtol=1e-4, atol=1e-3)


def test_hpl_validates():
    r = run_hpl(256, 64)
    assert r["passed"] and r["residual"] < 16.0


@pytest.mark.parametrize("prec", ["bf16", "fp8"])
def test_hplmxp_low_precision_refines_to_pass(prec):
    """The paper's method: low-precision LU + IR passes HPL validation
    (Table 9 criterion: scaled residual < 16)."""
    r = run_hplmxp(256, 64, lowprec=prec, ir_iters=8)
    assert r["passed"], r
    # refinement actually reduced the residual
    hist = r["ir_history"]
    assert hist[-1] <= hist[0]


def test_hplmxp_fp8_lu_alone_is_inaccurate():
    """Without IR, the fp8 factorization residual is orders worse — IR is
    doing real work (validates the paper's method, not just the matrix)."""
    a, b = make_test_matrix(256)
    lu8 = blocked_lu(a, nb=64, matmul="fp8")
    lu32 = blocked_lu(a, nb=64)
    x8 = lu_solve(lu8, b)
    x32 = lu_solve(lu32, b)
    r8 = float(hpl_residual(a, x8, b))
    r32 = float(hpl_residual(a, x32, b))
    assert r8 > 5 * r32


def test_hpcg_converges_and_is_memory_bound():
    r = run_hpcg(24, 24, 24, max_iters=50)
    assert r["converged"]
    # arithmetic intensity of the stencil benchmark is < 2 flop/byte
    ai = r["gflops"] / max(r["bandwidth_gbs"], 1e-9)
    assert ai < 4.0


def test_stencil_is_symmetric_operator():
    """CG requires a symmetric operator: <Ax, y> == <x, Ay>."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(8, 8, 8)), jnp.float32)
    y = jnp.asarray(rng.normal(size=(8, 8, 8)), jnp.float32)
    lhs = float(jnp.vdot(stencil_apply(x), y))
    rhs = float(jnp.vdot(x, stencil_apply(y)))
    assert lhs == pytest.approx(rhs, rel=1e-4)


def test_io500_runs_and_scores(tmp_path):
    r = run_io500(nproc=2, mb_per_proc=4, files_per_proc=20,
                  workdir=str(tmp_path))
    assert r["total_score"] > 0
    assert r["bandwidth_score_gibs"] > 0 and r["iops_score_kiops"] > 0
    for phase in ("ior_easy", "ior_hard"):
        assert r[phase]["write_gibs"] > 0 and r[phase]["read_gibs"] > 0


def test_quantize_fp8_roundtrip_error():
    x = jnp.linspace(-3, 3, 256)
    q, s = quantize_fp8(x)
    err = float(jnp.max(jnp.abs(q.astype(jnp.float32) * s - x)))
    assert err < 0.25                           # e4m3 relative step ~6%


def test_iterative_refinement_converges():
    rng = np.random.default_rng(1)
    n = 64
    a = jnp.asarray(rng.uniform(-0.5, 0.5, (n, n)) + 0.4 * n * np.eye(n),
                    jnp.float32)
    b = jnp.asarray(rng.uniform(-1, 1, n), jnp.float32)
    # deliberately bad inner solver: diagonal preconditioner only
    solve = lambda r: r / jnp.diag(a)
    x, hist = iterative_refinement(lambda v: a @ v, solve, b, iters=30)
    resid = float(jnp.linalg.norm(a @ x - b) / jnp.linalg.norm(b))
    assert resid < 1e-4


def test_hierarchical_allreduce_cheaper_cross_pod():
    """The rail-optimized property: hierarchical all-reduce moves 1/in_pod
    of the flat ring's cross-pod bytes (paper §2.2 rationale)."""
    bytes_per_chip = 1e9
    hier, parts = topology.hierarchical_allreduce_cost(bytes_per_chip, 16, 2)
    flat = topology.flat_allreduce_cost(bytes_per_chip, 16, 2)
    assert hier < flat / 4
    # cross-pod phase moved 1/16 the bytes
    assert parts["cross_pod"] < flat / 8


def test_rail_topology_hops():
    t = topology.RailTopology()
    assert t.num_gpus == 800
    assert t.hops(0, 1) == 0                   # same node (NVLink)
    assert t.hops(0, 8) == 1                   # same rail, next node
    assert t.hops(0, 9) == 3                   # cross-rail -> spine
    assert t.hops(0, 400 * 8 // 8) == 3        # cross-pod -> spine
    assert t.bisection_bw() == pytest.approx(16 * 8 * 100e9 / 2)


def test_roofline_terms():
    rt = topology.roofline(hlo_flops=1e15, hlo_bytes=1e12,
                           collective_bytes=1e11, n_chips=256)
    assert rt.dominant in ("compute", "memory", "collective")
    assert rt.step_s == max(rt.compute_s, rt.memory_s, rt.collective_s)
