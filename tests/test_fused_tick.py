"""One-dispatch decode tick: the fused device-resident path must be
token-identical to the pre-fusion per-tick engine (``fused=False``), the
device sampler must match the numpy oracle draw-for-draw, and churn
(admission / finish / preemption) must never retrace the fused jit."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.models import model as M
from repro.runtime import sampler as sampler_mod
from repro.runtime.sampler import Sampler, SamplingParams
from repro.runtime.serving import PagedServingEngine, ServingEngine


@pytest.fixture(scope="module")
def setup():
    cfg = reduced_config(get_config("qwen3-1.7b"))
    params = M.init_params(M.param_specs(cfg), jax.random.PRNGKey(0))
    return cfg, params


def _mixed_sampling(n):
    """Alternating greedy / temperature / top-k / top-p requests."""
    variants = [SamplingParams(),
                SamplingParams(temperature=0.8, seed=11),
                SamplingParams(temperature=1.2, top_k=7, seed=22),
                SamplingParams(temperature=0.7, top_p=0.85, seed=33)]
    return [variants[i % len(variants)] for i in range(n)]


# -- device sampler vs numpy oracle ------------------------------------------

def test_device_sampler_matches_oracle():
    """sample_tokens (batched, jitted) draws the exact token the numpy
    Sampler draws for every row, across greedy/temperature/top-k/top-p
    and many (seed, rid, step) keys."""
    rng = np.random.default_rng(0)
    oracle = Sampler()
    B, V = 32, 97
    for trial in range(6):
        logits = rng.normal(scale=3.0, size=(B, V)).astype(np.float32)
        temp = rng.choice([0.0, 0.5, 0.9, 1.3], size=B).astype(np.float32)
        top_k = rng.choice([0, 1, 5, 40, V], size=B).astype(np.int32)
        top_p = rng.choice([1.0, 0.95, 0.6, 0.3], size=B).astype(np.float32)
        seed = rng.integers(0, 2**31, size=B, dtype=np.int64)
        rid = rng.integers(0, 10_000, size=B, dtype=np.int64)
        step = rng.integers(0, 4096, size=B, dtype=np.int64)
        got = np.asarray(jax.jit(sampler_mod.sample_tokens)(
            jnp.asarray(logits), jnp.asarray(temp), jnp.asarray(top_k),
            jnp.asarray(top_p), jnp.asarray(seed.astype(np.uint32)),
            jnp.asarray(rid.astype(np.uint32)),
            jnp.asarray(step.astype(np.uint32))))
        for i in range(B):
            sp = SamplingParams(temperature=float(temp[i]),
                                top_k=int(top_k[i]), top_p=float(top_p[i]),
                                seed=int(seed[i]))
            want = oracle.sample(logits[i], sp, rid=int(rid[i]),
                                 step=int(step[i]))
            assert int(got[i]) == want, (trial, i, sp)


# -- fused engine vs per-tick oracle -----------------------------------------

def _run(cfg, params, fused, prompts, gens, sps, **kw):
    eng = PagedServingEngine(cfg, params, fused=fused, **kw)
    for p, g, sp in zip(prompts, gens, sps):
        eng.submit(p, max_new_tokens=g, sampling=sp)
    done = eng.run()
    return eng, {r.rid: r.generated for r in done}


def test_fused_matches_per_tick_mixed_sampling(setup):
    """Concurrent requests with mixed greedy/stochastic sampling, seat
    contention and chunked prefill: the fused tick must reproduce the
    per-tick engine's token streams exactly."""
    cfg, params = setup
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab_size, int(n)).astype(np.int32)
               for n in rng.integers(3, 20, size=6)]
    gens = [int(g) for g in rng.integers(2, 9, size=6)]
    sps = _mixed_sampling(6)
    kw = dict(page_size=8, num_pages=16, max_seats=2, max_seq_len=32,
              prefill_chunk=4)
    _, got = _run(cfg, params, True, prompts, gens, sps, **kw)
    _, want = _run(cfg, params, False, prompts, gens, sps, **kw)
    assert got == want


def test_fused_matches_per_tick_under_preemption(setup):
    """Page pressure forces preempt-and-recompute (stochastic replay
    must re-derive the same (seed, rid, step) streams); fused and
    per-tick engines must still agree token-for-token."""
    cfg, params = setup
    prompts = [(np.arange(8, dtype=np.int32) * (3 + 4 * k)) % cfg.vocab_size
               for k in range(2)]
    gens = [20, 20]
    sps = [SamplingParams(temperature=0.9, seed=5),
           SamplingParams(temperature=1.1, top_k=11, seed=6)]
    kw = dict(page_size=4, num_pages=8, max_seats=2, max_seq_len=28,
              prefill_chunk=8)
    ef, got = _run(cfg, params, True, prompts, gens, sps, **kw)
    eo, want = _run(cfg, params, False, prompts, gens, sps, **kw)
    assert eo.metrics.preemptions > 0     # scenario actually preempts
    assert ef.metrics.preemptions == eo.metrics.preemptions
    assert got == want


def test_fused_no_retrace_across_churn(setup):
    """Admission, finish and preemption churn must reuse ONE fused-tick
    trace: every argument keeps a fixed (max_seats,)-based shape, so the
    jit cache stays at a single entry for the whole run."""
    cfg, params = setup
    rng = np.random.default_rng(11)
    eng = PagedServingEngine(cfg, params, page_size=4, num_pages=8,
                             max_seats=2, max_seq_len=28, prefill_chunk=8)
    for k in range(5):                    # staggered lengths/budgets
        eng.submit(rng.integers(0, cfg.vocab_size, 4 + 3 * k)
                   .astype(np.int32), max_new_tokens=3 + 2 * k,
                   sampling=_mixed_sampling(5)[k])
    done = eng.run()
    assert len(done) == 5
    assert eng.policy._fused_fn._cache_size() == 1


def test_fused_steady_state_single_roundtrip(setup):
    """Between churn events the fused tick must not re-upload host
    state: _sync_device runs only when the dirty flag was set by
    admit/finish/preempt/grow/prefill-completion, never per tick."""
    cfg, params = setup
    eng = PagedServingEngine(cfg, params, page_size=8, num_pages=16,
                             max_seats=2, max_seq_len=64, prefill_chunk=8)
    calls = {"n": 0}
    orig = eng.policy._sync_device

    def counting():
        calls["n"] += 1
        orig()

    eng.policy._sync_device = counting
    eng.submit(np.arange(8, dtype=np.int32), max_new_tokens=30)
    done = eng.run()
    assert len(done) == 1 and len(done[0].generated) == 30
    # 30 decode ticks; syncs only on churn: admission/prefill completion
    # plus one per lazy page-growth boundary — far fewer than ticks
    assert calls["n"] < 10


def test_first_tokens_batched_share_timestamp(setup):
    """An admission burst samples all its first tokens in one batched
    call and timestamps after it — every request admitted in the same
    tick records the identical TTFT timestamp (no serialized
    per-request syncs inflating later requests' TTFT)."""
    cfg, params = setup
    eng = ServingEngine(cfg, params, slots=3, max_len=32)
    for k in range(3):
        eng.submit((np.arange(5, dtype=np.int32) + k) % cfg.vocab_size,
                   max_new_tokens=2)
    done = eng.run()
    stamps = {r.t_first_token for r in done}
    assert len(stamps) == 1
