"""Subprocess: validate hierarchical/compressed collectives on an 8-device
virtual mesh (2 pods × 2 data × 2 model). Prints OK lines; the parent test
asserts on them."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np
from functools import partial
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map

from repro.launch.mesh import _make_mesh
from repro.core.collectives import (hierarchical_psum_local,
                                    compressed_cross_pod_psum_local,
                                    hierarchical_psum)

mesh = _make_mesh((2, 2, 2), ("pod", "data", "model"))

x = jnp.arange(24.0).reshape(2, 12) / 7.0

# 1. hierarchical == flat psum over (data, pod)
flat = shard_map(lambda v: jax.lax.psum(v, ("data", "pod")), mesh=mesh,
                     in_specs=P(None, None), out_specs=P(None, None),
                     check_vma=False)(x)
hier = shard_map(partial(hierarchical_psum_local, in_axis="data",
                             cross_axis="pod"),
                     mesh=mesh, in_specs=P(None, None),
                     out_specs=P(None, None), check_vma=False)(x)
np.testing.assert_allclose(np.asarray(hier), np.asarray(flat), rtol=1e-6)
print("OK hierarchical==flat")

# 2. wrapper path
hier2 = hierarchical_psum(x, mesh)
np.testing.assert_allclose(np.asarray(hier2), np.asarray(flat), rtol=1e-6)
print("OK wrapper")

# 3. compressed psum ≈ flat psum, error bounded by int8 quantization
err0 = jnp.zeros((x.size // 2,), jnp.float32)
comp, new_err = shard_map(
    partial(compressed_cross_pod_psum_local, in_axis="data", cross_axis="pod"),
    mesh=mesh, in_specs=(P(None, None), P(None)),
    out_specs=(P(None, None), P(None)), check_vma=False)(x, err0)
rel = float(jnp.max(jnp.abs(comp - flat)) / jnp.max(jnp.abs(flat)))
assert rel < 0.02, rel
print("OK compressed rel_err=%.4f" % rel)

# 4. error feedback: residual is nonzero and bounded by one quant step
assert float(jnp.max(jnp.abs(new_err))) <= float(jnp.max(jnp.abs(x))) * 2 / 127 + 1e-6
print("OK error-feedback")

# 5. hierarchical psum on single-pod mesh (no 'pod' axis)
mesh2 = _make_mesh((4, 2), ("data", "model"))
flat2 = shard_map(lambda v: jax.lax.psum(v, "data"), mesh=mesh2,
                      in_specs=P(None, None), out_specs=P(None, None),
                      check_vma=False)(x)
hier3 = hierarchical_psum(x, mesh2)
np.testing.assert_allclose(np.asarray(hier3), np.asarray(flat2), rtol=1e-6)
print("OK single-pod fallback")


# 6. distributed HPL: sharded blocked LU == single-device factors
from repro.core.hpl import blocked_lu, make_test_matrix, distributed_hpl_setup
a, _ = make_test_matrix(256)
lu_ref = blocked_lu(a, nb=64)
fn, _, sharding = distributed_hpl_setup(mesh2, 256, nb=64)
with mesh2:
    lu_dist = fn(jax.device_put(a, sharding))
np.testing.assert_allclose(np.asarray(lu_dist), np.asarray(lu_ref),
                           rtol=2e-4, atol=2e-4)
print("OK distributed-hpl")
print("ALL_OK")
