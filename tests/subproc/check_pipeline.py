"""Subprocess: pipeline parallelism across 'pod' matches the reference
train step (fwd+bwd pipelines through scan+ppermute autodiff)."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

import jax
from repro.launch.mesh import _make_mesh
from repro.configs import get_config, reduced_config
from repro.configs.base import ShapeConfig
from repro.launch.steps import build_cell
from repro.models import model as M
from repro.optim.adamw import init_opt_state
from repro.data.pipeline import TokenPipeline

cfg = reduced_config(get_config("llama3-8b"))
shape = ShapeConfig("t", 32, 8, "train")
mesh = _make_mesh((2, 2, 2), ("pod", "data", "model"))
pipe = TokenPipeline(cfg.vocab_size, 32, 8)
batches = [pipe.get_batch(i) for i in range(3)]

res = {}
for mode, opts in (
        ("pp", M.RunOptions(q_chunk=16, xent_chunk=16, pipeline=True,
                            pp_microbatches=4)),
        ("ref", M.RunOptions(q_chunk=16, xent_chunk=16))):
    cell = build_cell(cfg, shape, mesh, opts=opts)
    fn = jax.jit(cell.fn, in_shardings=cell.in_shardings)
    with mesh:
        params = M.init_params(M.param_specs(cfg), jax.random.PRNGKey(0))
        params = jax.device_put(params, cell.in_shardings[0])
        opt = jax.device_put(init_opt_state(params), cell.in_shardings[1])
        losses = []
        for b in batches:
            params, opt, m = fn(params, opt, b)
            losses.append(float(m["loss"]))
    res[mode] = losses
    print(mode, ["%.5f" % l for l in losses])
diff = max(abs(a - b) for a, b in zip(res["pp"], res["ref"]))
assert diff < 5e-3, diff
print("OK pipeline==reference diff=%.5f" % diff)
print("ALL_OK")
