"""Subprocess: REAL sharded execution on an 8-device virtual mesh.

1. build_cell train step for a reduced MoE arch (exercises shard_map MoE,
   FSDP gathers, GQA fallback) and run TWO real steps — values must match
   the single-device reference exactly (same seeds).
2. decode cell runs and matches too.
3. elastic: save checkpoint from the 8-device mesh, restore onto a
   1-device mesh, losses continue identically (the recovery contract).
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
from repro.launch.mesh import _make_mesh
from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config, reduced_config
from repro.configs.base import ShapeConfig
from repro.data.pipeline import TokenPipeline
from repro.launch.steps import build_cell
from repro.models import model as M
from repro.optim.adamw import init_opt_state
from repro.parallel.sharding import SINGLE_DEVICE_RULES

cfg = reduced_config(get_config("qwen2-moe-a2.7b"))
shape = ShapeConfig("t", 32, 8, "train")
opts = M.RunOptions(q_chunk=16, xent_chunk=16)

mesh = _make_mesh((2, 2, 2), ("pod", "data", "model"))
cell = build_cell(cfg, shape, mesh, opts=opts)
step_fn = jax.jit(cell.fn, in_shardings=cell.in_shardings)

pipe = TokenPipeline(cfg.vocab_size, 32, 8)
batches = [pipe.get_batch(i) for i in range(3)]

with mesh:
    params = M.init_params(M.param_specs(cfg), jax.random.PRNGKey(0))
    params = jax.device_put(params, cell.in_shardings[0])
    opt = jax.device_put(init_opt_state(params), cell.in_shardings[1])
    losses_8dev = []
    for b in batches:
        params, opt, m = step_fn(params, opt, b)
        losses_8dev.append(float(m["loss"]))
    # save from the 8-device mesh after 2 steps for the elastic check
    ckdir = tempfile.mkdtemp(prefix="elastic_")
    mgr = CheckpointManager(ckdir)

    # re-run to the 2-step point to capture state (deterministic)
    params2 = M.init_params(M.param_specs(cfg), jax.random.PRNGKey(0))
    params2 = jax.device_put(params2, cell.in_shardings[0])
    opt2 = jax.device_put(init_opt_state(params2), cell.in_shardings[1])
    for b in batches[:2]:
        params2, opt2, _ = step_fn(params2, opt2, b)
    mgr.save(2, {"params": params2, "opt": opt2})
print("OK 8dev-train", ["%.6f" % l for l in losses_8dev])

# single-device reference
ref_cfg_opts = M.RunOptions(q_chunk=16, xent_chunk=16)
from repro.optim.adamw import adamw_update
from repro.optim.schedules import wsd_schedule

def ref_step(params, opt, batch):
    (loss, metrics), grads = jax.value_and_grad(M.lm_loss, has_aux=True)(
        params, cfg, batch, SINGLE_DEVICE_RULES, ref_cfg_opts)
    lr = wsd_schedule(opt["count"], peak=3e-4, warmup_steps=100,
                      total_steps=10_000)
    p2, o2, _ = adamw_update(grads, opt, params, lr)
    return p2, o2, loss

ref_step = jax.jit(ref_step)
params_r = M.init_params(M.param_specs(cfg), jax.random.PRNGKey(0))
opt_r = init_opt_state(params_r)
losses_1dev = []
for b in batches:
    params_r, opt_r, loss = ref_step(params_r, opt_r, b)
    losses_1dev.append(float(loss))
print("OK 1dev-train", ["%.6f" % l for l in losses_1dev])

# bf16 compute + different reduction orders across shardings:
np.testing.assert_allclose(losses_8dev, losses_1dev, rtol=3e-3, atol=3e-3)
print("OK sharded==single")

# elastic restore onto 1-device mesh, continue step 2 -> loss matches
restored_params = M.init_params(M.param_specs(cfg), jax.random.PRNGKey(1))
step, state = mgr.restore({"params": restored_params,
                           "opt": init_opt_state(restored_params)})
p3, o3 = state["params"], state["opt"]
_, _, loss3 = ref_step(p3, o3, batches[2])
np.testing.assert_allclose(float(loss3), losses_1dev[2], rtol=3e-3, atol=3e-3)
print("OK elastic-restore step=%d loss=%.6f" % (step, float(loss3)))

# decode cell on the 8-device mesh
dshape = ShapeConfig("d", 32, 8, "decode")
dcell = build_cell(cfg, dshape, mesh, opts=opts)
dfn = jax.jit(dcell.fn, in_shardings=dcell.in_shardings)
with mesh:
    params_d = M.init_params(M.param_specs(cfg), jax.random.PRNGKey(0),
                             dtype=jnp.bfloat16)
    params_d = jax.device_put(params_d, dcell.in_shardings[0])
    cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                         dcell.abstract_args[1])
    cache = jax.device_put(cache, dcell.in_shardings[1])
    tok = jnp.zeros((8, 1), jnp.int32)
    pos = jnp.zeros((8,), jnp.int32)
    logits, cache = dfn(params_d, cache, tok, pos)
    assert np.isfinite(np.asarray(logits)).all()
print("OK sharded-decode", logits.shape)
print("ALL_OK")
