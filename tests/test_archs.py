"""Per-architecture smoke tests: reduced same-family config, one forward +
one train step on CPU, asserting output shapes and finiteness (the
assignment's required smoke coverage for all 10 archs)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import (ARCH_IDS, get_config, reduced_config,
                           make_example_batch, SHAPES, cell_supported)
from repro.models import model as M
from repro.optim.adamw import init_opt_state, adamw_update
from repro.parallel.sharding import SINGLE_DEVICE_RULES

OPTS = M.RunOptions(q_chunk=32, xent_chunk=32)


@pytest.fixture(scope="module")
def arch_setup():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = reduced_config(get_config(arch))
            params = M.init_params(M.param_specs(cfg), jax.random.PRNGKey(0))
            cache[arch] = (cfg, params)
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_loss_finite(arch, arch_setup):
    cfg, params = arch_setup(arch)
    batch = make_example_batch(cfg, "train", 2, 64)
    loss, metrics = jax.jit(
        lambda p, b: M.lm_loss(p, cfg, b, SINGLE_DEVICE_RULES, OPTS))(params, batch)
    assert np.isfinite(float(loss))
    # next-token xent at init should be near ln(vocab)
    assert abs(float(metrics["xent"]) - np.log(cfg.vocab_size)) < 1.5


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step_improves_or_finite(arch, arch_setup):
    cfg, params = arch_setup(arch)
    batch = make_example_batch(cfg, "train", 2, 32)
    opt = init_opt_state(params)

    @jax.jit
    def step(p, o, b):
        (loss, _), g = jax.value_and_grad(M.lm_loss, has_aux=True)(
            p, cfg, b, SINGLE_DEVICE_RULES, OPTS)
        p2, o2, m = adamw_update(g, o, p, 1e-3)
        return p2, o2, loss, m["grad_norm"]

    p2, o2, loss, gnorm = step(params, opt, batch)
    assert np.isfinite(float(loss)) and np.isfinite(float(gnorm))
    assert float(gnorm) > 0
    # params actually moved
    delta = max(float(jnp.max(jnp.abs(a - b)))
                for a, b in zip(jax.tree.leaves(p2), jax.tree.leaves(params)))
    assert delta > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_consistency(arch, arch_setup):
    """Decode with a prefilled cache must reproduce prefill logits."""
    cfg, params = arch_setup(arch)
    B, S = 2, 32
    batch = make_example_batch(cfg, "prefill", B, S)
    logits_p, _ = jax.jit(
        lambda p, b: M.prefill(p, cfg, b, SINGLE_DEVICE_RULES, OPTS))(params, batch)
    batch1 = {k: (v[:, :S - 1] if k == "tokens" else v) for k, v in batch.items()}
    _, cache1 = jax.jit(
        lambda p, b: M.prefill(p, cfg, b, SINGLE_DEVICE_RULES, OPTS))(params, batch1)

    def pad(ent):
        return {k: (jnp.concatenate(
            [v, jnp.zeros(v.shape[:2] + (1,) + v.shape[3:], v.dtype)], axis=2)
            if k in ("k", "v") else v) for k, v in ent.items()}

    cache1 = {pos: pad(ent) for pos, ent in cache1.items()}
    tok = batch["tokens"][:, S - 1:S]
    pos = jnp.full((B,), S - 1, jnp.int32)
    logits_d, _ = jax.jit(
        lambda p, c, t, q: M.decode_step(p, cfg, c, t, q,
                                         SINGLE_DEVICE_RULES, OPTS))(
        params, cache1, tok, pos)
    np.testing.assert_allclose(np.asarray(logits_d), np.asarray(logits_p),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_exact_assigned_config(arch):
    """The full (non-reduced) config must match the assignment table."""
    cfg = get_config(arch)
    expected = {
        "minicpm-2b": (40, 2304, 36, 36, 5760, 122753),
        "llama3-8b": (32, 4096, 32, 8, 14336, 128256),
        "qwen3-1.7b": (28, 2048, 16, 8, 6144, 151936),
        "gemma3-12b": (48, 3840, 16, 8, 15360, 262144),
        "qwen2-moe-a2.7b": (24, 2048, 16, 16, 1408, 151936),
        "grok-1-314b": (64, 6144, 48, 8, 32768, 131072),
        "mamba2-130m": (24, 768, 0, 0, 0, 50280),
        "whisper-base": (6, 512, 8, 8, 2048, 51865),
        "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
        "phi-3-vision-4.2b": (32, 3072, 32, 32, 8192, 32064),
    }[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff if cfg.moe is None or arch != "qwen2-moe-a2.7b"
           else cfg.moe.d_ff_expert, cfg.vocab_size)
    assert got == expected


def test_moe_expert_counts():
    q = get_config("qwen2-moe-a2.7b").moe
    assert (q.num_experts, q.top_k, q.num_shared_experts) == (60, 4, 4)
    g = get_config("grok-1-314b").moe
    assert (g.num_experts, g.top_k) == (8, 2)
    j = get_config("jamba-v0.1-52b").moe
    assert (j.num_experts, j.top_k, j.moe_every) == (16, 2, 2)


def test_jamba_layer_pattern():
    cfg = get_config("jamba-v0.1-52b")
    kinds = cfg.layer_kinds()
    assert len(kinds) == 8 and kinds.count("attn") == 1
    assert kinds[4] == "attn"                   # 1:7 attn:mamba at offset 4
    mlps = cfg.mlp_kinds()
    assert mlps.count("moe") == 4 and mlps.count("dense") == 4


def test_gemma_local_global_pattern():
    cfg = get_config("gemma3-12b")
    kinds = cfg.layer_kinds()
    assert len(kinds) == 6
    assert kinds.count("attn_local") == 5 and kinds[5] == "attn"


def test_long500k_applicability():
    long = SHAPES["long_500k"]
    runs = {a: cell_supported(get_config(a), long)[0] for a in ARCH_IDS}
    assert runs["mamba2-130m"] and runs["jamba-v0.1-52b"] and runs["gemma3-12b"]
    assert not runs["llama3-8b"] and not runs["whisper-base"]
    assert sum(runs.values()) == 3
