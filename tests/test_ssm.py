"""SSD correctness: the chunked algorithm must equal the step-by-step
recurrence for every chunk size (the state-space-duality property)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs.base import ModelConfig, SSMConfig
from repro.models.modules import init_params
from repro.models.ssm import ssm_block, ssm_specs, ssm_dims, _ssd_chunked


def make_cfg(chunk=8, d_state=8, head_dim=8, d_model=16):
    return ModelConfig(
        name="t", family="ssm", num_layers=1, d_model=d_model, num_heads=0,
        num_kv_heads=0, d_ff=0, vocab_size=64, head_dim=head_dim,
        ssm=SSMConfig(d_state=d_state, conv_width=4, expand=2,
                      head_dim=head_dim, chunk_size=chunk))


def sequential_reference(xh, dt, A, Bm, Cm):
    """Naive per-step recurrence h_t = exp(dt A) h_{t-1} + dt B x."""
    B, S, H, P = xh.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    h = np.zeros((B, H, N, P))
    ys = []
    for t in range(S):
        dA = np.exp(np.asarray(dt[:, t]) * np.asarray(A)[None])     # (B,H)
        Bh = np.repeat(np.asarray(Bm[:, t]), rep, axis=1)            # (B,H,N)
        Ch = np.repeat(np.asarray(Cm[:, t]), rep, axis=1)
        h = h * dA[..., None, None] + (
            np.asarray(dt[:, t])[..., None, None] * Bh[..., None]
            * np.asarray(xh[:, t])[..., None, :])
        ys.append(np.einsum("bhn,bhnp->bhp", Ch, h))
    return np.stack(ys, axis=1), h


@pytest.mark.parametrize("chunk", [4, 8, 16, 32])
def test_ssd_chunked_equals_recurrence(chunk):
    key = jax.random.PRNGKey(chunk)
    B, S, H, P, G, N = 2, 32, 4, 8, 1, 8
    xh = jax.random.normal(key, (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1), (B, S, H)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 2), (H,)) * 0.3)
    Bm = jax.random.normal(jax.random.fold_in(key, 3), (B, S, G, N)) * 0.5
    Cm = jax.random.normal(jax.random.fold_in(key, 4), (B, S, G, N)) * 0.5
    y, state = _ssd_chunked(xh, dt, A, Bm, Cm, chunk)
    y_ref, state_ref = sequential_reference(xh, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=1e-4, atol=1e-4)
    # state layout (B,H,N,P)
    np.testing.assert_allclose(np.asarray(state), state_ref, rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(chunk=st.sampled_from([4, 8, 16]),
       seed=st.integers(0, 2 ** 16))
def test_ssd_chunk_invariance(chunk, seed):
    """Property: chunk size never changes the result (pure reformulation)."""
    key = jax.random.PRNGKey(seed)
    B, S, H, P, G, N = 1, 16, 2, 4, 1, 4
    xh = jax.random.normal(key, (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1), (B, S, H)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 2), (H,)) * 0.3)
    Bm = jax.random.normal(jax.random.fold_in(key, 3), (B, S, G, N)) * 0.5
    Cm = jax.random.normal(jax.random.fold_in(key, 4), (B, S, G, N)) * 0.5
    y1, s1 = _ssd_chunked(xh, dt, A, Bm, Cm, chunk)
    y2, s2 = _ssd_chunked(xh, dt, A, Bm, Cm, S)    # single chunk
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=2e-4, atol=2e-4)


def test_ssm_block_prefill_decode_state_handoff():
    """Prefill final states must continue exactly into decode steps."""
    cfg = make_cfg(chunk=8)
    params = init_params(ssm_specs(cfg), jax.random.PRNGKey(0))
    B, S = 2, 24
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S + 1, cfg.d_model)) * 0.5

    # full pass over S+1 tokens
    y_full, _ = ssm_block(params, cfg, x)
    # prefill S, then decode token S with carried states
    y_pre, (conv_state, ssm_state) = ssm_block(params, cfg, x[:, :S])
    y_dec, _ = ssm_block(params, cfg, x[:, S:S + 1], conv_state=conv_state,
                         ssm_state=ssm_state, decode=True)
    np.testing.assert_allclose(np.asarray(y_dec[:, 0]),
                               np.asarray(y_full[:, S]), rtol=1e-3, atol=1e-3)
