"""SLO-tiered scheduling: priority/EDF admission with aging,
priority-aware preemption victim selection, per-class metrics, deadline
misses — and the two invariants the feature must never break: FCFS
stays bit-identical to the pre-SLO scheduler, and scheduling never
changes tokens (the sampler key is (seed, rid, step), not priority).

See docs/serving.md ("SLO classes") for the design this pins."""
import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.models import model as M
from repro.runtime.sampler import SamplingParams
from repro.runtime.serving import (PRIORITIES, PagedServingEngine,
                                   SchedulerStallError, ServingEngine,
                                   SLOAdmission)


@pytest.fixture(scope="module")
def setup():
    cfg = reduced_config(get_config("qwen3-1.7b"))
    params = M.init_params(M.param_specs(cfg), jax.random.PRNGKey(0))
    return cfg, params


def admit_order(eng):
    return [r for (_, k, r) in eng.trace if k == "admit"]


def preempted_rids(eng):
    return {r for (_, k, r) in eng.trace if k == "preempt"}


# -- admission ordering ------------------------------------------------------

def test_slo_admission_prefers_premium(setup):
    """With one slot and a pre-loaded queue, slo admission runs the
    late-submitted premium request first; batch ties keep submit
    order."""
    cfg, params = setup
    eng = ServingEngine(cfg, params, slots=1, max_len=32, admission="slo")
    for prio in ("batch", "batch", "premium"):
        eng.submit(np.arange(4, dtype=np.int32), max_new_tokens=2,
                   priority=prio)
    eng.run()
    assert admit_order(eng) == [2, 0, 1]


def test_edf_orders_within_class(setup):
    """Same class, both deadlined: the earlier absolute deadline is
    admitted first even with a higher rid; an undeadlined peer of the
    same class sorts after every deadlined one."""
    cfg, params = setup
    eng = ServingEngine(cfg, params, slots=1, max_len=32, admission="slo")
    eng.submit(np.arange(4, dtype=np.int32), max_new_tokens=2,
               deadline_ms=50_000.0)
    eng.submit(np.arange(4, dtype=np.int32), max_new_tokens=2,
               deadline_ms=100.0)
    eng.submit(np.arange(4, dtype=np.int32), max_new_tokens=2)
    eng.run()
    assert admit_order(eng) == [1, 0, 2]


def test_equal_priority_ties_fall_back_to_fcfs(setup):
    """A uniform-priority, no-deadline workload admits in exactly the
    FCFS order under slo admission — the whole trace matches."""
    cfg, params = setup

    def run(admission):
        eng = ServingEngine(cfg, params, slots=2, max_len=48,
                            admission=admission)
        for i in range(5):
            eng.submit(np.arange(3 + i, dtype=np.int32),
                       max_new_tokens=2 + (i % 3))
        eng.run()
        return eng

    assert run("slo").trace == run("fcfs").trace


def test_fcfs_default_is_bit_identical_with_priorities_present(setup):
    """admission='fcfs' (and the default) ignores priority entirely:
    the trace equals a default-constructed engine's on the same stream
    — mixed classes included — and admits in submit order."""
    cfg, params = setup
    prios = ["batch", "premium", "standard", "batch", "premium"]

    def run(**kw):
        eng = PagedServingEngine(cfg, params, page_size=8, num_pages=16,
                                 max_seats=2, max_seq_len=32,
                                 prefill_chunk=8, **kw)
        for i, p in enumerate(prios):
            eng.submit(np.arange(4 + i, dtype=np.int32), max_new_tokens=3,
                       priority=p)
        eng.run()
        return eng

    default = run()
    explicit = run(admission="fcfs")
    assert default.trace == explicit.trace
    assert admit_order(default) == [0, 1, 2, 3, 4]


def test_aging_unstarves_batch_under_sustained_premium_load(setup):
    """One slot, a batch request queued at tick 0, and a fresh premium
    request injected whenever the premium pipeline empties.  Without
    aging the batch request starves until the premium stream stops;
    with aging_ticks=2 its effective class outranks fresh premium
    arrivals within a few ticks and it is admitted mid-stream."""
    cfg, params = setup

    def run(aging_ticks, n_premium=6):
        eng = ServingEngine(cfg, params, slots=1, max_len=32,
                            admission="slo", aging_ticks=aging_ticks)
        batch_rid = eng.submit(np.arange(4, dtype=np.int32),
                               max_new_tokens=2, priority="batch")
        fed = 0
        for _ in range(200):
            if (fed < n_premium
                    and not any(r.priority == "premium" for r in eng.queue)):
                eng.submit(np.arange(4, dtype=np.int32), max_new_tokens=2,
                           priority="premium")
                fed += 1
            eng.step()
            if not eng.queue and not eng.seats:
                break
        assert not eng.queue and not eng.seats, "workload did not drain"
        order = admit_order(eng)
        return order.index(batch_rid), len(order)

    starved_pos, n = run(aging_ticks=10_000)
    assert starved_pos == n - 1          # batch ran dead last
    aged_pos, n = run(aging_ticks=2)
    assert aged_pos < n - 1              # un-starved mid-stream
    assert aged_pos > 0                  # but premium still went first


def test_slo_admission_rank_is_unclamped():
    """The aging boost has no floor: any class eventually outranks a
    fresh premium arrival — the anti-starvation bound is
    (level_gap + 1) * aging_ticks ticks."""
    import dataclasses

    @dataclasses.dataclass
    class Stub:
        rid: int
        priority: str
        deadline_ms: object
        submit_tick: int
        t_submit: float = 0.0

    pol = SLOAdmission(aging_ticks=4)
    old_batch = Stub(0, "batch", None, submit_tick=0)
    fresh_premium = Stub(9, "premium", None, submit_tick=12)
    tick = 12
    assert pol.rank(old_batch, tick)[0] == PRIORITIES["batch"] - 3
    assert pol.rank(old_batch, tick) < pol.rank(fresh_premium, tick)
    with pytest.raises(ValueError):
        SLOAdmission(aging_ticks=0)


def test_submit_rejects_bad_priority_and_deadline(setup):
    cfg, params = setup
    eng = ServingEngine(cfg, params, slots=1, max_len=32)
    with pytest.raises(ValueError, match="priority"):
        eng.submit(np.arange(4, dtype=np.int32), priority="vip")
    with pytest.raises(ValueError, match="deadline_ms"):
        eng.submit(np.arange(4, dtype=np.int32), deadline_ms=0)
    with pytest.raises(ValueError, match="admission"):
        ServingEngine(cfg, params, slots=1, max_len=32, admission="bogus")


# -- priority-aware preemption ----------------------------------------------

PKW = dict(page_size=4, max_seats=2, max_seq_len=24, prefill_chunk=8)


def _run_pair(cfg, params, num_pages, prios, **over):
    eng = PagedServingEngine(cfg, params, num_pages=num_pages,
                             **{**PKW, **over})
    for i, prio in enumerate(prios):
        mult = 3 if i == 0 else 7
        eng.submit((np.arange(8, dtype=np.int32) * mult) % cfg.vocab_size,
                   max_new_tokens=10, priority=prio)
    eng.run()
    return eng, {r.rid: r.generated for r in eng.finished}


def test_victim_is_lowest_class_not_youngest(setup):
    """Growth failure with an old batch request and a young premium
    one: the batch request is preempted even though the pre-SLO rule
    (youngest first) would have evicted the premium request."""
    cfg, params = setup
    _, ref = _run_pair(cfg, params, 32, ("batch", "premium"))
    tight, out = _run_pair(cfg, params, 7, ("batch", "premium"))
    assert tight.metrics.preemptions >= 1
    assert preempted_rids(tight) == {0}          # batch, despite rid 0
    assert out == ref                            # replay token-identical
    assert tight.metrics.preemptions_by_class.get("premium", 0) == 0


def test_victim_is_youngest_within_a_class(setup):
    """Uniform classes keep the historical youngest-first rule (rid 1
    evicted) — the degenerate case FCFS trace-identity relies on."""
    cfg, params = setup
    tight, _ = _run_pair(cfg, params, 7, ("standard", "standard"))
    assert tight.metrics.preemptions >= 1
    assert preempted_rids(tight) == {1}


def test_grower_never_preempts_strictly_higher_class(setup):
    """When the only other decoding request outranks the grower, the
    grower evicts itself: premium keeps decoding untouched while the
    batch grower takes the preempt-and-recompute path."""
    cfg, params = setup
    _, ref = _run_pair(cfg, params, 32, ("premium", "batch"))
    tight, out = _run_pair(cfg, params, 7, ("premium", "batch"))
    assert tight.metrics.preemptions >= 1
    assert preempted_rids(tight) == {1}          # the batch request only
    assert tight.metrics.preemptions_by_class.get("premium", 0) == 0
    assert out == ref


def test_preemption_resets_aging_base(setup):
    """Aging measures queue wait, not lifetime: preemption restarts the
    aging base at the preemption tick, so time spent decoding on a seat
    cannot boost a preempted batch request past fresh premium
    arrivals when it re-queues."""
    cfg, params = setup
    eng = ServingEngine(cfg, params, slots=1, max_len=32, admission="slo")
    eng.submit(np.arange(6, dtype=np.int32), max_new_tokens=8,
               priority="batch")
    for _ in range(3):
        eng.step()
    req = eng.seats[0]
    assert req.submit_tick == 0 and eng._tick == 3
    eng.preempt(req)
    assert req.submit_tick == 3                  # aging base restarted
    assert len(eng.run()) == 1                   # replay still completes


# -- tokens are scheduling-invariant ----------------------------------------

def test_sampler_keying_unchanged_by_priority(setup):
    """Priority classes and the admission policy reorder *when*
    requests run, never *which* tokens they produce: the stochastic
    sampler keys by (seed, rid, step) only, so per-rid outputs match
    between an all-standard FCFS run and a mixed-class slo run."""
    cfg, params = setup
    sp = SamplingParams(temperature=0.8, top_p=0.9, seed=11)
    prompts = [(np.arange(6 + i, dtype=np.int32) * (2 * i + 3))
               % cfg.vocab_size for i in range(4)]

    def run(admission, prios):
        eng = PagedServingEngine(cfg, params, page_size=8, num_pages=32,
                                 max_seats=2, max_seq_len=32,
                                 prefill_chunk=8, admission=admission)
        for p, prio in zip(prompts, prios):
            eng.submit(p, max_new_tokens=5, sampling=sp, priority=prio)
        eng.run()
        return {r.rid: r.generated for r in eng.finished}

    ref = run("fcfs", ["standard"] * 4)
    mixed = run("slo", ["batch", "premium", "batch", "premium"])
    assert mixed == ref


# -- observability -----------------------------------------------------------

def test_stall_error_names_rids_and_priorities(setup):
    cfg, params = setup
    eng = PagedServingEngine(cfg, params, page_size=8, num_pages=8,
                             max_seats=1, max_seq_len=24, prefill_chunk=8)
    eng.submit(np.arange(4, dtype=np.int32), max_new_tokens=6,
               priority="premium")
    eng.submit(np.arange(4, dtype=np.int32), max_new_tokens=6,
               priority="batch")
    with pytest.raises(SchedulerStallError) as ei:
        eng.run(max_ticks=1)
    msg = str(ei.value)
    assert "queued" in msg
    assert "0(premium)" in msg and "1(batch)" in msg


def test_deadline_miss_recorded(setup):
    """An unmeetable TTFT deadline lands in the trace, the per-class
    counters, and the snapshot's miss rate; a generous deadline does
    not."""
    cfg, params = setup
    eng = ServingEngine(cfg, params, slots=2, max_len=32)
    eng.submit(np.arange(4, dtype=np.int32), max_new_tokens=2,
               priority="premium", deadline_ms=1e-4)
    eng.submit(np.arange(5, dtype=np.int32), max_new_tokens=2,
               priority="premium", deadline_ms=1e9)
    eng.run()
    assert [r for (_, k, r) in eng.trace if k == "deadline_miss"] == [0]
    cls = eng.metrics.snapshot()["classes"]["premium"]
    assert cls["deadline_requests"] == 2
    assert cls["deadline_misses"] == 1
    assert cls["deadline_miss_rate"] == 0.5


def test_per_class_metrics_snapshot(setup):
    """The classes breakdown: completion counts partition the total,
    TTFT percentiles are ordered, and paged runs report a per-class
    peak page footprint."""
    cfg, params = setup
    eng = PagedServingEngine(cfg, params, page_size=8, num_pages=16,
                             max_seats=2, max_seq_len=32, prefill_chunk=8,
                             admission="slo")
    for i, prio in enumerate(["premium", "batch", "standard", "batch"]):
        eng.submit(np.arange(5 + i, dtype=np.int32), max_new_tokens=3,
                   priority=prio)
    eng.run()
    m = eng.metrics.snapshot()
    cls = m["classes"]
    assert set(cls) == {"premium", "standard", "batch"}
    assert sum(c["completed"] for c in cls.values()) == m["completed"] == 4
    for c in cls.values():
        assert 0 < c["ttft_p50_s"] <= c["ttft_p95_s"]
        assert c["peak_pages"] >= 1
    assert sum(c["preemptions"] for c in cls.values()) \
        == m["preemptions"]


# -- TBT decode deadlines ----------------------------------------------------

def test_tbt_tightens_rank_and_ages_past_standard():
    """A batch request with a tight TBT deadline starts below a fresh
    standard arrival, but aging lifts its class while the TBT due time
    gives it a finite effective deadline — so once aged level with the
    (undeadlined) standard request it strictly outranks it, despite
    the higher rid."""
    import dataclasses

    @dataclasses.dataclass
    class Stub:
        rid: int
        priority: str
        deadline_ms: object
        submit_tick: int
        t_submit: float = 0.0
        t_last_token: object = None
        tbt_deadline_ms: object = None

    pol = SLOAdmission(aging_ticks=4)
    tbt_batch = Stub(7, "batch", None, submit_tick=0,
                     tbt_deadline_ms=50.0)
    standard = Stub(1, "standard", None, submit_tick=4)
    assert pol.rank(tbt_batch, 3) > pol.rank(standard, 3)   # fresh: loses
    tick = 4                                 # aged one class: now wins
    assert pol.rank(tbt_batch, tick)[0] == PRIORITIES["standard"]
    assert pol.rank(tbt_batch, tick) < pol.rank(standard, tick)
    # the effective deadline follows the *next token*: a later
    # t_last_token pushes it out
    d0 = pol.rank(tbt_batch, tick)[1]
    tbt_batch.t_last_token = 2.0
    assert pol.rank(tbt_batch, tick)[1] == pytest.approx(2.0 + 0.050)
    assert pol.rank(tbt_batch, tick)[1] > d0


def test_tbt_effective_deadline_is_min_of_ttft_and_next_token():
    """With both deadlines set, rank uses whichever due time is
    earlier: TTFT before the first token, the TBT due time after a
    token lands (when it is tighter)."""
    import dataclasses

    @dataclasses.dataclass
    class Stub:
        rid: int
        priority: str
        deadline_ms: object
        submit_tick: int
        t_submit: float = 0.0
        t_last_token: object = None
        tbt_deadline_ms: object = None

    pol = SLOAdmission(aging_ticks=64)
    req = Stub(0, "standard", 1000.0, submit_tick=0,
               tbt_deadline_ms=40.0)
    assert pol.rank(req, 0)[1] == pytest.approx(0.040)   # TBT tighter
    req.tbt_deadline_ms = None
    assert pol.rank(req, 0)[1] == pytest.approx(1.0)     # TTFT only


def test_submit_rejects_bad_tbt_deadline(setup):
    cfg, params = setup
    eng = ServingEngine(cfg, params, slots=1, max_len=32)
    with pytest.raises(ValueError, match="tbt_deadline_ms"):
        eng.submit(np.arange(4, dtype=np.int32), tbt_deadline_ms=0)


def test_victim_shields_tbt_deadlined_within_class(setup):
    """Uniform class, one request TBT-deadlined: the undeadlined one
    is evicted even though the historical youngest-first rule would
    have picked the other — a decode-deadline-critical request is
    never the preferred victim while an alternative exists."""
    cfg, params = setup

    def run(tbt_rid):
        eng = PagedServingEngine(cfg, params, num_pages=7, **PKW)
        for i in range(2):
            mult = 3 if i == 0 else 7
            eng.submit((np.arange(8, dtype=np.int32) * mult)
                       % cfg.vocab_size, max_new_tokens=10,
                       tbt_deadline_ms=(10_000.0 if i == tbt_rid
                                        else None))
        eng.run()
        return eng

    shielded = run(tbt_rid=1)
    assert shielded.metrics.preemptions >= 1
    assert preempted_rids(shielded) == {0}   # youngest-first would say 1
    both_plain = run(tbt_rid=-1)
    assert preempted_rids(both_plain) == {1}  # fallback: youngest first


def test_pick_victim_no_tbt_matches_historical_key(setup):
    """With no TBT deadlines present, pick_victim's ordering collapses
    to the pre-TBT (class, rid) key on any victim set — the middle key
    is constant."""
    cfg, params = setup
    eng = PagedServingEngine(cfg, params, num_pages=32, **PKW)
    for prio in ("standard", "batch", "batch"):
        eng.submit(np.arange(6, dtype=np.int32), max_new_tokens=4,
                   priority=prio)
    victims = list(eng.queue)
    from repro.runtime.serving import priority_level
    old_rule = max(victims, key=lambda r: (priority_level(r), r.rid))
    assert eng.pick_victim(victims, victims[0]) is old_rule
