"""Checkpoint store: atomic commit, striping, async, GC, crash recovery."""
import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager, MANIFEST


@pytest.fixture
def tree():
    key = jax.random.PRNGKey(0)
    return {
        "params": {"w": jax.random.normal(key, (33, 17)),
                   "b": jnp.zeros((17,), jnp.bfloat16)},
        "opt": {"mu": {"w": jnp.ones((33, 17))}, "count": jnp.int32(7)},
    }


def trees_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(la, lb))


def test_save_restore_roundtrip(tmp_path, tree):
    mgr = CheckpointManager(str(tmp_path), stripes=3)
    mgr.save(5, tree)
    step, got = mgr.restore(tree)
    assert step == 5 and trees_equal(tree, got)
    # dtype preserved (incl. bfloat16)
    assert got["params"]["b"].dtype == np.dtype("bfloat16") or \
        str(got["params"]["b"].dtype) == "bfloat16"


def test_striping_layout(tmp_path, tree):
    mgr = CheckpointManager(str(tmp_path), stripes=4)
    d = mgr.save(1, tree)
    m = json.load(open(os.path.join(d, MANIFEST)))
    big = next(r for r in m["leaves"] if r["name"] == "params/w")
    assert len(big["files"]) == 4                      # striped across 4 OSTs
    osts = {f.split(os.sep)[0] for f in big["files"]}
    assert len(osts) == 4


def test_async_save_then_restore(tmp_path, tree):
    mgr = CheckpointManager(str(tmp_path))
    fut = mgr.save_async(3, tree)
    fut.result()
    step, got = mgr.restore(tree)
    assert step == 3 and trees_equal(tree, got)


def test_crash_mid_save_leaves_previous_intact(tmp_path, tree):
    """A stale .tmp dir (simulated crash) must be invisible to restore."""
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, tree)
    # simulate a crash: partial stage dir without manifest
    stage = os.path.join(str(tmp_path), "step_2.tmp")
    os.makedirs(os.path.join(stage, "ost0"))
    with open(os.path.join(stage, "ost0", "params.w.stripe0"), "wb") as f:
        f.write(b"garbage")
    assert mgr.latest_step() == 1
    step, got = mgr.restore(tree)
    assert step == 1 and trees_equal(tree, got)


def test_gc_keeps_last_k(tmp_path, tree):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    assert mgr.steps() == [3, 4]


def test_elastic_restore_to_new_sharding(tmp_path, tree):
    """Restore with explicit (single-device) shardings => device_put path."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(9, tree)
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)
    step, got = mgr.restore(tree, shardings=sh)
    assert step == 9 and trees_equal(tree, got)
    assert all(x.sharding == NamedSharding(mesh, P())
               for x in jax.tree.leaves(got))
