"""MoE block invariants: routing conservation, dropless exactness vs a
naive per-token loop, shared-expert path, aux loss properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, MoEConfig
from repro.models.modules import init_params
from repro.models.moe import (_moe_local, moe_specs, aux_load_balance_loss)


def make_cfg(E=6, k=2, shared=0):
    return ModelConfig(
        name="t", family="moe", num_layers=1, d_model=16, num_heads=2,
        num_kv_heads=2, d_ff=32, vocab_size=64, head_dim=8,
        # capacity_factor = num_experts => no capacity drops: these tests
        # assert exactness vs the naive loop (same convention as
        # configs.reduced_config; production keeps 1.25)
        moe=MoEConfig(num_experts=E, top_k=k, d_ff_expert=24,
                      capacity_factor=float(E),
                      num_shared_experts=shared, d_ff_shared=32 if shared else 0))


def naive_moe(p, cfg, x):
    """Per-token loop: route, apply each selected expert, combine."""
    m = cfg.moe
    B, S, D = x.shape
    xt = np.asarray(x.reshape(-1, D), np.float32)
    router = np.asarray(p["router"], np.float32)
    logits = xt @ router
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs /= probs.sum(-1, keepdims=True)
    out = np.zeros_like(xt)
    wg = np.asarray(p["wg"], np.float32)
    wu = np.asarray(p["wu"], np.float32)
    wd = np.asarray(p["wd"], np.float32)
    for t in range(xt.shape[0]):
        top = np.argsort(-probs[t])[:m.top_k]
        w = probs[t][top]
        w = w / w.sum()
        for e, we in zip(top, w):
            g = xt[t] @ wg[e]
            u = xt[t] @ wu[e]
            h = g / (1 + np.exp(-g)) * u
            out[t] += we * (h @ wd[e])
    return out.reshape(B, S, D)


@pytest.mark.parametrize("E,k", [(6, 2), (8, 1), (4, 4)])
def test_moe_matches_naive_loop(E, k):
    cfg = make_cfg(E, k)
    p = init_params(moe_specs(cfg), jax.random.PRNGKey(E * 10 + k))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 16), jnp.float32)
    got = _moe_local(p, cfg, x)
    want = naive_moe(p, cfg, x)
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-3, atol=2e-3)


def test_moe_shared_expert_added():
    cfg = make_cfg(4, 2, shared=2)
    p = init_params(moe_specs(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 4, 16), jnp.float32)
    with_shared = _moe_local(p, cfg, x)
    p_no = {k: v for k, v in p.items() if k != "shared"}
    without = _moe_local(p_no, cfg, x)
    assert float(jnp.max(jnp.abs(with_shared - without))) > 1e-6


def test_aux_loss_bounds():
    """Load-balance loss is >= 1 (perfect balance) for top-1 routing and
    penalizes collapse."""
    cfg = make_cfg(4, 1)
    p = init_params(moe_specs(cfg), jax.random.PRNGKey(3))
    # positive activations so a positive router column collapses routing
    x = jnp.abs(jax.random.normal(jax.random.PRNGKey(4), (4, 16, 16))) + 0.1
    loss = float(aux_load_balance_loss(p, cfg, x))
    assert loss >= 0.99
    # collapsed router (all tokens -> expert 0) must be >> balanced
    p_collapse = dict(p)
    bias = jnp.zeros((16, 4)).at[:, 0].set(100.0)
    p_collapse["router"] = p["router"] + bias
    loss_c = float(aux_load_balance_loss(p_collapse, cfg, x))
    assert loss_c > 2.0


def test_moe_flops_are_topk_not_all_experts():
    """Dropless path computes only top_k expert GEMMs per token: doubling
    the expert count with the same top_k must not change output given the
    same routing (new experts unrouted)."""
    cfg = make_cfg(4, 2)
    p = init_params(moe_specs(cfg), jax.random.PRNGKey(5))
    # positive activations: the -1e9 router columns then always lose
    x = jnp.abs(jax.random.normal(jax.random.PRNGKey(6), (1, 8, 16))) + 0.1
    base = _moe_local(p, cfg, x)
    cfg2 = make_cfg(8, 2)
    p2 = {
        "router": jnp.concatenate(
            [p["router"], jnp.full((16, 4), -1e9)], axis=1),
        "wg": jnp.concatenate([p["wg"], jnp.zeros_like(p["wg"])], axis=0),
        "wu": jnp.concatenate([p["wu"], jnp.zeros_like(p["wu"])], axis=0),
        "wd": jnp.concatenate([p["wd"], jnp.zeros_like(p["wd"])], axis=0),
    }
    out2 = _moe_local(p2, cfg2, x)
    np.testing.assert_allclose(np.asarray(base), np.asarray(out2),
                               rtol=1e-5, atol=1e-5)
