import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()
"""Perf hillclimb driver: run tagged variants of the three chosen cells and
print before/after roofline terms (EXPERIMENTS.md §Perf iteration log).

  PYTHONPATH=src python experiments/hillclimb.py <iter-name>
"""
import json
import sys

from repro.launch.dryrun import run_cell
from repro.models.model import RunOptions

OUT = "experiments/dryrun"


def show(rec, base_file):
    base = json.load(open(os.path.join(OUT, base_file)))
    for label, r in (("base", base), ("new ", rec)):
        rt = r["roofline"]
        print(f"  {label}: compute={rt['compute_s']:.3f}s memory={rt['memory_s']:.3f}s "
              f"collective={rt['collective_s']:.3f}s dominant={rt['dominant']} "
              f"flops/dev={r['per_device']['hlo_flops']:.3e} "
              f"args={r['memory']['argument_bytes'] / 2**30:.2f}GiB")


ITERS = {}


def register(name):
    def deco(fn):
        ITERS[name] = fn
        return fn
    return deco


@register("minicpm-padheads")
def _():
    """Iter M1: pad 36->48 heads so attention TPs 16-way instead of
    replicating. Hypothesis: per-device attention flops / score memory
    ÷(16/1.33)=12; function unchanged (padded heads hard-masked)."""
    r = run_cell("minicpm-2b", "train_4k", multi_pod=False,
                 tag="padheads", pad_heads=48)
    show(r, "minicpm-2b__train_4k__pod16x16.json")


@register("minicpm-padheads-bf16w")
def _():
    """Iter M2: + bf16 weight cast at step entry. Hypothesis: FSDP gather
    bytes halve; per-use converts collapse to one per param."""
    r = run_cell("minicpm-2b", "train_4k", multi_pod=False,
                 tag="padheads_bf16w", pad_heads=48,
                 opts=RunOptions(bf16_weights=True))
    show(r, "minicpm-2b__train_4k__pod16x16__padheads.json")


@register("minicpm-decode-padheads")
def _():
    """Iter M3: decode_32k with padded heads. Hypothesis: KV cache args
    96->~6GiB/dev (36 kv heads were replicated; 48 shard 16-way)."""
    r = run_cell("minicpm-2b", "decode_32k", multi_pod=False,
                 tag="padheads", pad_heads=48)
    show(r, "minicpm-2b__decode_32k__pod16x16.json")


@register("moe-capacity")
def _():
    """Iter Q1: capacity-based expert dispatch instead of ragged_dot's
    dense-per-expert fallback. Hypothesis: MoE GEMM flops ÷(E/(k·cf)) =
    60/(4·1.25)=12 on the MoE share; memory down similarly."""
    r = run_cell("qwen2-moe-a2.7b", "train_4k", multi_pod=False,
                 tag="capacity", opts=RunOptions(moe_impl="capacity"))
    show(r, "qwen2-moe-a2.7b__train_4k__pod16x16.json")


@register("moe-capacity-bf16w")
def _():
    """Iter Q2: + bf16 weights."""
    r = run_cell("qwen2-moe-a2.7b", "train_4k", multi_pod=False,
                 tag="capacity_bf16w",
                 opts=RunOptions(moe_impl="capacity", bf16_weights=True))
    show(r, "qwen2-moe-a2.7b__train_4k__pod16x16__capacity.json")


@register("grok-capacity")
def _():
    """Iter G1: grok-1-314b with capacity dispatch (8e top-2 => ÷3.2)."""
    r = run_cell("grok-1-314b", "train_4k", multi_pod=False,
                 tag="capacity", opts=RunOptions(moe_impl="capacity"))
    show(r, "grok-1-314b__train_4k__pod16x16.json")


@register("gemma-decode-kvseq")
def _():
    """Iter S1: decode_32k KV cache seq dim sharded over the (otherwise
    idle for 8-kv-head GQA) model axis. Hypothesis: args 96->~8GiB/dev,
    memory term ÷~12 (attention reads dominate decode)."""
    r = run_cell("gemma3-12b", "decode_32k", multi_pod=False,
                 tag="kvseq", opts=RunOptions(decode_kv_seq_axis=True))
    show(r, "gemma3-12b__decode_32k__pod16x16.json")


@register("gemma-long-ring")
def _():
    """Iter S2: long_500k with ring buffers on the 40 sliding-window layers
    (1024 slots instead of 524288). Hypothesis: cache bytes ÷~6 (only the
    8 global layers keep full KV)."""
    r = run_cell("gemma3-12b", "long_500k", multi_pod=False,
                 tag="ring", opts=RunOptions(ring_local_cache=True))
    show(r, "gemma3-12b__long_500k__pod16x16.json")


@register("gemma-long-ring-kvseq")
def _():
    """Iter S3: ring buffers + seq-sharded global-layer KV combined."""
    r = run_cell("gemma3-12b", "long_500k", multi_pod=False,
                 tag="ring_kvseq",
                 opts=RunOptions(ring_local_cache=True, decode_kv_seq_axis=True))
    show(r, "gemma3-12b__long_500k__pod16x16__ring.json")


@register("llama-bf16w")
def _():
    """Iter L1: llama3 train_4k with bf16 weight cast. Hypothesis: all-
    gather (FSDP) bytes halve; convert traffic drops; memory term down."""
    r = run_cell("llama3-8b", "train_4k", multi_pod=False, tag="bf16w",
                 opts=RunOptions(bf16_weights=True))
    show(r, "llama3-8b__train_4k__pod16x16.json")


@register("llama-bf16w-remat-dots")
def _():
    """Iter L2: + dots-saveable remat policy. Hypothesis: backward no
    longer recomputes matmuls => compute term ÷~1.3, memory term up a bit
    (saved activations)."""
    r = run_cell("llama3-8b", "train_4k", multi_pod=False,
                 tag="bf16w_dots",
                 opts=RunOptions(bf16_weights=True, remat_policy="dots"))
    show(r, "llama3-8b__train_4k__pod16x16__bf16w.json")




@register("llama-gradsync-multipod")
def _():
    """Iter L3 (paper-faithful rail-optimized sync, MULTI-POD): in-pod
    reduction full-precision on ICI (via FSDP reduce-scatter), cross-pod
    hop int8+error-feedback via partial shard_map over 'pod'. Hypothesis:
    cross-pod bytes ÷4 => collective term down ~proportionally to the
    pod-hop share of all-reduce traffic."""
    r = run_cell("llama3-8b", "train_4k", multi_pod=True, tag="gradsync",
                 opts=RunOptions(grad_sync="compressed"))
    show(r, "llama3-8b__train_4k__pod2x16x16.json")




@register("llama-pp-multipod")
def _():
    """Iter L4 (beyond-paper, fabric-aware): GPipe pipeline stages across
    the thin 'pod' axis (16 layer groups per stage, 8 microbatches).
    Hypothesis: layer-param gradients stop crossing pods entirely; cross-pod
    traffic becomes microbatch activation ppermutes (8 x 32*4096*4096*2B
    ~ 2.1 GiB/step total vs FSDP's per-shard grad hop) and per-stage layer
    memory halves. Cost: pipeline bubble 1/(M+1) ~ 11% of compute."""
    r = run_cell("llama3-8b", "train_4k", multi_pod=True, tag="pp",
                 opts=RunOptions(pipeline=True, pp_microbatches=8))
    show(r, "llama3-8b__train_4k__pod2x16x16.json")


if __name__ == "__main__":
    names = sys.argv[1:] or list(ITERS)
    for n in names:
        print(f"=== {n} ===")
        print(" ", ITERS[n].__doc__.strip().splitlines()[0])
        ITERS[n]()
