"""Render experiments/dryrun/*.json into the EXPERIMENTS.md tables.

  python experiments/make_tables.py [--mesh pod16x16] [--tag ""]
"""
import argparse
import json
import os

HERE = os.path.dirname(os.path.abspath(__file__))

ARCH_ORDER = ["minicpm-2b", "llama3-8b", "qwen3-1.7b", "gemma3-12b",
              "qwen2-moe-a2.7b", "grok-1-314b", "mamba2-130m",
              "whisper-base", "jamba-v0.1-52b", "phi-3-vision-4.2b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]

# 6·N·D model flops per token-equivalent; N from the configs (active for MoE)
PARAMS_B = {   # total, active (backbone, non-embedding, approx)
    "minicpm-2b": (2.4, 2.4), "llama3-8b": (8.0, 8.0),
    "qwen3-1.7b": (1.7, 1.7), "gemma3-12b": (11.8, 11.8),
    "qwen2-moe-a2.7b": (14.3, 2.7), "grok-1-314b": (314.0, 86.0),
    "mamba2-130m": (0.13, 0.13), "whisper-base": (0.073, 0.073),
    "jamba-v0.1-52b": (51.6, 12.0), "phi-3-vision-4.2b": (4.2, 4.2),
}
SHAPE_TOKENS = {"train_4k": 4096 * 256, "prefill_32k": 32768 * 32,
                "decode_32k": 128, "long_500k": 1}


def load(mesh: str, tag: str = ""):
    d = os.path.join(HERE, "dryrun")
    out = {}
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            name = f"{a}__{s}__{mesh}" + (f"__{tag}" if tag else "")
            p = os.path.join(d, name + ".json")
            if os.path.exists(p):
                out[(a, s)] = json.load(open(p))
    return out


def fmt_sec(x):
    return f"{x * 1e3:.1f}ms" if x < 10 else f"{x:.1f}s"


def roofline_table(mesh: str, tag: str = ""):
    recs = load(mesh, tag)
    print(f"\n### Roofline — {mesh}" + (f" ({tag})" if tag else "") + "\n")
    print("| arch | shape | compute | memory | collective | dominant | "
          "MODEL_FLOPS/HLO | note |")
    print("|---|---|---|---|---|---|---|---|")
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r = recs.get((a, s))
            if r is None:
                continue
            if not r.get("supported", True):
                print(f"| {a} | {s} | — | — | — | — | — | SKIP (full attention) |")
                continue
            rt = r["roofline"]
            n = r["n_chips"]
            _, active = PARAMS_B[a]
            mult = 6 if s == "train_4k" else 2
            model_flops = mult * active * 1e9 * SHAPE_TOKENS[s]
            ratio = model_flops / max(r["per_device"]["hlo_flops"] * n, 1)
            print(f"| {a} | {s} | {fmt_sec(rt['compute_s'])} | "
                  f"{fmt_sec(rt['memory_s'])} | {fmt_sec(rt['collective_s'])} | "
                  f"**{rt['dominant']}** | {ratio:.2f} | "
                  f"args {r['memory']['argument_bytes'] / 2**30:.1f}GiB/dev |")


def dryrun_table(mesh: str):
    recs = load(mesh)
    print(f"\n### Dry-run — {mesh}\n")
    print("| arch | shape | lower | compile | args/dev | temp/dev | "
          "flops/dev | coll bytes/dev |")
    print("|---|---|---|---|---|---|---|---|")
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r = recs.get((a, s))
            if r is None:
                continue
            if not r.get("supported", True):
                print(f"| {a} | {s} | — | — | — | — | — | SKIP |")
                continue
            m = r["memory"]
            print(f"| {a} | {s} | {r['lower_s']:.1f}s | {r['compile_s']:.1f}s | "
                  f"{(m['argument_bytes'] or 0) / 2**30:.2f}GiB | "
                  f"{(m['temp_bytes'] or 0) / 2**30:.2f}GiB | "
                  f"{r['per_device']['hlo_flops']:.2e} | "
                  f"{r['per_device']['collective_bytes']:.2e} |")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod16x16")
    ap.add_argument("--tag", default="")
    ap.add_argument("--table", default="roofline", choices=["roofline", "dryrun"])
    args = ap.parse_args()
    if args.table == "roofline":
        roofline_table(args.mesh, args.tag)
    else:
        dryrun_table(args.mesh)
