#!/usr/bin/env python
"""Check intra-repo markdown links in docs/ and README.md.

Every ``[text](target)`` whose target is a relative path must resolve to
a file in the repo (anchors are stripped; ``http(s)://`` and ``mailto:``
targets are skipped).  Also enforces the docs-set contract: README.md
must link both docs/serving.md and docs/benchmarks.md.

Run from the repo root (CI's docs job does):

  python scripts/check_doc_links.py

Exits non-zero listing every broken reference.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
EXTERNAL = ("http://", "https://", "mailto:")


def md_files(root: Path):
    files = [root / "README.md"]
    files += sorted((root / "docs").glob("*.md"))
    return [f for f in files if f.exists()]


def check(root: Path):
    errors = []
    readme_targets = set()
    for f in md_files(root):
        for m in LINK.finditer(f.read_text()):
            target = m.group(1).split("#")[0]
            if not target or target.startswith(EXTERNAL):
                continue
            resolved = (f.parent / target).resolve()
            if f.name == "README.md":
                readme_targets.add(target)
            if not resolved.exists():
                errors.append(f"{f.relative_to(root)}: broken link "
                              f"-> {m.group(1)}")
    required = {"docs/serving.md", "docs/benchmarks.md"}
    missing = {r for r in required
               if not any(t.endswith(r.split('/')[-1])
                          for t in readme_targets)}
    for r in sorted(missing):
        errors.append(f"README.md: missing required link to {r}")
    if not (root / "README.md").exists():
        errors.append("README.md does not exist")
    return errors


def main():
    root = Path(__file__).resolve().parent.parent
    errors = check(root)
    if errors:
        print(f"{len(errors)} broken doc reference(s):")
        for e in errors:
            print(f"  {e}")
        return 1
    n = len(md_files(root))
    print(f"doc links ok across {n} markdown file(s)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
