#!/usr/bin/env python
"""Check intra-repo markdown links in docs/ and README.md.

Thin shim kept for muscle memory and old CI references — the logic
lives in :mod:`repro.analysis.docscheck` and the canonical entry point
is::

  PYTHONPATH=src python -m repro.analysis --docs

Exits non-zero listing every broken reference.
"""
from __future__ import annotations

import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))

from repro.analysis.docscheck import run_docs_check  # noqa: E402


if __name__ == "__main__":
    sys.exit(run_docs_check(ROOT))
