#!/usr/bin/env bash
# Tier-1 test gate, exactly as CI runs it (ROADMAP.md "Tier-1 verify").
#
#   scripts/run_tests.sh              # full tier-1 suite
#   FAST=1 scripts/run_tests.sh       # skip slow/multidevice tests
#   scripts/run_tests.sh --lint       # repro-lint + doc links only (no pytest)
#   scripts/run_tests.sh tests/test_paged_kv.py   # extra pytest args pass through
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
if [[ "${1:-}" == "--lint" ]]; then
  shift
  python -m repro.analysis "$@"
  python -m repro.analysis --docs
  exit 0
fi
extra=()
if [[ "${FAST:-0}" == "1" ]]; then
  extra+=(-m "not slow and not multidevice")
fi
exec python -m pytest -x -q ${extra[@]+"${extra[@]}"} "$@"
