#!/usr/bin/env python
"""Render a flight-recorder or Perfetto dump as a per-request timeline.

Stdlib-only companion to :mod:`repro.runtime.telemetry` for when a
browser (ui.perfetto.dev) is not at hand — point it at any of:

* a flight-recorder snapshot or postmortem JSON (top-level ``events``
  list, or nested under ``flight_recorder``; postmortems written by
  ``Telemetry.write_postmortem`` are the latter),
* a Chrome trace-event JSON written by ``write_perfetto`` /
  ``--trace-export`` (top-level ``traceEvents``),

and it prints one timeline per request id: the span phases
(queued / prefill / replay / decode) with durations, plus instant
events (preempt, deadline_miss, tbt_miss, ...) in order::

  PYTHONPATH=src python scripts/trace_view.py postmortem.json
  python scripts/trace_view.py trace.json --rid 7 --format md

``--format md`` emits a markdown table per request for pasting into an
issue; the default is aligned plain text.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import Dict, List, Tuple

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))

from repro.runtime.telemetry import build_spans, event_from_dict  # noqa: E402


def load_trace(path: str) -> Tuple[List[dict], List[dict]]:
    """Return (spans, instants) from any supported dump format."""
    with open(path) as f:
        doc = json.load(f)
    if not isinstance(doc, dict):
        raise SystemExit(f"{path}: expected a JSON object at top level")
    if "traceEvents" in doc:
        return _from_perfetto(doc["traceEvents"])
    events = doc.get("events")
    if events is None:
        events = doc.get("flight_recorder", {}).get("events")
    if events is None:
        raise SystemExit(f"{path}: no 'events', 'flight_recorder.events' "
                         "or 'traceEvents' key — not a telemetry dump")
    built = build_spans([event_from_dict(d) for d in events])
    return built["spans"], built["instants"]


def _from_perfetto(trace_events: List[dict]) -> Tuple[List[dict], List[dict]]:
    """Recover span/instant dicts from Chrome trace-event JSON."""
    pid_engine: Dict[int, str] = {}
    for ev in trace_events:
        if ev.get("ph") == "M" and ev.get("name") == "process_name":
            pid_engine[ev["pid"]] = ev.get("args", {}).get("name", "engine")
    spans, instants = [], []
    for ev in trace_events:
        ph = ev.get("ph")
        engine = pid_engine.get(ev.get("pid"), "engine")
        if ph == "X":
            tid = ev.get("tid", 0)
            spans.append({
                "engine": engine,
                "rid": ev.get("args", {}).get("rid", -1),
                "name": ev["name"],
                "t0": ev["ts"] / 1e6,
                "t1": (ev["ts"] + ev.get("dur", 0)) / 1e6,
                "seat": None if tid == 0 else tid - 1,
            })
        elif ph in ("i", "I"):
            args = dict(ev.get("args", {}))
            instants.append({
                "engine": engine,
                "rid": args.pop("rid", -1),
                "kind": ev["name"],
                "t": ev["ts"] / 1e6,
                "seat": None,
                "attrs": args,
            })
    return spans, instants


def _fmt_s(dt: float) -> str:
    if dt >= 1.0:
        return f"{dt:.3f}s"
    return f"{dt * 1e3:.3f}ms"


def render(spans: List[dict], instants: List[dict], *, rid=None,
           fmt: str = "text") -> str:
    """Render per-rid timelines; returns the full report string."""
    by_rid: Dict[Tuple[str, int], List[dict]] = {}
    for sp in spans:
        if sp["rid"] < 0 or (rid is not None and sp["rid"] != rid):
            continue
        by_rid.setdefault((sp["engine"], sp["rid"]), []).append(sp)
    inst_by_rid: Dict[Tuple[str, int], List[dict]] = {}
    for ins in instants:
        if ins["rid"] < 0 or (rid is not None and ins["rid"] != rid):
            continue
        inst_by_rid.setdefault((ins["engine"], ins["rid"]), []).append(ins)

    out: List[str] = []
    for key in sorted(by_rid, key=lambda k: (k[0], k[1])):
        engine, r = key
        rows = sorted(by_rid[key], key=lambda s: s["t0"])
        t_base = rows[0]["t0"]
        marks = sorted(inst_by_rid.get(key, []), key=lambda i: i["t"])
        if fmt == "md":
            out.append(f"### rid {r} ({engine})")
            out.append("")
            out.append("| phase | start | duration | seat |")
            out.append("|---|---|---|---|")
            for sp in rows:
                seat = "-" if sp["seat"] is None else str(sp["seat"])
                out.append(f"| {sp['name']} | +{_fmt_s(sp['t0'] - t_base)} "
                           f"| {_fmt_s(sp['t1'] - sp['t0'])} | {seat} |")
            for ins in marks:
                out.append(f"| *{ins['kind']}* "
                           f"| +{_fmt_s(ins['t'] - t_base)} | - | - |")
            out.append("")
        else:
            out.append(f"rid {r} ({engine})")
            for sp in rows:
                seat = " " if sp["seat"] is None else str(sp["seat"])
                out.append(f"  {sp['name']:<10s} +{_fmt_s(sp['t0'] - t_base):>10s}"
                           f"  dur {_fmt_s(sp['t1'] - sp['t0']):>10s}  seat {seat}")
            for ins in marks:
                out.append(f"  ! {ins['kind']:<12s} "
                           f"+{_fmt_s(ins['t'] - t_base):>10s}")
    if not out:
        out.append("no request spans found"
                   + ("" if rid is None else f" for rid {rid}"))
    return "\n".join(out)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="flight-recorder / postmortem / Perfetto JSON")
    ap.add_argument("--rid", type=int, default=None,
                    help="only show this request id")
    ap.add_argument("--format", choices=("text", "md"), default="text")
    args = ap.parse_args(argv)
    spans, instants = load_trace(args.trace)
    try:
        print(render(spans, instants, rid=args.rid, fmt=args.format))
    except BrokenPipeError:                 # | head closed the pipe
        sys.stderr.close()
    return 0


if __name__ == "__main__":
    sys.exit(main())
