"""Continuous-batching serving demo: a stream of mixed-length requests
flows through a fixed pool of KV-cache slots; slots are re-admitted as
requests finish (no head-of-line blocking on the longest generation).

The fixed-slot engine runs through the same unified ``Scheduler`` and
shared sampler as the paged engine, so the sampling flags behave
identically here (default greedy; ``--temperature`` > 0 draws from the
per-request deterministic stream), and so do the SLO flags:
``--admission slo`` reorders the queue by priority class + earliest
deadline, ``--mixed-classes`` cycles each request through
premium/standard/batch to make the reordering visible in a single run.

  PYTHONPATH=src python examples/continuous_batching.py --arch qwen3-1.7b \
      --temperature 0.8 --top-p 0.9 --seed 7
  PYTHONPATH=src python examples/continuous_batching.py --slots 2 \
      --mixed-classes --admission slo
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, reduced_config
from repro.launch.serve import (add_model_arg, add_sampling_args,
                                add_slo_args, sampling_from_args)
from repro.models import model as M
from repro.runtime.serving import ServingEngine


def main():
    ap = argparse.ArgumentParser()
    add_model_arg(ap)   # --model/--arch via the config registry
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--mixed-classes", action="store_true",
                    help="cycle requests through premium/standard/batch "
                         "instead of a single --priority class")
    add_sampling_args(ap)
    add_slo_args(ap)
    args = ap.parse_args()
    sampling = sampling_from_args(args)

    cfg = reduced_config(get_config(args.arch))
    params = M.init_params(M.param_specs(cfg), jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, slots=args.slots, max_len=96,
                        admission=args.admission,
                        aging_ticks=args.aging_ticks)

    classes = ("premium", "standard", "batch")
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for i in range(args.requests):
        plen = int(rng.integers(4, 24))
        gen = int(rng.integers(4, 20))
        prio = classes[i % 3] if args.mixed_classes else args.priority
        eng.submit(rng.integers(0, cfg.vocab_size, plen).astype(np.int32),
                   max_new_tokens=gen, eos_id=args.eos_id, sampling=sampling,
                   priority=prio, deadline_ms=args.deadline_ms)
    done = eng.run()
    wall = time.perf_counter() - t0

    total_toks = sum(len(r.generated) for r in done)
    print(f"served {len(done)} requests / {total_toks} tokens "
          f"in {wall:.2f}s on {args.slots} slots")
    for r in done[:5]:
        ttft = (r.t_first_token - r.t_submit) * 1e3
        print(f"  req{r.rid}: prompt={len(r.prompt):2d} "
              f"gen={len(r.generated):2d} class={r.priority:8s} "
              f"ttft={ttft:6.0f}ms")
    for cls, cm in eng.metrics.snapshot()["classes"].items():
        print(f"  class {cls}: ttft_avg {cm['ttft_avg_s'] * 1e3:.0f} ms "
              f"(p95 {cm['ttft_p95_s'] * 1e3:.0f} ms), "
              f"{cm['completed']:.0f} completed")


if __name__ == "__main__":
    main()
