"""Fault-tolerance demo: train, crash mid-run, recover from the last
committed striped checkpoint, and verify the deterministic data pipeline
replays the exact stream (DESIGN.md §8 recovery contract).

  PYTHONPATH=src python examples/elastic_recovery.py
"""
import tempfile

from repro.launch.train import train
from repro.runtime.elastic import plan_remesh


def main():
    ckpt = tempfile.mkdtemp(prefix="elastic_demo_")
    print("=== phase 1: train, then crash at step 17 ===")
    try:
        train("mamba2-130m", steps=30, batch=4, seq=64, reduced=True,
              ckpt_dir=ckpt, ckpt_every=5, fail_at_step=17, log_every=5)
    except RuntimeError as e:
        print(f"!! {e}")

    print("\n=== phase 2: restart -> resumes from last committed step ===")
    losses = train("mamba2-130m", steps=30, batch=4, seq=64, reduced=True,
                   ckpt_dir=ckpt, ckpt_every=10, log_every=5)
    print(f"recovered and finished; final loss {losses[-1]:.4f}")

    print("\n=== phase 3: remesh planning after node failures ===")
    hosts = [f"node{i:03d}" for i in range(64)]           # 64 hosts × 8 chips
    for lost in (0, 3, 17):
        survivors = hosts[lost:]
        plan = plan_remesh(survivors, devices_per_host=8, model_parallel=16,
                           num_pods=2)
        print(f"lost {lost:2d} hosts -> mesh {plan.mesh_shape} "
              f"(idle hosts: {len(plan.hosts_idle)}, capacity dropped "
              f"{plan.dropped_capacity_frac:.1%})")


if __name__ == "__main__":
    main()
