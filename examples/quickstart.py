"""Quickstart: build a model, run a forward pass, take one training step.

  PYTHONPATH=src python examples/quickstart.py [--arch qwen3-1.7b]
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced_config, make_example_batch
from repro.models import model as M
from repro.optim.adamw import adamw_update, init_opt_state
from repro.parallel.sharding import SINGLE_DEVICE_RULES


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    args = ap.parse_args()

    # 1. Config: the exact assigned architecture, reduced for CPU.
    cfg = reduced_config(get_config(args.arch))
    print(f"arch={cfg.name} family={cfg.family} layers={cfg.num_layers} "
          f"d_model={cfg.d_model}")

    # 2. Parameters from the spec tree (logical axes drive sharding on TPU).
    specs = M.param_specs(cfg)
    params = M.init_params(specs, jax.random.PRNGKey(0))
    from repro.models.modules import count_params
    print(f"params: {count_params(specs):,}")

    # 3. Forward + loss.
    opts = M.RunOptions(q_chunk=32, xent_chunk=32)
    batch = make_example_batch(cfg, "train", batch=2, seq=64)
    loss, metrics = jax.jit(
        lambda p, b: M.lm_loss(p, cfg, b, SINGLE_DEVICE_RULES, opts))(params, batch)
    print(f"initial loss={float(loss):.4f} (ln V = "
          f"{jnp.log(cfg.vocab_size):.4f})")

    # 4. One AdamW step.
    opt = init_opt_state(params)
    (loss2, _), grads = jax.jit(jax.value_and_grad(
        lambda p, b: M.lm_loss(p, cfg, b, SINGLE_DEVICE_RULES, opts),
        has_aux=True))(params, batch)
    params, opt, om = adamw_update(grads, opt, params, 1e-3)
    print(f"step done; grad_norm={float(om['grad_norm']):.3f}")


if __name__ == "__main__":
    main()
