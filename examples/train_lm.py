"""End-to-end training driver example (deliverable b): trains a reduced
model for a few hundred steps with WSD schedule, striped async checkpoints,
and deterministic data — loss must visibly decrease.

  PYTHONPATH=src python examples/train_lm.py --arch minicpm-2b --steps 300
"""
import argparse
import tempfile

from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minicpm-2b",
                    help="minicpm-2b uses the WSD schedule (its assigned "
                         "signature feature)")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    ckpt = args.ckpt_dir or tempfile.mkdtemp(prefix="train_lm_")
    losses = train(args.arch, steps=args.steps, batch=args.batch,
                   seq=args.seq, reduced=True, ckpt_dir=ckpt,
                   ckpt_every=50, log_every=20)
    first = sum(losses[:10]) / 10
    last = sum(losses[-10:]) / 10
    print(f"\nloss: {first:.4f} -> {last:.4f} "
          f"({'IMPROVED' if last < first else 'no improvement?'})")
    print(f"checkpoints in {ckpt}")


if __name__ == "__main__":
    main()
