"""Multi-model fleet demo: two architectures served from one process,
one shared host page budget, replica routing, and session affinity.

A ``ModelFleet`` owns one engine per (model, replica) — here a
2-replica qwen3 group and a single llama3 engine, all reduced configs —
and routes ``submit(model=..., session_id=...)`` calls across them.
The demo runs two chat turns per session: turn 2 extends turn 1's
prompt, and because affinity pins a session to the replica that served
it, the follow-up turn lands where the session's prompt pages are
still registered — watch the nonzero prefix-hit rate on the home
replica and rids that never collide across engines.

  PYTHONPATH=src python examples/multi_model_fleet.py --sessions 3
  PYTHONPATH=src python examples/multi_model_fleet.py --selection round-robin
"""
import argparse

import jax
import numpy as np

from repro.configs import get_config, reduced_config
from repro.launch.serve import add_sampling_args, sampling_from_args
from repro.models import model as M
from repro.runtime.router import FleetModel, ModelFleet


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sessions", type=int, default=3,
                    help="two-turn chat sessions on the replicated model")
    ap.add_argument("--oneshots", type=int, default=4,
                    help="single-turn requests on the second model")
    ap.add_argument("--selection", choices=("least-loaded", "round-robin"),
                    default="least-loaded")
    ap.add_argument("--total-pages", type=int, default=48,
                    help="shared host page budget across every engine")
    ap.add_argument("--gen", type=int, default=6)
    add_sampling_args(ap)
    args = ap.parse_args()
    sampling = sampling_from_args(args)

    page_size = 8
    entries = []
    for i, (name, replicas) in enumerate((("qwen3-1.7b", 2),
                                          ("llama3-8b", 1))):
        cfg = reduced_config(get_config(name))
        params = M.init_params(M.param_specs(cfg),
                               jax.random.PRNGKey(args.seed + i))
        entries.append(FleetModel(name, cfg, params, replicas=replicas))
    fleet = ModelFleet(entries, total_pages=args.total_pages,
                       page_size=page_size, max_seats=4,
                       max_seq_len=64, prefill_chunk=page_size,
                       selection=args.selection)

    rng = np.random.default_rng(args.seed)
    vocab = entries[0].cfg.vocab_size

    # turn 1: one prompt per session on the replicated model (prompts
    # span >1 page so at least one full page lands in the prefix index),
    # plus unrelated one-shot requests on the second model
    turn1 = {}
    for s in range(args.sessions):
        prompt = rng.integers(0, vocab, page_size + 4).astype(np.int32)
        rid = fleet.submit(model="qwen3-1.7b", prompt=prompt,
                           max_new_tokens=args.gen, eos_id=args.eos_id,
                           sampling=sampling, session_id=f"chat-{s}")
        turn1[s] = (rid, prompt)
    for _ in range(args.oneshots):
        plen = int(rng.integers(4, 2 * page_size))
        fleet.submit(model="llama3-8b",
                     prompt=rng.integers(0, vocab, plen).astype(np.int32),
                     max_new_tokens=args.gen, eos_id=args.eos_id,
                     sampling=sampling)
    done = fleet.run()

    # turn 2: extend each session's conversation (turn-1 prompt + reply
    # + a fresh user utterance) — affinity routes it to the home
    # replica, where the leading pages are prefix-cache hits
    for s in range(args.sessions):
        rid1, prompt = turn1[s]
        reply = np.asarray(done[rid1].generated, np.int32)
        follow = np.concatenate(
            [prompt, reply, rng.integers(0, vocab, 3).astype(np.int32)])
        fleet.submit(model="qwen3-1.7b", prompt=follow,
                     max_new_tokens=args.gen, eos_id=args.eos_id,
                     sampling=sampling, session_id=f"chat-{s}")
    done = fleet.run()

    m = fleet.metrics_snapshot()
    f = m["fleet"]
    print(f"fleet:   {f['completed']:.0f} requests, "
          f"{f['generated_tokens']:.0f} tokens "
          f"({f['tokens_per_s']:.1f} tok/s), budget "
          f"{m['budget']['total_pages']} pages "
          f"(surplus {m['budget']['surplus_pages']})")
    for name, mm in m["models"].items():
        print(f"model:   {name}: {mm['completed']:.0f} completed, "
              f"prefix_hit_rate={mm['prefix_hit_rate']:.2f}, "
              f"preemptions={mm['preemptions']:.0f}")
        for i, rs in enumerate(mm["replicas"]):
            print(f"           replica {i}: {rs['completed']:.0f} done, "
                  f"prefix_hit_rate={rs['prefix_hit_rate']:.2f}")
    for s in range(args.sessions):
        home = fleet.home_replica("qwen3-1.7b", f"chat-{s}")
        print(f"session: chat-{s} pinned to qwen3-1.7b replica {home}")
    rids = sorted(done)
    print(f"rids:    {rids[0]}..{rids[-1]} fleet-global "
          "(no sampler-key collisions across engines)")


if __name__ == "__main__":
    main()
