"""Batched serving example: prefill a batch of prompts, decode with greedy
sampling from the KV cache (the same decode_step the decode_32k /
long_500k dry-run cells lower).

  PYTHONPATH=src python examples/serve_lm.py --arch gemma3-12b --gen 24
"""
import argparse

from repro.launch.serve import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-12b",
                    help="gemma3 exercises the 5:1 local:global attention "
                         "cache (sliding-window + global layers)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()
    r = serve(args.arch, batch=args.batch, prompt_len=args.prompt_len,
              gen=args.gen)
    print(f"prefill: {r['prefill_s'] * 1e3:.0f} ms")
    print(f"decode:  {r['decode_s'] * 1e3:.0f} ms "
          f"({r['tokens_per_s']:.1f} tok/s)")
    for i, row in enumerate(r["generated"][:4]):
        print(f"  request[{i}] -> {row.tolist()}")


if __name__ == "__main__":
    main()
