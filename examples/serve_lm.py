"""Serving example: static batch or the paged continuous-batching engine.

``--engine batch`` prefills a batch of equal-length prompts and decodes
them in lockstep; ``--engine paged`` streams mixed-length requests
through the paged-KV engine (shared page pool, chunked prefill,
continuous admission, refcounted prefix caching) and prints its serving
metrics.  Sampling flags (``--temperature/--top-k/--top-p/--seed``) and
``--eos-id`` flow through the shared ``runtime.sampler`` on both paths;
the default is greedy.

SLO flags (``--priority/--deadline-ms/--admission/--aging-ticks``) tag
every request with a priority class and switch the scheduler queue from
FCFS to priority + earliest-deadline-first admission — see
docs/serving.md.

  PYTHONPATH=src python examples/serve_lm.py --arch gemma3-12b --gen 24
  PYTHONPATH=src python examples/serve_lm.py --engine paged \
      --arch qwen3-1.7b --requests 8 --temperature 0.7 --top-k 40
  PYTHONPATH=src python examples/serve_lm.py --engine paged \
      --admission slo --priority premium --deadline-ms 2000
"""
import argparse

from repro.launch.serve import (add_model_arg, add_sampling_args,
                                add_slo_args, sampling_from_args, serve,
                                serve_paged)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--engine", choices=("batch", "paged"), default="batch")
    # --model/--arch resolves through configs.registry (module-style
    # aliases like gemma3_12b work; unknown names error naming the flag).
    # gemma3 exercises the 5:1 local:global attention cache
    # (sliding-window + global layers).
    add_model_arg(ap, default="gemma3-12b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=None,
                    help="fixed prompt length (batch default 32; the "
                         "paged engine samples lengths when unset)")
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-seq-len", type=int, default=None,
                    help="per-request prompt+generation bound (paged)")
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="disable prefix-cache page sharing (paged engine)")
    ap.add_argument("--lazy-pages", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="on-demand KV page growth + preemption (paged)")
    ap.add_argument("--watermark", type=float, default=0.05,
                    help="lazy admission free-page headroom fraction")
    add_sampling_args(ap)
    add_slo_args(ap)
    args = ap.parse_args()
    sampling = sampling_from_args(args)
    if args.engine == "paged":
        r = serve_paged(args.arch, requests=args.requests, gen=args.gen,
                        seed=args.seed, eos_id=args.eos_id, sampling=sampling,
                        prefix_cache=not args.no_prefix_cache,
                        max_seq_len=args.max_seq_len,
                        prompt_len=args.prompt_len,
                        lazy_pages=args.lazy_pages,
                        watermark=args.watermark,
                        priority=args.priority, deadline_ms=args.deadline_ms,
                        admission=args.admission,
                        aging_ticks=args.aging_ticks)
        m = r["metrics"]
        print(f"served:  {m['completed']:.0f} requests, "
              f"{m['generated_tokens']:.0f} tokens "
              f"({m['tokens_per_s']:.1f} tok/s)")
        print(f"ttft:    avg {m['ttft_avg_s'] * 1e3:.0f} ms, "
              f"max {m['ttft_max_s'] * 1e3:.0f} ms")
        print(f"pages:   peak {m['peak_pages_in_use']:.0f}/"
              f"{m['page_capacity']:.0f} "
              f"(util {m['peak_page_utilization']:.2f}, "
              f"prefix hits {m['prefix_hit_rate']:.2f}, "
              f"preemptions {m['preemptions']:.0f})")
        for cls, cm in m["classes"].items():
            print(f"classes: {cls}: ttft_avg "
                  f"{cm['ttft_avg_s'] * 1e3:.0f} ms, p95 "
                  f"{cm['ttft_p95_s'] * 1e3:.0f} ms, "
                  f"deadline misses {cm['deadline_misses']:.0f}/"
                  f"{cm['deadline_requests']:.0f}")
        for req in r["finished"][:4]:
            print(f"  request[{req.rid}] -> {req.generated}")
        return
    r = serve(args.arch, batch=args.batch, prompt_len=args.prompt_len or 32,
              gen=args.gen, seed=args.seed, sampling=sampling)
    print(f"prefill: {r['prefill_s'] * 1e3:.0f} ms")
    print(f"decode:  {r['decode_s'] * 1e3:.0f} ms "
          f"({r['tokens_per_s']:.1f} tok/s)")
    for i, row in enumerate(r["generated"][:4]):
        print(f"  request[{i}] -> {row.tolist()}")


if __name__ == "__main__":
    main()
