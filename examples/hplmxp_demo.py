"""The paper's HPL-MxP method end-to-end: factor in 'sloppy FP8', refine to
full accuracy, validate with the TOP500 criterion (residual < 16).

  PYTHONPATH=src python examples/hplmxp_demo.py --n 768
"""
import argparse

from repro.core.hpl import run_hpl
from repro.core.hplmxp import run_hplmxp


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=768)
    ap.add_argument("--nb", type=int, default=128)
    args = ap.parse_args()

    print(f"=== HPL (fp32 reference), N={args.n} NB={args.nb} ===")
    hi = run_hpl(args.n, args.nb)
    print(f"  {hi['gflops']:.2f} GFLOP/s, residual {hi['residual']:.2e}, "
          f"passed={hi['passed']}")

    for prec in ("bf16", "fp8"):
        print(f"=== HPL-MxP ({prec} LU + iterative refinement) ===")
        r = run_hplmxp(args.n, args.nb, lowprec=prec, ir_iters=6)
        print(f"  LU-only: {r['gflops_lu_only']:.2f} GFLOP/s")
        print(f"  residual {r['residual']:.2e} -> passed={r['passed']} "
          f"(criterion < 16, paper Table 9: 5.01e-05)")
        print(f"  IR history: {[f'{h:.1e}' for h in r['ir_history']]}")


if __name__ == "__main__":
    main()
