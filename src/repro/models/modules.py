"""Parameter spec system + common neural-net modules (pure JAX).

Parameters are described by ``ParamSpec(shape, axes, init)`` where ``axes``
is a tuple of *logical* axis names consumed by ``repro.parallel.sharding``.
A model is a nested dict of ParamSpecs; ``init_params`` materializes arrays
and ``abstract_params`` produces ShapeDtypeStructs for allocation-free
lowering (the multi-pod dry-run).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"       # normal | zeros | ones
    scale: float = 1.0

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def init_params(specs, key, dtype=jnp.float32):
    """Materialize a params pytree from a spec tree (smoke tests / examples)."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    out = []
    for spec, k in zip(leaves, keys):
        if spec.init == "zeros":
            out.append(jnp.zeros(spec.shape, dtype))
        elif spec.init == "ones":
            out.append(jnp.ones(spec.shape, dtype))
        else:
            fan_in = spec.shape[0] if spec.shape else 1
            std = spec.scale / math.sqrt(max(fan_in, 1))
            out.append((jax.random.normal(k, spec.shape) * std).astype(dtype))
    return jax.tree.unflatten(treedef, out)


def abstract_params(specs, dtype=jnp.float32):
    """ShapeDtypeStruct pytree — no allocation; feeds .lower() in the dry-run."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype), specs, is_leaf=is_spec)


def axes_tree(specs):
    """Logical-axes pytree parallel to the params pytree."""
    return jax.tree.map(lambda s: s.axes, specs, is_leaf=is_spec)


def count_params(specs) -> int:
    return sum(int(np.prod(s.shape)) for s in jax.tree.leaves(specs, is_leaf=is_spec))


# ---------------------------------------------------------------------------
# Modules
# ---------------------------------------------------------------------------

def rms_norm(x, w, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + w.astype(jnp.float32))).astype(x.dtype)


def rope(x, positions, theta=10_000.0):
    """Rotary embedding. x: (..., seq, heads..., head_dim); positions (..., seq)."""
    hd = x.shape[-1]
    freqs = 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))
    angles = positions.astype(jnp.float32)[..., None] * freqs  # (..., seq, hd/2)
    # insert singleton axes for head dims between seq and head_dim
    extra = x.ndim - angles.ndim
    for _ in range(extra):
        angles = angles[..., None, :]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def swiglu(x, wg, wu, wd, compute_dtype):
    g = jnp.einsum("...d,df->...f", x, wg.astype(compute_dtype))
    u = jnp.einsum("...d,df->...f", x, wu.astype(compute_dtype))
    h = jax.nn.silu(g) * u
    return jnp.einsum("...f,fd->...d", h, wd.astype(compute_dtype))


def mlp_specs(d_model: int, d_ff: int, prefix_axes=("embed", "ff")) -> dict:
    e, f = prefix_axes
    return {
        "wg": ParamSpec((d_model, d_ff), (e, f)),
        "wu": ParamSpec((d_model, d_ff), (e, f)),
        "wd": ParamSpec((d_ff, d_model), (f, e)),
    }


def softmax_xent_chunked(x, w_out, labels, *, chunk: int = 512,
                         compute_dtype=jnp.bfloat16):
    """Cross-entropy without materializing full (B,S,V) logits.

    x: (B, S, D) final hidden; w_out: (D, V); labels: (B, S) int32.
    Scans over sequence chunks so peak logits memory is (B, chunk, V).
    Returns (sum_loss, sum_tokens).
    """
    B, S, D = x.shape
    n = max(S // chunk, 1)
    cs = S // n
    xs = x.reshape(B, n, cs, D).swapaxes(0, 1)          # (n, B, cs, D)
    ls = labels.reshape(B, n, cs).swapaxes(0, 1)        # (n, B, cs)

    def body(carry, xl):
        xc, lc = xl
        logits = jnp.einsum("bsd,dv->bsv", xc, w_out.astype(compute_dtype))
        logits = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        mask = (lc >= 0).astype(jnp.float32)
        loss = jnp.sum((lse - gold) * mask)
        return (carry[0] + loss, carry[1] + jnp.sum(mask)), None

    (total, count), _ = jax.lax.scan(body, (0.0, 0.0), (xs, ls))
    return total, count
