"""Mamba-2 (SSD — state-space duality) block, pure JAX.

Training / prefill uses the chunked SSD algorithm (arXiv:2405.21060):
intra-chunk quadratic ("attention-like") term + inter-chunk recurrent state
carried with an associative scan.  Decode is the O(1)-per-token recurrence
on the (B, H, P, N) state, which is what makes the assigned ``long_500k``
cell applicable to SSM/hybrid architectures.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.modules import ParamSpec, rms_norm


def ssm_dims(cfg):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    nheads = d_inner // s.head_dim
    conv_dim = d_inner + 2 * s.n_groups * s.d_state
    return d_inner, nheads, conv_dim


def ssm_specs(cfg) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    d_inner, nheads, conv_dim = ssm_dims(cfg)
    d_in_proj = 2 * d_inner + 2 * s.n_groups * s.d_state + nheads
    return {
        "in_proj": ParamSpec((d, d_in_proj), ("embed", "ssm_inner")),
        "conv_w": ParamSpec((s.conv_width, conv_dim), ("conv_width", "ssm_inner")),
        "conv_b": ParamSpec((conv_dim,), ("ssm_inner",), "zeros"),
        "A_log": ParamSpec((nheads,), ("ssm_heads",), "ones"),
        "D": ParamSpec((nheads,), ("ssm_heads",), "ones"),
        "dt_bias": ParamSpec((nheads,), ("ssm_heads",), "zeros"),
        "norm": ParamSpec((d_inner,), ("ssm_inner",), "zeros"),
        "out_proj": ParamSpec((d_inner, d), ("ssm_inner", "embed")),
    }


def _split_zxbcdt(cfg, zxbcdt):
    s = cfg.ssm
    d_inner, nheads, _ = ssm_dims(cfg)
    gs = s.n_groups * s.d_state
    z, xc, B, C, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + gs, 2 * d_inner + 2 * gs], axis=-1)
    return z, xc, B, C, dt


def _causal_conv(x, w, b, *, init_state=None):
    """Depthwise causal conv, width W. x: (B,S,C); w: (W,C). Returns y, tail."""
    W = w.shape[0]
    if init_state is None:
        pad = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    else:
        pad = init_state
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i].astype(x.dtype) for i in range(W))
    tail = xp[:, -(W - 1):, :] if W > 1 else jnp.zeros((x.shape[0], 0, x.shape[2]), x.dtype)
    return y + b.astype(x.dtype), tail


def _ssd_chunked(xh, dt, A, Bm, Cm, chunk: int):
    """Chunked SSD. xh:(B,S,H,P) dt:(B,S,H) A:(H,) Bm/Cm:(B,S,G,N).

    Returns y:(B,S,H,P).
    """
    B, S, H, P = xh.shape
    G, N = Bm.shape[2], Bm.shape[3]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    rep = H // G

    def cshape(t):  # (B,S,...) -> (B,nc,chunk,...)
        return t.reshape(B, nc, chunk, *t.shape[2:])

    xh_, dt_, B_, C_ = map(cshape, (xh, dt, Bm, Cm))
    dA = dt_ * A[None, None, None, :]                       # (B,nc,L,H) negative
    dA_cs = jnp.cumsum(dA, axis=2)                          # within-chunk cumsum

    # Intra-chunk (quadratic in chunk length): mask s>=t, decay exp(dAcs_s - dAcs_t)
    Bh = jnp.repeat(B_, rep, axis=3)                        # (B,nc,L,H,N) via group->head
    Ch = jnp.repeat(C_, rep, axis=3)
    scores = jnp.einsum("bclhn,bcthn->bchlt", Ch, Bh)       # (B,nc,H,L,T)
    dh = dA_cs.transpose(0, 1, 3, 2)                        # (B,nc,H,L)
    diff = dh[..., :, None] - dh[..., None, :]              # (B,nc,H,L,T)
    li = jnp.arange(chunk)
    causal = li[:, None] >= li[None, :]
    # mask BEFORE exp: above-diagonal diffs are positive and would overflow
    decay = jnp.exp(jnp.where(causal, diff, -jnp.inf))
    M = scores * decay.astype(scores.dtype)
    Mdt = M * dt_.transpose(0, 1, 3, 2)[..., None, :].astype(scores.dtype)
    y_intra = jnp.einsum("bchlt,bcthp->bclhp", Mdt, xh_)

    # Chunk summary states: h_c = sum_t exp(dAcs_L - dAcs_t) dt_t B_t x_t
    seg = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)              # (B,nc,L,H)
    h_chunk = jnp.einsum("bclh,bclhn,bclhp->bchnp",
                         dt_ * seg, Bh, xh_)                # (B,nc,H,N,P)

    # Inter-chunk recurrence via associative scan over chunks:
    # H_c = exp(sum dA_c) H_{c-1} + h_c
    total_decay = jnp.exp(jnp.sum(dA, axis=2))              # (B,nc,H)

    def combine(a, b):
        da, ha = a
        db, hb = b
        return da * db, ha * db[..., None, None] + hb

    dec_acc, h_acc = jax.lax.associative_scan(combine, (total_decay, h_chunk), axis=1)
    # state entering chunk c = H_{c-1}
    h_prev = jnp.concatenate(
        [jnp.zeros_like(h_acc[:, :1]), h_acc[:, :-1]], axis=1)  # (B,nc,H,N,P)

    # Inter-chunk output: y_t += C_t · exp(dAcs_t) H_prev
    in_decay = jnp.exp(dA_cs)                               # (B,nc,L,H)
    y_inter = jnp.einsum("bclhn,bchnp->bclhp",
                         Ch * in_decay[..., None].astype(Ch.dtype), h_prev.astype(Ch.dtype))
    y = (y_intra + y_inter).reshape(B, S, H, P)
    final_state = h_acc[:, -1]                              # (B,H,N,P)
    return y, final_state


def ssm_block(p, cfg, x, *, conv_state=None, ssm_state=None, decode: bool = False):
    """Mamba-2 block. x: (B,S,D).

    Train/prefill: decode=False, returns (y, (conv_tail, final_state)).
    Decode: decode=True with S==1 and both states given; returns
    (y, (new_conv_state, new_ssm_state)).
    """
    s = cfg.ssm
    d_inner, nheads, conv_dim = ssm_dims(cfg)
    dt_c = x.dtype
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(dt_c))
    z, xBC_pre, Bm_pre, Cm_pre, dt_raw = _split_zxbcdt(cfg, zxbcdt)
    conv_in = jnp.concatenate([xBC_pre, Bm_pre, Cm_pre], axis=-1)  # (B,S,conv_dim)
    conv_out, conv_tail = _causal_conv(conv_in, p["conv_w"], p["conv_b"],
                                       init_state=conv_state)
    conv_out = jax.nn.silu(conv_out)
    xc, Bm, Cm = jnp.split(conv_out, [d_inner, d_inner + s.n_groups * s.d_state], axis=-1)

    Bq = Bm.reshape(*Bm.shape[:-1], s.n_groups, s.d_state)
    Cq = Cm.reshape(*Cm.shape[:-1], s.n_groups, s.d_state)
    xh = xc.reshape(*xc.shape[:-1], nheads, s.head_dim)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    if decode:
        # Recurrent single step: states required.
        dA = jnp.exp(dt[:, 0] * A[None, :])                 # (B,H)
        rep = nheads // s.n_groups
        Bh = jnp.repeat(Bq[:, 0], rep, axis=1)              # (B,H,N)
        Ch = jnp.repeat(Cq[:, 0], rep, axis=1)
        dBx = (dt[:, 0][..., None, None] * Bh[..., :, None]
               * xh[:, 0][..., None, :].astype(jnp.float32))  # (B,H,N,P)
        new_state = ssm_state * dA[..., None, None] + dBx
        y = jnp.einsum("bhn,bhnp->bhp", Ch.astype(jnp.float32), new_state)
        y = y[:, None].astype(dt_c).reshape(x.shape[0], 1, d_inner)
        y = y + xc * p["D"].astype(dt_c).repeat(s.head_dim)[None, None, :]
        states = (conv_tail, new_state)
    else:
        S = x.shape[1]
        chunk = min(s.chunk_size, S) if S % s.chunk_size else s.chunk_size
        pad = (-S) % chunk
        if pad:
            # zero-pad the tail: padded steps have dt=0 => identity on state
            padfn = lambda t: jnp.pad(t, [(0, 0), (0, pad)] + [(0, 0)] * (t.ndim - 2))
            xh_p, dt_p, Bq_p, Cq_p = map(padfn, (xh, dt, Bq, Cq))
        else:
            xh_p, dt_p, Bq_p, Cq_p = xh, dt, Bq, Cq
        yh, final_state = _ssd_chunked(
            xh_p.astype(jnp.float32), dt_p, A,
            Bq_p.astype(jnp.float32), Cq_p.astype(jnp.float32), chunk)
        if pad:
            yh = yh[:, :S]
        y = yh.astype(dt_c).reshape(*x.shape[:2], d_inner)
        y = y + xc * p["D"].astype(dt_c).repeat(s.head_dim)[None, None, :]
        states = (conv_tail, final_state)

    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(dt_c))
    return out, states
