from repro.models.model import (  # noqa: F401
    param_specs, init_params, abstract_params, axes_tree,
    lm_loss, prefill, decode_step, init_cache, cache_specs,
)
