"""Attention: GQA with RoPE, optional qk-norm / sliding window, KV cache.

Prefill/train uses a query-chunked implementation (bounded score memory —
32k×32k scores are never materialized); decode attends a single query token
against the cache.  A Pallas flash-attention kernel (repro.kernels.flash
_attention) can be swapped in via ``impl='pallas'`` for TPU runs; the
chunked jnp path is the portable oracle and the dry-run default.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import mixed_precision as mp
from repro.models.modules import ParamSpec, rms_norm, rope

NEG_INF = -1e30


def attn_specs(cfg, *, cross: bool = False) -> dict:
    d, h, kvh, hd = (cfg.d_model, cfg.padded_heads, cfg.padded_kv_heads,
                     cfg.resolved_head_dim)
    specs = {
        "wq": ParamSpec((d, h, hd), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((d, kvh, hd), ("embed", "kv_heads", "head_dim")),
        "wv": ParamSpec((d, kvh, hd), ("embed", "kv_heads", "head_dim")),
        "wo": ParamSpec((h, hd, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qk_norm and not cross:
        specs["q_norm"] = ParamSpec((hd,), ("head_dim",), "zeros")
        specs["k_norm"] = ParamSpec((hd,), ("head_dim",), "zeros")
    return specs


def _head_mask(cfg, out):
    """Zero the padded heads (cfg.pad_heads_to): keeps the padded model
    EXACTLY equal to the assigned config while enabling 16-way TP."""
    if cfg.pad_heads_to is None:
        return out
    hp = cfg.padded_heads
    mask = (jnp.arange(hp) < cfg.num_heads).astype(out.dtype)
    return out * mask[None, None, :, None]


def _project_qkv(p, cfg, xq, xkv, positions_q, positions_kv, *, use_rope=True):
    dt = xq.dtype
    q = jnp.einsum("bsd,dhk->bshk", xq, p["wq"].astype(dt))
    k = jnp.einsum("btd,dnk->btnk", xkv, p["wk"].astype(dt))
    v = jnp.einsum("btd,dnk->btnk", xkv, p["wv"].astype(dt))
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if use_rope:
        q = rope(q, positions_q, cfg.rope_theta)
        k = rope(k, positions_kv, cfg.rope_theta)
    return q, k, v


def _gqa_attend(q, k, v, mask_fn, sq_positions, kv_positions, scale):
    """q: (B,Sq,H,hd); k,v: (B,T,KVH,hd). mask_fn(qpos, kpos)->bool keep."""
    B, Sq, H, hd = q.shape
    KVH = k.shape[2]
    G = H // KVH
    qg = q.reshape(B, Sq, KVH, G, hd)
    scores = jnp.einsum("bsngd,btnd->bngst", qg, k) * scale   # (B,KVH,G,Sq,T)
    keep = mask_fn(sq_positions[:, :, None], kv_positions[:, None, :])  # (B,Sq,T)
    scores = jnp.where(keep[:, None, None], scores.astype(jnp.float32), NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bngst,btnd->bsngd", probs, v)
    return out.reshape(B, Sq, H, hd)


def attention(p, cfg, x, *, kind: str = "attn", causal: bool = True,
              positions=None, x_kv=None, kv_positions=None,
              q_chunk: int = 1024, use_rope: bool = True):
    """Full-sequence (train / prefill) attention.

    kind: 'attn' (global) or 'attn_local' (sliding window cfg.sliding_window).
    x_kv: source for K/V in cross-attention (positions via kv_positions).
    Returns (out, (k, v)) — k/v returned so prefill can seed the cache.
    """
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    cross = x_kv is not None
    xkv = x_kv if cross else x
    if kv_positions is None:
        kv_positions = (jnp.broadcast_to(jnp.arange(xkv.shape[1], dtype=jnp.int32),
                                         (B, xkv.shape[1])) if cross else positions)
    q, k, v = _project_qkv(p, cfg, x, xkv, positions, kv_positions,
                           use_rope=use_rope and not cross)
    hd = cfg.resolved_head_dim
    scale = hd ** -0.5
    window = cfg.sliding_window if kind == "attn_local" else None

    def mask_fn(qp, kp):
        keep = jnp.ones(jnp.broadcast_shapes(qp.shape, kp.shape), bool)
        if causal and not cross:
            keep &= kp <= qp
        if window is not None:
            keep &= kp > qp - window
        return keep

    n_chunks = S // q_chunk if (S % q_chunk == 0 and S > q_chunk) else 1
    if n_chunks <= 1:
        out = _gqa_attend(q, k, v, mask_fn, positions, kv_positions, scale)
    else:
        qs = q.reshape(B, n_chunks, q_chunk, *q.shape[2:]).swapaxes(0, 1)
        ps = positions.reshape(B, n_chunks, q_chunk).swapaxes(0, 1)

        def body(_, qc):
            qi, pi = qc
            return None, _gqa_attend(qi, k, v, mask_fn, pi, kv_positions, scale)

        _, outs = jax.lax.scan(body, None, (qs, ps))
        out = outs.swapaxes(0, 1).reshape(B, S, *outs.shape[3:])
    proj = jnp.einsum("bshd,hdD->bsD", _head_mask(cfg, out),
                      p["wo"].astype(x.dtype))
    return proj, (k, v)


def decode_attention(p, cfg, x, cache_k, cache_v, pos, *, kind: str = "attn",
                     cross: bool = False, use_rope: bool = True):
    """Single-token decode. x: (B,1,D); cache_k/v: (B,T,KVH,hd); pos: (B,) int32.

    Returns (out, new_k, new_v).  For cross-attention the cache holds the
    (fixed) encoder K/V and is not updated.
    """
    B = x.shape[0]
    T = cache_k.shape[1]
    if cross:
        k, v = cache_k, cache_v
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
        kv_pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
        keep = jnp.ones((B, 1, T), bool)
    else:
        q, k_new, v_new = _project_qkv(
            p, cfg, x, x, pos[:, None], pos[:, None], use_rope=use_rope)
        # write the new K/V at position pos (per-batch dynamic index)
        upd = jax.vmap(lambda c, n, i: jax.lax.dynamic_update_slice_in_dim(c, n, i, axis=0))
        k = upd(cache_k, k_new, pos)
        v = upd(cache_v, v_new, pos)
        kv_pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
        keep = kv_pos[:, None, :] <= pos[:, None, None]
        if kind == "attn_local" and cfg.sliding_window is not None:
            keep &= kv_pos[:, None, :] > (pos[:, None, None] - cfg.sliding_window)
    hd = cfg.resolved_head_dim
    out = _gqa_attend(q, k, v, lambda qp, kp: keep,
                      pos[:, None], kv_pos, hd ** -0.5)
    proj = jnp.einsum("bshd,hdD->bsD", _head_mask(cfg, out),
                      p["wo"].astype(x.dtype))
    if cross:
        return proj, cache_k, cache_v
    return proj, k, v


def paged_attention(p, cfg, x, kv_entry, page_table, qpos, n_valid,
                    *, kind: str = "attn", impl: str = "auto"):
    """Attention against a paged KV pool (serving decode + chunked prefill).

    x: (A, C, D) — A seats, each advancing by up to C tokens this call
       (C=1 is plain decode; C>1 is one prefill chunk);
    kv_entry: one layer-group's cache entry — ``{"k", "v"}`` pools of
       (P, page, KVH, hd) shared physical pages (page 0 is the scratch
       page: writes from idle seats / chunk padding land there), plus
       ``{"ks", "vs"}`` (P, page, KVH) f32 per-(slot, head) scales when
       the pool stores fp8/int8 (see models.model.init_paged_cache);
    page_table: (A, n) int32 — seat a's logical page i lives in physical
       page page_table[a, i] (dead entries 0);
    qpos: (A, C) int32 absolute position of each token;
    n_valid: (A,) int32 — how many of the C tokens are real.

    impl: 'jnp' gathers pages and runs the dense oracle; 'pallas' streams
    pages through the gather-over-page-table kernel (single-query global
    decode only — chunked prefill and sliding-window layers always take
    the jnp path); 'auto' = pallas on TPU, jnp elsewhere.

    New K/V are scattered into the pool *before* the gather, so token t
    attends to itself and everything earlier.  For quantized pools each
    written token's (KVH, hd) vector is amax-quantized independently and
    its scales scattered with the same indices — write order never
    changes a token's stored bytes.  Returns (out (A, C, D), new_entry).
    """
    A, C, _ = x.shape
    k_pool, v_pool = kv_entry["k"], kv_entry["v"]
    quantized = "ks" in kv_entry
    P, page = k_pool.shape[0], k_pool.shape[1]
    n = page_table.shape[1]
    q, k_new, v_new = _project_qkv(p, cfg, x, x, qpos, qpos)

    valid_tok = jnp.arange(C, dtype=jnp.int32)[None, :] < n_valid[:, None]
    blk = jnp.clip(qpos // page, 0, n - 1)
    phys = jnp.take_along_axis(page_table, blk, axis=1)          # (A, C)
    phys = jnp.where(valid_tok, phys, 0)                         # -> scratch
    off = jnp.where(valid_tok, qpos % page, 0)
    if quantized:
        kv_dtype = "fp8" if k_pool.dtype == jnp.uint8 else "int8"
        kq, ks = mp.quantize_kv_page(k_new, kv_dtype)
        vq, vs = mp.quantize_kv_page(v_new, kv_dtype)
        k_pool = k_pool.at[phys, off].set(kq)
        v_pool = v_pool.at[phys, off].set(vq)
        ks_pool = kv_entry["ks"].at[phys, off].set(ks)
        vs_pool = kv_entry["vs"].at[phys, off].set(vs)
    else:
        k_pool = k_pool.at[phys, off].set(k_new)
        v_pool = v_pool.at[phys, off].set(v_new)

    hd = cfg.resolved_head_dim
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "jnp"
    if impl == "pallas" and C == 1 and kind == "attn":
        from repro.kernels.ops import paged_decode_attention
        out = paged_decode_attention(
            q, k_pool, v_pool, page_table, qpos[:, 0] + 1,
            k_scale=ks_pool if quantized else None,
            v_scale=vs_pool if quantized else None)
        out = out.astype(q.dtype)
    else:
        if quantized:
            kd = mp.dequantize_kv_page(k_pool, ks_pool).astype(q.dtype)
            vd = mp.dequantize_kv_page(v_pool, vs_pool).astype(q.dtype)
        else:
            kd, vd = k_pool, v_pool
        k = kd[page_table].reshape(A, n * page, *kd.shape[2:])
        v = vd[page_table].reshape(A, n * page, *vd.shape[2:])
        kv_pos = jnp.broadcast_to(jnp.arange(n * page, dtype=jnp.int32),
                                  (A, n * page))
        keep = kv_pos[:, None, :] <= qpos[:, :, None]            # (A, C, T)
        if kind == "attn_local" and cfg.sliding_window is not None:
            keep &= kv_pos[:, None, :] > (qpos[:, :, None]
                                          - cfg.sliding_window)
        out = _gqa_attend(q, k, v, lambda qp, kp: keep, qpos, kv_pos,
                          hd ** -0.5)
    # a pool stored above the compute dtype (e.g. --kv-dtype f32 under
    # bf16 compute) attends at pool precision; the residual stream stays
    # in compute dtype either way
    out = out.astype(x.dtype)
    proj = jnp.einsum("bshd,hdD->bsD", _head_mask(cfg, out),
                      p["wo"].astype(x.dtype))
    new_entry = ({"k": k_pool, "v": v_pool, "ks": ks_pool, "vs": vs_pool}
                 if quantized else {"k": k_pool, "v": v_pool})
    return proj, new_entry


def ring_decode_attention(p, cfg, x, cache_k, cache_v, pos):
    """Sliding-window decode against a ring buffer of size W = sliding_window.

    The cache keeps only the last W tokens (slot = position mod W), cutting
    local-layer KV memory for long-context decode from O(S) to O(W) — the
    memory-term optimization recorded in EXPERIMENTS.md §Perf.
    """
    W = cache_k.shape[1]
    B = x.shape[0]
    q, k_new, v_new = _project_qkv(p, cfg, x, x, pos[:, None], pos[:, None])
    slot = pos % W
    upd = jax.vmap(lambda c, n, i: jax.lax.dynamic_update_slice_in_dim(c, n, i, axis=0))
    k = upd(cache_k, k_new, slot)
    v = upd(cache_v, v_new, slot)
    # Absolute position stored in each slot j: pos - ((pos - j) mod W)
    j = jnp.arange(W, dtype=jnp.int32)[None, :]
    kv_pos = pos[:, None] - jnp.mod(pos[:, None] - j, W)
    keep = (kv_pos >= 0)[:, None, :]                        # unfilled slots masked
    hd = cfg.resolved_head_dim
    out = _gqa_attend(q, k, v, lambda qp, kp: keep, pos[:, None], kv_pos, hd ** -0.5)
    proj = jnp.einsum("bshd,hdD->bsD", _head_mask(cfg, out),
                      p["wo"].astype(x.dtype))
    return proj, k, v
