"""Mixture-of-experts block: dropless routing via jax.lax.ragged_dot.

Tokens are routed top-k, replicated k times, sorted by expert id, and pushed
through grouped GEMMs (``ragged_dot``) — the TPU-native analogue of
megablocks.  Sharding strategy (DESIGN.md §5): the expert FFN hidden dim is
tensor-parallel over the ``model`` axis ("MoE-TP"), which divides evenly for
any expert count (60, 16, 8) on the fixed 16-wide model axis; routing + sort
stay *local* to each data shard, expressed with ``jax.shard_map`` so no
global token sort ever crosses the network (true expert-parallel all-to-all
is a recorded perf-iteration alternative).

Compute is per routed token only (top_k × T), so HLO FLOPs track
6·N_active·D for the roofline's MoE model-FLOPs line.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map

from repro.models.modules import ParamSpec, swiglu
from repro.parallel.sharding import spec_for


def moe_specs(cfg) -> dict:
    m = cfg.moe
    d = cfg.d_model
    specs = {
        "router": ParamSpec((d, m.num_experts), ("embed", "expert")),
        "wg": ParamSpec((m.num_experts, d, m.d_ff_expert), ("expert", "embed", "expert_ff")),
        "wu": ParamSpec((m.num_experts, d, m.d_ff_expert), ("expert", "embed", "expert_ff")),
        "wd": ParamSpec((m.num_experts, m.d_ff_expert, d), ("expert", "expert_ff", "embed")),
    }
    if m.num_shared_experts:
        f_sh = m.d_ff_shared or m.num_shared_experts * m.d_ff_expert
        specs["shared"] = {
            "wg": ParamSpec((d, f_sh), ("embed", "ff")),
            "wu": ParamSpec((d, f_sh), ("embed", "ff")),
            "wd": ParamSpec((f_sh, d), ("ff", "embed")),
            "gate": ParamSpec((d, 1), ("embed", None)),
        }
    return specs


def _expert_gemms_ragged(p, m, xs, group_sizes, dt):
    """Dropless grouped GEMMs via ragged_dot.  On TPU this lowers to the
    native grouped-matmul (megablocks-style); on CPU/GPU XLA falls back to
    one DENSE (T·k, D)×(D, F) dot per expert — E/k× the true FLOPs — so the
    dry-run uses the capacity path below for honest compiled cost."""
    g = jax.lax.ragged_dot(xs, p["wg"].astype(dt), group_sizes)
    u = jax.lax.ragged_dot(xs, p["wu"].astype(dt), group_sizes)
    h = jax.nn.silu(g) * u
    return jax.lax.ragged_dot(h, p["wd"].astype(dt), group_sizes)


def _expert_gemms_capacity(p, m, xs, group_sizes, dt):
    """Capacity-based expert GEMMs, batched-einsum formulation (GShard):
    expert e reads the C-slot window of the sorted token array at its
    group offset (one gather), all experts' FFNs run as ONE batched GEMM
    einsum('ecd,edf->ecf'), results scatter back to their sorted slots.

    Compiled FLOPs = cf × the true grouped FLOPs on every backend (the
    honest dry-run cost ragged_dot's dense fallback can't give); tokens
    beyond an expert's capacity are dropped (exact when cf covers the max
    group size).  No scan => no O(E·|buffer|) carry traffic in backward.
    """
    TK, D = xs.shape
    E = m.num_experts
    C = int(m.capacity_factor * TK / E) + 1
    C = min(max((C + 7) // 8 * 8, 8), TK)      # pad to 8, bound by TK
    offsets = jnp.cumsum(group_sizes) - group_sizes            # (E,)
    slot = offsets[:, None] + jnp.arange(C)[None, :]           # (E, C)
    valid = jnp.arange(C)[None, :] < group_sizes[:, None]      # (E, C)
    idx = jnp.clip(slot, 0, TK - 1)
    xe = jnp.take(xs, idx.reshape(-1), axis=0).reshape(E, C, D)
    xe = xe * valid[..., None].astype(dt)

    g = jnp.einsum("ecd,edf->ecf", xe, p["wg"].astype(dt))
    u = jnp.einsum("ecd,edf->ecf", xe, p["wu"].astype(dt))
    ye = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, p["wd"].astype(dt))
    ye = ye * valid[..., None].astype(dt)

    # each sorted slot belongs to exactly one (e, c) cell
    out = jnp.zeros((TK, D), dt).at[idx.reshape(-1)].add(
        ye.reshape(-1, D) * valid.reshape(-1, 1).astype(dt))
    return out


def _moe_local(p, cfg, x, *, psum_axis=None, impl: str = "capacity"):
    """Local (per-shard) MoE. x: (B, S, D) -> (B, S, D).

    impl: 'capacity' (portable, honest FLOPs, capacity drops) or
          'ragged' (dropless ragged_dot — the TPU production path).
    """
    m = cfg.moe
    B, S, D = x.shape
    dt = x.dtype
    xt = x.reshape(B * S, D)
    T = B * S

    logits = jnp.einsum("td,de->te", xt, p["router"].astype(dt)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, idx = jax.lax.top_k(probs, m.top_k)               # (T, k)
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)

    flat_expert = idx.reshape(-1)                               # (T*k,)
    sort_idx = jnp.argsort(flat_expert)                         # stable
    tok_ids = sort_idx // m.top_k                               # source token per slot
    xs = jnp.take(xt, tok_ids, axis=0)                          # (T*k, D)
    group_sizes = jnp.bincount(flat_expert, length=m.num_experts).astype(jnp.int32)

    if impl == "ragged":
        y = _expert_gemms_ragged(p, m, xs, group_sizes, dt)
    else:
        y = _expert_gemms_capacity(p, m, xs, group_sizes, dt)

    w_sorted = jnp.take(weights.reshape(-1), sort_idx, axis=0).astype(dt)
    out = jnp.zeros((T, D), dt).at[tok_ids].add(y * w_sorted[:, None])

    if "shared" in p:
        sh = p["shared"]
        ys = swiglu(xt, sh["wg"], sh["wu"], sh["wd"], dt)
        gate = jax.nn.sigmoid(
            jnp.einsum("td,dz->tz", xt, sh["gate"].astype(dt)).astype(jnp.float32))
        out = out + ys * gate.astype(dt)

    if psum_axis is not None:
        out = jax.lax.psum(out, psum_axis)
    return out.reshape(B, S, D)


def moe_block(p, cfg, x, rules=None, mesh=None,
              xaxes=("batch", "seq_shard", None), impl: str = "capacity"):
    """Sharded MoE: shard_map keeps routing local, TPs the expert FFN dim.

    Falls back to the plain local implementation when no mesh is given
    (single-device smoke tests).
    """
    if mesh is None or mesh.size == 1 or "model" not in mesh.axis_names:
        return _moe_local(p, cfg, x, impl=impl)

    xspec = spec_for(xaxes, rules)
    # Partition specs for the weights (same table the params are laid out by).
    pspec = {
        "router": spec_for(("embed", "expert"), rules),
        "wg": spec_for(("expert", "embed", "expert_ff"), rules),
        "wu": spec_for(("expert", "embed", "expert_ff"), rules),
        "wd": spec_for(("expert", "expert_ff", "embed"), rules),
    }
    if "shared" in p:
        pspec["shared"] = {
            "wg": spec_for(("embed", "ff"), rules),
            "wu": spec_for(("embed", "ff"), rules),
            "wd": spec_for(("ff", "embed"), rules),
            "gate": spec_for(("embed", None), rules),
        }

    # FSDP: if the "embed" (d_model) weight dim is sharded, gather it inside
    # the shard_map body before use (manual regions don't get GSPMD's
    # automatic ZeRO gathers).
    emb = rules.mesh_axes("embed")
    emb_axes = (emb,) if isinstance(emb, str) else (emb or ())
    emb_axes = tuple(a for a in emb_axes if a in mesh.axis_names)
    # embed-dim position within each weight's shape
    EMB_DIM = {"router": 0, "wg": 1, "wu": 1, "wd": 2}
    EMB_DIM_SHARED = {"wg": 0, "wu": 0, "wd": 1, "gate": 0}

    def gather_emb(w, dim):
        for a in emb_axes:
            w = jax.lax.all_gather(w, a, axis=dim, tiled=True)
        return w

    def body(pp, xx):
        if emb_axes:
            pp = dict(pp)
            for k2, d2 in EMB_DIM.items():
                pp[k2] = gather_emb(pp[k2], d2)
            if "shared" in pp:
                pp["shared"] = {k2: gather_emb(v2, EMB_DIM_SHARED[k2])
                                for k2, v2 in pp["shared"].items()}
        return _moe_local(pp, cfg, xx, psum_axis="model", impl=impl)

    fn = shard_map(body, mesh=mesh, in_specs=(pspec, xspec),
                       out_specs=xspec, check_vma=False)
    return fn(p, x)


def aux_load_balance_loss(p, cfg, x) -> jnp.ndarray:
    """Switch-style load-balancing auxiliary loss (fraction × probability)."""
    m = cfg.moe
    xt = x.reshape(-1, x.shape[-1])
    logits = jnp.einsum("td,de->te", xt, p["router"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    _, idx = jax.lax.top_k(probs, m.top_k)
    counts = jnp.sum(jax.nn.one_hot(idx, m.num_experts, dtype=jnp.float32), axis=(0, 1))
    frac = counts / jnp.maximum(jnp.sum(counts), 1.0)
    imp = jnp.mean(probs, axis=0)
    return m.num_experts * jnp.sum(frac * imp)
