"""Model assembly: specs, forward (scan over layer blocks), loss, decode.

The layer stack is organized as ``num_layers = G × period`` where ``period``
is the architecture's repeating pattern (1 for homogeneous stacks, 6 for
gemma3's 5-local:1-global, 8 for jamba's 7-mamba:1-attn with MoE every 2).
Parameters for each position in the period are stacked with a leading (G,)
axis and the stack is traversed with ``lax.scan`` — keeping the lowered HLO
small enough that 40 (arch × shape) dry-run cells compile quickly.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import mixed_precision
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.modules import (ParamSpec, is_spec, rms_norm, swiglu,
                                  mlp_specs, softmax_xent_chunked,
                                  init_params, abstract_params, axes_tree)
from repro.parallel.sharding import LogicalRules, spec_for
from repro.runtime import sampler as sampler_mod

init_params = init_params          # re-export
abstract_params = abstract_params  # re-export
axes_tree = axes_tree              # re-export


@dataclasses.dataclass(frozen=True)
class RunOptions:
    """Tunable execution options — the perf-hillclimb surface."""
    remat: bool = True
    remat_policy: str = "nothing"    # nothing | dots | none(=no remat)
    q_chunk: int = 1024
    xent_chunk: int = 512
    ring_local_cache: bool = False   # sliding-window layers keep window-sized cache
    aux_loss_weight: float = 0.01
    scan_layers: bool = True
    mesh: Any = None                 # Mesh for shard_map regions (MoE); None on CPU
    moe_impl: str = "capacity"       # capacity (portable) | ragged (TPU gmm)
    paged_attn_impl: str = "auto"    # auto (pallas on TPU, jnp elsewhere) |
                                     # jnp | pallas — serving decode path
    grad_sync: str = "auto"          # auto (GSPMD) | compressed (int8 error-
                                     # feedback on the thin cross-pod hop)
    pipeline: bool = False           # GPipe PP: stages = the 'pod' axis
    pp_microbatches: int = 4
    microbatches: int = 1            # gradient-accumulation microbatches:
                                     # activations shrink ÷k and XLA overlaps
                                     # microbatch i+1 compute with i's grad
                                     # collectives (comm/compute overlap)
    bf16_weights: bool = False       # cast params to bf16 once per step (halves
                                     # FSDP gather traffic + per-use converts)
    decode_kv_seq_axis: bool = False  # shard decode KV cache seq over 'model'


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------

def _block_specs(cfg: ModelConfig, *, decoder_side: bool = True) -> Dict[str, Any]:
    """Specs for ONE period of layers: {'pos0': {...}, 'pos1': {...}, ...}."""
    kinds = cfg.layer_kinds()
    mlps = cfg.mlp_kinds()
    out: Dict[str, Any] = {}
    for i, (kind, mlpk) in enumerate(zip(kinds, mlps)):
        sub: Dict[str, Any] = {"ln1": ParamSpec((cfg.d_model,), ("embed",), "zeros")}
        if kind == "ssm":
            sub["mixer"] = ssm_mod.ssm_specs(cfg)
        else:
            sub["mixer"] = attn_mod.attn_specs(cfg)
        if cfg.encoder_decoder and decoder_side:
            sub["ln_cross"] = ParamSpec((cfg.d_model,), ("embed",), "zeros")
            sub["cross"] = attn_mod.attn_specs(cfg, cross=True)
        if mlpk == "moe":
            sub["ln2"] = ParamSpec((cfg.d_model,), ("embed",), "zeros")
            sub["moe"] = moe_mod.moe_specs(cfg)
        elif mlpk == "dense":
            sub["ln2"] = ParamSpec((cfg.d_model,), ("embed",), "zeros")
            sub["mlp"] = mlp_specs(cfg.d_model, cfg.d_ff)
        out[f"pos{i}"] = sub
    return out


def _stack(specs, g: int):
    return jax.tree.map(
        lambda s: ParamSpec((g,) + s.shape, ("layers",) + s.axes, s.init, s.scale),
        specs, is_leaf=is_spec)


def param_specs(cfg: ModelConfig) -> Dict[str, Any]:
    period = cfg.scan_period()
    g = cfg.num_layers // period
    specs: Dict[str, Any] = {
        "embed": ParamSpec((cfg.vocab_size, cfg.d_model), ("vocab", "embed")),
        "blocks": _stack(_block_specs(cfg), g),
        "final_norm": ParamSpec((cfg.d_model,), ("embed",), "zeros"),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = ParamSpec((cfg.d_model, cfg.vocab_size), ("embed", "vocab"))
    if cfg.encoder_decoder:
        enc_cfg = dataclasses.replace(
            cfg, encoder_decoder=False, moe=None, attn_period=None,
            local_global_period=None, num_layers=cfg.num_encoder_layers)
        specs["encoder"] = {
            "blocks": _stack(_block_specs(enc_cfg, decoder_side=False),
                             cfg.num_encoder_layers),
            "final_norm": ParamSpec((cfg.d_model,), ("embed",), "zeros"),
        }
    if cfg.frontend is not None:
        specs["frontend_proj"] = ParamSpec(
            (cfg.frontend_dim, cfg.d_model), (None, "embed"))
    return specs


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _maybe_bf16(params, opts: "RunOptions"):
    """Optional one-shot bf16 cast of the weights at step entry.  GSPMD then
    moves the convert BEFORE the FSDP all-gathers => half the gather bytes
    and one convert per parameter instead of one per use (§Perf lever)."""
    if not opts.bf16_weights:
        return params
    return jax.tree.map(
        lambda x: x.astype(jnp.bfloat16) if x.dtype == jnp.float32 else x,
        params)


def _constraint(x, rules: LogicalRules, axes):
    spec = spec_for(axes, rules)
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:
        return x  # no mesh context (single-device smoke tests)


def _apply_sublayer(p, cfg, x, kind, mlpk, positions, rules, opts,
                    enc_out=None, want_cache=False):
    """One (mixer + mlp) sublayer in full-sequence mode. Returns (x, aux, cache)."""
    cache = {}
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if kind == "ssm":
        mix, (conv_tail, ssm_state) = ssm_mod.ssm_block(p["mixer"], cfg, h)
        if want_cache:
            cache["conv"] = conv_tail
            cache["ssm"] = ssm_state
    else:
        mix, (k, v) = attn_mod.attention(
            p["mixer"], cfg, h, kind=kind, positions=positions,
            q_chunk=opts.q_chunk)
        if want_cache:
            if (kind == "attn_local" and opts.ring_local_cache
                    and cfg.sliding_window and k.shape[1] > cfg.sliding_window):
                k = k[:, -cfg.sliding_window:]
                v = v[:, -cfg.sliding_window:]
            cache["k"], cache["v"] = k, v
    x = x + mix
    if enc_out is not None and "cross" in p:
        h = rms_norm(x, p["ln_cross"], cfg.norm_eps)
        cmix, (ck, cv) = attn_mod.attention(
            p["cross"], cfg, h, x_kv=enc_out, causal=False, q_chunk=opts.q_chunk)
        x = x + cmix
        if want_cache:
            cache["ck"], cache["cv"] = ck, cv
    aux = jnp.zeros((), jnp.float32)
    if mlpk == "moe":
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        x = x + moe_mod.moe_block(p["moe"], cfg, h, rules=rules, mesh=opts.mesh,
                                  impl=opts.moe_impl)
        aux = moe_mod.aux_load_balance_loss(p["moe"], cfg, h)
    elif mlpk == "dense":
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        x = x + swiglu(h, p["mlp"]["wg"], p["mlp"]["wu"], p["mlp"]["wd"], x.dtype)
    x = _constraint(x, rules, ("batch", "seq_shard", None))
    return x, aux, cache


def backbone(params_blocks, cfg: ModelConfig, x, positions, rules, opts,
             *, enc_out=None, want_cache=False, decoder_side=True,
             train: bool = False):
    """Scan the layer stack. Returns (x, aux_loss_sum, caches or None)."""
    kinds = cfg.layer_kinds()
    mlps = cfg.mlp_kinds()

    def block(carry, blk):
        x, aux = carry
        caches = {}
        for i, (kind, mlpk) in enumerate(zip(kinds, mlps)):
            x, a, c = _apply_sublayer(
                blk[f"pos{i}"], cfg, x, kind, mlpk, positions, rules, opts,
                enc_out=enc_out if decoder_side else None,
                want_cache=want_cache)
            aux = aux + a
            if want_cache:
                caches[f"pos{i}"] = c
        return (x, aux), (caches if want_cache else None)

    if train and opts.remat:
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if opts.remat_policy == "dots" else None)
        block = jax.checkpoint(block, policy=policy, prevent_cse=False)

    (x, aux), caches = jax.lax.scan(block, (x, jnp.zeros((), jnp.float32)),
                                    params_blocks)
    return x, aux, caches


def _embed_inputs(params, cfg: ModelConfig, batch, rules):
    """Token (+ modality stub) embedding. Returns (x, positions, enc_out)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    dt = jnp.dtype(cfg.compute_dtype)
    if "tok_embeds" in batch:
        # precomputed embeddings (the compressed grad-sync path hoists the
        # gather out of the pod-manual shard_map region — XLA's partitioner
        # cannot partition gathers inside manual subgroups)
        x = batch["tok_embeds"].astype(dt)
    else:
        x = jnp.take(params["embed"], tokens, axis=0).astype(dt)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    if cfg.frontend == "vision" and "patches" in batch:
        proj = jnp.einsum("bpf,fd->bpd", batch["patches"].astype(dt),
                          params["frontend_proj"].astype(dt))
        x = jax.lax.dynamic_update_slice(x, proj, (0, 0, 0))
    x = _constraint(x, rules, ("batch", "seq_shard", None))
    enc_out = None
    if cfg.encoder_decoder:
        frames = batch["audio"]  # (B, L_enc, frontend_dim) — stub embeddings
        e = jnp.einsum("blf,fd->bld", frames.astype(dt),
                       params["frontend_proj"].astype(dt))
        enc_pos = jnp.broadcast_to(
            jnp.arange(e.shape[1], dtype=jnp.int32), (B, e.shape[1]))
        enc_cfg = dataclasses.replace(
            cfg, encoder_decoder=False, moe=None, attn_period=None,
            local_global_period=None, num_layers=cfg.num_encoder_layers)

        def enc_block(h, blk):
            hh = rms_norm(h, blk["pos0"]["ln1"], cfg.norm_eps)
            mix, _ = attn_mod.attention(blk["pos0"]["mixer"], enc_cfg, hh,
                                        causal=False, positions=enc_pos)
            h = h + mix
            hh = rms_norm(h, blk["pos0"]["ln2"], cfg.norm_eps)
            h = h + swiglu(hh, blk["pos0"]["mlp"]["wg"], blk["pos0"]["mlp"]["wu"],
                           blk["pos0"]["mlp"]["wd"], h.dtype)
            return h, None

        e, _ = jax.lax.scan(enc_block, e, params["encoder"]["blocks"])
        enc_out = rms_norm(e, params["encoder"]["final_norm"], cfg.norm_eps)
    return x, positions, enc_out


def _output_weight(params, cfg):
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["lm_head"]


def lm_loss(params, cfg: ModelConfig, batch, rules: LogicalRules,
            opts: RunOptions = RunOptions()) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Mean next-token cross-entropy (+ MoE aux loss)."""
    params = _maybe_bf16(params, opts)
    x, positions, enc_out = _embed_inputs(params, cfg, batch, rules)
    x, aux, _ = backbone(params["blocks"], cfg, x, positions, rules, opts,
                         enc_out=enc_out, train=True)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    total, count = softmax_xent_chunked(
        x, _output_weight(params, cfg).astype(x.dtype), batch["labels"],
        chunk=opts.xent_chunk)
    loss = total / jnp.maximum(count, 1.0)
    metrics = {"xent": loss, "aux_loss": aux}
    if cfg.moe is not None:
        loss = loss + opts.aux_loss_weight * aux
    return loss, metrics


def prefill(params, cfg: ModelConfig, batch, rules: LogicalRules,
            opts: RunOptions = RunOptions()):
    """Run the prompt through the model; return (last_logits, cache)."""
    x, positions, enc_out = _embed_inputs(params, cfg, batch, rules)
    x, _, caches = backbone(params["blocks"], cfg, x, positions, rules, opts,
                            enc_out=enc_out, want_cache=True)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    last = x[:, -1:]
    logits = jnp.einsum("bsd,dv->bsv", last,
                        _output_weight(params, cfg).astype(x.dtype))
    return logits.astype(jnp.float32), caches


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def cache_entry_shapes(cfg: ModelConfig, pos_idx: int, batch: int, seq: int,
                       opts: RunOptions = RunOptions()):
    """Shape/axes template for one period-position's cache entry."""
    kinds = cfg.layer_kinds()
    kvh, hd = cfg.padded_kv_heads, cfg.resolved_head_dim
    kind = kinds[pos_idx]
    ent: Dict[str, Tuple[Tuple[int, ...], Tuple[Optional[str], ...]]] = {}
    if kind == "ssm":
        d_inner, nheads, conv_dim = ssm_mod.ssm_dims(cfg)
        w = cfg.ssm.conv_width
        ent["conv"] = ((batch, w - 1, conv_dim), ("batch", None, "ssm_inner"))
        ent["ssm"] = ((batch, nheads, cfg.ssm.d_state, cfg.ssm.head_dim),
                      ("batch", "ssm_heads", None, None))
    else:
        t = seq
        if kind == "attn_local" and opts.ring_local_cache and cfg.sliding_window:
            t = min(seq, cfg.sliding_window)
        ent["k"] = ((batch, t, kvh, hd), ("batch", "seq_shard", "kv_heads", None))
        ent["v"] = ((batch, t, kvh, hd), ("batch", "seq_shard", "kv_heads", None))
    if cfg.encoder_decoder:
        ent["ck"] = ((batch, cfg.encoder_len, kvh, hd),
                     ("batch", None, "kv_heads", None))
        ent["cv"] = ((batch, cfg.encoder_len, kvh, hd),
                     ("batch", None, "kv_heads", None))
    return ent


def cache_specs(cfg: ModelConfig, batch: int, seq: int,
                opts: RunOptions = RunOptions()):
    """(abstract_cache, axes_tree) for decode-cell dry-runs."""
    period = cfg.scan_period()
    g = cfg.num_layers // period
    dt = jnp.dtype(cfg.compute_dtype)
    shapes, axes = {}, {}
    for i in range(period):
        ent = cache_entry_shapes(cfg, i, batch, seq, opts)
        shapes[f"pos{i}"] = {
            k: jax.ShapeDtypeStruct((g,) + s,
                                    jnp.float32 if k in ("ssm",) else dt)
            for k, (s, _) in ent.items()}
        axes[f"pos{i}"] = {k: ("layers",) + a for k, (_, a) in ent.items()}
    return shapes, axes


def init_cache(cfg: ModelConfig, batch: int, seq: int,
               opts: RunOptions = RunOptions()):
    """Zero-initialized cache (smoke tests / serving)."""
    shapes, _ = cache_specs(cfg, batch, seq, opts)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shapes)


def paged_cache_supported(cfg: ModelConfig) -> bool:
    """The paged layout covers pure-attention decoders (global and
    sliding-window layers).  SSM state and encoder K/V are fixed-size per
    request — nothing to page — so those archs stay on the slot engine."""
    return (not cfg.encoder_decoder and cfg.frontend is None
            and all(k in ("attn", "attn_local") for k in cfg.layer_kinds()))


def init_paged_cache(cfg: ModelConfig, num_pages: int, page_size: int,
                     kv_dtype: Optional[str] = None):
    """Zero paged KV pools: {posN: {k,v: (G, num_pages, page, KVH, hd)}}.

    ``num_pages`` counts *physical* pages including the reserved scratch
    page 0 (see runtime.paged_kv.BlockManager).

    ``kv_dtype`` picks the pool's storage precision (one of
    ``core.mixed_precision.KV_DTYPES``); None keeps the config's compute
    dtype — the pre-quantization layout, bit-for-bit.  Quantized dtypes
    (fp8/int8) add f32 ``ks``/``vs`` scale leaves of
    (G, num_pages, page, KVH) — one scale per stored (token, head)
    vector, page-adjacent so copy-on-write and donation treat values
    and scales as one pytree."""
    assert paged_cache_supported(cfg), cfg.name
    period = cfg.scan_period()
    g = cfg.num_layers // period
    kvh, hd = cfg.padded_kv_heads, cfg.resolved_head_dim
    if kv_dtype is None:
        dt, quantized = jnp.dtype(cfg.compute_dtype), False
    else:
        dt = jnp.dtype(mixed_precision.kv_storage_dtype(kv_dtype))
        quantized = mixed_precision.kv_is_quantized(kv_dtype)
    shape = (g, num_pages, page_size, kvh, hd)

    def entry():
        ent = {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}
        if quantized:
            sshape = (g, num_pages, page_size, kvh)
            ent["ks"] = jnp.zeros(sshape, jnp.float32)
            ent["vs"] = jnp.zeros(sshape, jnp.float32)
        return ent

    return {f"pos{i}": entry() for i in range(period)}


def paged_page_bytes(cfg: ModelConfig, page_size: int,
                     kv_dtype: Optional[str] = None) -> int:
    """Bytes one physical page costs across the whole paged cache (all
    layers, K and V, values plus scales for quantized dtypes) — the
    figure byte-denominated budget accounting compares across engines
    of different precisions (runtime.router.HostBudget)."""
    kvh, hd = cfg.padded_kv_heads, cfg.resolved_head_dim
    if kv_dtype is None:
        tok = kvh * hd * jnp.dtype(cfg.compute_dtype).itemsize
    else:
        tok = kvh * mixed_precision.kv_token_bytes(kv_dtype, hd)
    return cfg.num_layers * page_size * tok * 2      # K and V


def copy_paged_page(cache, src, dst):
    """Copy physical page ``src`` onto page ``dst`` in every K/V pool of a
    paged cache (prefix-cache copy-on-write: a request that shares only
    part of a cached page gets its own copy to write its tail into).

    ``src``/``dst`` may be traced scalars; jit-compatible.  ``src == dst``
    is a no-op: callers jit this with the pool donated, and an aliased
    self-copy must not read from the buffer it is overwriting.
    """
    return jax.lax.cond(
        jnp.asarray(src) == jnp.asarray(dst),
        lambda c: c,
        lambda c: jax.tree.map(lambda a: a.at[:, dst].set(a[:, src]), c),
        cache)


def paged_decode_step(params, cfg: ModelConfig, cache, tokens, pos,
                      page_table, n_valid, rules: LogicalRules,
                      opts: RunOptions = RunOptions()):
    """Advance every seat by up to C tokens against the paged KV pool.

    tokens: (A, C) int32 (C=1: batched decode; C>1: one prefill chunk);
    pos: (A,) int32 first position of each seat's chunk;
    page_table: (A, n) int32 logical->physical page map;
    n_valid: (A,) int32 valid tokens per seat (0 = idle seat; its writes
    are routed to the scratch page and its logits are garbage).

    Returns (logits (A, C, V) fp32, new_cache).
    """
    kinds = cfg.layer_kinds()
    mlps = cfg.mlp_kinds()
    dt = jnp.dtype(cfg.compute_dtype)
    A, C = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(dt)
    x = _constraint(x, rules, ("batch", None, None))
    qpos = pos[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]

    def block(x, blk_and_cache):
        blk, cac = blk_and_cache
        new_cac = {}
        for i, (kind, mlpk) in enumerate(zip(kinds, mlps)):
            p = blk[f"pos{i}"]
            c = cac[f"pos{i}"]
            h = rms_norm(x, p["ln1"], cfg.norm_eps)
            mix, nc = attn_mod.paged_attention(
                p["mixer"], cfg, h, c, page_table, qpos,
                n_valid, kind=kind, impl=opts.paged_attn_impl)
            x = x + mix
            if mlpk == "moe":
                hh = rms_norm(x, p["ln2"], cfg.norm_eps)
                x = x + moe_mod.moe_block(p["moe"], cfg, hh, rules=rules,
                                          mesh=opts.mesh,
                                          xaxes=("batch", None, None),
                                          impl=opts.moe_impl)
            elif mlpk == "dense":
                hh = rms_norm(x, p["ln2"], cfg.norm_eps)
                x = x + swiglu(hh, p["mlp"]["wg"], p["mlp"]["wu"],
                               p["mlp"]["wd"], x.dtype)
            new_cac[f"pos{i}"] = nc
        return x, new_cac

    x, new_cache = jax.lax.scan(
        lambda carry, xs: block(carry, xs), x, (params["blocks"], cache))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x,
                        _output_weight(params, cfg).astype(x.dtype))
    return logits.astype(jnp.float32), new_cache


def fused_decode_tick(params, cfg: ModelConfig, cache, last_tok, pos,
                      page_table, n_valid, temperature, top_k, top_p,
                      seed, rid, step, rules: LogicalRules,
                      opts: RunOptions = RunOptions()):
    """One whole serving decode tick as a single device dispatch.

    Runs the batched paged model step AND batched sampling (greedy
    argmax / temperature / top-k / top-p via
    ``runtime.sampler.sample_tokens``, keyed per ``(seed, rid, step)``)
    on device, then advances every active seat's position, sampler step
    and last-token slot functionally — so the serving state lives on
    the device between ticks and exactly one ``(A,)`` int32 token
    vector crosses to the host per tick.  Idle seats (``n_valid == 0``)
    ride through with their state unchanged.

    last_tok: (A,) int32 — each seat's previously emitted token (the
    tick's model input);
    pos: (A,) int32 next write position per seat;
    page_table: (A, n) int32 logical->physical page map;
    n_valid: (A,) int32 — 1 for seats decoding this tick, else 0;
    temperature/top_p: (A,) float32, top_k: (A,) int32,
    seed/rid/step: (A,) uint32 — per-seat sampling state.

    Returns ``(tokens, new_cache, new_pos, new_step, page_table)``:
    ``tokens`` is both the tick's emission and the next tick's
    ``last_tok`` (inactive seats keep their previous token), and
    ``page_table`` is returned untouched so callers can donate it.
    """
    logits, new_cache = paged_decode_step(
        params, cfg, cache, last_tok[:, None], pos, page_table, n_valid,
        rules, opts)
    toks = sampler_mod.sample_tokens(logits[:, 0], temperature, top_k,
                                     top_p, seed, rid, step)
    active = n_valid > 0
    toks = jnp.where(active, toks, last_tok)
    new_pos = pos + n_valid
    new_step = step + n_valid.astype(step.dtype)
    return toks, new_cache, new_pos, new_step, page_table


def decode_step(params, cfg: ModelConfig, cache, tokens, pos,
                rules: LogicalRules, opts: RunOptions = RunOptions()):
    """One token step. tokens: (B,1) int32; pos: (B,) int32 (next position).

    Returns (logits (B,1,V) fp32, new_cache).
    """
    kinds = cfg.layer_kinds()
    mlps = cfg.mlp_kinds()
    dt = jnp.dtype(cfg.compute_dtype)
    x = jnp.take(params["embed"], tokens, axis=0).astype(dt)
    x = _constraint(x, rules, ("batch", None, None))

    def block(x, blk_and_cache):
        blk, cac = blk_and_cache
        new_cac = {}
        for i, (kind, mlpk) in enumerate(zip(kinds, mlps)):
            p = blk[f"pos{i}"]
            c = cac[f"pos{i}"]
            nc = {}
            h = rms_norm(x, p["ln1"], cfg.norm_eps)
            if kind == "ssm":
                mix, (conv, ssm) = ssm_mod.ssm_block(
                    p["mixer"], cfg, h, conv_state=c["conv"],
                    ssm_state=c["ssm"], decode=True)
                nc["conv"], nc["ssm"] = conv, ssm
            else:
                if (kind == "attn_local" and opts.ring_local_cache
                        and cfg.sliding_window
                        and c["k"].shape[1] == cfg.sliding_window):
                    mix, k, v = attn_mod.ring_decode_attention(
                        p["mixer"], cfg, h, c["k"], c["v"], pos)
                else:
                    mix, k, v = attn_mod.decode_attention(
                        p["mixer"], cfg, h, c["k"], c["v"], pos, kind=kind)
                nc["k"], nc["v"] = k, v
            x = x + mix
            if cfg.encoder_decoder:
                hh = rms_norm(x, p["ln_cross"], cfg.norm_eps)
                cmix, _, _ = attn_mod.decode_attention(
                    p["cross"], cfg, hh, c["ck"], c["cv"], pos, cross=True)
                x = x + cmix
                nc["ck"], nc["cv"] = c["ck"], c["cv"]
            if mlpk == "moe":
                hh = rms_norm(x, p["ln2"], cfg.norm_eps)
                x = x + moe_mod.moe_block(p["moe"], cfg, hh, rules=rules,
                                          mesh=opts.mesh,
                                          xaxes=("batch", None, None),
                                          impl=opts.moe_impl)
            elif mlpk == "dense":
                hh = rms_norm(x, p["ln2"], cfg.norm_eps)
                x = x + swiglu(hh, p["mlp"]["wg"], p["mlp"]["wu"],
                               p["mlp"]["wd"], x.dtype)
            new_cac[f"pos{i}"] = nc
        return x, new_cac

    x, new_cache = jax.lax.scan(
        lambda carry, xs: block(carry, xs), x, (params["blocks"], cache))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x,
                        _output_weight(params, cfg).astype(x.dtype))
    return logits.astype(jnp.float32), new_cache
