from repro.parallel.sharding import (  # noqa: F401
    LogicalRules, DEFAULT_RULES, SINGLE_DEVICE_RULES,
    spec_for, shardings_for_tree, batch_spec, activation_rules,
)
