"""Cross-pod pipeline parallelism (GPipe-style, stages = the 'pod' axis).

The SAKURAONE-aware placement: pipeline stages exchange only microbatch
activations (mb·S·D bytes per tick, via ppermute), which is exactly the
kind of thin traffic the paper's 2-pod spine is provisioned for — while
data/tensor parallelism stay on the fat in-pod links.  Layer-group
parameters are sharded over 'pod' (each stage holds G/stages groups), so
layer gradients never cross pods at all.

Schedule: M microbatches, M+stages-1 ticks; every tick each stage applies
its local layer groups to its current input and ppermutes the result
forward.  The loss is computed on the last stage (SPMD-uniform: other
stages compute-and-mask).  Backward is jax.grad through scan+ppermute —
the reverse pipeline falls out of autodiff.

Restrictions (asserted): decoder-only dense/ssm-free archs (no MoE
shard_map nesting, no enc-dec), num_layer_groups % stages == 0.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import SHARD_MAP_PARTIAL_AUTO, shard_map
from repro.parallel.sharding import SINGLE_DEVICE_RULES

from repro.models import model as M
from repro.models.modules import rms_norm, softmax_xent_chunked


def pp_supported(cfg, mesh: Mesh) -> bool:
    if "pod" not in mesh.axis_names:
        return False
    if cfg.moe is not None or cfg.encoder_decoder or cfg.attn_period:
        return False
    groups = cfg.num_layers // cfg.scan_period()
    return groups % mesh.shape["pod"] == 0


def pp_loss_fn(cfg, mesh: Mesh, rules, opts, num_microbatches: int):
    """Returns loss(params, batch) with the layer stack pipelined over
    'pod'.  params['blocks'] must be sharded over 'pod' on the group dim
    (rules override 'layers' -> 'pod' — see steps.build_cell)."""
    stages = mesh.shape["pod"]
    if SHARD_MAP_PARTIAL_AUTO:
        inner_rules = rules.with_overrides(
            batch=tuple(a for a in ("data",) if a in mesh.axis_names),
            layers=None)
    else:
        # fully-manual region (0.4.x fallback): no GSPMD inside, so any
        # constraint naming a mesh axis is illegal — drop them all
        inner_rules = SINGLE_DEVICE_RULES

    def loss(params, batch):
        tokens, labels = batch["tokens"], batch["labels"]
        B, S = tokens.shape
        mb = B // num_microbatches
        dt = jnp.dtype(cfg.compute_dtype)
        # embedding gather stays OUTSIDE the manual region (XLA cannot
        # partition gathers inside manual subgroups)
        x_emb = jnp.take(params["embed"], tokens, axis=0)
        xs = x_emb.reshape(num_microbatches, mb, S, -1)
        ys = labels.reshape(num_microbatches, mb, S)
        ticks = num_microbatches + stages - 1
        pad = ticks - num_microbatches
        xs_pad = jnp.concatenate(
            [xs, jnp.zeros((pad, *xs.shape[1:]), xs.dtype)], axis=0)
        # labels for the microbatch REACHING the last stage at tick t
        ys_pad = jnp.concatenate(
            [jnp.zeros((pad, *ys.shape[1:]), ys.dtype), ys], axis=0)

        non_block = {k: v for k, v in params.items() if k != "blocks"}

        def body(blocks_local, nb_params, xs_pad, ys_pad):
            stage = jax.lax.axis_index("pod")
            positions = jnp.broadcast_to(
                jnp.arange(S, dtype=jnp.int32), (mb, S))
            w_out = (nb_params["embed"].T if cfg.tie_embeddings
                     else nb_params["lm_head"]).astype(dt)

            def stage_fn(x):
                x, _, _ = M.backbone(blocks_local, cfg, x, positions,
                                     inner_rules, opts, train=True)
                return x

            def tick(carry, inp):
                h_recv, acc_loss, acc_cnt = carry
                x_mb, y_mb, t = inp
                x_in = jnp.where(stage == 0, x_mb.astype(dt), h_recv)
                h_out = stage_fn(x_in)
                h_next = jax.lax.ppermute(
                    h_out, "pod", [(i, i + 1) for i in range(stages - 1)])
                # last stage computes the LM loss for valid ticks
                hn = rms_norm(h_out, nb_params["final_norm"], cfg.norm_eps)
                total, count = softmax_xent_chunked(
                    hn, w_out, y_mb, chunk=opts.xent_chunk)
                valid = jnp.logical_and(stage == stages - 1,
                                        t >= stages - 1).astype(jnp.float32)
                return (h_next, acc_loss + valid * total,
                        acc_cnt + valid * count), None

            # init must be TRACED zeros (derived from an input), not eager
            # jnp.zeros: closed-over array constants get wrong sharding
            # names in jax 0.4.x's shard_map transpose (_SpecError).
            h0 = xs_pad[0].astype(dt) * 0
            z0 = h0.reshape(-1)[0].astype(jnp.float32)
            init = (h0, z0, z0)
            (_, tot, cnt), _ = jax.lax.scan(
                tick, init,
                (xs_pad, ys_pad, jnp.arange(ticks, dtype=jnp.int32)))
            tot = jax.lax.psum(tot, "pod")
            cnt = jax.lax.psum(cnt, "pod")
            return tot / jnp.maximum(cnt, 1.0)

        fn = shard_map(
            body, mesh=mesh, axis_names={"pod"},
            in_specs=(P("pod"), P(), P(), P()),
            out_specs=P(), check_vma=False)
        out = fn(params["blocks"], non_block, xs_pad, ys_pad)
        return out, {"xent": out, "aux_loss": jnp.zeros((), jnp.float32)}

    return loss
