"""Logical-axis sharding rules (t5x-style, dependency-free).

Every parameter / activation in the model is annotated with a tuple of
*logical* axis names ("vocab", "embed", "heads", "ff", "expert", "batch",
"seq", ...).  A ``LogicalRules`` table maps logical names to physical mesh
axes of the production mesh ``(pod, data, model)``.  This keeps the model
code mesh-agnostic: DP/TP/EP/SP are all expressed as rule tables, and the
perf hillclimb swaps rule tables rather than editing the model.
"""
from __future__ import annotations

from typing import Mapping, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]


class LogicalRules:
    def __init__(self, rules: Mapping[str, MeshAxes]):
        self.rules = dict(rules)

    def mesh_axes(self, logical: Optional[str]) -> MeshAxes:
        if logical is None:
            return None
        return self.rules.get(logical)

    def with_overrides(self, **overrides: MeshAxes) -> "LogicalRules":
        new = dict(self.rules)
        new.update(overrides)
        return LogicalRules(new)


# Production rules for the (pod, data, model) mesh.
#  - "batch" shards over both DP axes (pod outermost = thin cross-pod hop,
#    mirroring SAKURAONE's 2-pod rail-optimized layout).
#  - tensor-parallel dims ("heads", "ff", "vocab", "expert_ff") on "model".
#  - "embed" (the d_model dim of weights) shards over "data" => FSDP/ZeRO-3:
#    parameters + optimizer moments scale down with DP size, which is what
#    lets grok-1-314b fit 16 GB/chip; GSPMD inserts the per-layer gathers.
#  - "seq_shard" is used for sequence parallelism on long-context cells.
DEFAULT_RULES = LogicalRules({
    "batch": ("pod", "data"),
    "seq": None,
    "seq_shard": "data",          # sequence parallelism (long_500k)
    "embed": "data",              # FSDP dim
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "ff": "model",
    "expert": None,               # experts replicated; expert_ff TP'd (MoE-TP)
    "expert_ff": "model",
    "ssm_inner": "model",
    "ssm_state": None,
    "ssm_heads": "model",
    "conv_width": None,
})

SINGLE_DEVICE_RULES = LogicalRules({k: None for k in DEFAULT_RULES.rules})


def rules_for_mesh(mesh: Mesh, base: "LogicalRules" = None) -> "LogicalRules":
    """Restrict a rule table to axes that exist on `mesh` (e.g. no 'pod' on
    the single-pod production mesh)."""
    base = base or DEFAULT_RULES
    names = set(mesh.axis_names)
    out = {}
    for k, v in base.rules.items():
        if v is None:
            out[k] = None
        elif isinstance(v, str):
            out[k] = v if v in names else None
        else:
            kept = tuple(a for a in v if a in names)
            out[k] = kept if kept else None
    return LogicalRules(out)


def spec_for(logical_axes: Sequence[Optional[str]], rules: LogicalRules) -> P:
    """PartitionSpec for one array annotated with logical axis names."""
    used = set()
    out = []
    for name in logical_axes:
        axes = rules.mesh_axes(name)
        if axes is None:
            out.append(None)
            continue
        if isinstance(axes, str):
            axes = (axes,)
        free = tuple(a for a in axes if a not in used)
        used.update(free)
        out.append(free if len(free) > 1 else (free[0] if free else None))
    return P(*out)


def spec_for_shape(logical_axes: Sequence[Optional[str]],
                   shape: Sequence[int], rules: LogicalRules,
                   mesh: Mesh) -> P:
    """Like spec_for, but drops mesh axes that do not divide the dim size.

    E.g. GQA with 8 KV heads on a 16-wide model axis: the kv_heads dim
    cannot shard 16 ways, so it is replicated (the standard KV-replication
    fallback) instead of erroring.
    """
    used = set()
    out = []
    for name, dim in zip(logical_axes, shape):
        axes = rules.mesh_axes(name)
        if axes is None:
            out.append(None)
            continue
        if isinstance(axes, str):
            axes = (axes,)
        kept = []
        rem = dim
        for a in axes:
            if a in used:
                continue
            if rem % mesh.shape[a] == 0:
                kept.append(a)
                rem //= mesh.shape[a]
        used.update(kept)
        out.append(tuple(kept) if len(kept) > 1 else (kept[0] if kept else None))
    return P(*out)


def shardings_for_tree(axes_tree, mesh: Mesh, rules: LogicalRules):
    """Map a pytree of logical-axes tuples to a pytree of NamedShardings."""
    return jax.tree.map(
        lambda axes: NamedSharding(mesh, spec_for(axes, rules)),
        axes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x),
    )


def batch_spec(rules: LogicalRules, *, seq_sharded: bool = False) -> P:
    """(batch, seq) PartitionSpec for token arrays."""
    b = rules.mesh_axes("batch")
    s = rules.mesh_axes("seq_shard") if seq_sharded else None
    # Avoid double-assigning an axis to both batch and seq.
    if s is not None and b is not None:
        baxes = (b,) if isinstance(b, str) else b
        saxes = (s,) if isinstance(s, str) else s
        if set(baxes) & set(saxes):
            s = None
    return P(b, s)


def activation_rules(rules: LogicalRules, global_batch: int, mesh: Mesh) -> Tuple[LogicalRules, bool]:
    """Decide whether to switch on sequence parallelism for small batches.

    When the global batch cannot saturate the DP axes (e.g. long_500k with
    batch=1) we re-map "batch"→None-leftover and "seq_shard"→"data" so the
    sequence dimension carries the data-axis sharding instead.
    """
    dp = 1
    b = rules.mesh_axes("batch")
    baxes = (b,) if isinstance(b, str) else (b or ())
    for a in baxes:
        dp *= mesh.shape[a]
    if global_batch % dp == 0:
        return rules, False
    # Shrink batch sharding to axes that divide the batch; hand "data" to seq.
    keep = []
    rem = global_batch
    for a in baxes:
        if rem % mesh.shape[a] == 0 and a != "data":
            keep.append(a)
            rem //= mesh.shape[a]
    new = rules.with_overrides(batch=tuple(keep) or None, seq_shard="data")
    return new, True
