"""mamba2-130m [ssm] — SSD (state-space duality), attention-free.

long_500k decode RUNS: the recurrent state is O(1) per token.
[arXiv:2405.21060; unverified]
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-130m", family="ssm",
    num_layers=24, d_model=768, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=50_280, head_dim=64,
    ssm=SSMConfig(d_state=128, conv_width=4, expand=2, head_dim=64, n_groups=1),
    tie_embeddings=True, subquadratic=True,
)
