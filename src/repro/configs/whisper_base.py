"""whisper-base [audio] — enc-dec transformer backbone; conv frontend is a
STUB (input_specs provides precomputed frame embeddings). [arXiv:2212.04356]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", family="audio",
    num_layers=6, d_model=512, num_heads=8, num_kv_heads=8,
    d_ff=2048, vocab_size=51_865, head_dim=64,
    encoder_decoder=True, num_encoder_layers=6, encoder_len=1500,
    frontend="audio", frontend_len=1500, frontend_dim=80,
)
