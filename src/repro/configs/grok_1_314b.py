"""grok-1-314b [moe] — 8 experts top-2, the largest assigned cell.

[hf:xai-org/grok-1; unverified]
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="grok-1-314b", family="moe",
    num_layers=64, d_model=6144, num_heads=48, num_kv_heads=8,
    d_ff=32_768, vocab_size=131_072, head_dim=128,
    moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=32_768),
)
