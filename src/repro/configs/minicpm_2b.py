"""minicpm-2b [dense] — WSD schedule, llama-like, MHA (kv=36).

[arXiv:2404.06395; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b", family="dense",
    num_layers=40, d_model=2304, num_heads=36, num_kv_heads=36,
    d_ff=5760, vocab_size=122_753, head_dim=64,
    tie_embeddings=True,
)
# WSD (warmup-stable-decay) learning-rate schedule is this arch's signature
# training feature — see repro.optim.schedules.wsd_schedule.
SCHEDULE = "wsd"
