"""phi-3-vision-4.2b [vlm] — phi3-mini backbone + CLIP frontend STUB
(input_specs provides precomputed patch embeddings).
[hf:microsoft/Phi-3-vision-128k-instruct; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b", family="vlm",
    num_layers=32, d_model=3072, num_heads=32, num_kv_heads=32,
    d_ff=8192, vocab_size=32_064, head_dim=96,
    frontend="vision", frontend_len=256, frontend_dim=1024,
)
