from repro.configs.base import (  # noqa: F401
    ModelConfig, MoEConfig, SSMConfig, ShapeConfig, SHAPES, reduced_config)
from repro.configs.registry import (  # noqa: F401
    ARCH_IDS, get_config, get_shape, cell_supported, input_specs, input_axes,
    make_example_batch, resolve_arch)
