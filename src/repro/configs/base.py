"""Model / run configuration dataclasses.

One ``ModelConfig`` describes a full architecture; ``ShapeConfig`` describes
one assigned (seq_len, global_batch, kind) cell.  All ten assigned
architectures instantiate these in ``src/repro/configs/<id>.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts block configuration."""
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared_experts: int = 0
    d_ff_shared: int = 0            # total shared-expert hidden dim
    moe_every: int = 1              # MoE MLP every Nth layer (others dense)
    moe_offset: int = 0             # first MoE layer index within the period
    router_jitter: float = 0.0
    capacity_factor: float = 1.25   # capacity-impl slots = cf·T·k/E


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 (SSD) block configuration."""
    d_state: int
    conv_width: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk_size: int = 256           # SSD chunk length for training/prefill


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # defaults to d_model // num_heads
    qk_norm: bool = False
    sliding_window: Optional[int] = None
    local_global_period: Optional[int] = None  # e.g. 6 => 5 local : 1 global
    rope_theta: float = 10_000.0
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # Hybrid (jamba): attention layer every `attn_period` layers at
    # `attn_offset`; all other layers are SSM blocks.
    attn_period: Optional[int] = None
    attn_offset: int = 0
    # Encoder-decoder (whisper)
    encoder_decoder: bool = False
    num_encoder_layers: int = 0
    encoder_len: int = 0            # fixed encoder sequence length (frames)
    # Modality frontend stub: 'audio' | 'vision' | None.  The frontend itself
    # is a stub per the assignment brief — input_specs() provides precomputed
    # frame/patch embeddings.
    frontend: Optional[str] = None
    frontend_len: int = 0           # frames (audio) or patches (vision)
    frontend_dim: int = 0           # embedding dim supplied by the stub
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    # True when *every* layer is sub-quadratic (SSM) or the arch has a
    # sliding-window majority — used to decide long_500k applicability.
    subquadratic: bool = False
    # Pad attention heads up to a TP-divisible count (e.g. minicpm's 36
    # heads -> 48 on a 16-wide model axis).  Padded heads are hard-masked
    # to zero output so the function is EXACTLY the unpadded model; the
    # win is 16-way sharding of attention instead of full replication.
    pad_heads_to: Optional[int] = None

    @property
    def padded_heads(self) -> int:
        return self.pad_heads_to or self.num_heads

    @property
    def padded_kv_heads(self) -> int:
        if self.pad_heads_to is None or self.num_kv_heads == 0:
            return self.num_kv_heads
        group = self.num_heads // self.num_kv_heads
        return self.padded_heads // group

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.num_heads

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer mixer kind ('attn' | 'attn_local' | 'ssm') for one period."""
        period = self.scan_period()
        kinds = []
        for i in range(period):
            if self.family in ("ssm",):
                kinds.append("ssm")
            elif self.attn_period:  # hybrid
                kinds.append("attn" if i % self.attn_period == self.attn_offset else "ssm")
            elif self.local_global_period:
                # gemma3 style: (period-1) local then 1 global
                kinds.append("attn" if (i + 1) % self.local_global_period == 0 else "attn_local")
            else:
                kinds.append("attn")
        return tuple(kinds)

    def mlp_kinds(self) -> Tuple[str, ...]:
        """Per-layer MLP kind ('dense' | 'moe') for one period."""
        period = self.scan_period()
        kinds = []
        for i in range(period):
            if self.moe is not None and i % self.moe.moe_every == self.moe.moe_offset % self.moe.moe_every:
                kinds.append("moe")
            elif self.family == "ssm":
                kinds.append("none")  # mamba2 blocks have no separate MLP
            else:
                kinds.append("dense")
        return tuple(kinds)

    def scan_period(self) -> int:
        """Length of the repeating layer block that lax.scan iterates over."""
        period = 1
        if self.attn_period:
            period = self.attn_period
        if self.local_global_period:
            period = max(period, self.local_global_period)
        if self.moe is not None and self.moe.moe_every > 1:
            import math
            period = period * self.moe.moe_every // math.gcd(period, self.moe.moe_every)
        assert self.num_layers % period == 0, (self.name, self.num_layers, period)
        return period


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str                       # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


# The four assigned LM shapes (identical for every assigned architecture).
SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def reduced_config(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A small same-family config for CPU smoke tests."""
    period = cfg.scan_period()
    small = dict(
        num_layers=2 * period,
        d_model=64,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 4) if cfg.num_kv_heads else 4,
        d_ff=128,
        vocab_size=256,
        head_dim=16,
        sliding_window=32 if cfg.sliding_window else None,
        encoder_len=cfg.encoder_len and 32,
        num_encoder_layers=cfg.num_encoder_layers and 2,
        frontend_len=cfg.frontend_len and 8,
        frontend_dim=cfg.frontend_dim and 64,
    )
    if cfg.moe is not None:
        # capacity_factor = num_experts => no capacity drops: smoke tests
        # assert exact prefill/decode consistency (production keeps 1.25)
        small["moe"] = dataclasses.replace(
            cfg.moe, num_experts=4, top_k=min(cfg.moe.top_k, 2), d_ff_expert=32,
            d_ff_shared=64 if cfg.moe.d_ff_shared else 0, capacity_factor=4.0)
    if cfg.ssm is not None:
        small["ssm"] = dataclasses.replace(
            cfg.ssm, d_state=16, head_dim=16, chunk_size=16)
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
