"""Architecture registry + per-cell input specs.

``get_config(arch_id)`` returns the exact assigned ModelConfig;
``input_specs(cfg, shape)`` returns allocation-free ShapeDtypeStruct
stand-ins for every model input of that (arch × shape) cell — the dry-run
feeds these straight into ``jax.jit(...).lower()``.
"""
from __future__ import annotations

import importlib
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig, SHAPES, reduced_config

_MODULES = {
    "minicpm-2b": "minicpm_2b",
    "llama3-8b": "llama3_8b",
    "qwen3-1.7b": "qwen3_1_7b",
    "gemma3-12b": "gemma3_12b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "grok-1-314b": "grok_1_314b",
    "mamba2-130m": "mamba2_130m",
    "whisper-base": "whisper_base",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "phi-3-vision-4.2b": "phi_3_vision_4_2b",
}

ARCH_IDS = tuple(_MODULES)

# module-style aliases: "llama3_8b" -> "llama3-8b" etc., so CLI specs can
# use either the registry id or the config module's name
_ALIASES = {mod: arch for arch, mod in _MODULES.items()}


def resolve_arch(name: str) -> str:
    """Canonical registry id for ``name`` — the id itself or a
    module-style alias (``llama3_8b`` for ``llama3-8b``).

    Raises:
      KeyError: unknown name; the message lists every known id and
          alias so CLI flag errors are self-explanatory."""
    if name in _MODULES:
        return name
    if name in _ALIASES:
        return _ALIASES[name]
    raise KeyError(f"unknown model {name!r}; known: {sorted(_MODULES)} "
                   f"(aliases: {sorted(_ALIASES)})")


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.CONFIG


def get_shape(shape_id: str) -> ShapeConfig:
    return SHAPES[shape_id]


def cell_supported(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether this (arch × shape) cell runs, per the assignment rules."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, ("skip: long_500k needs sub-quadratic attention; "
                       f"{cfg.name} is pure full-attention (DESIGN.md §6)")
    return True, ""


def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                reduced: Optional[ModelConfig] = None) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for the batch of one cell.

    For decode cells this is the *per-step* input (tokens + positions); the
    KV cache is produced separately by ``repro.models.cache_specs``.
    """
    c = reduced or cfg
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    f32 = jnp.float32
    if shape.kind == "decode":
        specs = {
            "tokens": jax.ShapeDtypeStruct((B, 1), i32),
            "pos": jax.ShapeDtypeStruct((B,), i32),
        }
        return specs
    specs = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
    if shape.kind == "train":
        specs["labels"] = jax.ShapeDtypeStruct((B, S), i32)
    if c.frontend == "vision":
        specs["patches"] = jax.ShapeDtypeStruct((B, c.frontend_len, c.frontend_dim), f32)
    if c.frontend == "audio":
        specs["audio"] = jax.ShapeDtypeStruct((B, c.encoder_len, c.frontend_dim), f32)
    return specs


def input_axes(cfg: ModelConfig, shape: ShapeConfig,
               seq_sharded: bool = False) -> Dict[str, tuple]:
    """Logical axes per input array (feeds parallel.sharding.spec_for)."""
    c = cfg
    seq = "seq_shard" if seq_sharded else None
    if shape.kind == "decode":
        return {"tokens": ("batch", None), "pos": ("batch",)}
    axes = {"tokens": ("batch", seq)}
    if shape.kind == "train":
        axes["labels"] = ("batch", seq)
    if c.frontend == "vision":
        axes["patches"] = ("batch", None, None)
    if c.frontend == "audio":
        axes["audio"] = ("batch", None, None)
    return axes


def make_example_batch(cfg: ModelConfig, shape_kind: str, batch: int, seq: int,
                       key=None) -> Dict[str, jnp.ndarray]:
    """Small concrete batch for smoke tests / examples."""
    key = key if key is not None else jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    out = {"tokens": jax.random.randint(k1, (batch, seq), 0, cfg.vocab_size, jnp.int32)}
    if shape_kind == "train":
        out["labels"] = jax.random.randint(k2, (batch, seq), 0, cfg.vocab_size, jnp.int32)
    if cfg.frontend == "vision":
        out["patches"] = jax.random.normal(k3, (batch, cfg.frontend_len, cfg.frontend_dim))
    if cfg.frontend == "audio":
        # distinct stream from the vision patches (k3 must not be
        # consumed twice; fold_in keeps k1-k3 streams unchanged)
        k4 = jax.random.fold_in(k3, 1)
        out["audio"] = jax.random.normal(k4, (batch, cfg.encoder_len, cfg.frontend_dim))
    return out
