"""gemma3-12b [dense] — 5:1 local:global attention, 256k vocab.

Local layers use a 1024-token sliding window => the majority of the stack is
sub-quadratic, so the long_500k decode cell RUNS for this arch (global
layers' 500k KV cache is sequence-sharded).  [hf:google/gemma-3-1b-pt]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b", family="dense",
    num_layers=48, d_model=3840, num_heads=16, num_kv_heads=8,
    d_ff=15_360, vocab_size=262_144, head_dim=256,
    sliding_window=1024, local_global_period=6,
    tie_embeddings=True, rope_theta=1_000_000.0,
    subquadratic=True,
)
