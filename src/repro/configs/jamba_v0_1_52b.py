"""jamba-v0.1-52b [hybrid] — Mamba+attention 1:7 interleave, MoE 16e top-2
every 2 layers. long_500k decode RUNS (mamba layers O(1); the 4 attention
layers' KV is sequence-sharded). [arXiv:2403.19887; hf]
"""
from repro.configs.base import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14_336, vocab_size=65_536, head_dim=128,
    attn_period=8, attn_offset=4,
    moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=14_336,
                  moe_every=2, moe_offset=1),
    ssm=SSMConfig(d_state=16, conv_width=4, expand=2, head_dim=64, n_groups=1),
    subquadratic=True,
)
