"""Pallas-TPU API compatibility across installed JAX versions.

JAX renamed ``pltpu.TPUCompilerParams`` (0.4.x) to ``pltpu.CompilerParams``
(0.5+); kernels import the alias from here so either version lowers.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    getattr(pltpu, "TPUCompilerParams")
