"""Pallas TPU kernel: fused RMSNorm (read-once, row-tiled).

A small memory-bound fusion: one HBM pass per row tile instead of the
unfused mean-of-squares -> rsqrt -> scale chain.  Included because every
assigned architecture norms 2×/layer; on the memory-dominated decode cells
each avoided pass is visible in the roofline memory term.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)                      # (bm, d)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * (1.0 + w_ref[...].astype(jnp.float32))).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "eps", "interpret"))
def rmsnorm_pallas(x, w, *, eps: float = 1e-6, bm: int = 256,
                   interpret: bool = False):
    """x: (M, D); w: (D,). Returns (M, D) in x.dtype."""
    m, d = x.shape
    assert m % bm == 0, (m, bm)
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, d), x.dtype),
        interpret=interpret,
    )(x, w)
