"""Pallas TPU kernel: blocked FP8(e4m3) GEMM with fp32 accumulation.

The HPL-MxP hot spot (paper Table 9: "sloppy FP8" trailing-update GEMMs)
adapted to the TPU memory hierarchy: operands live in HBM as e4m3 (half the
bf16 footprint => half the HBM traffic), tiles are staged through VMEM with
MXU-aligned (128-multiple) BlockSpecs, and accumulation happens in an fp32
VMEM scratch tile across the K grid dimension.

Per-tile scales (a_scale: (M/bm,), b_scale: (N/bn,)) keep e4m3's narrow
dynamic range usable — the TPU rendering of tensor-core FP8 scaling.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams


def _fp8_matmul_kernel(a_ref, b_ref, sa_ref, sb_ref, o_ref, acc_ref, *,
                       k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...].astype(jnp.float32)          # (bm, bk) e4m3 -> f32
    b = b_ref[...].astype(jnp.float32)          # (bk, bn)
    acc_ref[...] += jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _done():
        scale = sa_ref[0] * sb_ref[0]
        o_ref[...] = acc_ref[...] * scale


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def fp8_matmul_pallas(a_q, b_q, a_scale, b_scale, *, bm: int = 128,
                      bn: int = 128, bk: int = 128, interpret: bool = False):
    """a_q: (M, K) e4m3; b_q: (K, N) e4m3; per-row-block / per-col-block
    scales a_scale: (M//bm,), b_scale: (N//bn,). Returns (M, N) f32.

    Every dimension must be an exact multiple of its block size — the
    grid is built by floor division, so a ragged edge would silently
    drop the remainder rows/cols.  Ragged shapes raise ``ValueError``
    naming the offender; the ``repro.kernels.ops.fp8_matmul`` wrapper
    pads to block multiples before calling this."""
    m, k = a_q.shape
    k2, n = b_q.shape
    if k != k2:
        raise ValueError(
            f"fp8_matmul_pallas: contraction mismatch — a_q is (M={m}, "
            f"K={k}) but b_q is (K={k2}, N={n})")
    for dim_name, dim, blk_name, blk in (
            ("M", m, "bm", bm), ("N", n, "bn", bn), ("K", k, "bk", bk)):
        if dim % blk != 0:
            raise ValueError(
                f"fp8_matmul_pallas: {dim_name}={dim} is not a multiple "
                f"of {blk_name}={blk} (shapes a_q={a_q.shape}, "
                f"b_q={b_q.shape}); the grid would silently truncate — "
                "pad to block multiples or use repro.kernels.ops."
                "fp8_matmul, which pads for you")
    k_steps = k // bk

    return pl.pallas_call(
        functools.partial(_fp8_matmul_kernel, k_steps=k_steps),
        grid=(m // bm, n // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
            pl.BlockSpec((bk, bn), lambda i, j, s: (s, j)),
            pl.BlockSpec((1,), lambda i, j, s: (i,)),
            pl.BlockSpec((1,), lambda i, j, s: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a_q, b_q, a_scale, b_scale)
