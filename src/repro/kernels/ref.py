"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def fp8_matmul_ref(a_q, b_q, a_scale, b_scale, *, bm: int = 128, bn: int = 128):
    """Dequantize-then-matmul oracle. Same per-block scale layout as the
    kernel: a_scale[i] applies to rows [i*bm, (i+1)*bm) — so, like the
    kernel, M and N must be exact multiples of the block sizes."""
    m, _ = a_q.shape
    _, n = b_q.shape
    for dim_name, dim, blk_name, blk in (("M", m, "bm", bm),
                                         ("N", n, "bn", bn)):
        if dim % blk != 0:
            raise ValueError(
                f"fp8_matmul_ref: {dim_name}={dim} is not a multiple of "
                f"{blk_name}={blk} (shapes a_q={a_q.shape}, "
                f"b_q={b_q.shape}); the per-block scale layout cannot "
                "cover a ragged edge — pad to block multiples first")
    sa = jnp.repeat(a_scale, bm)[:, None]
    sb = jnp.repeat(b_scale, bn)[None, :]
    out = jax.lax.dot_general(
        a_q.astype(jnp.float32), b_q.astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    return out * (sa * sb)


def attention_ref(q, k, v, *, causal: bool = True, window: int | None = None):
    """Dense-softmax oracle. q: (BH, Sq, d); k/v: (BH, Skv, d)."""
    d = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (d ** -0.5)
    qp = jnp.arange(q.shape[1])[:, None]
    kp = jnp.arange(k.shape[1])[None, :]
    keep = jnp.ones_like(s[0], bool)
    if causal:
        keep &= kp <= qp
    if window is not None:
        keep &= kp > qp - window
    s = jnp.where(keep[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)


def rmsnorm_ref(x, w, *, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + w.astype(jnp.float32))).astype(x.dtype)


def decode_attention_ref(q, k, v, lengths):
    """Oracle for single-query decode. q: (BH, d); k/v: (BH, T, d)."""
    d = q.shape[-1]
    s = jnp.einsum("bd,btd->bt", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (d ** -0.5)
    t = jnp.arange(k.shape[1])[None, :]
    s = jnp.where(t < lengths[:, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bt,btd->bd", p, v.astype(jnp.float32)).astype(q.dtype)


def paged_decode_attention_ref(q, k_pages, v_pages, page_table, lengths):
    """Oracle for paged decode: gather each row's pages into a contiguous
    cache, then dense decode.  q: (BH, d); k_pages/v_pages: (P, page, d);
    page_table: (BH, n) int32; lengths: (BH,)."""
    bh = q.shape[0]
    _, page, d = k_pages.shape
    k = k_pages[page_table].reshape(bh, -1, d)     # (BH, n*page, d)
    v = v_pages[page_table].reshape(bh, -1, d)
    return decode_attention_ref(q, k, v, lengths)


def quantized_paged_decode_attention_ref(q, k_pages, v_pages, k_scale,
                                         v_scale, page_table, lengths):
    """Oracle for paged decode over quantized pools: dequantize every
    page with its per-(slot, head-row) scales, then run the f32 paged
    oracle.  k_pages/v_pages: (P, page, d) fp8/int8 — uint8 arrays are
    fp8 bit patterns (core.mixed_precision.kv_storage_dtype) and are
    bitcast to e4m3 before the value cast; k_scale/v_scale: (P, page)
    f32 — one scale per stored d-vector."""
    if k_pages.dtype == jnp.uint8:
        k_pages = jax.lax.bitcast_convert_type(k_pages, jnp.float8_e4m3fn)
        v_pages = jax.lax.bitcast_convert_type(v_pages, jnp.float8_e4m3fn)
    k = k_pages.astype(jnp.float32) * k_scale[..., None]
    v = v_pages.astype(jnp.float32) * v_scale[..., None]
    return paged_decode_attention_ref(q, k, v, page_table, lengths)
