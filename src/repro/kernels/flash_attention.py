"""Pallas TPU kernel: flash attention (online-softmax tiled attention).

The prefill hot spot.  Q/K/V tiles are staged HBM->VMEM with MXU-aligned
BlockSpecs; softmax statistics (running max / normalizer) and the output
accumulator live in fp32 VMEM scratch across the KV grid dimension, so the
(Sq × Skv) score matrix is never materialized — the memory-term fix that
lets 32k-prefill run without O(S²) intermediates.

Supports causal masking and sliding-window (local) attention — the gemma3
5:1 local:global pattern runs both variants.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: int | None,
                  bq: int, bk: int, k_steps: int, kv_len: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)

    # Skip fully-masked tiles (upper-triangle blocks under causal masking).
    run = jnp.asarray(True)
    if causal:
        run = (ki * bk) <= (qi * bq + bq - 1)
    if window is not None:
        run = jnp.logical_and(run, (ki + 1) * bk - 1 > qi * bq - window)

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                    # (bq, d)
        k = k_ref[0].astype(jnp.float32)                    # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        keep = k_pos < kv_len                   # mask zero-padding tail
        if causal:
            keep &= k_pos <= q_pos
        if window is not None:
            keep &= k_pos > q_pos - window
        s = jnp.where(keep, s, NEG_INF)

        m_old = m_ref[...]                                  # (bq, 1)
        m_new = jnp.maximum(m_old, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_old - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0].astype(jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == k_steps - 1)
    def _done():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "interpret", "kv_len"))
def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           window: int | None = None, bq: int = 128,
                           bk: int = 128, interpret: bool = False,
                           kv_len: int | None = None):
    """q: (BH, Sq, d); k/v: (BH, Skv, d). Returns (BH, Sq, d) in q.dtype.

    BH is the flattened batch×heads dim (GQA head expansion happens in the
    ops.py wrapper).  Sq % bq == 0 and Skv % bk == 0 (wrapper pads).
    """
    bh, sq, d = q.shape
    _, skv, _ = k.shape
    assert sq % bq == 0 and skv % bk == 0, (sq, skv, bq, bk)
    k_steps = skv // bk
    scale = d ** -0.5
    kv_len = kv_len if kv_len is not None else skv

    return pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          window=window, bq=bq, bk=bk, k_steps=k_steps,
                          kv_len=kv_len),
        grid=(bh, sq // bq, k_steps),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, s: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, s: (b, s, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, s: (b, s, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, s: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
