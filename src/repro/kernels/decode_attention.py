"""Pallas TPU kernel: single-query (decode) flash attention.

The serving hot spot: one new query token attends against a long KV cache
(decode_32k: 32768 keys; long_500k: 524288).  Memory-bound by the KV read —
so the kernel streams K/V tiles HBM->VMEM exactly once, carries online-
softmax statistics in scratch, and masks by each row's current length
``pos`` (slots beyond the write position are dead).

Layout: q (BH, d); k/v (BH, T, d); lengths (BH,) int32 (number of valid
keys = pos+1).  GQA expansion happens in the ops.py wrapper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                   acc_ref, *, scale: float, bk: int, k_steps: int):
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[0]
    # skip tiles entirely beyond the valid length
    @pl.when(ki * bk < length)
    def _compute():
        q = q_ref[...].astype(jnp.float32)                  # (1, d)
        k = k_ref[0].astype(jnp.float32)                    # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
        s = jnp.where(kpos < length, s, NEG_INF)            # (1, bk)
        m_old = m_ref[...]
        m_new = jnp.maximum(m_old, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_old - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0].astype(jnp.float32)                    # (bk, d)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == k_steps - 1)
    def _done():
        o_ref[...] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                      ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bk", "interpret"))
def decode_attention_pallas(q, k, v, lengths, *, bk: int = 256,
                            interpret: bool = False):
    """q: (BH, d); k/v: (BH, T, d); lengths: (BH,) valid-key counts.
    Returns (BH, d) in q.dtype."""
    bh, d = q.shape
    _, t, _ = k.shape
    assert t % bk == 0, (t, bk)
    k_steps = t // bk
    scale = d ** -0.5

    return pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale, bk=bk, k_steps=k_steps),
        grid=(bh, k_steps),
        in_specs=[
            pl.BlockSpec((1,), lambda b, s: (b,)),
            pl.BlockSpec((1, d), lambda b, s: (b, 0)),
            pl.BlockSpec((1, bk, d), lambda b, s: (b, s, 0)),
            pl.BlockSpec((1, bk, d), lambda b, s: (b, s, 0)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda b, s: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(lengths, q, k, v)
