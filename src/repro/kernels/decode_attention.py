"""Pallas TPU kernel: single-query (decode) flash attention.

The serving hot spot: one new query token attends against a long KV cache
(decode_32k: 32768 keys; long_500k: 524288).  Memory-bound by the KV read —
so the kernel streams K/V tiles HBM->VMEM exactly once, carries online-
softmax statistics in scratch, and masks by each row's current length
``pos`` (slots beyond the write position are dead).

Layout: q (BH, d); k/v (BH, T, d); lengths (BH,) int32 (number of valid
keys = pos+1).  GQA expansion happens in the ops.py wrapper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                   acc_ref, *, scale: float, bk: int, k_steps: int):
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[0]
    # skip tiles entirely beyond the valid length
    @pl.when(ki * bk < length)
    def _compute():
        q = q_ref[...].astype(jnp.float32)                  # (1, d)
        k = k_ref[0].astype(jnp.float32)                    # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
        s = jnp.where(kpos < length, s, NEG_INF)            # (1, bk)
        m_old = m_ref[...]
        m_new = jnp.maximum(m_old, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_old - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0].astype(jnp.float32)                    # (bk, d)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == k_steps - 1)
    def _done():
        o_ref[...] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                      ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bk", "interpret"))
def decode_attention_pallas(q, k, v, lengths, *, bk: int = 256,
                            interpret: bool = False):
    """q: (BH, d); k/v: (BH, T, d); lengths: (BH,) valid-key counts.
    Returns (BH, d) in q.dtype.

    ``bk`` is clamped to the cache length and the cache is zero-padded
    up to the next tile multiple (padded keys sit beyond every row's
    ``lengths`` so the in-kernel mask drops them), so any ``T`` works —
    e.g. the fixed-slot engine's ``max_len + 1`` scratch layouts and
    odd ``max_len`` configs that are not multiples of the tile."""
    bh, d = q.shape
    _, t, _ = k.shape
    bk = min(bk, t)
    pad = (-t) % bk
    if pad:
        widths = [(0, 0), (0, pad), (0, 0)]
        k = jnp.pad(k, widths)
        v = jnp.pad(v, widths)
        t += pad
    k_steps = t // bk
    scale = d ** -0.5

    return pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale, bk=bk, k_steps=k_steps),
        grid=(bh, k_steps),
        in_specs=[
            pl.BlockSpec((1,), lambda b, s: (b,)),
            pl.BlockSpec((1, d), lambda b, s: (b, 0)),
            pl.BlockSpec((1, bk, d), lambda b, s: (b, s, 0)),
            pl.BlockSpec((1, bk, d), lambda b, s: (b, s, 0)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda b, s: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(lengths, q, k, v)


# ---------------------------------------------------------------------------
# Paged decode attention (gather-over-page-table)
# ---------------------------------------------------------------------------
#
# The serving engine stores KV in fixed-size pages drawn from a shared pool;
# a request's cache is the (non-contiguous) set of pages named by its page
# table.  The kernel walks the page table with scalar prefetch: the block
# index_map reads ``page_table[b, i]`` so the DMA for grid step (b, i) pulls
# exactly that physical page HBM->VMEM — no contiguous copy of the request's
# KV is ever materialized.


def _paged_decode_kernel(len_ref, pt_ref, q_ref, k_ref, v_ref, o_ref,
                         m_ref, l_ref, acc_ref, *, scale: float, page: int,
                         n_pages: int):
    b = pl.program_id(0)
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[b]
    # pages entirely beyond the valid length are dead (their table entries
    # point at the scratch page) — skip the whole tile
    @pl.when(i * page < length)
    def _compute():
        q = q_ref[...].astype(jnp.float32)                  # (1, d)
        k = k_ref[0].astype(jnp.float32)                    # (page, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        kpos = i * page + jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)
        s = jnp.where(kpos < length, s, NEG_INF)            # (1, page)
        m_old = m_ref[...]
        m_new = jnp.maximum(m_old, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_old - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0].astype(jnp.float32)                    # (page, d)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(i == n_pages - 1)
    def _done():
        o_ref[...] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                      ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention_pallas(q, k_pages, v_pages, page_table, lengths, *,
                                  interpret: bool = False):
    """Decode attention over a paged KV pool.

    q: (BH, d); k_pages/v_pages: (P, page, d) shared physical pool;
    page_table: (BH, n) int32 — physical page of each row's i-th logical
    page (dead entries must still name a valid page, e.g. scratch page 0);
    lengths: (BH,) valid-key counts.  Returns (BH, d) in q.dtype.
    """
    bh, d = q.shape
    _, page, _ = k_pages.shape
    n_pages = page_table.shape[1]
    scale = d ** -0.5

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                   # lengths, page_table
        grid=(bh, n_pages),
        in_specs=[
            pl.BlockSpec((1, d), lambda b, i, lens, pt: (b, 0)),
            pl.BlockSpec((1, page, d), lambda b, i, lens, pt: (pt[b, i], 0, 0)),
            pl.BlockSpec((1, page, d), lambda b, i, lens, pt: (pt[b, i], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda b, i, lens, pt: (b, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_paged_decode_kernel, scale=scale, page=page,
                          n_pages=n_pages),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bh, d), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(lengths.astype(jnp.int32), page_table.astype(jnp.int32), q,
      k_pages, v_pages)


# ---------------------------------------------------------------------------
# Quantized paged decode attention (fp8/int8 pages + per-slot scales)
# ---------------------------------------------------------------------------
#
# Same gather-over-page-table structure, but the pool stores K/V quantized
# (fp8 e4m3 or int8) with one f32 scale per stored d-vector.  The scale
# arrays ride the SAME scalar-prefetched page table as the value pages —
# grid step (b, i) DMAs page ``pt[b, i]``'s values AND its scale row into
# VMEM together — and the tiles are dequantized to f32 in VMEM before the
# flash inner loop, so the softmax/accumulate math is identical to the
# full-precision kernel.


def _quantized_paged_decode_kernel(len_ref, pt_ref, q_ref, k_ref, v_ref,
                                   ks_ref, vs_ref, o_ref, m_ref, l_ref,
                                   acc_ref, *, scale: float, page: int,
                                   n_pages: int):
    b = pl.program_id(0)
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[b]

    @pl.when(i * page < length)
    def _compute():
        q = q_ref[...].astype(jnp.float32)                  # (1, d)
        # dequantize in VMEM: values (page, d) * per-slot scales (page, 1)
        k = k_ref[0].astype(jnp.float32) * ks_ref[0][:, None]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        kpos = i * page + jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)
        s = jnp.where(kpos < length, s, NEG_INF)            # (1, page)
        m_old = m_ref[...]
        m_new = jnp.maximum(m_old, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_old - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0].astype(jnp.float32) * vs_ref[0][:, None]
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(i == n_pages - 1)
    def _done():
        o_ref[...] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                      ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def quantized_paged_decode_attention_pallas(q, k_pages, v_pages, k_scale,
                                            v_scale, page_table, lengths, *,
                                            interpret: bool = False):
    """Decode attention over a quantized paged KV pool.

    q: (BH, d); k_pages/v_pages: (P, page, d) fp8/int8 physical pool;
    k_scale/v_scale: (P, page) f32 — one scale per stored d-vector,
    laid out page-for-page with the value pools so the scalar-prefetched
    page table drives both DMAs; page_table: (BH, n) int32; lengths:
    (BH,).  Returns (BH, d) in q.dtype.  Tolerance vs the f32 kernel is
    bounded by the storage format's relative error (e4m3: 3 mantissa
    bits, ~6%/element on K/V — see tests/test_kernels.py).
    """
    if k_pages.dtype == jnp.uint8:
        # fp8 pools travel as uint8 bit patterns through the serving
        # stack (core.mixed_precision.kv_storage_dtype); recover the
        # e4m3 view here so the in-kernel f32 cast reads real values
        k_pages = jax.lax.bitcast_convert_type(k_pages, jnp.float8_e4m3fn)
        v_pages = jax.lax.bitcast_convert_type(v_pages, jnp.float8_e4m3fn)
    bh, d = q.shape
    _, page, _ = k_pages.shape
    n_pages = page_table.shape[1]
    scale = d ** -0.5

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                   # lengths, page_table
        grid=(bh, n_pages),
        in_specs=[
            pl.BlockSpec((1, d), lambda b, i, lens, pt: (b, 0)),
            pl.BlockSpec((1, page, d), lambda b, i, lens, pt: (pt[b, i], 0, 0)),
            pl.BlockSpec((1, page, d), lambda b, i, lens, pt: (pt[b, i], 0, 0)),
            pl.BlockSpec((1, page), lambda b, i, lens, pt: (pt[b, i], 0)),
            pl.BlockSpec((1, page), lambda b, i, lens, pt: (pt[b, i], 0)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda b, i, lens, pt: (b, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_quantized_paged_decode_kernel, scale=scale,
                          page=page, n_pages=n_pages),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bh, d), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(lengths.astype(jnp.int32), page_table.astype(jnp.int32), q,
      k_pages, v_pages, k_scale, v_scale)
