"""jit'd public wrappers for the Pallas kernels.

Handle quantization, padding to block multiples, GQA head expansion, and
the interpret-mode fallback (CPU containers validate kernel bodies with
``interpret=True``; on TPU the same call sites compile to Mosaic).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.mixed_precision import quantize_fp8, F8_MAX
from repro.kernels.fp8_matmul import fp8_matmul_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.rmsnorm import rmsnorm_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x, mult, axis):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), pad


def fp8_matmul(a, b, *, bm: int = 128, bn: int = 128, bk: int = 128,
               interpret: bool | None = None):
    """f32/bf16 (M,K) @ (K,N) through the FP8 Pallas kernel with per-block
    scaling. Pads every dim to the block multiple."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    a, pm = _pad_to(a, bm, 0)
    a, pk = _pad_to(a, bk, 1)
    b, _ = _pad_to(b, bk, 0)
    b, pn = _pad_to(b, bn, 1)
    m, k = a.shape
    n = b.shape[1]
    # per-row-block / per-col-block scales
    am = jnp.max(jnp.abs(a.reshape(m // bm, bm, k)), axis=(1, 2))
    bm_ = jnp.max(jnp.abs(b.reshape(k, n // bn, bn)), axis=(0, 2))
    sa = jnp.maximum(am, 1e-12) / F8_MAX
    sb = jnp.maximum(bm_, 1e-12) / F8_MAX
    a_q = (a / jnp.repeat(sa, bm)[:, None]).astype(jnp.float8_e4m3fn)
    b_q = (b / jnp.repeat(sb, bn)[None, :]).astype(jnp.float8_e4m3fn)
    out = fp8_matmul_pallas(a_q, b_q, sa.astype(jnp.float32),
                            sb.astype(jnp.float32), bm=bm, bn=bn, bk=bk,
                            interpret=interpret)
    if pm or pn:
        out = out[:out.shape[0] - pm or None, :out.shape[1] - pn or None]
    return out


def flash_attention(q, k, v, *, causal: bool = True, window: int | None = None,
                    bq: int = 128, bk: int = 128,
                    interpret: bool | None = None):
    """q: (B, Sq, H, d); k/v: (B, Skv, KVH, d) — GQA expanded here.

    Returns (B, Sq, H, d)."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    B, Sq, H, d = q.shape
    KVH = k.shape[2]
    rep = H // KVH
    k = jnp.repeat(k, rep, axis=2)
    v = jnp.repeat(v, rep, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, d)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, -1, d)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, -1, d)
    skv = kf.shape[1]
    qf, pq = _pad_to(qf, bq, 1)
    kf, _ = _pad_to(kf, bk, 1)
    vf, _ = _pad_to(vf, bk, 1)
    out = flash_attention_pallas(qf, kf, vf, causal=causal, window=window,
                                 bq=bq, bk=bk, interpret=interpret,
                                 kv_len=skv)
    if pq:
        out = out[:, :Sq]
    return out.reshape(B, H, Sq, d).transpose(0, 2, 1, 3)


def rmsnorm(x, w, *, eps: float = 1e-6, bm: int = 256,
            interpret: bool | None = None):
    """x: (..., D) fused RMSNorm."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    x2, pm = _pad_to(x2, bm, 0)
    out = rmsnorm_pallas(x2, w, eps=eps, bm=bm, interpret=interpret)
    if pm:
        out = out[:out.shape[0] - pm]
    return out.reshape(*lead, x.shape[-1])


def decode_attention(q, k, v, lengths, *, bk: int = 256,
                     interpret: bool | None = None):
    """Single-token decode attention against a KV cache.

    q: (B, 1, H, d); k/v: (B, T, KVH, d); lengths: (B,) valid-key counts.
    Returns (B, 1, H, d)."""
    from repro.kernels.decode_attention import decode_attention_pallas
    interpret = (not _on_tpu()) if interpret is None else interpret
    B, _, H, d = q.shape
    KVH = k.shape[2]
    rep = H // KVH
    kf = jnp.repeat(k, rep, axis=2).transpose(0, 2, 1, 3).reshape(B * H, -1, d)
    vf = jnp.repeat(v, rep, axis=2).transpose(0, 2, 1, 3).reshape(B * H, -1, d)
    qf = q[:, 0].transpose(0, 1, 2).reshape(B * H, d)
    kf, _ = _pad_to(kf, bk, 1)
    vf, _ = _pad_to(vf, bk, 1)
    lens = jnp.repeat(lengths, H)
    out = decode_attention_pallas(qf, kf, vf, lens.astype(jnp.int32),
                                  bk=bk, interpret=interpret)
    return out.reshape(B, H, d)[:, None].transpose(0, 1, 2, 3).reshape(B, 1, H, d)


def paged_decode_attention(q, k_pages, v_pages, page_table, lengths, *,
                           k_scale=None, v_scale=None,
                           interpret: bool | None = None):
    """Single-token decode attention over a paged KV pool.

    q: (B, 1, H, d); k_pages/v_pages: (P, page, KVH, d) shared pool;
    page_table: (B, n) int32 per-request logical->physical page map;
    lengths: (B,) valid-key counts.  Returns (B, 1, H, d).

    When the pool is quantized (fp8/int8), pass ``k_scale``/``v_scale``
    shaped (P, page, KVH) — one f32 scale per stored d-vector — and the
    quantized kernel dequantizes the tiles in VMEM (both scales must be
    given together).

    GQA expansion happens on the *page table*, not the pool: head h of
    request b reads pages ``kvh(h) * P + page_table[b]`` of the pool
    flattened to (KVH*P, page, d) — the big KV arrays are never repeated.
    """
    from repro.kernels.decode_attention import (
        paged_decode_attention_pallas, quantized_paged_decode_attention_pallas)
    if (k_scale is None) != (v_scale is None):
        raise ValueError("paged_decode_attention: pass k_scale and v_scale "
                         "together (quantized pool) or neither")
    interpret = (not _on_tpu()) if interpret is None else interpret
    B, _, H, d = q.shape
    P, page, KVH, _ = k_pages.shape
    rep = H // KVH
    n = page_table.shape[1]
    kf = k_pages.transpose(2, 0, 1, 3).reshape(KVH * P, page, d)
    vf = v_pages.transpose(2, 0, 1, 3).reshape(KVH * P, page, d)
    head_base = (jnp.arange(H, dtype=jnp.int32) // rep) * P          # (H,)
    pt = (head_base[None, :, None] + page_table[:, None, :]
          ).reshape(B * H, n)
    qf = q[:, 0].reshape(B * H, d)
    lens = jnp.repeat(lengths, H)
    if k_scale is not None:
        # flatten scales exactly like the pools: (P, page, KVH) ->
        # (KVH*P, page), so pt indexes values and scales identically
        ksf = k_scale.transpose(2, 0, 1).reshape(KVH * P, page)
        vsf = v_scale.transpose(2, 0, 1).reshape(KVH * P, page)
        out = quantized_paged_decode_attention_pallas(
            qf, kf, vf, ksf, vsf, pt.astype(jnp.int32),
            lens.astype(jnp.int32), interpret=interpret)
    else:
        out = paged_decode_attention_pallas(qf, kf, vf, pt.astype(jnp.int32),
                                            lens.astype(jnp.int32),
                                            interpret=interpret)
    return out.reshape(B, 1, H, d)
