"""Unified continuous-batching serving stack (vLLM-style, JAX-native).

One :class:`Scheduler` runs every arch; a pluggable *KV placement policy*
supplies the cache layout and the model arithmetic, and a shared
``runtime.sampler.Sampler`` turns logits into tokens for both::

            submit(prompt, sampling) ─────► FCFS queue
                                               │
                 ┌─────────────────────────────┘
                 ▼
            Scheduler.step()                      (one engine tick)
              1. admission  — policy.try_admit(): reserve a seat and
                 KV placement (fixed slot | pages + cached-prefix refs)
              2. policy.prefill_tick()  — prompt K/V into placement
              3. policy.decode_tick()   — one token per ready seat
                 │ per-seat logits row
                 ▼
            Sampler.sample(logits, req.sampling, rid, step)
                 │ next token id (greedy argmax when temperature=0)
                 ▼
            Scheduler bookkeeping — trace, EngineMetrics, eos/max-new
            completion, finish() → policy.release() returns the KV

    placement policies
      FixedSlotPolicy  — B dense cache slots of max_len tokens each;
                         whole-prompt prefill scattered into the slot.
                         Covers the archs with fixed-size per-request
                         state (SSM, encoder/decoder, vision/audio
                         frontends) and is the equivalence oracle for
                         the paged path.
      PagedPolicy      — KV in a shared pool of fixed-size pages
                         (``runtime.paged_kv.BlockManager``), chunked
                         prefill interleaved with decode, gather-over-
                         page-table attention (``attention.paged_
                         attention``; Pallas kernel on TPU), and
                         refcounted prefix caching: admission points the
                         leading page-table entries of a request whose
                         prompt starts with an already-cached page-
                         aligned token run at those physical pages
                         (refcount++), copy-on-writes only the last
                         partially matching page, and skips prefilling
                         everything cached.  Refcount-0 cached pages
                         park in an LRU list and are evicted under
                         pressure.

:class:`ServingEngine` (fixed-slot) and :class:`PagedServingEngine` are
thin façades binding the Scheduler to one policy; both complete requests
on max_new_tokens or eos and ``run`` raises :class:`SchedulerStallError`
when ticks run out with work still pending (stalls fail loudly).

Scheduling is deterministic (FCFS admission, lowest-rid prefill first,
seats scanned in index order) so trace tests can assert exact
interleavings.  ``trace`` records (tick, event, rid) tuples with events:
admit / prefix_hit / prefill_chunk / first_token / decode / finish.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M
from repro.parallel.sharding import LogicalRules, SINGLE_DEVICE_RULES
from repro.runtime.paged_kv import BlockManager, EngineMetrics
from repro.runtime.sampler import GREEDY, Sampler, SamplingParams


class SchedulerStallError(RuntimeError):
    """``run`` exhausted ``max_ticks`` with requests still queued/active."""


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (P,) int32
    max_new_tokens: int
    eos_id: Optional[int] = None
    sampling: SamplingParams = GREEDY
    # filled by the engine:
    generated: List[int] = dataclasses.field(default_factory=list)
    slot: Optional[int] = None      # seat index (paged) / cache slot (fixed)
    pages: List[int] = dataclasses.field(default_factory=list)
    prefill_pos: int = 0            # prompt tokens already placed (paged)
    cached_tokens: int = 0          # prompt tokens served by the prefix cache
    registered_pages: int = 0       # prompt pages published to the prefix index
    match_version: Optional[int] = None  # BlockManager.version at last failed
    #                                      admission attempt (re-match gate)
    done: bool = False
    t_submit: float = 0.0
    t_first_token: Optional[float] = None
    t_done: Optional[float] = None


class Scheduler:
    """Engine-agnostic serving loop: queue, seats, admission, sampling,
    completion, metrics and trace.  All KV placement and model calls live
    in the bound policy (see module docstring)."""

    default_max_ticks = 100_000

    def __init__(self, policy, *, max_seats: int,
                 sampler: Optional[Sampler] = None, page_capacity: int = 0):
        self.policy = policy
        self.max_seats = max_seats
        self.sampler = sampler or Sampler()
        self.seats: Dict[int, Request] = {}             # seat -> request
        self.queue: Deque[Request] = deque()
        self.finished: List[Request] = []
        self.metrics = EngineMetrics(page_capacity=page_capacity)
        self.trace: List[Tuple[int, str, int]] = []
        self._next_rid = 0
        self._tick = 0
        policy.bind(self)

    # -- queue ---------------------------------------------------------------

    def submit(self, prompt, max_new_tokens: int = 16,
               eos_id: Optional[int] = None,
               sampling: Optional[SamplingParams] = None) -> int:
        req = Request(self._next_rid, np.asarray(prompt, np.int32),
                      max_new_tokens, eos_id, sampling or GREEDY,
                      t_submit=time.perf_counter())
        self.policy.validate(req)
        self._next_rid += 1
        self.queue.append(req)
        self.metrics.submitted += 1
        return req.rid

    def _free_seats(self) -> List[int]:
        return [s for s in range(self.max_seats) if s not in self.seats]

    def _admit_from_queue(self):
        """FCFS: admit while the head request's seat AND placement are
        available (preemption-free — an admitted request can always run
        to completion; shortfall queues, never crashes)."""
        for seat in self._free_seats():
            if not self.queue:
                break
            req = self.queue[0]
            if not self.policy.try_admit(req, seat):
                break
            self.queue.popleft()
            req.slot = seat
            self.seats[seat] = req
            self.metrics.admitted += 1
            self.trace.append((self._tick, "admit", req.rid))
            if req.cached_tokens:
                self.metrics.cached_prompt_tokens += req.cached_tokens
                self.trace.append((self._tick, "prefix_hit", req.rid))

    # -- token bookkeeping ----------------------------------------------------

    def _emit_first_token(self, req: Request, logits_row) -> None:
        """Sample the TTFT token from the last prompt position's logits."""
        if req.sampling.greedy:
            tok = int(jnp.argmax(logits_row))    # device reduce, 1 int out
        else:
            tok = self.sampler.sample(np.asarray(logits_row), req.sampling,
                                      rid=req.rid, step=0)
        req.generated.append(tok)
        req.t_first_token = time.perf_counter()
        self.metrics.ttft_s.append(req.t_first_token - req.t_submit)
        self.metrics.first_tokens += 1
        self.trace.append((self._tick, "first_token", req.rid))
        hit_eos = req.eos_id is not None and tok == req.eos_id
        if req.max_new_tokens <= 1 or hit_eos:
            self.finish(req)

    def _sample_decode_batch(self, last_logits, seat_ids) -> Dict[int, int]:
        """Next token per seat from ``(max_seats, V)`` device logits.
        Greedy seats share one on-device argmax (only ints cross to host);
        full logits rows are pulled only when a stochastic seat needs
        them."""
        greedy = np.asarray(jnp.argmax(last_logits, axis=-1), np.int32)
        rows = None
        toks: Dict[int, int] = {}
        for s in seat_ids:
            req = self.seats[s]
            if req.sampling.greedy:
                toks[s] = int(greedy[s])
            else:
                if rows is None:
                    rows = np.asarray(last_logits)
                toks[s] = self.sampler.sample(rows[s], req.sampling,
                                              rid=req.rid,
                                              step=len(req.generated))
        return toks

    def _emit_decode_token(self, req: Request, tok: int) -> None:
        req.generated.append(tok)
        self.metrics.decode_tokens += 1
        self.trace.append((self._tick, "decode", req.rid))
        hit_eos = req.eos_id is not None and tok == req.eos_id
        if len(req.generated) >= req.max_new_tokens or hit_eos:
            self.finish(req)

    def finish(self, req: Request) -> None:
        req.done = True
        req.t_done = time.perf_counter()
        self.policy.release(req)
        del self.seats[req.slot]
        self.finished.append(req)
        self.metrics.completed += 1
        self.trace.append((self._tick, "finish", req.rid))

    # -- one engine tick -----------------------------------------------------

    def step(self):
        self.metrics.begin()
        self._tick += 1
        self._admit_from_queue()
        self.policy.prefill_tick()
        self.policy.decode_tick()
        cached, evictions = self.policy.cache_stats()
        self.metrics.tick(queued=len(self.queue), active=len(self.seats),
                          pages_in_use=self.policy.pages_in_use(),
                          cached_pages=cached, evictions=evictions)

    def run(self, max_ticks: Optional[int] = None) -> List[Request]:
        if max_ticks is None:
            max_ticks = self.default_max_ticks
        t = 0
        while (self.queue or self.seats) and t < max_ticks:
            self.step()
            t += 1
        if self.queue or self.seats:
            raise SchedulerStallError(
                f"run() exhausted max_ticks={max_ticks} with "
                f"{len(self.queue)} queued and {len(self.seats)} active "
                f"requests (rids "
                f"{sorted([r.rid for r in self.queue] + [r.rid for r in self.seats.values()])})")
        return self.finished


# ---------------------------------------------------------------------------
# KV placement policies
# ---------------------------------------------------------------------------

class FixedSlotPolicy:
    """Dense fixed-slot placement: B cache slots of ``max_len`` tokens,
    whole-prompt prefill scattered into the slot.  Wastes
    ``max_len - len`` KV tokens per short request, but its per-request
    state is constant-size, so it covers SSM / encoder-decoder / frontend
    archs and is the arithmetic oracle for the paged path."""

    def __init__(self, cfg, params, *, slots: int, max_len: int,
                 rules: LogicalRules, opts: Optional[M.RunOptions]):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.rules = rules
        self.opts = opts or M.RunOptions(q_chunk=min(max_len, 512))
        self.cache = M.init_cache(cfg, slots, max_len, self.opts)
        self.pos = jnp.zeros((slots,), jnp.int32)       # next write position
        self._decode = jax.jit(
            lambda p, c, t, q: M.decode_step(p, cfg, c, t, q, rules, self.opts))
        self._prefill = jax.jit(
            lambda p, b: M.prefill(p, cfg, b, rules, self.opts))

    def bind(self, sched: Scheduler) -> None:
        self.sched = sched

    def pages_in_use(self) -> int:
        return 0

    def cache_stats(self) -> Tuple[int, int]:
        return 0, 0

    def validate(self, req: Request) -> None:
        total = len(req.prompt) + req.max_new_tokens
        if len(req.prompt) == 0:
            raise ValueError("empty prompt")
        if len(req.prompt) >= self.max_len:
            raise ValueError(f"prompt length {len(req.prompt)} >= "
                             f"max_len={self.max_len}")
        if total > self.max_len:
            raise ValueError(f"request needs {total} tokens > "
                             f"max_len={self.max_len}; decode would clamp "
                             "into the last cache slot and corrupt KV")

    def try_admit(self, req: Request, seat: int) -> bool:
        return True                       # the seat is the only resource

    def release(self, req: Request) -> None:
        pass                              # slot frees with the seat

    def prefill_tick(self) -> None:
        """Whole-prompt prefill for every seat admitted this tick, in rid
        order (so the newly admitted request decodes in the same tick —
        the pre-refactor fixed-slot cadence)."""
        pending = sorted((r for r in self.sched.seats.values()
                          if r.prefill_pos < len(r.prompt)),
                         key=lambda r: r.rid)
        for req in pending:
            self._prefill_one(req)

    def _prefill_one(self, req: Request) -> None:
        slot = req.slot
        P = len(req.prompt)
        batch = {"tokens": jnp.asarray(req.prompt, jnp.int32)[None]}
        if self.cfg.frontend == "vision":
            batch["patches"] = jnp.zeros(
                (1, self.cfg.frontend_len, self.cfg.frontend_dim), jnp.float32)
        if self.cfg.frontend == "audio":
            batch["audio"] = jnp.zeros(
                (1, self.cfg.encoder_len, self.cfg.frontend_dim), jnp.float32)
        logits, row_cache = self._prefill(self.params, batch)

        # scatter the single-row cache into this slot's region
        def place(full, row, k2):
            if k2 in ("k", "v"):                 # (G,1,P,KVH,hd) -> slot, pad seq
                pad = self.max_len - row.shape[2]
                row = jnp.pad(row, [(0, 0), (0, 0), (0, pad), (0, 0), (0, 0)])
                return full.at[:, slot].set(row[:, 0])
            if k2 in ("ck", "cv", "conv", "ssm"):
                return full.at[:, slot].set(row[:, 0])
            return full
        self.cache = {
            pos: {k2: place(self.cache[pos][k2], row_cache[pos][k2], k2)
                  for k2 in self.cache[pos]}
            for pos in self.cache}
        self.pos = self.pos.at[slot].set(P)
        req.prefill_pos = P
        self.sched.metrics.prefill_tokens += P
        self.sched._emit_first_token(req, logits[0, -1])

    def decode_tick(self) -> None:
        """One token for every active slot (prefill completes in the
        admission tick, so every seat is decode-ready)."""
        sched = self.sched
        if not sched.seats:
            return
        tok = np.zeros((self.slots, 1), np.int32)
        for slot, req in sched.seats.items():
            tok[slot, 0] = req.generated[-1]
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(tok), self.pos)
        toks = sched._sample_decode_batch(logits[:, -1], list(sched.seats))
        new_pos = self.pos
        for slot, req in list(sched.seats.items()):
            new_pos = new_pos.at[slot].add(1)
            sched._emit_decode_token(req, toks[slot])
        self.pos = new_pos


class PagedPolicy:
    """Paged-KV placement (see module docstring): shared page pool,
    chunked prefill, page-table decode, refcounted prefix caching with
    copy-on-write of the last partially shared page."""

    def __init__(self, cfg, params, *, page_size: int, num_pages: int,
                 max_seats: int, max_seq_len: int, prefill_chunk: int,
                 rules: LogicalRules, opts: Optional[M.RunOptions],
                 prefix_cache: bool = True):
        if not M.paged_cache_supported(cfg):
            raise ValueError(
                f"{cfg.name}: paged KV needs a pure-attention decoder; "
                "use ServingEngine for ssm/enc-dec/frontend archs")
        self.cfg = cfg
        self.params = params
        self.page_size = page_size
        self.max_seats = max_seats
        self.max_seq_len = max_seq_len
        self.prefill_chunk = prefill_chunk
        self.rules = rules
        self.opts = opts or M.RunOptions(q_chunk=min(max_seq_len, 512))

        self.bm = BlockManager(num_pages, page_size, prefix_cache=prefix_cache)
        self.n_tables = max(1, -(-max_seq_len // page_size))
        self.cache = M.init_paged_cache(cfg, num_pages, page_size)
        self.page_table = np.zeros((max_seats, self.n_tables), np.int32)
        self.pos = np.zeros((max_seats,), np.int32)     # next write position

        self._step_fn = jax.jit(
            lambda p, c, t, q, pt, nv: M.paged_decode_step(
                p, cfg, c, t, q, pt, nv, rules, self.opts))
        # donate the pool so copy-on-write is an in-place one-page update,
        # not a fresh copy of the whole KV pool (donation is a no-op on
        # CPU and would only warn there)
        donate = (0,) if jax.default_backend() != "cpu" else ()
        self._cow_fn = jax.jit(M.copy_paged_page, donate_argnums=donate)

    def bind(self, sched: Scheduler) -> None:
        self.sched = sched

    def pages_in_use(self) -> int:
        return self.bm.in_use

    def cache_stats(self) -> Tuple[int, int]:
        return self.bm.cached, self.bm.evictions

    def validate(self, req: Request) -> None:
        total = len(req.prompt) + req.max_new_tokens
        if len(req.prompt) == 0:
            raise ValueError("empty prompt")
        if total > self.max_seq_len:
            raise ValueError(f"request needs {total} tokens > "
                             f"max_seq_len={self.max_seq_len}")
        if self.bm.pages_needed(total) > self.bm.capacity:
            raise ValueError(f"request needs {self.bm.pages_needed(total)} "
                             f"pages > pool capacity {self.bm.capacity}")

    # -- admission: seat + page budget + prefix reuse -------------------------

    def try_admit(self, req: Request, seat: int) -> bool:
        # a starved queue head re-attempts every tick; skip the O(prompt)
        # prefix match until the pool/index actually changed
        if req.match_version == self.bm.version:
            return False
        need = self.bm.pages_needed(len(req.prompt) + req.max_new_tokens)
        match = self.bm.match_prefix(req.prompt)
        # feasibility before any side effect: acquiring a reclaimable
        # matched page consumes one allocatable slot, so a starved head
        # request must not churn refcounts/LRU order every tick
        reclaimed = sum(1 for pg in match.pages if self.bm.refcount(pg) == 0)
        if not self.bm.can_alloc(need - len(match.pages) + reclaimed):
            req.match_version = self.bm.version
            return False
        for pg in match.pages:                   # pin shares before alloc can
            self.bm.acquire(pg, req.rid)         # evict them
        fresh = self.bm.alloc(need - len(match.pages), req.rid)
        if fresh is None:                        # unreachable after the guard
            self.bm.free(match.pages)
            return False
        if match.cow_src is not None:
            # the partially matched page: copy, then own the copy — its
            # tail will be overwritten with this request's own tokens
            self.cache = self._cow_fn(self.cache, match.cow_src, fresh[0])
        req.pages = match.pages + fresh
        req.prefill_pos = req.cached_tokens = match.n_cached
        req.registered_pages = len(match.pages)
        row = np.zeros((self.n_tables,), np.int32)
        row[:len(req.pages)] = req.pages
        self.page_table[seat] = row
        self.pos[seat] = 0
        return True

    def release(self, req: Request) -> None:
        self.bm.free(req.pages)
        self.page_table[req.slot] = 0
        self.pos[req.slot] = 0

    # -- prefill / decode ------------------------------------------------------

    def prefill_tick(self) -> None:
        """One prompt chunk for the oldest mid-prefill request (chunked
        prefill: long prompts share the engine with everyone's decode).
        Requests with a prefix-cache hit start at ``cached_tokens``."""
        cands = [r for r in self.sched.seats.values()
                 if r.prefill_pos < len(r.prompt)]
        if not cands:
            return
        req = min(cands, key=lambda r: r.rid)
        seat = req.slot
        start = req.prefill_pos
        chunk = req.prompt[start:start + self.prefill_chunk]
        c = len(chunk)
        tok = np.zeros((1, self.prefill_chunk), np.int32)
        tok[0, :c] = chunk
        logits, self.cache = self._step_fn(
            self.params, self.cache, jnp.asarray(tok),
            jnp.asarray([start], jnp.int32),
            jnp.asarray(self.page_table[seat:seat + 1]),
            jnp.asarray([c], jnp.int32))
        req.prefill_pos += c
        self.sched.metrics.prefill_tokens += c
        self.sched.trace.append((self.sched._tick, "prefill_chunk", req.rid))
        self._register_full_pages(req)
        if req.prefill_pos == len(req.prompt):
            self.pos[seat] = len(req.prompt)
            self.sched._emit_first_token(req, logits[0, c - 1])

    def _register_full_pages(self, req: Request) -> None:
        """Publish every page now fully covered by prompt tokens to the
        prefix index (idempotent for pages the request shares)."""
        if not self.bm.prefix_cache:
            return
        full = req.prefill_pos // self.page_size
        while req.registered_pages < full:
            i = req.registered_pages
            self.bm.register_prefix(req.prompt[:(i + 1) * self.page_size],
                                    req.pages[i])
            req.registered_pages += 1

    def decode_tick(self) -> None:
        """One token for every seat whose prefill is complete."""
        sched = self.sched
        decoding = [s for s, r in sched.seats.items()
                    if r.prefill_pos >= len(r.prompt)]
        if not decoding:
            return
        tok = np.zeros((self.max_seats, 1), np.int32)
        nv = np.zeros((self.max_seats,), np.int32)
        for s in decoding:
            tok[s, 0] = sched.seats[s].generated[-1]
            nv[s] = 1
        logits, self.cache = self._step_fn(
            self.params, self.cache, jnp.asarray(tok),
            jnp.asarray(self.pos), jnp.asarray(self.page_table),
            jnp.asarray(nv))
        toks = sched._sample_decode_batch(logits[:, 0], decoding)
        for s in decoding:
            req = sched.seats[s]
            self.pos[s] += 1
            sched._emit_decode_token(req, toks[s])


# ---------------------------------------------------------------------------
# Engine façades (public API)
# ---------------------------------------------------------------------------

class ServingEngine(Scheduler):
    """Fixed-slot continuous-batching engine: the Scheduler bound to
    :class:`FixedSlotPolicy`.  Serves every arch (SSM, enc-dec, frontend)
    and is the equivalence oracle for the paged engine."""

    default_max_ticks = 10_000

    def __init__(self, cfg, params, *, slots: int = 4, max_len: int = 256,
                 rules: LogicalRules = SINGLE_DEVICE_RULES,
                 opts: Optional[M.RunOptions] = None,
                 sampler: Optional[Sampler] = None):
        policy = FixedSlotPolicy(cfg, params, slots=slots, max_len=max_len,
                                 rules=rules, opts=opts)
        super().__init__(policy, max_seats=slots, sampler=sampler)
        self.cfg = cfg
        self.params = params
        self.B = slots
        self.max_len = max_len
        self.rules = rules
        self.opts = policy.opts

    @property
    def active(self) -> Dict[int, Request]:
        return self.seats

    @property
    def cache(self):
        return self.policy.cache

    @property
    def pos(self):
        return self.policy.pos


class PagedServingEngine(Scheduler):
    """Paged-KV continuous-batching engine: the Scheduler bound to
    :class:`PagedPolicy` (shared page pool, chunked prefill, refcounted
    prefix caching — ``prefix_cache=False`` disables sharing for A/B
    comparisons)."""

    default_max_ticks = 100_000

    def __init__(self, cfg, params, *, page_size: int = 16,
                 num_pages: int = 64, max_seats: int = 8,
                 max_seq_len: int = 256, prefill_chunk: int = 32,
                 rules: LogicalRules = SINGLE_DEVICE_RULES,
                 opts: Optional[M.RunOptions] = None,
                 sampler: Optional[Sampler] = None,
                 prefix_cache: bool = True):
        policy = PagedPolicy(cfg, params, page_size=page_size,
                             num_pages=num_pages, max_seats=max_seats,
                             max_seq_len=max_seq_len,
                             prefill_chunk=prefill_chunk, rules=rules,
                             opts=opts, prefix_cache=prefix_cache)
        super().__init__(policy, max_seats=max_seats, sampler=sampler,
                         page_capacity=policy.bm.capacity)
        self.cfg = cfg
        self.params = params
        self.page_size = page_size
        self.max_seq_len = max_seq_len
        self.prefill_chunk = prefill_chunk
        self.rules = rules
        self.opts = policy.opts

    @property
    def bm(self) -> BlockManager:
        return self.policy.bm

    @property
    def n_tables(self) -> int:
        return self.policy.n_tables

    @property
    def cache(self):
        return self.policy.cache

    @property
    def page_table(self) -> np.ndarray:
        return self.policy.page_table

    @property
    def pos(self) -> np.ndarray:
        return self.policy.pos
