"""Continuous-batching serving engine (vLLM-style, JAX-native).

The decode_32k / long_500k cells lower a single ``decode_step``; this
module is the runtime that drives it in production fashion:

  - a request queue; each request = (prompt tokens, max_new_tokens)
  - a fixed pool of B cache slots (the decode batch); requests are admitted
    into free slots as others finish (continuous batching — no head-of-line
    blocking on the longest generation)
  - per-slot prefill writes the prompt's KV into the slot's cache region;
    decode steps advance ALL active slots together (one jitted call)
  - greedy sampling; completion on max_new_tokens (or an optional eos id)

Per-slot prefill is implemented by running the model's ``prefill`` on a
single row and scattering the resulting K/V into the batched cache at the
slot index — the same cache layout the dry-run decode cells shard.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M
from repro.parallel.sharding import LogicalRules, SINGLE_DEVICE_RULES


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (P,) int32
    max_new_tokens: int
    eos_id: Optional[int] = None
    # filled by the engine:
    generated: List[int] = dataclasses.field(default_factory=list)
    slot: Optional[int] = None
    done: bool = False
    t_submit: float = 0.0
    t_first_token: Optional[float] = None
    t_done: Optional[float] = None


class ServingEngine:
    def __init__(self, cfg, params, *, slots: int = 4, max_len: int = 256,
                 rules: LogicalRules = SINGLE_DEVICE_RULES,
                 opts: Optional[M.RunOptions] = None):
        self.cfg = cfg
        self.params = params
        self.B = slots
        self.max_len = max_len
        self.rules = rules
        self.opts = opts or M.RunOptions(q_chunk=min(max_len, 512))
        self.cache = M.init_cache(cfg, slots, max_len, self.opts)
        self.pos = jnp.zeros((slots,), jnp.int32)       # next write position
        self.active: Dict[int, Request] = {}            # slot -> request
        self.queue: Deque[Request] = deque()
        self.finished: List[Request] = []
        self._next_rid = 0

        self._decode = jax.jit(
            lambda p, c, t, q: M.decode_step(p, cfg, c, t, q, rules, self.opts))
        self._prefill = jax.jit(
            lambda p, b: M.prefill(p, cfg, b, rules, self.opts))

    # -- queue ---------------------------------------------------------------

    def submit(self, prompt, max_new_tokens: int = 16,
               eos_id: Optional[int] = None) -> int:
        req = Request(self._next_rid, np.asarray(prompt, np.int32),
                      max_new_tokens, eos_id, t_submit=time.perf_counter())
        self._next_rid += 1
        self.queue.append(req)
        return req.rid

    def _free_slots(self) -> List[int]:
        return [s for s in range(self.B) if s not in self.active]

    # -- admission: per-slot prefill ------------------------------------------

    def _admit(self, req: Request, slot: int):
        P = len(req.prompt)
        assert P < self.max_len
        batch = {"tokens": jnp.asarray(req.prompt, jnp.int32)[None]}
        if self.cfg.frontend == "vision":
            batch["patches"] = jnp.zeros(
                (1, self.cfg.frontend_len, self.cfg.frontend_dim), jnp.float32)
        if self.cfg.frontend == "audio":
            batch["audio"] = jnp.zeros(
                (1, self.cfg.encoder_len, self.cfg.frontend_dim), jnp.float32)
        logits, row_cache = self._prefill(self.params, batch)

        # scatter the single-row cache into this slot's region
        def place(full, row, k2):
            if k2 in ("k", "v"):                 # (G,1,P,KVH,hd) -> slot, pad seq
                pad = self.max_len - row.shape[2]
                row = jnp.pad(row, [(0, 0), (0, 0), (0, pad), (0, 0), (0, 0)])
                return full.at[:, slot].set(row[:, 0])
            if k2 in ("ck", "cv", "conv", "ssm"):
                return full.at[:, slot].set(row[:, 0])
            return full
        self.cache = {
            pos: {k2: place(self.cache[pos][k2], row_cache[pos][k2], k2)
                  for k2 in self.cache[pos]}
            for pos in self.cache}
        self.pos = self.pos.at[slot].set(P)
        first = int(jnp.argmax(logits[0, -1]))
        req.generated.append(first)
        req.t_first_token = time.perf_counter()
        req.slot = slot
        self.active[slot] = req

    # -- one engine tick -------------------------------------------------------

    def step(self):
        """Admit queued requests into free slots, then decode one token for
        every active slot."""
        for slot in self._free_slots():
            if not self.queue:
                break
            self._admit(self.queue.popleft(), slot)
        if not self.active:
            return
        tok = np.zeros((self.B, 1), np.int32)
        for slot, req in self.active.items():
            tok[slot, 0] = req.generated[-1]
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(tok), self.pos)
        nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1), np.int32)
        new_pos = self.pos
        for slot, req in list(self.active.items()):
            req.generated.append(int(nxt[slot]))
            new_pos = new_pos.at[slot].add(1)
            hit_eos = req.eos_id is not None and nxt[slot] == req.eos_id
            if len(req.generated) >= req.max_new_tokens or hit_eos:
                req.done = True
                req.t_done = time.perf_counter()
                self.finished.append(req)
                del self.active[slot]
        self.pos = new_pos

    def run(self, max_ticks: int = 10_000) -> List[Request]:
        t = 0
        while (self.queue or self.active) and t < max_ticks:
            self.step()
            t += 1
        return self.finished
