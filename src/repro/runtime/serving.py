"""Continuous-batching serving engines (vLLM-style, JAX-native).

Two engines share one request/queue model:

:class:`PagedServingEngine` — the production path.  KV lives in a shared
pool of fixed-size *pages* (``models.model.init_paged_cache``); each
request owns only the pages its page table names, handed out by
``runtime.paged_kv.BlockManager``.  Scheduling is continuous and
preemption-free: a request is admitted the moment a seat and its full
page budget (``ceil((prompt+max_new)/page_size)`` pages) are free — not
when a whole ``max_len`` slot frees up — and long prompts prefill in
chunks interleaved with everyone else's decode steps, so a 10k-token
prompt does not stall the batch (bounded time-to-first-token for the
short requests behind it).  Decode gathers K/V through the page table
(``attention.paged_attention``; on TPU the global-attention decode step
dispatches to the gather-over-page-table Pallas kernel in
``kernels.decode_attention`` — ``RunOptions.paged_attn_impl`` selects
jnp/pallas explicitly).
Engine metrics (admitted/active/queued, page utilization, TTFT,
tokens/s) accumulate in ``runtime.paged_kv.EngineMetrics``.

:class:`ServingEngine` — the dense fixed-slot reference: B cache slots of
``max_len`` tokens each, whole-prompt prefill scattered into the slot.
It wastes ``max_len - len`` tokens of KV per short request and cannot
admit more than B requests, but its arithmetic is the equivalence oracle
for the paged path (tests assert token-identical outputs) and it still
serves the archs the paged layout does not cover (SSM state, encoder/
decoder, vision frontends — fixed-size per-request state; nothing to
page).

Both engines greedy-sample and complete on max_new_tokens or eos.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M
from repro.parallel.sharding import LogicalRules, SINGLE_DEVICE_RULES
from repro.runtime.paged_kv import BlockManager, EngineMetrics


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (P,) int32
    max_new_tokens: int
    eos_id: Optional[int] = None
    # filled by the engine:
    generated: List[int] = dataclasses.field(default_factory=list)
    slot: Optional[int] = None      # seat index (paged) / cache slot (fixed)
    pages: List[int] = dataclasses.field(default_factory=list)
    prefill_pos: int = 0            # prompt tokens already prefilled (paged)
    done: bool = False
    t_submit: float = 0.0
    t_first_token: Optional[float] = None
    t_done: Optional[float] = None


class ServingEngine:
    def __init__(self, cfg, params, *, slots: int = 4, max_len: int = 256,
                 rules: LogicalRules = SINGLE_DEVICE_RULES,
                 opts: Optional[M.RunOptions] = None):
        self.cfg = cfg
        self.params = params
        self.B = slots
        self.max_len = max_len
        self.rules = rules
        self.opts = opts or M.RunOptions(q_chunk=min(max_len, 512))
        self.cache = M.init_cache(cfg, slots, max_len, self.opts)
        self.pos = jnp.zeros((slots,), jnp.int32)       # next write position
        self.active: Dict[int, Request] = {}            # slot -> request
        self.queue: Deque[Request] = deque()
        self.finished: List[Request] = []
        self._next_rid = 0

        self._decode = jax.jit(
            lambda p, c, t, q: M.decode_step(p, cfg, c, t, q, rules, self.opts))
        self._prefill = jax.jit(
            lambda p, b: M.prefill(p, cfg, b, rules, self.opts))

    # -- queue ---------------------------------------------------------------

    def submit(self, prompt, max_new_tokens: int = 16,
               eos_id: Optional[int] = None) -> int:
        req = Request(self._next_rid, np.asarray(prompt, np.int32),
                      max_new_tokens, eos_id, t_submit=time.perf_counter())
        self._next_rid += 1
        self.queue.append(req)
        return req.rid

    def _free_slots(self) -> List[int]:
        return [s for s in range(self.B) if s not in self.active]

    # -- admission: per-slot prefill ------------------------------------------

    def _admit(self, req: Request, slot: int):
        P = len(req.prompt)
        assert P < self.max_len
        batch = {"tokens": jnp.asarray(req.prompt, jnp.int32)[None]}
        if self.cfg.frontend == "vision":
            batch["patches"] = jnp.zeros(
                (1, self.cfg.frontend_len, self.cfg.frontend_dim), jnp.float32)
        if self.cfg.frontend == "audio":
            batch["audio"] = jnp.zeros(
                (1, self.cfg.encoder_len, self.cfg.frontend_dim), jnp.float32)
        logits, row_cache = self._prefill(self.params, batch)

        # scatter the single-row cache into this slot's region
        def place(full, row, k2):
            if k2 in ("k", "v"):                 # (G,1,P,KVH,hd) -> slot, pad seq
                pad = self.max_len - row.shape[2]
                row = jnp.pad(row, [(0, 0), (0, 0), (0, pad), (0, 0), (0, 0)])
                return full.at[:, slot].set(row[:, 0])
            if k2 in ("ck", "cv", "conv", "ssm"):
                return full.at[:, slot].set(row[:, 0])
            return full
        self.cache = {
            pos: {k2: place(self.cache[pos][k2], row_cache[pos][k2], k2)
                  for k2 in self.cache[pos]}
            for pos in self.cache}
        self.pos = self.pos.at[slot].set(P)
        first = int(jnp.argmax(logits[0, -1]))
        req.generated.append(first)
        req.t_first_token = time.perf_counter()
        req.slot = slot
        self.active[slot] = req

    # -- one engine tick -------------------------------------------------------

    def step(self):
        """Admit queued requests into free slots, then decode one token for
        every active slot."""
        for slot in self._free_slots():
            if not self.queue:
                break
            self._admit(self.queue.popleft(), slot)
        if not self.active:
            return
        tok = np.zeros((self.B, 1), np.int32)
        for slot, req in self.active.items():
            tok[slot, 0] = req.generated[-1]
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(tok), self.pos)
        nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1), np.int32)
        new_pos = self.pos
        for slot, req in list(self.active.items()):
            req.generated.append(int(nxt[slot]))
            new_pos = new_pos.at[slot].add(1)
            hit_eos = req.eos_id is not None and nxt[slot] == req.eos_id
            if len(req.generated) >= req.max_new_tokens or hit_eos:
                req.done = True
                req.t_done = time.perf_counter()
                self.finished.append(req)
                del self.active[slot]
        self.pos = new_pos

    def run(self, max_ticks: int = 10_000) -> List[Request]:
        t = 0
        while (self.queue or self.active) and t < max_ticks:
            self.step()
            t += 1
        return self.finished


class PagedServingEngine:
    """Paged-KV continuous-batching engine (see module docstring).

    Scheduling is deterministic (FCFS admission, lowest-rid prefill first,
    seats scanned in index order) so trace tests can assert exact
    interleavings.  ``trace`` records (tick, event, rid) tuples with
    events: admit / prefill_chunk / first_token / decode / finish.
    """

    def __init__(self, cfg, params, *, page_size: int = 16,
                 num_pages: int = 64, max_seats: int = 8,
                 max_seq_len: int = 256, prefill_chunk: int = 32,
                 rules: LogicalRules = SINGLE_DEVICE_RULES,
                 opts: Optional[M.RunOptions] = None):
        if not M.paged_cache_supported(cfg):
            raise ValueError(
                f"{cfg.name}: paged KV needs a pure-attention decoder; "
                "use ServingEngine for ssm/enc-dec/frontend archs")
        self.cfg = cfg
        self.params = params
        self.page_size = page_size
        self.max_seats = max_seats
        self.max_seq_len = max_seq_len
        self.prefill_chunk = prefill_chunk
        self.rules = rules
        self.opts = opts or M.RunOptions(q_chunk=min(max_seq_len, 512))

        self.bm = BlockManager(num_pages, page_size)
        self.n_tables = max(1, -(-max_seq_len // page_size))
        self.cache = M.init_paged_cache(cfg, num_pages, page_size)
        self.page_table = np.zeros((max_seats, self.n_tables), np.int32)
        self.pos = np.zeros((max_seats,), np.int32)     # next write position

        self.seats: Dict[int, Request] = {}             # seat -> request
        self.queue: Deque[Request] = deque()
        self.finished: List[Request] = []
        self.metrics = EngineMetrics(page_capacity=self.bm.capacity)
        self.trace: List[Tuple[int, str, int]] = []
        self._next_rid = 0
        self._tick = 0

        self._step_fn = jax.jit(
            lambda p, c, t, q, pt, nv: M.paged_decode_step(
                p, cfg, c, t, q, pt, nv, rules, self.opts))

    # -- queue ---------------------------------------------------------------

    def submit(self, prompt, max_new_tokens: int = 16,
               eos_id: Optional[int] = None) -> int:
        prompt = np.asarray(prompt, np.int32)
        total = len(prompt) + max_new_tokens
        if len(prompt) == 0:
            raise ValueError("empty prompt")
        if total > self.max_seq_len:
            raise ValueError(f"request needs {total} tokens > "
                             f"max_seq_len={self.max_seq_len}")
        if self.bm.pages_needed(total) > self.bm.capacity:
            raise ValueError(f"request needs {self.bm.pages_needed(total)} "
                             f"pages > pool capacity {self.bm.capacity}")
        req = Request(self._next_rid, prompt, max_new_tokens, eos_id,
                      t_submit=time.perf_counter())
        self._next_rid += 1
        self.queue.append(req)
        self.metrics.submitted += 1
        return req.rid

    # -- scheduling ----------------------------------------------------------

    def _free_seats(self) -> List[int]:
        return [s for s in range(self.max_seats) if s not in self.seats]

    def _admit_from_queue(self):
        """FCFS: admit while the head request's seat AND full page budget
        are available (preemption-free — an admitted request can always
        run to completion; shortfall queues, never crashes)."""
        for seat in self._free_seats():
            if not self.queue:
                break
            req = self.queue[0]
            need = self.bm.pages_needed(len(req.prompt) + req.max_new_tokens)
            pages = self.bm.alloc(need, req.rid)
            if pages is None:
                break
            self.queue.popleft()
            req.slot, req.pages = seat, pages
            row = np.zeros((self.n_tables,), np.int32)
            row[:len(pages)] = pages
            self.page_table[seat] = row
            self.pos[seat] = 0
            self.seats[seat] = req
            self.metrics.admitted += 1
            self.trace.append((self._tick, "admit", req.rid))

    def _prefill_tick(self):
        """One prompt chunk for the oldest mid-prefill request (chunked
        prefill: long prompts share the engine with everyone's decode)."""
        cands = [r for r in self.seats.values()
                 if r.prefill_pos < len(r.prompt)]
        if not cands:
            return
        req = min(cands, key=lambda r: r.rid)
        seat = req.slot
        start = req.prefill_pos
        chunk = req.prompt[start:start + self.prefill_chunk]
        c = len(chunk)
        tok = np.zeros((1, self.prefill_chunk), np.int32)
        tok[0, :c] = chunk
        logits, self.cache = self._step_fn(
            self.params, self.cache, jnp.asarray(tok),
            jnp.asarray([start], jnp.int32),
            jnp.asarray(self.page_table[seat:seat + 1]),
            jnp.asarray([c], jnp.int32))
        req.prefill_pos += c
        self.metrics.prefill_tokens += c
        self.trace.append((self._tick, "prefill_chunk", req.rid))
        if req.prefill_pos == len(req.prompt):
            first = int(jnp.argmax(logits[0, c - 1]))
            req.generated.append(first)
            req.t_first_token = time.perf_counter()
            self.metrics.ttft_s.append(req.t_first_token - req.t_submit)
            self.metrics.first_tokens += 1
            self.pos[seat] = len(req.prompt)
            self.trace.append((self._tick, "first_token", req.rid))
            hit_eos = req.eos_id is not None and first == req.eos_id
            if req.max_new_tokens <= 1 or hit_eos:
                self._finish(req)

    def _finish(self, req: Request):
        seat = req.slot
        req.done = True
        req.t_done = time.perf_counter()
        self.bm.free(req.pages)
        self.page_table[seat] = 0
        self.pos[seat] = 0
        del self.seats[seat]
        self.finished.append(req)
        self.metrics.completed += 1
        self.trace.append((self._tick, "finish", req.rid))

    def _decode_tick(self):
        """One token for every seat whose prefill is complete."""
        decoding = [s for s, r in self.seats.items()
                    if r.prefill_pos >= len(r.prompt)]
        if not decoding:
            return
        tok = np.zeros((self.max_seats, 1), np.int32)
        nv = np.zeros((self.max_seats,), np.int32)
        for s in decoding:
            tok[s, 0] = self.seats[s].generated[-1]
            nv[s] = 1
        logits, self.cache = self._step_fn(
            self.params, self.cache, jnp.asarray(tok),
            jnp.asarray(self.pos), jnp.asarray(self.page_table),
            jnp.asarray(nv))
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1), np.int32)
        for s in decoding:
            req = self.seats[s]
            req.generated.append(int(nxt[s]))
            self.pos[s] += 1
            self.metrics.decode_tokens += 1
            self.trace.append((self._tick, "decode", req.rid))
            hit_eos = req.eos_id is not None and nxt[s] == req.eos_id
            if len(req.generated) >= req.max_new_tokens or hit_eos:
                self._finish(req)

    # -- one engine tick -----------------------------------------------------

    def step(self):
        self.metrics.begin()
        self._tick += 1
        self._admit_from_queue()
        self._prefill_tick()
        self._decode_tick()
        self.metrics.tick(queued=len(self.queue), active=len(self.seats),
                          pages_in_use=self.bm.in_use)

    def run(self, max_ticks: int = 100_000) -> List[Request]:
        t = 0
        while (self.queue or self.seats) and t < max_ticks:
            self.step()
            t += 1
        return self.finished
