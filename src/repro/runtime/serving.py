"""Unified continuous-batching serving stack (vLLM-style, JAX-native).

One :class:`Scheduler` runs every arch; a pluggable *KV placement policy*
supplies the cache layout and the model arithmetic, and a shared
``runtime.sampler.Sampler`` turns logits into tokens for both::

            submit(prompt, sampling) ─────► FCFS queue
                                               │
                 ┌─────────────────────────────┘
                 ▼
            Scheduler.step()                      (one engine tick)
              1. admission  — policy.try_admit(): reserve a seat and
                 KV placement (fixed slot | pages + cached-prefix refs)
              2. policy.prefill_tick()  — prompt K/V into placement
              3. policy.decode_tick()   — one token per ready seat
                 │ per-seat logits row
                 ▼
            Sampler.sample(logits, req.sampling, rid, step)
                 │ next token id (greedy argmax when temperature=0)
                 ▼
            Scheduler bookkeeping — trace, EngineMetrics, eos/max-new
            completion, finish() → policy.release() returns the KV

    placement policies
      FixedSlotPolicy  — B dense cache slots of max_len tokens each;
                         whole-prompt prefill scattered into the slot.
                         Covers the archs with fixed-size per-request
                         state (SSM, encoder/decoder, vision/audio
                         frontends) and is the equivalence oracle for
                         the paged path.
      PagedPolicy      — KV in a shared pool of fixed-size pages
                         (``runtime.paged_kv.BlockManager``), chunked
                         prefill interleaved with decode, gather-over-
                         page-table attention (``attention.paged_
                         attention``; Pallas kernel on TPU), and
                         refcounted prefix caching: admission points the
                         leading page-table entries of a request whose
                         prompt starts with an already-cached page-
                         aligned token run at those physical pages
                         (refcount++), copy-on-writes only the last
                         partially matching page, and skips prefilling
                         everything cached.  Refcount-0 cached pages
                         park in an LRU list and are evicted under
                         pressure.

    lazy growth + preemption (PagedPolicy, ``lazy_pages=True`` default)
      Admission reserves only the *prompt's* pages (plus cached-prefix
      refs) instead of ``ceil((prompt + max_new) / page_size)`` up
      front; ``decode_tick`` calls ``BlockManager.try_grow`` for one
      page whenever a request's next write crosses a page boundary.  A
      low-watermark admission gate (``watermark`` fraction of capacity,
      ≥1 page, waived when the pool is idle) keeps headroom so live
      requests usually grow unopposed.  When growth still fails the
      Scheduler *preempts the youngest decoding request*: its pages are
      freed (full prompt pages stay in the prefix index, so re-admission
      recomputes them through the prefix-hit path), its generated tokens
      are kept, and it returns to the queue head; on re-admission it
      re-prefills ``prompt + generated[:-1]`` and re-enters decode by
      feeding ``generated[-1]`` — token streams are exactly preserved
      (the sampler is deterministic per (seed, rid, step)).

:class:`ServingEngine` (fixed-slot) and :class:`PagedServingEngine` are
thin façades binding the Scheduler to one policy; both complete requests
on max_new_tokens or eos and ``run`` raises :class:`SchedulerStallError`
when ticks run out with work still pending (stalls fail loudly).

    SLO classes (admission + preemption)
      Every request carries a ``priority`` class — ``premium`` >
      ``standard`` > ``batch`` — and an optional TTFT deadline
      (``deadline_ms``).  Admission is a pluggable policy:
      :class:`FCFSAdmission` (the default — byte-for-byte the historical
      strict-FCFS behavior) or :class:`SLOAdmission`, which admits by
      (effective class, earliest deadline, submit order) with an aging
      bound: a queued request gains one effective class per
      ``aging_ticks`` ticks waited, unclamped, so ``batch`` always
      eventually outranks a stream of fresh ``premium`` arrivals.
      Preemption victim selection is priority-aware under *every*
      admission policy: lowest class first, youngest (highest rid)
      within a class, and a grower never preempts a strictly
      higher-class request on its own behalf (it evicts itself
      instead).  With uniform priorities this degenerates to the
      historical youngest-first rule, so default traces are unchanged.

Scheduling is deterministic (FCFS admission, lowest-rid prefill first,
seats scanned in index order, priority-aware youngest-first preemption)
so trace tests can assert exact interleavings.  ``trace`` records
(tick, event, rid) tuples with events: admit / prefix_hit /
prefill_chunk / first_token / decode / preempt / deadline_miss /
tbt_miss / finish.

Every ``_trace`` site also feeds the optional structured telemetry
plane (``telemetry=`` a :class:`~repro.runtime.telemetry.Telemetry`):
the same events — plus a telemetry-only ``submit`` — land in a bounded
ring-buffer flight recorder as :class:`~repro.runtime.telemetry.
TraceEvent` records carrying injected-clock wall time, the engine id
and small attrs, exportable as Perfetto span timelines and dumped with
a full engine-state snapshot when ``run`` stalls.  With the default
``telemetry=None`` the hot path pays one attribute load + None check
per event (benchmark workload 9 gates the on/off throughput ratio).
See ``docs/observability.md``.

See ``docs/serving.md`` for the end-to-end architecture guide (tick
loop, page lifecycle, prefix-cache CoW, lazy growth, preemption replay,
SLO classes) and ``docs/benchmarks.md`` for how the serving benchmarks
measure this stack.
"""
from __future__ import annotations

import contextlib
import dataclasses
import math
import time
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mixed_precision
from repro.models import model as M
from repro.parallel.sharding import LogicalRules, SINGLE_DEVICE_RULES
from repro.runtime.paged_kv import BlockManager, EngineMetrics, PrefixMatch
from repro.runtime.sampler import GREEDY, Sampler, SamplingParams


class SchedulerStallError(RuntimeError):
    """``run`` exhausted ``max_ticks`` with requests still queued/active.

    The message names every stalled request as ``rid(priority)`` so
    starvation and deadline bugs are debuggable straight from the
    exception (and the trace): a stall whose stragglers are all
    ``batch`` under an aggressive aging bound reads very differently
    from one whose ``premium`` head is blocked on pages."""


#: Priority classes, best first.  Lower level = higher priority; the
#: admission and preemption orderings compare these levels, never the
#: class names.
PRIORITIES: Dict[str, int] = {"premium": 0, "standard": 1, "batch": 2}

DEFAULT_PRIORITY = "standard"

#: Reusable no-op context manager: the fused decode tick wraps its
#: dispatch in ``jax.profiler.TraceAnnotation`` only while the tick
#: profiler is live, and this stand-in keeps the unprofiled path free.
_NULL_CTX = contextlib.nullcontext()


def priority_level(req: "Request") -> int:
    """Numeric level of ``req``'s priority class (0 = most urgent)."""
    return PRIORITIES[req.priority]


class FCFSAdmission:
    """Strict first-come-first-served admission (the default).

    Always proposes the queue head and nothing else; if the head cannot
    be placed, admission stops for the tick (no skip-ahead — a convoy
    of small requests cannot starve a large head).  This is exactly the
    pre-SLO Scheduler behavior: with it, traces are bit-identical to
    engines built before admission became pluggable."""

    name = "fcfs"

    def select(self, sched: "Scheduler") -> Optional["Request"]:
        """Return the next admission candidate or None when the queue
        is empty.  The Scheduler stops admitting for the tick when the
        returned candidate cannot be placed."""
        return sched.queue[0] if sched.queue else None


class SLOAdmission:
    """Priority + earliest-deadline-first admission with aging.

    Candidates are ranked by ``(effective class, absolute TTFT
    deadline, rid)``:

    - *effective class* is the request's priority level minus one for
      every ``aging_ticks`` ticks it has waited in the queue (time
      spent decoding on a seat never counts: preemption restarts the
      aging base at the preemption tick).  The boost is unclamped, so
      any request — ``batch`` included — eventually outranks an
      endless stream of fresh ``premium`` arrivals: the starvation
      bound is ``(level_gap + 1) * aging_ticks`` ticks of queue wait.
    - within a class, requests sort earliest *effective deadline*
      first (EDF).  The effective deadline is the earlier of the TTFT
      deadline (``t_submit + deadline_ms``) and — for TBT-deadlined
      requests — the next-token due time (last emission, or submit
      when nothing is emitted yet, plus ``tbt_deadline_ms``): a
      preempted decode-deadline request re-queues with the urgency of
      its *next* token, and a fresh TBT-deadlined request carries its
      first-token urgency from submit.  Requests with neither
      deadline sort after all deadlined peers;
    - remaining ties fall back to submit order (rid), i.e. FCFS — a
      uniform-priority, no-deadline workload admits in exactly the
      FCFS order.

    Like FCFS, admission is strict head-of-line over this ordering:
    when the best-ranked candidate cannot be placed, nothing else is
    admitted this tick (skipping ahead would hand the pages the head
    is waiting for to lower-ranked work)."""

    name = "slo"

    def __init__(self, aging_ticks: int = 64):
        if aging_ticks < 1:
            raise ValueError(f"aging_ticks must be >= 1, got {aging_ticks}")
        self.aging_ticks = aging_ticks

    def rank(self, req: "Request", tick: int) -> Tuple[int, float, int]:
        """Admission key for ``req`` at scheduler ``tick`` (lower is
        admitted first): (aged priority level, effective absolute
        deadline seconds or +inf, rid).  The effective deadline is the
        earlier of the TTFT deadline and the TBT next-token due time
        (see the class docstring)."""
        waited = max(0, tick - req.submit_tick)
        eff = priority_level(req) - waited // self.aging_ticks
        deadline = (req.t_submit + req.deadline_ms / 1e3
                    if req.deadline_ms is not None else math.inf)
        tbt_ms = getattr(req, "tbt_deadline_ms", None)  # stub-tolerant
        if tbt_ms is not None:
            base = (req.t_last_token if req.t_last_token is not None
                    else req.t_submit)
            deadline = min(deadline, base + tbt_ms / 1e3)
        return (eff, deadline, req.rid)

    def select(self, sched: "Scheduler") -> Optional["Request"]:
        """Best-ranked queued request for this tick, or None.

        Single manual pass with :meth:`rank`'s key computation inlined:
        this scan is O(queue) per free seat per tick and dominates an
        overloaded engine's host time (the load harness drives queues
        thousands deep), where ``min(queue, key=...)`` pays a Python
        frame per element.  Must order identically to
        ``min(queue, key=lambda r: self.rank(r, tick))``."""
        queue = sched.queue
        if not queue:
            return None
        tick, aging = sched._tick, self.aging_ticks
        levels = PRIORITIES
        best = None
        best_key: Tuple[int, float, int] = (0, 0.0, 0)
        for req in queue:
            waited = tick - req.submit_tick
            eff = levels[req.priority] - (waited if waited > 0 else 0) // aging
            deadline = (req.t_submit + req.deadline_ms / 1e3
                        if req.deadline_ms is not None else math.inf)
            tbt_ms = req.tbt_deadline_ms
            if tbt_ms is not None:
                due = (req.t_last_token if req.t_last_token is not None
                       else req.t_submit) + tbt_ms / 1e3
                if due < deadline:
                    deadline = due
            key = (eff, deadline, req.rid)
            if best is None or key < best_key:
                best, best_key = req, key
        return best


def _make_admission(admission, aging_ticks: int):
    """Resolve an admission spec — ``"fcfs"``, ``"slo"`` or a policy
    object with ``select(scheduler)`` — into a policy instance."""
    if isinstance(admission, str):
        if admission == "fcfs":
            return FCFSAdmission()
        if admission == "slo":
            return SLOAdmission(aging_ticks)
        raise ValueError(f"unknown admission policy {admission!r}; "
                         "expected 'fcfs' or 'slo'")
    if not hasattr(admission, "select"):
        raise TypeError(f"admission policy {admission!r} has no select()")
    return admission


@dataclasses.dataclass
class Request:
    """One serving request and its scheduler-owned lifecycle state.

    Constructor-facing fields (set via :meth:`Scheduler.submit`):
      rid: engine-assigned id, monotonically increasing in submit order.
      prompt: (P,) int32 token ids.
      max_new_tokens: generation budget (the eos token counts toward it).
      eos_id: stop decoding early when this token is produced.
      sampling: per-request :class:`SamplingParams` (greedy by default).
      priority: SLO class name — one of :data:`PRIORITIES`.
      deadline_ms: optional TTFT deadline, milliseconds from submit;
          drives EDF ordering under :class:`SLOAdmission` and the
          deadline-miss metric/trace event under every policy.
      tbt_deadline_ms: optional per-token TBT (time-between-tokens)
          decode deadline, milliseconds between consecutive emitted
          tokens.  Under :class:`SLOAdmission` the request's *next*
          token due time (last emission + TBT budget) joins the EDF
          key, so a preempted TBT-deadlined request re-admits with
          real urgency; :meth:`Scheduler.pick_victim` never prefers a
          TBT-deadlined request as a preemption victim while a
          same-or-lower-class victim without one exists; TBT misses
          are counted per class under every policy.
    The remaining fields are filled in by the engine as the request
    moves through admit → prefill → decode → finish (or preempt)."""
    rid: int
    prompt: np.ndarray              # (P,) int32
    max_new_tokens: int
    eos_id: Optional[int] = None
    sampling: SamplingParams = GREEDY
    priority: str = DEFAULT_PRIORITY
    deadline_ms: Optional[float] = None
    tbt_deadline_ms: Optional[float] = None
    # filled by the engine:
    generated: List[int] = dataclasses.field(default_factory=list)
    slot: Optional[int] = None      # seat index (paged) / cache slot (fixed)
    pages: List[int] = dataclasses.field(default_factory=list)
    prefill_pos: int = 0            # prompt tokens already placed (paged)
    cached_tokens: int = 0          # prompt tokens served by the prefix cache
    registered_pages: int = 0       # prompt pages published to the prefix index
    match_version: Optional[int] = None  # BlockManager.version at last failed
    #                                      admission attempt (re-match gate)
    resume_tokens: Optional[np.ndarray] = None  # replay prefill source after
    #                                             a preemption (prompt +
    #                                             generated[:-1])
    times_preempted: int = 0
    done: bool = False
    submit_tick: int = 0            # scheduler tick at submit (aging base)
    t_submit: float = 0.0
    t_first_token: Optional[float] = None
    t_last_token: Optional[float] = None  # latest emission (TBT base)
    t_done: Optional[float] = None

    @property
    def prefill_src(self) -> np.ndarray:
        """Tokens the policy must (re)prefill: the prompt, or — after a
        preemption — the prompt plus all generated tokens but the last
        (the last one re-enters through the normal decode feed, so the
        replayed KV and sampling steps line up exactly with an
        uncontended run)."""
        return self.prompt if self.resume_tokens is None else self.resume_tokens


class Scheduler:
    """Engine-agnostic serving loop: queue, seats, admission, sampling,
    completion, metrics and trace.  All KV placement and model calls live
    in the bound policy (see module docstring)."""

    default_max_ticks = 100_000

    def __init__(self, policy, *, max_seats: int,
                 sampler: Optional[Sampler] = None, page_capacity: int = 0,
                 admission="fcfs", aging_ticks: int = 64,
                 clock=None, record_trace: bool = True, telemetry=None):
        """Bind ``policy`` (the KV placement + model arithmetic) to a
        fresh scheduler.

        Args:
          policy: placement policy (:class:`FixedSlotPolicy` or
              :class:`PagedPolicy`); ``policy.bind(self)`` is called.
          max_seats: concurrent-request limit (seat indices
              ``0..max_seats-1``).
          sampler: shared :class:`~repro.runtime.sampler.Sampler`;
              a default stateless one is built when None.
          page_capacity: usable KV pages, threaded into
              :class:`EngineMetrics` for utilization reporting (0 for
              pageless policies).
          admission: ``"fcfs"`` (default, historical behavior),
              ``"slo"`` (priority + EDF + aging) or a policy object
              with ``select(scheduler) -> Optional[Request]``.
          aging_ticks: SLO anti-starvation bound — a queued request
              gains one effective priority class per this many ticks
              waited.  Ignored by FCFS.
          clock: zero-arg callable returning monotonic seconds; every
              timestamp the engine records (submit, TTFT, TBT,
              completion, metric windows) reads it.  None (default) =
              ``time.perf_counter`` — wall time, the serving behavior.
              The load harness injects a
              :class:`~repro.runtime.workload.VirtualClock` so
              deadline verdicts and throughput are deterministic
              functions of the schedule, not of host speed.
          record_trace: keep the per-event ``trace`` list (default).
              ``False`` sets ``trace = None`` and skips every append —
              at 10⁵⁻⁶-request harness scale the trace would dominate
              memory.
          telemetry: optional
              :class:`~repro.runtime.telemetry.Telemetry` — every
              ``_trace`` site then also emits a structured
              :class:`~repro.runtime.telemetry.TraceEvent` (injected-
              clock time, ``engine_id``, attrs) into its flight
              recorder, deadlined TTFT/TBT verdicts feed its SLO
              burn-rate monitor, ``run`` dumps a postmortem through it
              on a stall, and its tick profiler (when enabled) times
              the step phases.  None (default) keeps the hot path at
              one attribute load + None check per event.

        Raises:
          ValueError: unknown ``admission`` name or ``aging_ticks < 1``.
          TypeError: ``admission`` object without a ``select`` method.
        """
        self.policy = policy
        self.max_seats = max_seats
        self.sampler = sampler or Sampler()
        self.admission = _make_admission(admission, aging_ticks)
        self.clock = clock if clock is not None else time.perf_counter
        self.seats: Dict[int, Request] = {}             # seat -> request
        self.queue: Deque[Request] = deque()
        self.finished: List[Request] = []
        self.metrics = EngineMetrics(page_capacity=page_capacity)
        self.trace: Optional[List[Tuple[int, str, int]]] = (
            [] if record_trace else None)
        self.telemetry = telemetry
        self.engine_id = ""       # the fleet labels replicas "model/i"
        self._next_rid = 0
        self._tick = 0
        policy.bind(self)

    def _trace(self, event: str, rid: int,
               attrs: Optional[dict] = None) -> None:
        """Append one (tick, event, rid) trace tuple — no-op when the
        trace is disabled (``record_trace=False``) — and mirror the
        event into the telemetry flight recorder when one is attached.
        ``attrs`` never reaches the flat trace (parity tests pin its
        exact tuples); hot callers pass None so the off path allocates
        nothing."""
        if self.trace is not None:
            self.trace.append((self._tick, event, rid))
        tel = self.telemetry
        if tel is not None:
            tel.emit(self._tick, self.clock(), self.engine_id, rid,
                     event, attrs)

    # -- queue ---------------------------------------------------------------

    def submit(self, prompt, max_new_tokens: int = 16,
               eos_id: Optional[int] = None,
               sampling: Optional[SamplingParams] = None,
               priority: str = DEFAULT_PRIORITY,
               deadline_ms: Optional[float] = None,
               tbt_deadline_ms: Optional[float] = None,
               rid: Optional[int] = None) -> int:
        """Queue one request; returns its engine-assigned rid.

        Args:
          prompt: 1-D int32 token ids (non-empty).
          max_new_tokens: generation budget, >= 1.
          eos_id: optional early-stop token id.
          sampling: per-request :class:`SamplingParams` (greedy when
              None).  The sampler keys its streams by (seed, rid,
              step) only — priority never changes tokens.
          priority: SLO class, one of :data:`PRIORITIES`
              (``premium``/``standard``/``batch``).
          deadline_ms: optional TTFT deadline in milliseconds from now
              (must be > 0): EDF ordering under ``slo`` admission and
              deadline-miss accounting under every policy.
          tbt_deadline_ms: optional per-token decode deadline in
              milliseconds (must be > 0): each decode token is due
              this long after the previous emission.  Folds into the
              ``slo`` EDF key (the next-token due time competes with
              the TTFT deadline), shields the request in
              :meth:`pick_victim`, and drives per-class TBT-miss
              accounting under every policy.
          rid: explicit request id (fleet routing — the
              :class:`~repro.runtime.router.ModelFleet` assigns rids
              from one fleet-global counter so sampler keys
              ``(seed, rid, step)`` never collide across engines and a
              routed request replays bit-identically on a solo engine
              given the same rid).  Must keep this engine's rids
              strictly increasing; None (default) auto-assigns.

        Raises:
          ValueError: unknown priority, non-positive deadline, a
              non-monotonic explicit ``rid``, or a prompt/budget the
              bound policy cannot ever place (empty prompt,
              ``prompt + max_new_tokens`` over the engine's length
              bound, or an infeasible page demand).
        """
        if priority not in PRIORITIES:
            raise ValueError(f"unknown priority {priority!r}; expected one "
                             f"of {sorted(PRIORITIES)}")
        if deadline_ms is not None and deadline_ms <= 0:
            raise ValueError(f"deadline_ms must be > 0, got {deadline_ms}")
        if tbt_deadline_ms is not None and tbt_deadline_ms <= 0:
            raise ValueError(
                f"tbt_deadline_ms must be > 0, got {tbt_deadline_ms}")
        if rid is None:
            rid = self._next_rid
        elif rid < self._next_rid:
            # rid order is load-bearing: prefill picks the lowest rid,
            # preemption the highest, SLO ties break on rid — an engine's
            # rids must stay strictly increasing in submit order
            raise ValueError(
                f"explicit rid {rid} is not monotonic: this engine has "
                f"already assigned rids up to {self._next_rid - 1}")
        req = Request(rid, np.asarray(prompt, np.int32),
                      max_new_tokens, eos_id, sampling or GREEDY,
                      priority=priority, deadline_ms=deadline_ms,
                      tbt_deadline_ms=tbt_deadline_ms,
                      submit_tick=self._tick, t_submit=self.clock())
        self.policy.validate(req)
        self._next_rid = rid + 1
        self.queue.append(req)
        self.metrics.submitted += 1
        tel = self.telemetry
        if tel is not None:
            # telemetry-only event: the flat trace's exact tuple
            # sequence is pinned by parity tests and starts at admit
            tel.emit(self._tick, req.t_submit, self.engine_id, req.rid,
                     "submit", {"priority": req.priority,
                                "prompt_tokens": int(req.prompt.size),
                                "max_new_tokens": req.max_new_tokens})
        return req.rid

    def _free_seats(self) -> List[int]:
        return [s for s in range(self.max_seats) if s not in self.seats]

    def _admit_from_queue(self):
        """Admit while the admission policy's candidate has a seat AND
        a placement (preemption-free at admission time — an admitted
        request can always start; shortfall queues, never crashes).
        The candidate is the queue head under FCFS, the best
        (class, deadline, rid) rank under SLO; either way admission is
        strict head-of-line: the first unplaceable candidate ends the
        tick's admissions."""
        for seat in self._free_seats():
            req = self.admission.select(self)
            if req is None:
                break
            if not self.policy.try_admit(req, seat):
                break
            self.queue.remove(req)
            req.slot = seat
            self.seats[seat] = req
            self.metrics.admitted += 1
            self._trace("admit", req.rid,
                        None if self.telemetry is None else
                        {"seat": seat, "priority": req.priority,
                         "cached_tokens": req.cached_tokens,
                         "preempted_before": req.times_preempted})
            if req.cached_tokens:
                self.metrics.cached_prompt_tokens += req.cached_tokens
                self._trace("prefix_hit", req.rid)

    # -- token bookkeeping ----------------------------------------------------

    def _emit_first_tokens(self, ready: List[Tuple[Request, object]]) -> None:
        """Sample the TTFT token for every request whose prefill
        completed this tick, in ONE batched sampler call with ONE
        device→host transfer, then timestamp all of them *after* the
        batch — a burst of K admissions used to serialize K blocking
        per-request argmax pulls, inflating every later request's
        recorded TTFT (and its deadline-miss verdict) with its
        predecessors' sync time.

        ready: ``[(req, logits_row)]`` in emission (rid) order, each
        row the last prompt position's ``(V,)`` logits."""
        if not ready:
            return
        if all(isinstance(row, np.ndarray) for _, row in ready):
            # oracle-policy path: rows never left the host, so a jnp
            # round-trip would only add dispatch latency at harness
            # scale — same argmax/Sampler algebra on numpy arrays
            host = np.stack([row for _, row in ready])
            if all(req.sampling.greedy for req, _ in ready):
                toks = np.argmax(host, axis=-1).astype(np.int32)
            else:
                toks = [self.sampler.sample(host[i], req.sampling,
                                            rid=req.rid, step=0)
                        for i, (req, _) in enumerate(ready)]
        else:
            rows = jnp.stack([row for _, row in ready])
            if all(req.sampling.greedy for req, _ in ready):
                # one on-device argmax over the burst; K ints cross to host
                toks = np.asarray(  # repro-lint: disable=RL001
                    jnp.argmax(rows, axis=-1), np.int32)
            else:
                # one (K, V) transfer for the whole burst
                host = np.asarray(rows)  # repro-lint: disable=RL001
                toks = [self.sampler.sample(host[i], req.sampling,
                                            rid=req.rid, step=0)
                        for i, (req, _) in enumerate(ready)]
        now = self.clock()
        for (req, _), tok in zip(ready, toks):
            tok = int(tok)
            req.generated.append(tok)
            req.t_first_token = now
            req.t_last_token = now
            ttft = now - req.t_submit
            missed = (req.deadline_ms is not None
                      and ttft * 1e3 > req.deadline_ms)
            self.metrics.note_first_token(
                req.priority, ttft, deadlined=req.deadline_ms is not None,
                missed=missed)
            tel = self.telemetry
            self._trace("first_token", req.rid,
                        None if tel is None else {"ttft_s": ttft})
            if tel is not None and req.deadline_ms is not None:
                tel.observe_slo(now, self._tick, self.engine_id,
                                req.priority, "ttft", missed)
            if missed:
                self._trace("deadline_miss", req.rid,
                            None if tel is None else
                            {"ttft_s": ttft,
                             "deadline_ms": req.deadline_ms})
            hit_eos = req.eos_id is not None and tok == req.eos_id
            if req.max_new_tokens <= 1 or hit_eos:
                self.finish(req)

    def _sample_decode_batch(self, last_logits, seat_ids) -> Dict[int, int]:
        """Next token per seat from ``(max_seats, V)`` device logits —
        the fallback per-tick path (fixed-slot archs and ``fused=False``
        paged engines; the fused paged path samples on device inside
        ``fused_decode_tick`` instead).  Only the *active* seats' rows
        are gathered — idle seats' logits are never reduced or
        transferred: greedy-only batches move K ints to the host, and
        the (K, V) active rows cross only when a stochastic seat needs
        them."""
        sel = last_logits[jnp.asarray(seat_ids, jnp.int32)]
        if all(self.seats[s].sampling.greedy for s in seat_ids):
            # the batch's one transfer: K ints, post-argmax
            toks = np.asarray(  # repro-lint: disable=RL001
                jnp.argmax(sel, axis=-1), np.int32)
            return {s: int(toks[i]) for i, s in enumerate(seat_ids)}
        # active rows only — never the full (max_seats, V) matrix
        rows = np.asarray(sel)  # repro-lint: disable=RL001
        return {s: self.sampler.sample(rows[i], self.seats[s].sampling,
                                       rid=self.seats[s].rid,
                                       step=len(self.seats[s].generated))
                for i, s in enumerate(seat_ids)}

    def _emit_decode_token(self, req: Request, tok: int) -> None:
        req.generated.append(tok)
        self.metrics.decode_tokens += 1
        now = self.clock()
        # TBT = gap since the previous emission (first token included
        # as the base); preemption replay gaps land here by design —
        # that is exactly the stall a TBT deadline is meant to expose
        tbt = now - req.t_last_token
        req.t_last_token = now
        deadlined = req.tbt_deadline_ms is not None
        missed = deadlined and tbt * 1e3 > req.tbt_deadline_ms
        self.metrics.note_decode_token(req.priority, tbt,
                                       deadlined=deadlined, missed=missed)
        self._trace("decode", req.rid)
        tel = self.telemetry
        if tel is not None and deadlined:
            tel.observe_slo(now, self._tick, self.engine_id,
                            req.priority, "tbt", missed)
        if missed:
            self._trace("tbt_miss", req.rid,
                        None if tel is None else
                        {"tbt_s": tbt,
                         "tbt_deadline_ms": req.tbt_deadline_ms})
        hit_eos = req.eos_id is not None and tok == req.eos_id
        if len(req.generated) >= req.max_new_tokens or hit_eos:
            self.finish(req)

    def finish(self, req: Request) -> None:
        """Complete ``req``: the policy releases its KV placement, the
        seat frees, and per-engine + per-class completion counters and
        the ``finish`` trace event are recorded."""
        req.done = True
        req.t_done = self.clock()
        self.policy.release(req)
        del self.seats[req.slot]
        self.finished.append(req)
        self.metrics.note_completion(req.priority)
        self._trace("finish", req.rid)

    def preempt(self, req: Request) -> None:
        """Evict a decoding request under memory pressure: the policy
        frees its placement (``policy.preempt`` also stashes the replay
        source), generated-so-far tokens are kept, and the request
        returns to the queue *head* — re-admission re-prefills
        ``prompt + generated``, cheap when the prefix index still holds
        the prompt pages.  (Under SLO admission the requeue position is
        cosmetic: ordering is recomputed from class/deadline/rid every
        tick.)

        Raises:
          ValueError: ``req`` has not emitted its first token yet (a
              mid-prefill request has no tokens to replay)."""
        if not req.generated:
            raise ValueError(
                f"cannot preempt request {req.rid} before its first "
                "token; only decoding requests are preemptible (a "
                "mid-prefill request has no tokens to replay)")
        self.policy.preempt(req)
        del self.seats[req.slot]
        req.slot = None
        self.queue.appendleft(req)
        # aging measures queue wait, not lifetime: restart the aging
        # base at the preemption tick so time spent decoding on a seat
        # cannot boost a preempted batch request past fresh premium
        # arrivals (FCFS ignores submit_tick entirely)
        req.submit_tick = self._tick
        req.times_preempted += 1
        self.metrics.note_preemption(req.priority)
        self._trace("preempt", req.rid)

    def pick_victim(self, victims: List[Request],
                    grower: Request) -> Request:
        """Priority-aware preemption victim among ``victims`` (all
        decoding) on behalf of ``grower``: the lowest class goes first;
        within a class, requests *without* a TBT decode deadline are
        preferred over TBT-deadlined ones (evicting a
        decode-deadline-critical request guarantees a TBT miss on its
        replay, so it is never the preferred victim while any
        same-or-lower-class alternative exists); youngest (highest
        rid) breaks the remaining ties — and a grower never preempts a
        strictly higher class than its own; when only higher-class
        victims exist it evicts itself.  With uniform priorities and
        no TBT deadlines anywhere this is bit-identical to the
        historical youngest-first rule (the middle key is constant).

        When ``grower`` is itself in ``victims`` (as in
        ``PagedPolicy._grow_tick``), the ``max`` alone already yields
        self-eviction — the grower outranks any strictly higher class
        in this ordering — so the explicit guard below exists for
        callers passing a victim set that *excludes* the grower, where
        it enforces the never-preempt-upward contract."""
        victim = max(victims, key=lambda r: (
            priority_level(r), r.tbt_deadline_ms is None, r.rid))
        if priority_level(victim) < priority_level(grower):
            return grower
        return victim

    # -- one engine tick -----------------------------------------------------

    def step(self):
        """One engine tick: admission, one prefill round, one decode
        round, then a metrics sample (queue depth, active seats, page
        occupancy overall and per priority class).  When the attached
        telemetry carries a live tick profiler the phases run through
        :meth:`_step_profiled` instead (identical order and effects,
        plus wall-time attribution)."""
        tel = self.telemetry
        if tel is not None and tel.profiler is not None:
            return self._step_profiled(tel.profiler)
        self.metrics.begin(self.clock())
        self._tick += 1
        self._admit_from_queue()
        self.policy.prefill_tick()
        self.policy.decode_tick()
        self._tick_bookkeeping()

    def _step_profiled(self, prof):
        """The tick with per-phase wall-time attribution
        (``admission`` / ``prefill`` / ``decode`` / ``bookkeeping``;
        the fused paged decode refines its share into ``decode/*``
        sub-phases).  Measured with ``time.perf_counter`` — profiling
        is a wall-time tool, deliberately not the injected clock, which
        is virtual under the harness and would time every phase as 0."""
        self.metrics.begin(self.clock())
        self._tick += 1
        t0 = time.perf_counter()
        self._admit_from_queue()
        t1 = time.perf_counter()
        prof.add("admission", t1 - t0)
        self.policy.prefill_tick()
        t2 = time.perf_counter()
        prof.add("prefill", t2 - t1)
        self.policy.decode_tick()
        t3 = time.perf_counter()
        prof.add("decode", t3 - t2)
        self._tick_bookkeeping()
        prof.add("bookkeeping", time.perf_counter() - t3)
        prof.note_tick()

    def _tick_bookkeeping(self):
        """The tick's closing metrics sample (shared by the plain and
        profiled step paths)."""
        cached, evictions = self.policy.cache_stats()
        pages_by_class: Dict[str, int] = {}
        for r in self.seats.values():
            if r.pages:
                pages_by_class[r.priority] = (
                    pages_by_class.get(r.priority, 0) + len(r.pages))
        self.metrics.tick(queued=len(self.queue), active=len(self.seats),
                          pages_in_use=self.policy.pages_in_use(),
                          cached_pages=cached, evictions=evictions,
                          pages_by_class=pages_by_class,
                          now=self.clock())

    def run(self, max_ticks: Optional[int] = None) -> List[Request]:
        """Tick until every submitted request finishes.

        Args:
          max_ticks: stall bound; the engine's ``default_max_ticks``
              when None.

        Returns:
          All finished :class:`Request` objects, completion order.

        Raises:
          SchedulerStallError: ticks ran out with work still pending;
              the message names each stalled request as
              ``rid(priority)``."""
        if max_ticks is None:
            max_ticks = self.default_max_ticks
        t = 0
        while (self.queue or self.seats) and t < max_ticks:
            self.step()
            t += 1
        if self.queue or self.seats:
            stalled = sorted(list(self.queue) + list(self.seats.values()),
                             key=lambda r: r.rid)
            msg = (f"run() exhausted max_ticks={max_ticks} with "
                   f"{len(self.queue)} queued and {len(self.seats)} "
                   f"active requests: "
                   + ", ".join(f"{r.rid}({r.priority})" for r in stalled))
            if self.telemetry is not None:
                # dump the flight recorder + full engine state before
                # raising: the stall is exactly when the evidence is hot
                self.telemetry.write_postmortem(
                    "SchedulerStallError: " + msg,
                    engines={self.engine_id or "engine": self})
            raise SchedulerStallError(msg)
        return self.finished


# ---------------------------------------------------------------------------
# KV placement policies
# ---------------------------------------------------------------------------

class FixedSlotPolicy:
    """Dense fixed-slot placement: B cache slots of ``max_len`` tokens,
    whole-prompt prefill scattered into the slot.  Wastes
    ``max_len - len`` KV tokens per short request, but its per-request
    state is constant-size, so it covers SSM / encoder-decoder / frontend
    archs and is the arithmetic oracle for the paged path.

    The cache carries one extra *scratch position* at index ``max_len``
    (the fixed-slot analogue of the paged path's scratch page 0): idle
    slots still ride through the batched ``decode_step``, and routing
    their token-0 writes to the scratch position keeps them from
    rewriting KV at whatever position the slot's previous occupant left
    behind.  No live query ever attends to it (live positions are
    < ``max_len`` and the causal mask drops keys beyond the query)."""

    def __init__(self, cfg, params, *, slots: int, max_len: int,
                 rules: LogicalRules, opts: Optional[M.RunOptions]):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.rules = rules
        self.opts = opts or M.RunOptions(q_chunk=min(max_len, 512))
        self.cache = M.init_cache(cfg, slots, max_len + 1, self.opts)
        # next write position; max_len = scratch (slot idle)
        self.pos = jnp.full((slots,), max_len, jnp.int32)
        self._decode = jax.jit(
            lambda p, c, t, q: M.decode_step(p, cfg, c, t, q, rules, self.opts))
        self._prefill = jax.jit(
            lambda p, b: M.prefill(p, cfg, b, rules, self.opts))

    def bind(self, sched: Scheduler) -> None:
        """Attach the owning :class:`Scheduler` (called once, by its
        constructor)."""
        self.sched = sched

    def pages_in_use(self) -> int:
        """Always 0 — fixed slots are not page-accounted."""
        return 0

    def cache_stats(self) -> Tuple[int, int]:
        """(cached reclaimable pages, evictions) — both always 0 here;
        the fixed-slot layout has no prefix cache."""
        return 0, 0

    def validate(self, req: Request) -> None:
        """Reject a request this layout could never place.

        Raises:
          ValueError: empty prompt, prompt >= ``max_len``, or
              ``prompt + max_new_tokens`` > ``max_len`` (decode would
              clamp into the last cache slot and corrupt KV)."""
        total = len(req.prompt) + req.max_new_tokens
        if len(req.prompt) == 0:
            raise ValueError("empty prompt")
        if len(req.prompt) >= self.max_len:
            raise ValueError(f"prompt length {len(req.prompt)} >= "
                             f"max_len={self.max_len}")
        if total > self.max_len:
            raise ValueError(f"request needs {total} tokens > "
                             f"max_len={self.max_len}; decode would clamp "
                             "into the last cache slot and corrupt KV")

    def try_admit(self, req: Request, seat: int) -> bool:
        """Always True: the seat itself is the only fixed-slot
        resource (every slot is pre-provisioned for ``max_len``)."""
        return True

    def release(self, req: Request) -> None:
        """Return a finished request's slot: the write position parks
        on the scratch index so the idle slot's pass through the
        batched decode stops touching the KV its previous occupant
        wrote."""
        self.pos = self.pos.at[req.slot].set(self.max_len)

    def preempt(self, req: Request) -> None:
        """Hook-surface parity with PagedPolicy (the fixed-slot engine
        never preempts on its own — the seat is the only resource — but
        ``Scheduler.preempt`` works against either policy): the slot goes
        back to scratch and the request replays prompt + generated[:-1]
        on re-admission."""
        self.pos = self.pos.at[req.slot].set(self.max_len)
        req.resume_tokens = np.concatenate(
            [req.prompt, np.asarray(req.generated[:-1], np.int32)])
        req.prefill_pos = 0

    def prefill_tick(self) -> None:
        """Whole-prompt prefill for every seat admitted this tick, in rid
        order (so the newly admitted request decodes in the same tick —
        the pre-refactor fixed-slot cadence).  First tokens for the whole
        admission burst are sampled in ONE batched call after all the
        prefills dispatch, not one blocking sync per request."""
        pending = sorted((r for r in self.sched.seats.values()
                          if r.prefill_pos < len(r.prefill_src)),
                         key=lambda r: r.rid)
        ready = []
        for req in pending:
            row = self._prefill_one(req)
            if row is not None:
                ready.append((req, row))
        self.sched._emit_first_tokens(ready)

    def _prefill_one(self, req: Request):
        slot = req.slot
        src = req.prefill_src
        P = len(src)
        batch = {"tokens": jnp.asarray(src, jnp.int32)[None]}
        if self.cfg.frontend == "vision":
            batch["patches"] = jnp.zeros(
                (1, self.cfg.frontend_len, self.cfg.frontend_dim), jnp.float32)
        if self.cfg.frontend == "audio":
            batch["audio"] = jnp.zeros(
                (1, self.cfg.encoder_len, self.cfg.frontend_dim), jnp.float32)
        logits, row_cache = self._prefill(self.params, batch)

        # scatter the single-row cache into this slot's region (the +1
        # pads through the scratch position at index max_len)
        def place(full, row, k2):
            if k2 in ("k", "v"):                 # (G,1,P,KVH,hd) -> slot, pad seq
                pad = self.max_len + 1 - row.shape[2]
                row = jnp.pad(row, [(0, 0), (0, 0), (0, pad), (0, 0), (0, 0)])
                return full.at[:, slot].set(row[:, 0])
            if k2 in ("ck", "cv", "conv", "ssm"):
                return full.at[:, slot].set(row[:, 0])
            return full
        self.cache = {
            pos: {k2: place(self.cache[pos][k2], row_cache[pos][k2], k2)
                  for k2 in self.cache[pos]}
            for pos in self.cache}
        self.pos = self.pos.at[slot].set(P)
        req.prefill_pos = P
        self.sched.metrics.prefill_tokens += P
        if req.resume_tokens is None:
            return logits[0, -1]         # first token sampled in the batch
        # replay after a preemption — the TTFT token was already emitted;
        # decode resumes by feeding generated[-1]
        return None

    def decode_tick(self) -> None:
        """One token for every active slot (prefill completes in the
        admission tick, so every seat is decode-ready)."""
        sched = self.sched
        if not sched.seats:
            return
        tok = np.zeros((self.slots, 1), np.int32)
        adv = np.zeros((self.slots,), np.int32)
        for slot, req in sched.seats.items():
            tok[slot, 0] = req.generated[-1]
            adv[slot] = 1
        # this tick's two uploads: the token batch and the advance mask
        logits, self.cache = self._decode(
            self.params, self.cache,
            jnp.asarray(tok),  # repro-lint: disable=RL001
            self.pos)
        toks = sched._sample_decode_batch(logits[:, -1], list(sched.seats))
        active = list(sched.seats.items())
        # advance positions BEFORE emitting, in ONE vectorized add (a
        # per-slot .at[slot].add(1) loop dispatched K ops per tick): a
        # token that finishes its request triggers release(), whose
        # scratch-position reset must not be clobbered by this tick's
        # increment
        self.pos = self.pos + jnp.asarray(adv)  # repro-lint: disable=RL001
        for slot, req in active:
            sched._emit_decode_token(req, toks[slot])


class PagedPolicy:
    """Paged-KV placement (see module docstring): shared page pool,
    chunked prefill, page-table decode, refcounted prefix caching with
    copy-on-write of the last partially shared page, and — with
    ``lazy_pages`` (default) — on-demand page growth with
    preempt-and-recompute under pressure."""

    def __init__(self, cfg, params, *, page_size: int, num_pages: int,
                 max_seats: int, max_seq_len: int, prefill_chunk: int,
                 rules: LogicalRules, opts: Optional[M.RunOptions],
                 prefix_cache: bool = True, lazy_pages: bool = True,
                 watermark: float = 0.05, fused: bool = True,
                 kv_dtype: Optional[str] = None,
                 class_precision: Optional[Dict[str, str]] = None):
        if not M.paged_cache_supported(cfg):
            raise ValueError(
                f"{cfg.name}: paged KV needs a pure-attention decoder; "
                "use ServingEngine for ssm/enc-dec/frontend archs")
        self.cfg = cfg
        self.params = params
        self.page_size = page_size
        self.max_seats = max_seats
        self.max_seq_len = max_seq_len
        self.prefill_chunk = prefill_chunk
        self.rules = rules
        self.opts = opts or M.RunOptions(q_chunk=min(max_seq_len, 512))
        # KV pool storage precision (uniform per engine; None = the
        # config's compute dtype, the pre-quantization layout) and the
        # per-SLO-class precision floors enforced by validate()
        if kv_dtype is not None:
            mixed_precision.kv_storage_dtype(kv_dtype)   # validate early
        self.kv_dtype = kv_dtype
        self.kv_dtype_name = kv_dtype or (
            "bf16" if jnp.dtype(cfg.compute_dtype) == jnp.bfloat16 else "f32")
        self.class_precision = dict(class_precision or {})
        for cls, want in self.class_precision.items():
            if cls not in PRIORITIES:
                raise ValueError(
                    f"class_precision names unknown class {cls!r}; "
                    f"expected one of {sorted(PRIORITIES)}")
            mixed_precision.kv_precision_bits(want)       # validate dtype
        self.page_bytes = M.paged_page_bytes(cfg, page_size, kv_dtype)

        self.bm = BlockManager(num_pages, page_size, prefix_cache=prefix_cache,
                               page_bytes=self.page_bytes)
        self.n_tables = max(1, -(-max_seq_len // page_size))
        self.lazy = lazy_pages
        # admission headroom so live requests usually grow unopposed
        # (>=1 page whenever a watermark is requested; 0 disables the
        # gate); waived when the pool is idle — a lone max-length prompt
        # must still be startable
        self.watermark_pages = (
            max(1, math.ceil(watermark * self.bm.capacity))
            if lazy_pages and watermark > 0 else 0)
        if lazy_pages and self.n_tables > self.bm.capacity:
            # liveness bound: any admitted request (total <= max_seq_len)
            # must be completable with the whole pool to itself, or the
            # preempt/recompute loop could never converge
            raise ValueError(
                f"lazy_pages needs the pool to cover one max-length "
                f"request: max_seq_len={max_seq_len} spans "
                f"{self.n_tables} pages > capacity {self.bm.capacity}; "
                "raise num_pages, lower max_seq_len, or set "
                "lazy_pages=False")
        self.page_table = np.zeros((max_seats, self.n_tables), np.int32)
        self.pos = np.zeros((max_seats,), np.int32)     # next write position
        self.fused = fused
        self._prefill_row: Optional[Tuple[int, jnp.ndarray]] = None
        # device mirrors of the serving state, rebuilt only on churn
        # (self._dirty); between churn events decode ticks run entirely
        # from the arrays the previous fused tick returned, so the only
        # per-tick host<->device traffic is the token vector coming back
        self._dev: Optional[Dict[str, jnp.ndarray]] = None
        self._dirty = True
        self._init_model_state(num_pages)

    def _init_model_state(self, num_pages: int) -> None:
        """Allocate the device KV pool and compile the jitted tick
        functions.  Split out of ``__init__`` so a model-free policy
        (:class:`~repro.runtime.workload.OraclePolicy`) can inherit all
        the placement bookkeeping above while replacing the device
        state with host stubs."""
        cfg, rules = self.cfg, self.rules
        self.cache = M.init_paged_cache(cfg, num_pages, self.page_size,
                                        kv_dtype=self.kv_dtype)

        self._step_fn = jax.jit(
            lambda p, c, t, q, pt, nv: M.paged_decode_step(
                p, cfg, c, t, q, pt, nv, rules, self.opts))
        # prefill variant of the step: start/valid-count travel as ONE
        # (2,) int32 upload split inside the trace, and the seat's page
        # table row arrives pre-uploaded (it is invariant across a
        # request's chunks — pages are placed at admission, growth only
        # happens in decode — so prefill_tick caches the device copy
        # per request instead of re-uploading it every chunk)
        self._prefill_fn = jax.jit(
            lambda p, c, t, meta, pt: M.paged_decode_step(
                p, cfg, c, t, meta[:1], pt, meta[1:], rules, self.opts))
        # donate the pool so copy-on-write is an in-place one-page update,
        # not a fresh copy of the whole KV pool (donation is a no-op on
        # CPU and would only warn there)
        donate = (0,) if jax.default_backend() != "cpu" else ()
        self._cow_fn = jax.jit(M.copy_paged_page, donate_argnums=donate)

        # fused one-dispatch tick: model step + batched sampling in one
        # jitted call over device-resident state.  Every argument keeps a
        # fixed (max_seats,)-based shape so admission/finish/preemption
        # churn never retraces; cache / last-token / pos / table / step
        # are donated (functional in-place update) off-CPU.  Arg order:
        # 0=params 1=cache 2=last 3=pos 4=table 5=nv 6=temp 7=top_k
        # 8=top_p 9=seed 10=rid 11=step; outputs alias 1->cache, 2->toks,
        # 3->pos, 4->table, 11->step.
        fdonate = ((1, 2, 3, 4, 11)
                   if jax.default_backend() != "cpu" else ())
        self._fused_fn = jax.jit(
            lambda p, c, last, q, pt, nv, t, tk, tp, sd, rd, st:
                M.fused_decode_tick(p, cfg, c, last, q, pt, nv, t, tk, tp,
                                    sd, rd, st, rules, self.opts),
            donate_argnums=fdonate)

    def bind(self, sched: Scheduler) -> None:
        """Attach the owning :class:`Scheduler` (called once, by its
        constructor)."""
        self.sched = sched

    def pages_in_use(self) -> int:
        """Pages currently referenced by at least one live request."""
        return self.bm.in_use

    def cache_stats(self) -> Tuple[int, int]:
        """(reclaimable prefix-cache pages, evictions so far) from the
        underlying :class:`BlockManager`."""
        return self.bm.cached, self.bm.evictions

    def validate(self, req: Request) -> None:
        """Reject a request this pool could never place.

        Raises:
          ValueError: empty prompt; ``prompt + max_new_tokens`` >
              ``max_seq_len``; the request's SLO class carries a
              precision floor (``class_precision``) this pool's
              ``kv_dtype`` does not meet; or (reserved mode only) a
              page demand over the whole pool's capacity.  In lazy
              mode the constructor's ``n_tables <= capacity`` bound
              already makes ``max_seq_len`` the per-request
              feasibility limit."""
        total = len(req.prompt) + req.max_new_tokens
        if len(req.prompt) == 0:
            raise ValueError("empty prompt")
        want = self.class_precision.get(req.priority)
        if want is not None and (mixed_precision.kv_precision_bits(
                self.kv_dtype_name)
                < mixed_precision.kv_precision_bits(want)):
            raise ValueError(
                f"request class {req.priority!r} requires kv precision "
                f">= {want} but this engine's pool stores "
                f"{self.kv_dtype_name}; route it to a full-precision "
                "replica (see runtime.router) or drop the class's "
                "precision floor")
        if total > self.max_seq_len:
            raise ValueError(f"request needs {total} tokens > "
                             f"max_seq_len={self.max_seq_len}")
        if not self.lazy and self.bm.pages_needed(total) > self.bm.capacity:
            # up-front reservation must fit the pool; in lazy mode the
            # constructor's n_tables <= capacity bound already makes
            # max_seq_len the per-request feasibility limit
            raise ValueError(f"request needs {self.bm.pages_needed(total)} "
                             f"pages > pool capacity {self.bm.capacity}")

    # -- admission: seat + page budget + prefix reuse -------------------------

    def try_admit(self, req: Request, seat: int) -> bool:
        """Place ``req`` at ``seat`` if the pool allows: reserve its
        pages (prompt-only in lazy mode, prompt + full budget in
        reserved mode), take refs on cached prefix pages, copy-on-write
        a partially matching page, and point the seat's page-table row
        at the result.  Returns False — with no side effects — when
        the pool cannot cover the demand (the scheduler keeps the
        request queued)."""
        # a starved queue head re-attempts every tick; skip the O(prompt)
        # prefix match until the pool/index actually changed
        if req.match_version == self.bm.version:
            return False
        src = req.prefill_src
        if self.lazy:
            # reserve only the prompt's pages; decode grows on demand.
            # keep watermark headroom unless the pool is idle
            need = self.bm.pages_needed(len(src))
            gate = self.watermark_pages if self.sched.seats else 0
        else:
            need = self.bm.pages_needed(len(src) + req.max_new_tokens)
            gate = 0
        match = self.bm.match_prefix(src)
        # feasibility before any side effect: acquiring a reclaimable
        # matched (or CoW-source) page consumes one allocatable slot, so
        # a starved head request must not churn refcounts/LRU order
        # every tick
        pinned = list(match.pages)
        if match.cow_src is not None:
            pinned.append(match.cow_src)
        reclaimed = sum(1 for pg in pinned if self.bm.refcount(pg) == 0)
        if not self.bm.can_alloc(need - len(match.pages) + reclaimed + gate):
            if match.cow_src is not None:
                # the CoW transient (source + copy live at once) can be
                # what doesn't fit; forgo the partial-page match rather
                # than defer — the partial page is recomputed from
                # tokens, full-page shares are kept
                match = PrefixMatch(match.pages, None,
                                    len(match.pages) * self.page_size)
                pinned = list(match.pages)
                reclaimed = sum(1 for pg in pinned
                                if self.bm.refcount(pg) == 0)
            if not self.bm.can_alloc(need - len(match.pages)
                                     + reclaimed + gate):
                req.match_version = self.bm.version
                return False
        for pg in pinned:                        # pin shares AND the CoW
            self.bm.acquire(pg, req.rid)         # source before alloc can
        fresh = self.bm.alloc(need - len(match.pages), req.rid)  # evict them
        if fresh is None:                        # unreachable after the guard
            self.bm.free(pinned)
            return False
        if match.cow_src is not None:
            # the partially matched page: copy, then own the copy — its
            # tail will be overwritten with this request's own tokens.
            # The pin above keeps the source out of alloc's reach (it
            # could otherwise be evicted and handed back as fresh[0],
            # self-copying a donated buffer); drop it once copied
            self.cache = self._cow_fn(self.cache, match.cow_src, fresh[0])
            self.bm.free([match.cow_src])
        req.pages = match.pages + fresh
        req.prefill_pos = req.cached_tokens = match.n_cached
        req.registered_pages = len(match.pages)
        row = np.zeros((self.n_tables,), np.int32)
        row[:len(req.pages)] = req.pages
        self.page_table[seat] = row
        self.pos[seat] = 0
        self._dirty = True
        return True

    def release(self, req: Request) -> None:
        """Drop a finished request's page refs and clear its page-table
        row; registered prompt pages park reclaimable in the prefix
        index, everything else returns to the free list."""
        self.bm.free(req.pages)
        self.page_table[req.slot] = 0
        self.pos[req.slot] = 0
        self._dirty = True
        self._prefill_row = None

    def preempt(self, req: Request) -> None:
        """Free the request's placement for replay: refcounts drop
        (shared prefix pages stay live for their other holders;
        registered full prompt pages park reclaimable, so the
        re-admission prefix match revives them), and the request will
        re-prefill ``prompt + generated[:-1]`` before feeding
        ``generated[-1]`` back through the normal decode path."""
        self.bm.free(req.pages)
        self.page_table[req.slot] = 0
        self.pos[req.slot] = 0
        self._dirty = True
        self._prefill_row = None
        req.resume_tokens = np.concatenate(
            [req.prompt, np.asarray(req.generated[:-1], np.int32)])
        req.pages = []
        req.prefill_pos = 0
        req.cached_tokens = 0
        req.registered_pages = 0
        req.match_version = None

    # -- prefill / decode ------------------------------------------------------

    def prefill_tick(self) -> None:
        """One prompt chunk for the oldest mid-prefill request (chunked
        prefill: long prompts share the engine with everyone's decode).
        Requests with a prefix-cache hit start at ``cached_tokens``;
        preempted requests replay ``prompt + generated[:-1]``."""
        cands = [r for r in self.sched.seats.values()
                 if r.prefill_pos < len(r.prefill_src)]
        if not cands:
            return
        req = min(cands, key=lambda r: r.rid)
        seat = req.slot
        src = req.prefill_src
        start = req.prefill_pos
        chunk = src[start:start + self.prefill_chunk]
        c = len(chunk)
        tok = np.zeros((1, self.prefill_chunk), np.int32)
        tok[0, :c] = chunk
        meta = np.asarray([start, c], np.int32)
        if self._prefill_row is None or self._prefill_row[0] != req.rid:
            # upload the seat's table row once per request, not per
            # chunk (invalidated on release/preempt; the row cannot
            # change mid-prefill — see _prefill_fn)
            self._prefill_row = (
                req.rid,
                jnp.asarray(  # repro-lint: disable=RL001
                    self.page_table[seat:seat + 1]))
        # per-chunk payload: the token chunk and the (start, count) pair
        logits, self.cache = self._prefill_fn(
            self.params, self.cache,
            jnp.asarray(tok),   # repro-lint: disable=RL001
            jnp.asarray(meta),  # repro-lint: disable=RL001
            self._prefill_row[1])
        req.prefill_pos += c
        self.sched.metrics.prefill_tokens += c
        self.sched._trace("prefill_chunk", req.rid)
        self._register_full_pages(req)
        if req.prefill_pos == len(src):
            self.pos[seat] = len(src)
            self._dirty = True           # seat joins the decoding set
            if req.resume_tokens is None:
                self.sched._emit_first_tokens([(req, logits[0, c - 1])])
            # else: replay — TTFT token already emitted before the
            # preemption; decode resumes by feeding generated[-1]

    def _register_full_pages(self, req: Request) -> None:
        """Publish every page now fully covered by prefill tokens to the
        prefix index (idempotent for pages the request shares)."""
        if not self.bm.prefix_cache:
            return
        src = req.prefill_src
        full = req.prefill_pos // self.page_size
        while req.registered_pages < full:
            i = req.registered_pages
            self.bm.register_prefix(src[:(i + 1) * self.page_size],
                                    req.pages[i])
            req.registered_pages += 1

    def _decoding_seats(self) -> List[int]:
        return [s for s, r in self.sched.seats.items()
                if r.prefill_pos >= len(r.prefill_src)]

    def _grow_tick(self) -> None:
        """Lazy mode: hand each decoding seat the page its next write
        needs (one page per boundary crossing), oldest request first.
        When the pool cannot grow, preempt the
        :meth:`Scheduler.pick_victim` choice — lowest priority class
        first, youngest within a class, never a strictly higher class
        than the grower's (then the grower evicts itself) — until the
        allocation succeeds or the grower is gone."""
        sched = self.sched
        for s in sorted(self._decoding_seats(),
                        key=lambda s: sched.seats[s].rid):
            req = sched.seats.get(s)
            if req is None:                  # preempted for an older seat
                continue
            if self.pos[s] < len(req.pages) * self.page_size:
                continue                     # next write is covered
            pg = self.bm.try_grow(req.rid)
            while pg is None:
                victims = [sched.seats[v] for v in self._decoding_seats()]
                victim = sched.pick_victim(victims, req)
                sched.preempt(victim)
                if victim is req:
                    break                    # grower evicted itself
                pg = self.bm.try_grow(req.rid)
            if pg is not None:
                self.page_table[s, len(req.pages)] = pg
                req.pages.append(pg)
                self._dirty = True       # table row changed on host

    def _sync_device(self) -> None:
        """Rebuild the device-resident tick state from the host mirrors
        after a churn event (admit / finish / preempt / page growth /
        prefill completion).  Steady-state decode ticks never call this —
        they run entirely off the arrays the previous fused tick
        returned, and the host mirrors (``self.pos``/``self.page_table``,
        which bookkeeping and tests introspect) stay authoritative for
        scheduling decisions.  Every array keeps a fixed
        ``(max_seats,)``-based shape and dtype so the fused jit never
        retraces."""
        A = self.max_seats
        last = np.zeros((A,), np.int32)
        nv = np.zeros((A,), np.int32)
        temp = np.zeros((A,), np.float32)
        top_k = np.zeros((A,), np.int32)
        top_p = np.ones((A,), np.float32)
        seed = np.zeros((A,), np.uint32)
        rid = np.zeros((A,), np.uint32)
        step = np.zeros((A,), np.uint32)
        for s, r in self.sched.seats.items():
            if r.prefill_pos < len(r.prefill_src):
                continue                 # still prefilling: stays masked
            nv[s] = 1
            last[s] = r.generated[-1]
            sp = r.sampling
            temp[s] = sp.temperature
            top_k[s] = sp.top_k
            top_p[s] = sp.top_p
            seed[s] = sp.seed & 0xFFFFFFFF
            rid[s] = r.rid & 0xFFFFFFFF
            step[s] = len(r.generated)
        self._dev = {
            "last": jnp.asarray(last), "pos": jnp.asarray(self.pos),
            "table": jnp.asarray(self.page_table), "nv": jnp.asarray(nv),
            "temp": jnp.asarray(temp), "top_k": jnp.asarray(top_k),
            "top_p": jnp.asarray(top_p), "seed": jnp.asarray(seed),
            "rid": jnp.asarray(rid), "step": jnp.asarray(step),
        }
        self._dirty = False

    def decode_tick(self) -> None:
        """One token for every seat whose prefill is complete (growing
        page tables first in lazy mode).

        Fused mode (default): ONE jitted dispatch runs the model step
        and the batched sampler over device-resident state, and the only
        host<->device traffic for the tick is the ``(max_seats,)`` int32
        token vector coming back.  ``fused=False`` keeps the pre-fusion
        per-tick path (host-built token/nv arrays, host-side sampling) —
        the equivalence oracle the fused path is pinned token-identical
        to."""
        sched = self.sched
        if self.lazy:
            self._grow_tick()
        decoding = self._decoding_seats()
        if not decoding:
            return
        if not self.fused:
            tok = np.zeros((self.max_seats, 1), np.int32)
            for s in decoding:
                tok[s, 0] = sched.seats[s].generated[-1]
            if self._dirty:
                self._sync_device()  # table/nv re-upload only on churn
            d = self._dev
            # per-tick payload: the token batch and the advancing
            # positions; the page table and valid mask ride the
            # churn-gated device mirrors (every event that changes them
            # — admit completion, finish, preempt, growth — sets
            # self._dirty, so between churn events they are reused)
            logits, self.cache = self._step_fn(
                self.params, self.cache,
                jnp.asarray(tok),       # repro-lint: disable=RL001
                jnp.asarray(self.pos),  # repro-lint: disable=RL001
                d["table"], d["nv"])
            toks = sched._sample_decode_batch(logits[:, 0], decoding)
            for s in decoding:
                req = sched.seats[s]
                self.pos[s] += 1
                sched._emit_decode_token(req, toks[s])
            return
        # tick profiler: refine the step-level "decode" phase into
        # sync (device-mirror rebuild) / dispatch (fused-call enqueue)
        # / host (the ONE blocking token pull) / sample (host
        # acceptance); perf_counter reads happen only while profiling
        tel = sched.telemetry
        prof = None if tel is None else tel.profiler
        t0 = time.perf_counter() if prof is not None else 0.0
        if self._dirty:
            self._sync_device()
            if prof is not None:
                now = time.perf_counter()
                prof.add("decode/sync", now - t0)
                t0 = now
        d = self._dev
        with (jax.profiler.TraceAnnotation("fused_decode_tick")
              if prof is not None else _NULL_CTX):
            toks_dev, self.cache, d["pos"], d["step"], d["table"] = \
                self._fused_fn(self.params, self.cache, d["last"],
                               d["pos"], d["table"], d["nv"], d["temp"],
                               d["top_k"], d["top_p"], d["seed"],
                               d["rid"], d["step"])
        d["last"] = toks_dev             # this tick's token = next input
        if prof is not None:
            now = time.perf_counter()
            prof.add("decode/dispatch", now - t0)
            t0 = now
        # the tick's ONE device->host sync
        toks = np.asarray(toks_dev)  # repro-lint: disable=RL001
        if prof is not None:
            now = time.perf_counter()
            prof.add("decode/host", now - t0)
            t0 = now
        for s in decoding:
            req = sched.seats[s]
            self.pos[s] += 1
            sched._emit_decode_token(req, int(toks[s]))
        if prof is not None:
            prof.add("decode/sample", time.perf_counter() - t0)


# ---------------------------------------------------------------------------
# Engine façades (public API)
# ---------------------------------------------------------------------------

class ServingEngine(Scheduler):
    """Fixed-slot continuous-batching engine: the Scheduler bound to
    :class:`FixedSlotPolicy`.  Serves every arch (SSM, enc-dec, frontend)
    and is the equivalence oracle for the paged engine.

    ``admission`` selects the queue policy (``"fcfs"`` default /
    ``"slo"``) and ``aging_ticks`` its anti-starvation bound — see
    :class:`SLOAdmission` and docs/serving.md."""

    default_max_ticks = 10_000

    def __init__(self, cfg, params, *, slots: int = 4, max_len: int = 256,
                 rules: LogicalRules = SINGLE_DEVICE_RULES,
                 opts: Optional[M.RunOptions] = None,
                 sampler: Optional[Sampler] = None,
                 admission="fcfs", aging_ticks: int = 64,
                 clock=None, record_trace: bool = True, telemetry=None):
        policy = FixedSlotPolicy(cfg, params, slots=slots, max_len=max_len,
                                 rules=rules, opts=opts)
        super().__init__(policy, max_seats=slots, sampler=sampler,
                         admission=admission, aging_ticks=aging_ticks,
                         clock=clock, record_trace=record_trace,
                         telemetry=telemetry)
        self.cfg = cfg
        self.params = params
        self.B = slots
        self.max_len = max_len
        self.rules = rules
        self.opts = policy.opts

    @property
    def active(self) -> Dict[int, Request]:
        return self.seats

    @property
    def cache(self):
        return self.policy.cache

    @property
    def pos(self):
        return self.policy.pos


class PagedServingEngine(Scheduler):
    """Paged-KV continuous-batching engine: the Scheduler bound to
    :class:`PagedPolicy` (shared page pool, chunked prefill, refcounted
    prefix caching — ``prefix_cache=False`` disables sharing for A/B
    comparisons).  ``lazy_pages`` (default True) reserves only prompt
    pages at admission and grows on demand, preempting the youngest
    decoding request (recompute-on-readmission) under page pressure;
    ``lazy_pages=False`` restores up-front full reservation.
    ``watermark`` is the lazy admission gate's free-page headroom as a
    fraction of pool capacity (≥1 page; waived on an idle pool).
    ``fused`` (default True) runs each decode tick as ONE jitted
    dispatch — model step plus batched on-device sampling over
    device-resident pos/page-table/last-token state — so a single
    ``(max_seats,)`` token vector is the tick's only host↔device
    round-trip; ``fused=False`` keeps the pre-fusion per-tick path
    (the equivalence oracle).
    ``admission`` selects the queue policy (``"fcfs"`` default /
    ``"slo"``) and ``aging_ticks`` its anti-starvation bound — see
    :class:`SLOAdmission` and docs/serving.md.
    ``kv_dtype`` picks the KV pool's storage precision
    (``f32``/``bf16``/``fp8``/``int8``; None = the config's compute
    dtype — the pre-quantization layout, token streams bit-identical
    to it).  Quantized pools store per-(token, head) scales next to
    the pages and dequantize inside the decode path, so the same
    byte budget holds ~4× the tokens at hd=64 (docs/serving.md
    §"Quantized KV pages").  ``class_precision`` maps SLO classes to
    minimum precisions (e.g. ``{"premium": "f32"}``): a request whose
    class's floor this pool cannot meet is rejected at submit — the
    fleet router uses the same map to route such classes to
    full-precision replicas."""

    default_max_ticks = 100_000

    def __init__(self, cfg, params, *, page_size: int = 16,
                 num_pages: int = 64, max_seats: int = 8,
                 max_seq_len: int = 256, prefill_chunk: int = 32,
                 rules: LogicalRules = SINGLE_DEVICE_RULES,
                 opts: Optional[M.RunOptions] = None,
                 sampler: Optional[Sampler] = None,
                 prefix_cache: bool = True, lazy_pages: bool = True,
                 watermark: float = 0.05, fused: bool = True,
                 admission="fcfs", aging_ticks: int = 64,
                 kv_dtype: Optional[str] = None,
                 class_precision: Optional[Dict[str, str]] = None,
                 clock=None, record_trace: bool = True,
                 telemetry=None, policy_cls: Optional[type] = None):
        # policy_cls swaps the placement+arithmetic implementation while
        # keeping every Scheduler behavior: the load harness passes
        # workload.OraclePolicy (model-free hash logits) here
        policy = (policy_cls or PagedPolicy)(
            cfg, params, page_size=page_size,
            num_pages=num_pages, max_seats=max_seats,
            max_seq_len=max_seq_len,
            prefill_chunk=prefill_chunk, rules=rules,
            opts=opts, prefix_cache=prefix_cache,
            lazy_pages=lazy_pages, watermark=watermark,
            fused=fused, kv_dtype=kv_dtype,
            class_precision=class_precision)
        super().__init__(policy, max_seats=max_seats, sampler=sampler,
                         page_capacity=policy.bm.capacity,
                         admission=admission, aging_ticks=aging_ticks,
                         clock=clock, record_trace=record_trace,
                         telemetry=telemetry)
        self.metrics.kv_dtype = policy.kv_dtype_name
        self.metrics.page_bytes = policy.page_bytes
        self.cfg = cfg
        self.params = params
        self.page_size = page_size
        self.max_seq_len = max_seq_len
        self.prefill_chunk = prefill_chunk
        self.rules = rules
        self.opts = policy.opts

    @property
    def kv_dtype(self) -> str:
        """The pool's storage precision name (resolved)."""
        return self.policy.kv_dtype_name

    @property
    def bm(self) -> BlockManager:
        return self.policy.bm

    @property
    def n_tables(self) -> int:
        return self.policy.n_tables

    @property
    def cache(self):
        return self.policy.cache

    @property
    def page_table(self) -> np.ndarray:
        return self.policy.page_table

    @property
    def pos(self) -> np.ndarray:
        return self.policy.pos
