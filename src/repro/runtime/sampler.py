"""Shared token sampler: temperature / top-k / top-p with per-request seeds.

Both serving engines (every ``runtime.serving.Scheduler``) draw tokens
through one :class:`Sampler`, so fixed-slot and paged decode share a
single sampling implementation instead of each engine hard-coding
argmax.  ``temperature <= 0`` (the default) is exact greedy argmax — the
path the engine-equivalence tests pin to the pre-refactor outputs.

Stochastic sampling is deterministic per ``(seed, rid, step)``: the RNG
for every drawn token is seeded from the request's
:class:`SamplingParams.seed`, its engine-assigned ``rid`` and the token
index, so a replayed request reproduces its token stream exactly and two
requests in the same batch never share a stream.

The key is ``(seed, rid, step)`` and nothing else — deliberately NOT
the request's SLO priority class, deadline, or the scheduler's
admission policy: scheduling decides *when* a request runs, never
*which* tokens it produces (tests/test_slo_scheduling.py pins this).
See docs/serving.md for where the sampler sits in the serving stack.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling configuration.

    temperature: 0 (default) = greedy argmax; > 0 scales logits.
    top_k: keep only the k highest logits (0 = off).
    top_p: nucleus sampling — keep the smallest set of tokens whose
        probability mass reaches ``top_p`` (1.0 = off).
    seed: base seed for the per-request token stream (>= 0).
    """
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0

    def __post_init__(self):
        if self.temperature < 0:
            raise ValueError(f"temperature must be >= 0, got {self.temperature}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {self.top_k}")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")
        if self.seed < 0:
            raise ValueError(f"seed must be >= 0, got {self.seed}")

    @property
    def greedy(self) -> bool:
        return self.temperature <= 0.0


GREEDY = SamplingParams()


class Sampler:
    """Stateless sampler; all randomness derives from (seed, rid, step)."""

    def sample(self, logits, params: SamplingParams = GREEDY, *,
               rid: int = 0, step: int = 0) -> int:
        """Draw one token id from a ``(V,)`` logits row.

        Args:
          logits: length-V array-like of unnormalized log-probs.
          params: sampling configuration; greedy (or None) returns the
              plain argmax with no RNG involved.
          rid: engine-assigned request id — part of the RNG key.
          step: token index within the request — part of the RNG key.

        Returns:
          The drawn token id in ``[0, V)``; identical for identical
          ``(logits, params.seed, rid, step)`` regardless of batch
          composition, scheduling order, or the request's SLO class."""
        logits = np.asarray(logits, np.float64).reshape(-1)
        if params is None or params.greedy:
            return int(np.argmax(logits))
        x = logits / params.temperature
        if 0 < params.top_k < x.size:
            kth = np.partition(x, -params.top_k)[-params.top_k]
            x = np.where(x < kth, -np.inf, x)
        x = x - np.max(x)
        p = np.exp(x)
        p /= p.sum()
        if params.top_p < 1.0:
            order = np.argsort(-p, kind="stable")
            csum = np.cumsum(p[order])
            # keep the minimal nucleus; the top token always survives
            in_nucleus = np.zeros(p.size, bool)
            in_nucleus[order] = csum - p[order] < params.top_p
            p = np.where(in_nucleus, p, 0.0)
            p /= p.sum()
        rng = np.random.default_rng(
            np.random.SeedSequence([params.seed, rid, step]))
        return int(rng.choice(p.size, p=p))
