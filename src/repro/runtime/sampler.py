"""Shared token sampler: temperature / top-k / top-p with per-request seeds.

Both serving engines (every ``runtime.serving.Scheduler``) draw tokens
through one sampling algorithm, so fixed-slot and paged decode share a
single implementation instead of each engine hard-coding argmax.
``temperature <= 0`` (the default) is exact greedy argmax — the path the
engine-equivalence tests pin to the pre-refactor outputs.

Stochastic sampling is deterministic per ``(seed, rid, step)``: every
drawn token derives from a counter-based integer hash of the request's
:class:`SamplingParams.seed`, its engine-assigned ``rid`` and the token
index, so a replayed request reproduces its token stream exactly and two
requests in the same batch never share a stream.

The key is ``(seed, rid, step)`` and nothing else — deliberately NOT
the request's SLO priority class, deadline, or the scheduler's
admission policy: scheduling decides *when* a request runs, never
*which* tokens it produces (tests/test_slo_scheduling.py pins this).

The algorithm is the Gumbel-max trick over filtered logits, chosen
because it has TWO interchangeable implementations that draw identical
tokens:

- :meth:`Sampler.sample` — the numpy host oracle (one row at a time),
  used by the fallback per-tick engine paths and as the reference in
  equivalence tests;
- :func:`sample_tokens` — the batched jax device path, fused into the
  serving engine's one-dispatch decode tick
  (``models.model.fused_decode_tick``) so sampling never forces a
  per-request device→host sync.

Both compute, in float32: ``x = logits / T``; mask all but the top-k
logits; mask tokens outside the top-p nucleus (smallest prefix of the
descending-sorted softmax reaching ``top_p``); add Gumbel noise
``-log(-log(u))`` where ``u`` is a uniform derived from the
(seed, rid, step, token) hash; take the argmax.  Every arithmetic step
is elementwise IEEE float32 (exact in both numpy and XLA), so the two
paths agree token-for-token.

See docs/serving.md for where the sampler sits in the serving stack.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

#: fmix32 finalizer constants (MurmurHash3) — the per-token counter hash.
_M1, _M2, _GOLD = 0x85EBCA6B, 0xC2B2AE35, 0x9E3779B9
_MASK32 = 0xFFFFFFFF


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling configuration.

    temperature: 0 (default) = greedy argmax; > 0 scales logits.
    top_k: keep only the k highest logits (0 = off).
    top_p: nucleus sampling — keep the smallest set of tokens whose
        probability mass reaches ``top_p`` (1.0 = off).
    seed: base seed for the per-request token stream (>= 0).
    """
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0

    def __post_init__(self):
        if self.temperature < 0:
            raise ValueError(f"temperature must be >= 0, got {self.temperature}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {self.top_k}")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")
        if self.seed < 0:
            raise ValueError(f"seed must be >= 0, got {self.seed}")

    @property
    def greedy(self) -> bool:
        return self.temperature <= 0.0


GREEDY = SamplingParams()


# ---------------------------------------------------------------------------
# Counter-based uniform/Gumbel noise — twin numpy / jax implementations.
#
# All arithmetic is uint32 with wraparound, bit-identical between numpy
# arrays and XLA, so host and device derive the same noise for the same
# (seed, rid, step) key.
# ---------------------------------------------------------------------------

def _mix_np(h: np.ndarray) -> np.ndarray:
    """fmix32 avalanche over a uint32 ndarray (wraparound multiply)."""
    h = h ^ (h >> np.uint32(16))
    h = h * np.uint32(_M1)
    h = h ^ (h >> np.uint32(13))
    h = h * np.uint32(_M2)
    return h ^ (h >> np.uint32(16))


def _gumbel_np(seed: int, rid: int, step: int, n: int) -> np.ndarray:
    """(n,) float32 Gumbel noise keyed by (seed, rid, step)."""
    k = _mix_np(np.asarray([seed & _MASK32], np.uint32) ^ np.uint32(_GOLD))
    k = _mix_np(k ^ np.uint32(rid & _MASK32))
    k = _mix_np(k ^ np.uint32(step & _MASK32))
    u32 = _mix_np(k ^ np.arange(n, dtype=np.uint32))
    # 24 mantissa-exact bits, offset off 0 and 1 so both logs are finite
    u = ((u32 >> np.uint32(8)).astype(np.float32) + np.float32(0.5)) \
        * np.float32(2.0 ** -24)
    return (-np.log(-np.log(u))).astype(np.float32)


def _mix_jnp(h):
    """fmix32 avalanche over a uint32 jax array."""
    h = h ^ (h >> 16)
    h = h * jnp.uint32(_M1)
    h = h ^ (h >> 13)
    h = h * jnp.uint32(_M2)
    return h ^ (h >> 16)


def _gumbel_jnp(seed, rid, step, n: int):
    """(B, n) float32 Gumbel noise; seed/rid/step are (B,) uint32."""
    k = _mix_jnp(seed ^ jnp.uint32(_GOLD))
    k = _mix_jnp(k ^ rid)
    k = _mix_jnp(k ^ step)
    u32 = _mix_jnp(k[:, None] ^ jnp.arange(n, dtype=jnp.uint32)[None, :])
    u = ((u32 >> 8).astype(jnp.float32) + jnp.float32(0.5)) \
        * jnp.float32(2.0 ** -24)
    return -jnp.log(-jnp.log(u))


# ---------------------------------------------------------------------------
# Device path: batched sampling inside the fused decode tick
# ---------------------------------------------------------------------------

def sample_tokens(logits, temperature, top_k, top_p, seed, rid, step):
    """Batched device sampler: one token per row, jit-safe, no host sync.

    The device half of the shared sampling algorithm (see module
    docstring); ``models.model.fused_decode_tick`` composes it with the
    paged model step so exactly one token vector leaves the device per
    tick.  Token-for-token identical to looping :meth:`Sampler.sample`
    over the rows (the equivalence suite pins this).

    Args:
      logits: (B, V) unnormalized log-probs (any float dtype; sampled
          in float32 like the host oracle).
      temperature: (B,) float32; rows with ``temperature <= 0`` take
          the plain argmax (greedy) and ignore every other parameter.
      top_k: (B,) int32 (0 = off).
      top_p: (B,) float32 (1.0 = off).
      seed, rid, step: (B,) uint32 — the per-row RNG key.

    Returns:
      (B,) int32 token ids.
    """
    logits = logits.astype(jnp.float32)
    B, V = logits.shape
    rows = jnp.arange(B)[:, None]
    greedy_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    t_safe = jnp.where(temperature > 0, temperature, 1.0).astype(jnp.float32)
    x = logits / t_safe[:, None]
    # top-k: drop everything below the k-th largest (ties at the
    # threshold survive, matching the oracle)
    kth_idx = jnp.clip(top_k, 1, V) - 1
    kth = jnp.take_along_axis(jnp.sort(x, axis=-1)[:, ::-1],
                              kth_idx[:, None], axis=-1)
    apply_k = ((top_k > 0) & (top_k < V))[:, None]
    x = jnp.where(apply_k & (x < kth), -jnp.inf, x)
    # top-p: keep the smallest descending-probability prefix reaching
    # top_p (the top token always survives: its exclusive cumsum is 0)
    p = jnp.exp(x - jnp.max(x, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    order = jnp.argsort(-p, axis=-1)                    # stable, like numpy
    p_sorted = jnp.take_along_axis(p, order, axis=-1)
    keep_sorted = (jnp.cumsum(p_sorted, axis=-1) - p_sorted) < top_p[:, None]
    in_nucleus = jnp.zeros((B, V), bool).at[rows, order].set(keep_sorted)
    x = jnp.where((top_p < 1.0)[:, None] & ~in_nucleus, -jnp.inf, x)

    g = _gumbel_jnp(seed, rid, step, V)
    stoch_tok = jnp.argmax(x + g, axis=-1).astype(jnp.int32)
    return jnp.where(temperature > 0, stoch_tok, greedy_tok)


# ---------------------------------------------------------------------------
# Batched host path — numpy twin of sample_tokens
# ---------------------------------------------------------------------------

def sample_tokens_np(logits, temperature, top_k, top_p, seed, rid, step):
    """Batched *host* sampler: the numpy twin of :func:`sample_tokens`,
    token-for-token identical to both it and a per-row
    :meth:`Sampler.sample` loop (tests/test_workload.py pins all
    three).  The model-free load-harness oracle
    (``runtime.workload.OraclePolicy``) decodes millions of tokens per
    run; a per-row Python loop over :meth:`Sampler.sample` would
    dominate its wall time, and a jnp round-trip would pay device
    dispatch for arithmetic that never needs the device.

    Args:
      logits: (B, V) float32 ndarray of unnormalized log-probs.
      temperature: (B,) float32; rows ``<= 0`` take the plain argmax.
      top_k: (B,) int32 (0 = off).
      top_p: (B,) float32 (1.0 = off).
      seed, rid, step: (B,) integer arrays — the per-row RNG key
        (hashed per row exactly like the scalar ``_gumbel_np``).

    Returns:
      (B,) int32 token ids.
    """
    x = np.asarray(logits, np.float32)
    B, V = x.shape
    temperature = np.asarray(temperature, np.float32)
    top_k = np.asarray(top_k, np.int32)
    top_p = np.asarray(top_p, np.float32)
    greedy_tok = np.argmax(x, axis=-1).astype(np.int32)
    stoch = temperature > 0
    if not stoch.any():
        return greedy_tok
    if not stoch.all():
        # run the stochastic path on just the stochastic rows: every
        # per-row quantity (threshold, nucleus, Gumbel key) is hashed
        # from (seed, rid, step), never from batch position, so the
        # subset call is bit-identical to the full-batch one — and in
        # mixed batches (the oracle default is 25% stochastic) it
        # skips the O(V log V) sort work for the greedy majority.
        out = greedy_tok.copy()
        idx = np.nonzero(stoch)[0]
        out[idx] = sample_tokens_np(
            x[idx], temperature[idx], np.asarray(top_k, np.int32)[idx],
            np.asarray(top_p, np.float32)[idx],
            np.asarray(seed)[idx], np.asarray(rid)[idx],
            np.asarray(step)[idx])
        return out
    t_safe = np.where(stoch, temperature, np.float32(1.0)).astype(np.float32)
    x = x / t_safe[:, None]
    # top-k: drop everything below the k-th largest (ties at the
    # threshold survive, matching the oracle); the O(V log V) sort is
    # skipped entirely when no row uses top-k
    apply_k = ((top_k > 0) & (top_k < V))[:, None]
    if apply_k.any():
        kth_idx = np.clip(top_k, 1, V) - 1
        kth = np.take_along_axis(np.sort(x, axis=-1)[:, ::-1],
                                 kth_idx[:, None], axis=-1)
        x = np.where(apply_k & (x < kth), -np.inf, x).astype(np.float32)
    # top-p: keep the smallest descending-probability prefix reaching
    # top_p (the top token always survives: its exclusive cumsum is 0)
    p = np.exp(x - np.max(x, axis=-1, keepdims=True))
    p = p / np.sum(p, axis=-1, keepdims=True)
    order = np.argsort(-p, axis=-1, kind="stable")
    p_sorted = np.take_along_axis(p, order, axis=-1)
    keep_sorted = (np.cumsum(p_sorted, axis=-1) - p_sorted) < top_p[:, None]
    in_nucleus = np.zeros((B, V), bool)
    np.put_along_axis(in_nucleus, order, keep_sorted, axis=-1)
    x = np.where((top_p < 1.0)[:, None] & ~in_nucleus,
                 -np.inf, x).astype(np.float32)
    # per-row Gumbel noise, hashed row-wise exactly like _gumbel_np
    k = _mix_np(np.asarray(seed, np.uint32) ^ np.uint32(_GOLD))
    k = _mix_np(k ^ np.asarray(rid, np.uint32))
    k = _mix_np(k ^ np.asarray(step, np.uint32))
    u32 = _mix_np(k[:, None] ^ np.arange(V, dtype=np.uint32)[None, :])
    u = ((u32 >> np.uint32(8)).astype(np.float32) + np.float32(0.5)) \
        * np.float32(2.0 ** -24)
    g = (-np.log(-np.log(u))).astype(np.float32)
    stoch_tok = np.argmax(x + g, axis=-1).astype(np.int32)
    return np.where(stoch, stoch_tok, greedy_tok)


# ---------------------------------------------------------------------------
# Host oracle
# ---------------------------------------------------------------------------

class Sampler:
    """Stateless sampler; all randomness derives from (seed, rid, step).

    This is the numpy *oracle* for :func:`sample_tokens` — the fallback
    per-tick engine paths call it directly, and the fused device path is
    pinned token-identical to it."""

    def sample(self, logits, params: SamplingParams = GREEDY, *,
               rid: int = 0, step: int = 0) -> int:
        """Draw one token id from a ``(V,)`` logits row.

        Args:
          logits: length-V array-like of unnormalized log-probs.
          params: sampling configuration; greedy (or None) returns the
              plain argmax with no RNG involved.
          rid: engine-assigned request id — part of the RNG key.
          step: token index within the request — part of the RNG key.

        Returns:
          The drawn token id in ``[0, V)``; identical for identical
          ``(logits, params.seed, rid, step)`` regardless of batch
          composition, scheduling order, or the request's SLO class."""
        # host oracle by contract: callers hand over rows they already
        # batch-transferred (see Scheduler._sample_decode_batch)
        x = np.asarray(  # repro-lint: disable=RL001
            logits, np.float32).reshape(-1)
        if params is None or params.greedy:
            return int(np.argmax(x))
        x = x / np.float32(params.temperature)
        if 0 < params.top_k < x.size:
            kth = np.sort(x)[::-1][params.top_k - 1]
            x = np.where(x < kth, -np.inf, x).astype(np.float32)
        if params.top_p < 1.0:
            p = np.exp(x - np.max(x))
            p = p / p.sum()
            order = np.argsort(-p, kind="stable")
            csum = np.cumsum(p[order])
            # keep the minimal nucleus; the top token always survives
            keep = np.zeros(p.size, bool)
            keep[order] = csum - p[order] < np.float32(params.top_p)
            x = np.where(keep, x, -np.inf).astype(np.float32)
        g = _gumbel_np(params.seed, rid, step, x.size)
        return int(np.argmax(x + g))
