"""Observability plane for the serving stack: flight recorder, spans,
metrics registry, tick profiler and SLO burn-rate monitor.

The serving runtime's only debugging evidence used to be the flat
``Scheduler.trace`` list of ``(tick, event, rid)`` tuples and a terminal
metrics snapshot.  This module adds the structured layer underneath —
without ever touching a device array:

- :class:`TraceEvent` — a structured event (monotonic tick, injected-
  clock wall time, engine id, rid, kind, small attrs dict) emitted from
  every ``Scheduler._trace`` site and the fleet router's outer loop.
- :class:`FlightRecorder` — a bounded ring buffer of the last N events;
  on a ``SchedulerStallError`` or a load-harness invariant violation the
  ring plus a full engine-state snapshot (:func:`scheduler_state`:
  queue, seats, ``BlockManager`` partition, ``HostBudget`` grants) is
  dumped as a postmortem JSON artifact.
- span building + Chrome trace-event export (:func:`build_spans`,
  :func:`perfetto_trace`) — per-request timelines (queued → prefill →
  first token → decode → preempt → replay → finish), one track per
  engine seat, viewable in Perfetto (https://ui.perfetto.dev).
- :class:`MetricsRegistry` — counters, gauges and log-bucketed
  *mergeable* :class:`Histogram`\\ s with Prometheus text exposition
  (served by :class:`MetricsServer` behind ``launch/serve.py
  --metrics-port``).
- :class:`TickProfiler` — wall-time breakdown of the tick phases
  (admission / prefill / decode, and the fused tick's sync / dispatch /
  host-crossing / sample sub-phases).
- :class:`BurnRateMonitor` — sliding-window TTFT/TBT miss rates per SLO
  class, emitting edge-triggered ``slo_burn`` warning events.

Contracts this module must keep (see docs/observability.md):

- **stdlib only** — no jax, no numpy, no repro imports.  ``paged_kv``
  imports :class:`Histogram`, so any heavier dependency would cycle;
  and ``scripts/trace_view.py`` must render dumps on a bare Python.
- **free when off** — the emit path is reached only behind a single
  ``telemetry is not None`` check in the Scheduler; the benchmark
  workload 9 gates the telemetry-on/off tokens/s ratio at >= 0.98.
- **zero device syncs** — every function here is pure host bookkeeping;
  ``hotpaths.toml`` declares the emit path hot so repro-lint RL001
  polices that it stays that way.
- **injected-clock time** — event timestamps are whatever the
  Scheduler's ``clock`` returns (wall seconds in serving, virtual
  seconds under the load harness), never a private ``perf_counter``
  call, so harness timelines are deterministic.
"""
from __future__ import annotations

import http.server
import json
import math
import threading
from collections import OrderedDict, deque
from typing import (Callable, Deque, Dict, Iterable, List, NamedTuple,
                    Optional, Tuple)


class TraceEvent(NamedTuple):
    """One structured trace event.

    tick: the emitting scheduler's monotonic tick counter.
    t: injected-clock time, seconds (virtual under the load harness).
    engine: ``"model/replica"`` in a fleet, ``""`` on a solo engine.
    rid: request id; -1 for engine-level events (``fleet_tick``,
        ``slo_burn``).
    kind: event name — the ``Scheduler.trace`` events (admit /
        prefix_hit / prefill_chunk / first_token / decode / preempt /
        deadline_miss / tbt_miss / finish) plus the telemetry-only
        ``submit``, ``fleet_tick``, ``slo_burn`` and
        ``slo_burn_clear``.
    attrs: small JSON-safe dict of extras, or None (hot events carry
        None — no per-event allocation on the decode path)."""
    tick: int
    t: float
    engine: str
    rid: int
    kind: str
    attrs: Optional[dict]

    def to_dict(self) -> dict:
        d = {"tick": self.tick, "t": self.t, "engine": self.engine,
             "rid": self.rid, "kind": self.kind}
        if self.attrs:
            d["attrs"] = self.attrs
        return d


def event_from_dict(d: dict) -> TraceEvent:
    """Inverse of :meth:`TraceEvent.to_dict` (postmortem round-trip)."""
    return TraceEvent(int(d["tick"]), float(d["t"]),
                      str(d.get("engine", "")), int(d["rid"]),
                      str(d["kind"]), d.get("attrs"))


class FlightRecorder:
    """Bounded ring buffer of the last ``capacity`` trace events.

    The ring is a ``deque(maxlen=...)`` — appends are O(1) and the
    oldest events fall off silently; ``dropped`` counts them so a
    postmortem states how much history it is missing."""

    def __init__(self, capacity: int = 4096):
        if capacity < 1:
            raise ValueError(f"ring capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._ring: Deque[TraceEvent] = deque(maxlen=capacity)
        self.total = 0

    def append(self, ev: TraceEvent) -> None:
        self._ring.append(ev)
        self.total += 1

    @property
    def dropped(self) -> int:
        """Events that aged out of the ring."""
        return self.total - len(self._ring)

    def events(self) -> List[TraceEvent]:
        """The retained events, oldest first."""
        return list(self._ring)

    def clear(self) -> None:
        self._ring.clear()
        self.total = 0

    def snapshot(self) -> dict:
        """JSON-safe dump: capacity, totals, and the retained events."""
        return {"capacity": self.capacity, "total": self.total,
                "dropped": self.dropped,
                "events": [e.to_dict() for e in self._ring]}


# ---------------------------------------------------------------------------
# Metrics: log-bucketed histograms, registry, Prometheus exposition
# ---------------------------------------------------------------------------

#: Bucket key for non-positive samples (durations can be exactly 0.0
#: under the virtual clock when two emissions share a tick).
ZERO_BUCKET = -(10 ** 9)


class Histogram:
    """Log-bucketed mergeable histogram.

    Bucket ``i`` holds samples in ``(base**(i-1), base**i]``; samples
    <= 0 land in a dedicated zero bucket below every real one.  Merging
    is pure per-bucket count addition, so it is associative and
    commutative (tests/test_telemetry.py pins this with hypothesis) —
    replica histograms merge into model and fleet aggregates without
    ever re-touching the raw samples.

    Quantile contract: :meth:`quantile_bucket` applies exactly the
    nearest-rank rule of ``paged_kv._quantile`` to the bucket
    cumulative counts, and bucketing is monotone, so the bucket it
    returns always contains the exact sample quantile of the observed
    values — the histogram answer is the exact answer coarsened to one
    bucket width."""

    def __init__(self, base: float = 2.0):
        if base <= 1.0:
            raise ValueError(f"histogram base must be > 1, got {base}")
        self.base = base
        self.counts: Dict[int, int] = {}
        self.count = 0
        self.sum = 0.0
        self.min: Optional[float] = None
        self.max: Optional[float] = None

    def bucket_index(self, x: float) -> int:
        """The bucket a sample lands in (monotone in ``x``)."""
        if x <= 0.0:
            return ZERO_BUCKET
        # round() guards float fuzz at exact powers of the base so
        # x == base**k maps to bucket k (the (base**(k-1), base**k]
        # interval that contains it), not k+1
        return math.ceil(round(math.log(x, self.base), 9))

    def bucket_le(self, i: int) -> float:
        """Inclusive upper bound of bucket ``i``."""
        return 0.0 if i == ZERO_BUCKET else self.base ** i

    def observe(self, x: float) -> None:
        i = self.bucket_index(x)
        self.counts[i] = self.counts.get(i, 0) + 1
        self.count += 1
        self.sum += x
        self.min = x if self.min is None else min(self.min, x)
        self.max = x if self.max is None else max(self.max, x)

    def merge(self, other: "Histogram") -> "Histogram":
        """A new histogram holding both operands' samples."""
        if other.base != self.base:
            raise ValueError(f"cannot merge histograms with bases "
                             f"{self.base} and {other.base}")
        out = Histogram(self.base)
        out.counts = dict(self.counts)
        for i, n in other.counts.items():
            out.counts[i] = out.counts.get(i, 0) + n
        out.count = self.count + other.count
        out.sum = self.sum + other.sum
        mins = [m for m in (self.min, other.min) if m is not None]
        maxs = [m for m in (self.max, other.max) if m is not None]
        out.min = min(mins) if mins else None
        out.max = max(maxs) if maxs else None
        return out

    def quantile_bucket(self, q: float) -> Optional[int]:
        """Bucket index of the nearest-rank ``q`` quantile (None when
        empty).  Rank rule identical to ``paged_kv._quantile``:
        1-based rank ``ceil(q * n)``, clamped to [1, n]."""
        if self.count == 0:
            return None
        rank = max(1, min(self.count,
                          math.ceil(round(q * self.count, 9))))
        seen = 0
        for i in sorted(self.counts):
            seen += self.counts[i]
            if seen >= rank:
                return i
        return max(self.counts)                   # unreachable

    def quantile_bound(self, q: float) -> float:
        """Upper bound of the ``q``-quantile bucket (0.0 when empty)."""
        i = self.quantile_bucket(q)
        return 0.0 if i is None else self.bucket_le(i)

    def to_dict(self) -> dict:
        return {"base": self.base, "count": self.count, "sum": self.sum,
                "min": self.min, "max": self.max,
                "counts": {str(i): n for i, n in sorted(self.counts.items())}}

    @classmethod
    def from_dict(cls, d: dict) -> "Histogram":
        h = cls(float(d.get("base", 2.0)))
        h.counts = {int(i): int(n) for i, n in d.get("counts", {}).items()}
        h.count = int(d.get("count", 0))
        h.sum = float(d.get("sum", 0.0))
        h.min = d.get("min")
        h.max = d.get("max")
        return h


def _fmt(v: float) -> str:
    """Prometheus sample-value formatting (no exponent surprises for
    the common cases, stable round-trip for the rest)."""
    if v == math.inf:
        return "+Inf"
    if float(v).is_integer() and abs(v) < 1e15:
        return str(int(v))
    return format(float(v), ".9g")


def _labels_text(labels: Optional[Dict[str, str]],
                 extra: Optional[Dict[str, str]] = None) -> str:
    merged: Dict[str, str] = dict(labels or {})
    if extra:
        merged.update(extra)
    if not merged:
        return ""
    body = ",".join(f'{k}="{v}"' for k, v in sorted(merged.items()))
    return "{" + body + "}"


class MetricsRegistry:
    """Counters, gauges and histograms with Prometheus text exposition
    (format 0.0.4: ``# HELP`` / ``# TYPE`` headers, cumulative
    ``_bucket{le=...}`` lines, ``_sum`` and ``_count``).

    The serving stack does not mutate a registry on the hot path — it
    rebuilds one at scrape time from ``EngineMetrics`` (which carries
    the incrementally maintained histograms), so scrapes cost the
    scraper, never the tick loop."""

    _TYPES = ("counter", "gauge", "histogram")

    def __init__(self):
        # name -> {"type", "help", "samples": [(labels, value)]}
        self._fams: "OrderedDict[str, dict]" = OrderedDict()

    def _family(self, name: str, kind: str, help_: str) -> dict:
        fam = self._fams.get(name)
        if fam is None:
            fam = {"type": kind, "help": help_, "samples": []}
            self._fams[name] = fam
        elif fam["type"] != kind:
            raise ValueError(f"metric {name!r} registered as "
                             f"{fam['type']}, not {kind}")
        return fam

    def counter(self, name: str, value: float,
                labels: Optional[Dict[str, str]] = None,
                help: str = "") -> None:
        self._family(name, "counter", help)["samples"].append(
            (dict(labels or {}), float(value)))

    def gauge(self, name: str, value: float,
              labels: Optional[Dict[str, str]] = None,
              help: str = "") -> None:
        self._family(name, "gauge", help)["samples"].append(
            (dict(labels or {}), float(value)))

    def histogram(self, name: str, hist: Histogram,
                  labels: Optional[Dict[str, str]] = None,
                  help: str = "") -> None:
        self._family(name, "histogram", help)["samples"].append(
            (dict(labels or {}), hist))

    def render(self) -> str:
        """The whole registry as Prometheus exposition text."""
        lines: List[str] = []
        for name, fam in self._fams.items():
            if fam["help"]:
                lines.append(f"# HELP {name} {fam['help']}")
            lines.append(f"# TYPE {name} {fam['type']}")
            for labels, value in fam["samples"]:
                if fam["type"] == "histogram":
                    self._render_hist(lines, name, labels, value)
                else:
                    lines.append(
                        f"{name}{_labels_text(labels)} {_fmt(value)}")
        return "\n".join(lines) + "\n"

    @staticmethod
    def _render_hist(lines: List[str], name: str,
                     labels: Dict[str, str], h: Histogram) -> None:
        cum = 0
        for i in sorted(h.counts):
            cum += h.counts[i]
            le = _fmt(h.bucket_le(i))
            lines.append(f"{name}_bucket"
                         f"{_labels_text(labels, {'le': le})} {cum}")
        lines.append(f"{name}_bucket"
                     f"{_labels_text(labels, {'le': '+Inf'})} {h.count}")
        lines.append(f"{name}_sum{_labels_text(labels)} {_fmt(h.sum)}")
        lines.append(f"{name}_count{_labels_text(labels)} {h.count}")


def registry_from_metrics(named_metrics: Dict[str, object]
                          ) -> MetricsRegistry:
    """Build a scrape-time registry from ``{engine_id: EngineMetrics}``.

    Duck-typed over the ``EngineMetrics`` counter fields and the
    per-class TTFT/TBT histograms it maintains incrementally; works
    for any object carrying the same attributes (so telemetry never
    imports paged_kv — the import runs the other way)."""
    reg = MetricsRegistry()
    counters = (
        ("repro_requests_submitted_total", "submitted",
         "Requests accepted by submit()"),
        ("repro_requests_admitted_total", "admitted",
         "Requests placed on a seat"),
        ("repro_requests_completed_total", "completed",
         "Requests finished (eos or max_new_tokens)"),
        ("repro_tokens_prefill_total", "prefill_tokens",
         "Prompt tokens prefilled (replays included)"),
        ("repro_tokens_decode_total", "decode_tokens",
         "Decode tokens emitted"),
        ("repro_preemptions_total", "preemptions",
         "Requests preempted under page pressure"),
        ("repro_page_evictions_total", "evictions",
         "Reclaimable prefix-cache pages evicted"),
        ("repro_ticks_total", "ticks", "Engine ticks run"),
    )
    gauges = (
        ("repro_pages_in_use", "pages_in_use",
         "Pages referenced by live requests (last tick)"),
        ("repro_page_capacity", "page_capacity",
         "Usable KV pages in the pool"),
        ("repro_queue_depth", "queued", "Queued requests (last tick)"),
        ("repro_active_seats", "active", "Occupied seats (last tick)"),
    )
    for engine, m in named_metrics.items():
        lbl = {"engine": engine or "engine"}
        for name, field, help_ in counters:
            reg.counter(name, getattr(m, field, 0), lbl, help=help_)
        for name, field, help_ in gauges:
            reg.gauge(name, getattr(m, field, 0), lbl, help=help_)
        for cls, n in sorted(getattr(m, "completed_by_class",
                                     {}).items()):
            reg.counter("repro_class_completed_total", n,
                        {**lbl, "class": cls},
                        help="Completions per SLO class")
        for kind, misses in (("ttft", "deadline_misses_by_class"),
                             ("tbt", "tbt_misses_by_class")):
            for cls, n in sorted(getattr(m, misses, {}).items()):
                reg.counter("repro_slo_misses_total", n,
                            {**lbl, "class": cls, "kind": kind},
                            help="Deadline misses per class and kind "
                                 "(ttft|tbt)")
        for name, field, help_ in (
                ("repro_ttft_seconds", "ttft_hist_by_class",
                 "Time to first token (log-bucketed)"),
                ("repro_tbt_seconds", "tbt_hist_by_class",
                 "Time between decode tokens (log-bucketed)")):
            for cls, h in sorted(getattr(m, field, {}).items()):
                reg.histogram(name, h, {**lbl, "class": cls},
                              help=help_)
    return reg


def prometheus_text(named_metrics: Dict[str, object]) -> str:
    """One-call Prometheus exposition for ``{engine_id: metrics}``."""
    return registry_from_metrics(named_metrics).render()


class MetricsServer:
    """Background Prometheus scrape endpoint over ``http.server``.

    ``collect`` is a zero-arg callable returning exposition text; it
    runs on the server thread at scrape time, so the serving loop never
    pays for a scrape.  ``port=0`` binds an ephemeral port (tests)."""

    def __init__(self, collect: Callable[[], str], *, port: int = 0,
                 host: str = "127.0.0.1"):
        server = self

        class Handler(http.server.BaseHTTPRequestHandler):
            def do_GET(self):                     # noqa: N802 (stdlib API)
                if self.path.split("?")[0] not in ("/", "/metrics"):
                    self.send_error(404)
                    return
                try:
                    body = server.collect().encode()
                except Exception as e:            # surface, don't kill
                    self.send_error(500, str(e))
                    return
                self.send_response(200)
                self.send_header("Content-Type",
                                 "text/plain; version=0.0.4; "
                                 "charset=utf-8")
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, *args):         # silence per-request noise
                pass

        self.collect = collect
        self._httpd = http.server.ThreadingHTTPServer((host, port), Handler)
        self.port = self._httpd.server_address[1]
        self.url = f"http://{host}:{self.port}/metrics"
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, name="repro-metrics",
            daemon=True)
        self._thread.start()

    def close(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join(timeout=5)


# ---------------------------------------------------------------------------
# Tick-phase profiler
# ---------------------------------------------------------------------------

class TickProfiler:
    """Wall-time breakdown of the engine tick phases.

    Phases at the step level: ``admission``, ``prefill``, ``decode``,
    ``bookkeeping``; the fused decode tick refines its share into
    ``sync`` (device-mirror rebuild), ``dispatch`` (jitted-call
    enqueue), ``host`` (the blocking device→host token pull) and
    ``sample`` (host-side token acceptance).  Measured with
    ``time.perf_counter`` by the instrumented code — profiling is a
    wall-time tool and stays off under the virtual clock, where every
    phase would read as zero."""

    def __init__(self):
        self.totals: Dict[str, float] = {}
        self.calls: Dict[str, int] = {}
        self.ticks = 0

    def add(self, phase: str, dt: float) -> None:
        self.totals[phase] = self.totals.get(phase, 0.0) + dt
        self.calls[phase] = self.calls.get(phase, 0) + 1

    def note_tick(self) -> None:
        self.ticks += 1

    def snapshot(self) -> dict:
        """Per-phase totals, call counts, and share of profiled wall.

        The share denominator is the sum of *top-level* phases only
        (no ``/`` in the name): ``decode/dispatch`` etc. re-slice the
        wall already counted under ``decode``, so including them would
        double-count decode time and dilute every share."""
        wall = sum(t for p, t in self.totals.items() if "/" not in p) \
            or sum(self.totals.values()) or 1.0
        return {"ticks": self.ticks,
                "phases": {p: {"total_s": t,
                               "calls": self.calls[p],
                               "share": t / wall}
                           for p, t in sorted(self.totals.items())}}


# ---------------------------------------------------------------------------
# SLO burn-rate monitor
# ---------------------------------------------------------------------------

class BurnRateMonitor:
    """Sliding-window SLO miss rates per (class, kind).

    Every deadlined verdict — TTFT at first token, TBT per decode
    token — lands here with its injected-clock timestamp; entries
    strictly older than the window (``t <= now - window_s``, so an
    entry at exactly the boundary is out) are evicted on the next
    observation.  When a (class, kind) rate crosses ``threshold`` with
    at least ``min_samples`` in window, :meth:`observe` returns a
    ``fire`` transition exactly once (edge-triggered); dropping back
    returns one ``clear``.  The Telemetry facade turns transitions
    into ``slo_burn`` / ``slo_burn_clear`` warning events."""

    def __init__(self, *, window_s: float = 1.0, threshold: float = 0.5,
                 min_samples: int = 16):
        if window_s <= 0:
            raise ValueError(f"window_s must be > 0, got {window_s}")
        if not 0.0 <= threshold <= 1.0:
            raise ValueError(f"threshold must be in [0, 1], "
                             f"got {threshold}")
        self.window_s = window_s
        self.threshold = threshold
        self.min_samples = max(1, min_samples)
        self._entries: Deque[Tuple[float, Tuple[str, str], bool]] = deque()
        self._counts: Dict[Tuple[str, str], List[int]] = {}  # [n, missed]
        self._alert: Dict[Tuple[str, str], bool] = {}

    def _evict(self, now: float) -> None:
        cutoff = now - self.window_s
        entries = self._entries
        while entries and entries[0][0] <= cutoff:
            _, key, missed = entries.popleft()
            c = self._counts[key]
            c[0] -= 1
            if missed:
                c[1] -= 1

    def observe(self, now: float, priority: str, kind: str,
                missed: bool) -> Optional[dict]:
        """Record one deadlined verdict; returns an alert transition
        dict (``state`` = ``fire`` | ``clear``) or None."""
        self._evict(now)
        key = (priority, kind)
        c = self._counts.setdefault(key, [0, 0])
        c[0] += 1
        if missed:
            c[1] += 1
        self._entries.append((now, key, missed))
        n, bad = c
        rate = bad / n
        burning = n >= self.min_samples and rate > self.threshold
        if burning and not self._alert.get(key, False):
            self._alert[key] = True
            state = "fire"
        elif not burning and self._alert.get(key, False):
            self._alert[key] = False
            state = "clear"
        else:
            return None
        return {"state": state, "class": priority, "kind": kind,
                "miss_rate": rate, "samples": n,
                "window_s": self.window_s,
                "threshold": self.threshold}

    def rates(self, now: Optional[float] = None) -> Dict[str, dict]:
        """Current in-window rates keyed ``"class/kind"`` (evicting
        first when ``now`` is given)."""
        if now is not None:
            self._evict(now)
        return {f"{cls}/{kind}": {"samples": n, "missed": bad,
                                  "miss_rate": bad / n if n else 0.0}
                for (cls, kind), (n, bad) in sorted(self._counts.items())
                if n}


# ---------------------------------------------------------------------------
# Telemetry facade
# ---------------------------------------------------------------------------

class Telemetry:
    """One observability context shared by an engine or a whole fleet.

    Pass one instance as the ``telemetry=`` argument of
    :class:`~repro.runtime.serving.Scheduler` (or either engine
    façade), :class:`~repro.runtime.router.ModelFleet`, or
    :func:`~repro.runtime.workload.oracle_fleet`.  ``None`` (the
    default everywhere) keeps the stack on the zero-overhead path."""

    def __init__(self, *, ring: int = 4096, profile: bool = False,
                 burn_window_s: float = 1.0,
                 burn_threshold: float = 0.5,
                 burn_min_samples: int = 16,
                 heartbeat_every: int = 64,
                 postmortem_path: Optional[str] = None):
        self.recorder = FlightRecorder(ring)
        self.profiler: Optional[TickProfiler] = (
            TickProfiler() if profile else None)
        self.burn = BurnRateMonitor(window_s=burn_window_s,
                                    threshold=burn_threshold,
                                    min_samples=burn_min_samples)
        if heartbeat_every < 1:
            raise ValueError(f"heartbeat_every must be >= 1, "
                             f"got {heartbeat_every}")
        self.heartbeat_every = heartbeat_every
        self.postmortem_path = postmortem_path
        self.last_postmortem: Optional[dict] = None

    # -- hot path (declared hot in analysis/hotpaths.toml) -------------------

    def emit(self, tick: int, t: float, engine: str, rid: int,
             kind: str, attrs: Optional[dict] = None) -> None:
        """Record one structured event (pure host bookkeeping: a
        NamedTuple build and a ring append — no device access, ever)."""
        self.recorder.append(TraceEvent(tick, t, engine, rid, kind, attrs))

    def observe_slo(self, now: float, tick: int, engine: str,
                    priority: str, kind: str, missed: bool) -> None:
        """Feed one deadlined TTFT/TBT verdict to the burn monitor,
        emitting an ``slo_burn``/``slo_burn_clear`` event on an alert
        transition."""
        transition = self.burn.observe(now, priority, kind, missed)
        if transition is not None:
            state = transition.pop("state")
            kind_ev = "slo_burn" if state == "fire" else "slo_burn_clear"
            self.recorder.append(
                TraceEvent(tick, now, engine, -1, kind_ev, transition))

    # -- cold path -----------------------------------------------------------

    def events(self) -> List[TraceEvent]:
        return self.recorder.events()

    def postmortem(self, reason: str, *,
                   engines: Optional[Dict[str, object]] = None,
                   budget: Optional[dict] = None,
                   extra: Optional[dict] = None) -> dict:
        """Build (and remember) a postmortem dict: the ring's last N
        events plus a full state snapshot of every named engine
        (queue, seats, BlockManager partition) and the HostBudget
        grants."""
        pm = {"reason": reason,
              "flight_recorder": self.recorder.snapshot(),
              "engines": {name: scheduler_state(eng)
                          for name, eng in (engines or {}).items()}}
        if budget is not None:
            pm["budget"] = budget
        if self.profiler is not None:
            pm["profile"] = self.profiler.snapshot()
        burn = self.burn.rates()
        if burn:
            pm["slo_burn_rates"] = burn
        if extra:
            pm["extra"] = extra
        self.last_postmortem = pm
        return pm

    def write_postmortem(self, reason: str, *,
                         engines: Optional[Dict[str, object]] = None,
                         budget: Optional[dict] = None,
                         extra: Optional[dict] = None,
                         path: Optional[str] = None) -> Optional[str]:
        """Build a postmortem and write it as JSON to ``path`` (falling
        back to ``postmortem_path``); returns the path written, or None
        when neither is set (the dict still lands in
        ``last_postmortem``)."""
        pm = self.postmortem(reason, engines=engines, budget=budget,
                             extra=extra)
        path = path if path is not None else self.postmortem_path
        if path is None:
            return None
        with open(path, "w") as f:
            json.dump(pm, f, indent=1, default=str)
        return path


# ---------------------------------------------------------------------------
# Engine-state snapshots (postmortem ingredients, duck-typed)
# ---------------------------------------------------------------------------

def request_state(req) -> dict:
    """JSON-safe snapshot of one request's scheduler-owned state."""
    return {"rid": getattr(req, "rid", -1),
            "priority": getattr(req, "priority", None),
            "prompt_tokens": len(getattr(req, "prompt", ())),
            "generated": len(getattr(req, "generated", ())),
            "max_new_tokens": getattr(req, "max_new_tokens", None),
            "slot": getattr(req, "slot", None),
            "pages": [int(p) for p in getattr(req, "pages", [])],
            "prefill_pos": getattr(req, "prefill_pos", 0),
            "cached_tokens": getattr(req, "cached_tokens", 0),
            "times_preempted": getattr(req, "times_preempted", 0),
            "deadline_ms": getattr(req, "deadline_ms", None),
            "tbt_deadline_ms": getattr(req, "tbt_deadline_ms", None)}


def block_manager_state(bm) -> dict:
    """Snapshot of a ``BlockManager``'s page partition: live refcounts,
    the free list, the reclaimable LRU list, and whether the three sets
    still partition pages ``1..capacity`` (the structural invariant the
    load harness checks — a postmortem that fails ``partition_ok``
    names the corruption directly)."""
    live = {int(p): int(n) for p, n in getattr(bm, "_ref", {}).items()}
    free = sorted(int(p) for p in getattr(bm, "_free", []))
    reclaimable = sorted(int(p) for p in getattr(bm, "_reclaim", {}))
    capacity = int(getattr(bm, "capacity", 0))
    sets = [set(live), set(free), set(reclaimable)]
    disjoint = sum(len(s) for s in sets) == len(set().union(*sets))
    partition_ok = (disjoint and set().union(*sets)
                    == set(range(1, capacity + 1)))
    return {"capacity": capacity,
            "page_size": int(getattr(bm, "page_size", 0)),
            "in_use": int(getattr(bm, "in_use", 0)),
            "live_refcounts": {str(p): n
                               for p, n in sorted(live.items())},
            "free": free, "reclaimable": reclaimable,
            "evictions": int(getattr(bm, "evictions", 0)),
            "partition_ok": bool(partition_ok)}


def scheduler_state(sched) -> dict:
    """Full engine snapshot for a postmortem: tick, queue, seats, and
    the policy's BlockManager partition when it has one.  Duck-typed —
    works for a bare Scheduler, either engine façade, or the oracle
    policy."""
    queue = [request_state(r) for r in getattr(sched, "queue", [])]
    seats = {str(s): request_state(r)
             for s, r in sorted(getattr(sched, "seats", {}).items())}
    out = {"engine": getattr(sched, "engine_id", ""),
           "tick": getattr(sched, "_tick", 0),
           "queued": len(queue), "active": len(seats),
           "queue": queue, "seats": seats}
    bm = getattr(getattr(sched, "policy", None), "bm", None)
    if bm is not None:
        out["block_manager"] = block_manager_state(bm)
    return out


# ---------------------------------------------------------------------------
# Span timelines + Chrome trace-event (Perfetto) export
# ---------------------------------------------------------------------------

#: Reserved thread id for the per-engine queue track; seats map to
#: ``seat + 1`` so seat 0 keeps its own track.
QUEUE_TID = 0

_INSTANT_KINDS = ("preempt", "deadline_miss", "tbt_miss", "prefix_hit",
                  "slo_burn", "slo_burn_clear")


def build_spans(events: Iterable[TraceEvent]) -> dict:
    """Reduce a trace-event stream to per-request span timelines.

    Returns ``{"spans", "instants", "counters"}``:

    - spans: ``{engine, rid, seat, name, t0, t1}`` with names
      ``queued`` (submit/preempt → admit, on the queue track),
      ``prefill`` (admit → first token), ``replay`` (a re-admission's
      prefill — the request was preempted before, so its TTFT token
      already exists and decode resumes directly), and ``decode``
      (first/resumed token → finish or preempt).
    - instants: point events (preempt, deadline_miss, tbt_miss,
      prefix_hit, slo_burn...).
    - counters: ``fleet_tick`` heartbeat samples (queue depth, active
      seats, pages) for Perfetto counter tracks.

    The reducer is forgiving: a ring that dropped a request's early
    events simply yields that request's later spans only."""
    spans: List[dict] = []
    instants: List[dict] = []
    counters: List[dict] = []
    # (engine, rid) -> mutable request cursor
    state: Dict[Tuple[str, int], dict] = {}
    last_t = 0.0

    def cursor(ev: TraceEvent) -> dict:
        return state.setdefault((ev.engine, ev.rid), {
            "seat": None, "queue_t0": None, "phase": None,
            "t0": None, "preempted": 0})

    def close(ev: TraceEvent, cur: dict, t1: float,
              next_phase: Optional[str]) -> None:
        if cur["phase"] is not None and cur["t0"] is not None:
            spans.append({"engine": ev.engine, "rid": ev.rid,
                          "seat": cur["seat"], "name": cur["phase"],
                          "t0": cur["t0"], "t1": t1})
        cur["phase"], cur["t0"] = next_phase, (
            t1 if next_phase is not None else None)

    for ev in events:
        last_t = max(last_t, ev.t)
        if ev.kind == "fleet_tick":
            counters.append({"engine": ev.engine, "t": ev.t,
                             "attrs": ev.attrs or {}})
            continue
        if ev.kind in _INSTANT_KINDS or ev.rid < 0:
            if ev.kind in _INSTANT_KINDS:
                cur = state.get((ev.engine, ev.rid))
                instants.append({
                    "engine": ev.engine, "rid": ev.rid, "kind": ev.kind,
                    "seat": cur["seat"] if cur else None, "t": ev.t,
                    "attrs": ev.attrs})
            if ev.kind != "preempt":
                continue                      # preempt also edits spans
        cur = cursor(ev)
        if ev.kind == "submit":
            cur["queue_t0"] = ev.t
        elif ev.kind == "admit":
            if ev.attrs and "seat" in ev.attrs:
                cur["seat"] = ev.attrs["seat"]
            if cur["queue_t0"] is not None:
                spans.append({"engine": ev.engine, "rid": ev.rid,
                              "seat": None, "name": "queued",
                              "t0": cur["queue_t0"], "t1": ev.t})
                cur["queue_t0"] = None
            close(ev, cur, ev.t,
                  "replay" if cur["preempted"] else "prefill")
        elif ev.kind == "first_token":
            close(ev, cur, ev.t, "decode")
        elif ev.kind == "decode":
            if cur["phase"] in ("prefill", "replay"):
                # replay path: no second first_token — decode resumes
                # straight after the re-prefill completes
                close(ev, cur, ev.t, "decode")
        elif ev.kind == "preempt":
            close(ev, cur, ev.t, None)
            cur["preempted"] += 1
            cur["queue_t0"] = ev.t
            cur["seat"] = None
        elif ev.kind == "finish":
            close(ev, cur, ev.t, None)
    # requests still open when the stream ends (mid-run export): close
    # their spans at the last seen timestamp so the timeline renders
    for (engine, rid), cur in state.items():
        if cur["phase"] is not None and cur["t0"] is not None:
            spans.append({"engine": engine, "rid": rid,
                          "seat": cur["seat"], "name": cur["phase"],
                          "t0": cur["t0"], "t1": last_t})
    return {"spans": spans, "instants": instants, "counters": counters}


def perfetto_trace(events: Iterable[TraceEvent]) -> dict:
    """Chrome trace-event JSON (the format Perfetto's legacy importer
    and chrome://tracing read): one process per engine, one thread per
    engine seat plus a ``queue`` track, ``X`` complete events for
    spans, ``i`` instants for point events, ``C`` counters from the
    fleet heartbeat.  Timestamps are microseconds of injected-clock
    time."""
    reduced = build_spans(events)
    engines = sorted({s["engine"] for s in reduced["spans"]}
                     | {i["engine"] for i in reduced["instants"]}
                     | {c["engine"] for c in reduced["counters"]})
    pid_of = {e: p for p, e in enumerate(engines, start=1)}
    out: List[dict] = []
    for engine, pid in pid_of.items():
        out.append({"ph": "M", "name": "process_name", "pid": pid,
                    "tid": 0,
                    "args": {"name": engine or "engine"}})
        out.append({"ph": "M", "name": "thread_name", "pid": pid,
                    "tid": QUEUE_TID, "args": {"name": "queue"}})
    named_tids = {(pid, QUEUE_TID) for pid in pid_of.values()}

    def tid_for(engine: str, seat) -> int:
        pid = pid_of[engine]
        tid = QUEUE_TID if seat is None else int(seat) + 1
        if tid != QUEUE_TID and (pid, tid) not in named_tids:
            named_tids.add((pid, tid))
            out.append({"ph": "M", "name": "thread_name", "pid": pid,
                        "tid": tid, "args": {"name": f"seat {seat}"}})
        return tid

    for s in reduced["spans"]:
        out.append({"ph": "X", "name": s["name"], "cat": "serving",
                    "pid": pid_of[s["engine"]],
                    "tid": tid_for(s["engine"], s["seat"]),
                    "ts": s["t0"] * 1e6,
                    "dur": max(0.0, (s["t1"] - s["t0"]) * 1e6),
                    "args": {"rid": s["rid"]}})
    for i in reduced["instants"]:
        args = {"rid": i["rid"]}
        if i["attrs"]:
            args.update(i["attrs"])
        out.append({"ph": "i", "name": i["kind"], "cat": "serving",
                    "pid": pid_of[i["engine"]],
                    "tid": tid_for(i["engine"], i["seat"]),
                    "ts": i["t"] * 1e6, "s": "t", "args": args})
    for c in reduced["counters"]:
        attrs = {k: v for k, v in c["attrs"].items()
                 if isinstance(v, (int, float))}
        if attrs:
            out.append({"ph": "C", "name": "load", "cat": "serving",
                        "pid": pid_of[c["engine"]], "tid": QUEUE_TID,
                        "ts": c["t"] * 1e6, "args": attrs})
    return {"traceEvents": out, "displayTimeUnit": "ms"}


def validate_chrome_trace(doc) -> List[str]:
    """Schema errors for a Chrome trace-event JSON object (empty list =
    valid).  Checks the subset :func:`perfetto_trace` emits — the
    contract tests/test_telemetry.py holds the exporter to."""
    errors: List[str] = []
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        return ["document must be an object with a 'traceEvents' array"]
    evs = doc["traceEvents"]
    if not isinstance(evs, list):
        return ["'traceEvents' must be an array"]
    for n, ev in enumerate(evs):
        where = f"traceEvents[{n}]"
        if not isinstance(ev, dict):
            errors.append(f"{where}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in ("X", "i", "I", "C", "M", "B", "E"):
            errors.append(f"{where}: unknown phase {ph!r}")
            continue
        if not isinstance(ev.get("name"), str) or not ev["name"]:
            errors.append(f"{where}: missing event name")
        for key in ("pid", "tid"):
            if not isinstance(ev.get(key), int):
                errors.append(f"{where}: {key} must be an int")
        if ph != "M":
            ts = ev.get("ts")
            if not isinstance(ts, (int, float)):
                errors.append(f"{where}: ts must be a number")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errors.append(f"{where}: X event needs dur >= 0")
        if ph in ("i", "I") and ev.get("s") not in (None, "t", "p", "g"):
            errors.append(f"{where}: instant scope must be t|p|g")
        if ph == "C" and not isinstance(ev.get("args"), dict):
            errors.append(f"{where}: C event needs numeric args")
    return errors


def write_perfetto(path: str, events: Iterable[TraceEvent]) -> str:
    """Export ``events`` as Chrome trace-event JSON at ``path``."""
    with open(path, "w") as f:
        json.dump(perfetto_trace(events), f)
    return path
