"""Multi-model fleet router: N serving engines behind one submit() API.

One process, one shared host, several models — the operating reality of
a managed GPU cluster where many LLM workloads of different shapes
coexist on the same bare-metal hosts.  A :class:`ModelFleet` owns a set
of :class:`~repro.runtime.serving.PagedServingEngine` instances —
possibly different architectures, each with its own params and KV page
pool — and routes requests to them behind a single
``submit(model=..., prompt=...)`` call plus an outer tick loop that
interleaves ``engine.step()`` across the fleet::

    submit(model, prompt, priority, deadline_ms, session_id)
         │
         ▼
    ReplicaGroup[model] ── session affinity ──► home replica
         │ (no session / first turn)
         ▼
    replica selection          LeastLoaded (default) | RoundRobin
         │
         ▼
    engine.submit(prompt, rid=<fleet-global rid>)
         │                         │
         ▼                         ▼
    ModelFleet.step()        HostBudget — shared page budget:
      engine.step() per        per-model floors, surplus
      engine with work         redistributed at admission time

The three load-bearing properties:

**rid namespacing.**  The fleet assigns every request's rid from one
fleet-global counter, so each engine's rid set is a disjoint slice of
one monotonic sequence and sampler keys ``(seed, rid, step)`` never
collide across the fleet — two engines serving same-seed stochastic
requests produce independent streams.  Because the rid is fixed at
submit time (before and independent of routing), a routed request's
token stream is bit-identical to the same request submitted to a
dedicated solo engine with the same explicit rid: routing decides
*where* a request runs, never *which* tokens it produces
(tests/test_router.py fuzzes this against random routing schedules).

**shared host budget.**  All engines' page pools answer to one
:class:`HostBudget`: each model guarantees itself ``floor`` pages
(default: enough for one max-length request, so preempt-and-recompute
always converges), and the remaining surplus is granted to whichever
engine asks first, re-evaluated at every admission and growth attempt
(``BlockManager.can_alloc`` consults the budget; freeing pages in one
engine invalidates its siblings' admission caches so their starved
heads re-attempt).  A busy model borrows an idle model's headroom and
hands it back under pressure — no static partitioning decision.

**session affinity.**  A ``session_id``'s follow-up turns route to the
replica that served its earlier turns, where the session's prompt
pages are still registered in that replica's prefix index — the
multi-turn prefix hit is only warm on the home replica.

Fleet-level observability aggregates per-replica
:class:`~repro.runtime.paged_kv.EngineMetrics` via
``EngineMetrics.merged``: per-model tokens/s, TTFT percentiles,
prefix-hit rate, preemptions and SLO-class breakdowns, surfaced through
``launch/serve.py --fleet`` and benchmark workload 5
(``benchmarks/serving_paged.py``).  See docs/serving.md §"Multi-model
fleet".
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core import mixed_precision
from repro.models import model as M
from repro.parallel.sharding import LogicalRules, SINGLE_DEVICE_RULES
from repro.runtime.paged_kv import BlockManager, EngineMetrics
from repro.runtime.sampler import Sampler, SamplingParams
from repro.runtime.serving import (DEFAULT_PRIORITY, PagedServingEngine,
                                   Request, SchedulerStallError,
                                   priority_level)


class HostBudget:
    """One total figure carved across engines: floors + surplus.

    Each registered :class:`BlockManager` is guaranteed ``floor`` live
    pages; the surplus belongs to no engine and is granted on demand:
    an engine may hold its floor plus whatever surplus its siblings
    have not borrowed at any instant.  The grant is re-evaluated at
    every allocation (:meth:`allows` is called from
    ``BlockManager.can_alloc``), so the split between models tracks
    the live load instead of a static partition — *surplus
    redistribution at admission time*.

    The budget is denominated in BYTES, not pages, when engines differ
    in KV precision: ``total_pages`` is interpreted as pages of
    ``page_bytes`` bytes each (the reference page — by convention the
    fleet's most expensive page), and each registered manager's live
    pages are weighted by its own ``BlockManager.page_bytes``.  An fp8
    engine whose pages cost a quarter of an f32 engine's can therefore
    borrow ~4× as many pages from the same surplus — byte-for-byte
    fairness across precisions.  With the default ``page_bytes=1`` on
    both the budget and every manager, all the arithmetic collapses to
    plain page counting (the single-precision behavior, unchanged).

    Reclaimable prefix-cache pages do not count against the budget:
    they are evictable at will by their own engine, so only *live*
    (referenced) pages represent un-reclaimable host commitment.

    Freeing pages in one engine must un-starve queued admissions in the
    others, so any registered manager's state change bumps its
    siblings' ``version`` counters (:meth:`invalidate`) — the paged
    admission path caches failed attempts against that counter.
    """

    def __init__(self, total_pages: int, *, page_bytes: int = 1):
        if total_pages < 1:
            raise ValueError(f"total_pages must be >= 1, got {total_pages}")
        if page_bytes < 1:
            raise ValueError(f"page_bytes must be >= 1, got {page_bytes}")
        self.total = total_pages
        self.page_bytes = page_bytes
        self.total_bytes = total_pages * page_bytes
        self._floors: Dict[object, int] = {}
        self._managers: Dict[object, BlockManager] = {}

    def _floor_bytes(self) -> int:
        return sum(f * self._managers[k].page_bytes
                   for k, f in self._floors.items())

    @property
    def surplus_bytes(self) -> int:
        """Bytes beyond the floors, shared on demand."""
        return self.total_bytes - self._floor_bytes()

    @property
    def surplus(self) -> int:
        """The surplus in reference pages (``surplus_bytes`` at
        ``page_bytes`` per page) — equals ``total - sum(floors)`` when
        every engine shares the budget's page cost."""
        return self.surplus_bytes // self.page_bytes

    def register(self, key, bm: BlockManager, floor: int) -> None:
        """Put ``bm`` under this budget with a guaranteed ``floor`` (in
        ``bm``'s own pages).

        Raises:
          ValueError: duplicate key, non-positive floor, or floors
              exceeding the total (the surplus must stay >= 0)."""
        if key in self._managers:
            raise ValueError(f"budget key {key!r} already registered")
        if floor < 1:
            raise ValueError(f"floor must be >= 1, got {floor} for {key!r}")
        if self._floor_bytes() + floor * bm.page_bytes > self.total_bytes:
            raise ValueError(
                f"floors exceed the host budget: registering {key!r} with "
                f"floor {floor} ({floor * bm.page_bytes} bytes) on top of "
                f"{self._floor_bytes()} already-guaranteed bytes > total "
                f"{self.total_bytes}")
        bm.attach_budget(self, key)     # raises first: a rejected manager
        self._floors[key] = floor       # must leave this budget untouched
        self._managers[key] = bm

    def borrowed(self, key) -> int:
        """Live pages ``key`` currently holds beyond its floor (in its
        own pages)."""
        return max(0, self._managers[key].in_use - self._floors[key])

    def borrowed_bytes(self, key) -> int:
        """Bytes ``key`` currently holds beyond its floor."""
        return self.borrowed(key) * self._managers[key].page_bytes

    def allows(self, key, n: int) -> bool:
        """Whether engine ``key`` may take ``n`` more live pages now:
        its post-alloc overshoot past its floor, plus what the other
        engines have already borrowed, must fit in the surplus — all
        weighted by each engine's own page cost in bytes."""
        bm = self._managers[key]
        over = max(0, bm.in_use + n - self._floors[key]) * bm.page_bytes
        others = sum(self.borrowed_bytes(k)
                     for k in self._managers if k != key)
        return over + others <= self.surplus_bytes

    def invalidate(self, source: BlockManager) -> None:
        """Bump every *other* registered manager's version: pages freed
        (or taken) in ``source`` change what their next admission
        attempt could get, so cached failed attempts must retry."""
        for bm in self._managers.values():
            if bm is not source:
                bm.version += 1

    def usage(self) -> Dict[str, object]:
        """Budget accounting snapshot: total / surplus (pages and
        bytes) plus per-engine floor, live pages, borrowed-beyond-floor
        counts and byte footprints."""
        return {
            "total_pages": self.total,
            "surplus_pages": self.surplus,
            "total_bytes": self.total_bytes,
            "surplus_bytes": self.surplus_bytes,
            "engines": {
                str(k): {"floor": self._floors[k],
                         "in_use": self._managers[k].in_use,
                         "borrowed": self.borrowed(k),
                         "page_bytes": self._managers[k].page_bytes,
                         "bytes_in_use": self._managers[k].bytes_in_use,
                         "borrowed_bytes": self.borrowed_bytes(k)}
                for k in sorted(self._managers, key=str)},
        }


@dataclasses.dataclass
class FleetModel:
    """One model's entry in a :class:`ModelFleet`.

    name: routing key (the registry arch id by convention).
    cfg: the model's (usually reduced) ModelConfig.
    params: the model's parameter pytree — shared read-only across the
        model's replicas (JAX arrays are immutable).
    replicas: engine count for this model (>= 1); each replica gets its
        own KV page pool and prefix index.
    floor: guaranteed live pages per replica under the shared
        :class:`HostBudget`; None = enough pages for one max-length
        request (the minimum that keeps preempt-and-recompute
        convergent).
    kv_dtype: KV pool storage precision per replica — None (compute
        dtype everywhere), one dtype name for all replicas, or a
        per-replica sequence (e.g. ``["f32", "fp8"]``: one
        full-precision replica for precision-floored classes, one
        quantized replica holding ~4× the tokens per byte)."""
    name: str
    cfg: object
    params: object
    replicas: int = 1
    floor: Optional[int] = None
    kv_dtype: object = None         # None | str | Sequence[Optional[str]]

    def replica_dtypes(self) -> List[Optional[str]]:
        """Per-replica kv_dtype list, length ``replicas``.

        Raises:
          ValueError: a per-replica sequence of the wrong length."""
        if self.kv_dtype is None or isinstance(self.kv_dtype, str):
            return [self.kv_dtype] * self.replicas
        dts = list(self.kv_dtype)
        if len(dts) != self.replicas:
            raise ValueError(
                f"model {self.name!r}: kv_dtype sequence has {len(dts)} "
                f"entries for {self.replicas} replicas")
        return dts


@dataclasses.dataclass
class ReplicaGroup:
    """A model's replicas inside the fleet (internal)."""
    name: str
    cfg: object
    engines: List[PagedServingEngine]
    floor: int


class LeastLoaded:
    """Default replica selection: fewest (active + queued) requests
    first, then fewest live pages, then lowest replica index — new work
    lands on the replica with the most immediate headroom."""

    name = "least-loaded"

    def select(self, group: ReplicaGroup) -> int:
        """Index of the least-loaded replica in ``group``."""
        return min(
            range(len(group.engines)),
            key=lambda i: (len(group.engines[i].seats)
                           + len(group.engines[i].queue),
                           group.engines[i].policy.pages_in_use(), i))


class RoundRobin:
    """Alternative replica selection: strict rotation per model,
    ignoring load — useful as a predictable baseline and for tests."""

    name = "round-robin"

    def __init__(self):
        self._next: Dict[str, int] = {}

    def select(self, group: ReplicaGroup) -> int:
        """Next replica index in rotation for ``group``."""
        i = self._next.get(group.name, 0) % len(group.engines)
        self._next[group.name] = i + 1
        return i


class SLOAware:
    """Fleet-aware SLO placement: premium backlog depth leads the
    selection key, so new work steers away from replicas where
    premium requests are already waiting — total load and page
    pressure only break ties.

    :class:`LeastLoaded` counts *requests* and treats a replica with
    five queued batch jobs as busier than one with four queued premium
    jobs, even though the premium queue is where TTFT/TBT deadlines go
    to die.  This policy orders replicas by (queued premium-class
    requests, active + queued total, live pages, index): a standard or
    batch request avoids deepening a premium hot spot, and a premium
    request lands where it will see the shortest premium line.  With no
    premium traffic anywhere the first key is uniformly 0 and the
    policy degenerates to exactly :class:`LeastLoaded`."""

    name = "slo-aware"

    @staticmethod
    def premium_depth(eng: PagedServingEngine) -> int:
        """Queued top-class (premium) requests on ``eng``."""
        return sum(1 for r in eng.queue if priority_level(r) == 0)

    def select(self, group: ReplicaGroup) -> int:
        """Index of the replica with the shallowest premium backlog."""
        return min(
            range(len(group.engines)),
            key=lambda i: (self.premium_depth(group.engines[i]),
                           len(group.engines[i].seats)
                           + len(group.engines[i].queue),
                           group.engines[i].policy.pages_in_use(), i))


def _make_selection(selection):
    """Resolve a selection spec — ``"least-loaded"``, ``"round-robin"``,
    ``"slo-aware"`` or an object with ``select(group) -> int`` — into a
    policy."""
    if isinstance(selection, str):
        if selection == "least-loaded":
            return LeastLoaded()
        if selection == "round-robin":
            return RoundRobin()
        if selection == "slo-aware":
            return SLOAware()
        raise ValueError(f"unknown replica selection {selection!r}; "
                         "expected 'least-loaded', 'round-robin' or "
                         "'slo-aware'")
    if not hasattr(selection, "select"):
        raise TypeError(f"selection policy {selection!r} has no select()")
    return selection


class ModelFleet:
    """N paged serving engines — several models, optional replicas —
    behind one submit() API, one shared host page budget, and one outer
    tick loop (see module docstring).

    Every replica's physical pool is sized ``floor + surplus`` usable
    pages so it can absorb the whole surplus when its siblings are
    idle; the :class:`HostBudget` keeps the *live* total across the
    fleet within ``total_pages``.  Engine knobs (``page_size``,
    ``max_seats``, ``max_seq_len``, ``prefill_chunk``, sampling,
    admission) apply fleet-wide.
    """

    default_max_ticks = 100_000

    def __init__(self, models: Sequence[FleetModel], *,
                 total_pages: int, page_size: int = 16,
                 max_seats: int = 8, max_seq_len: int = 256,
                 prefill_chunk: int = 32, selection="least-loaded",
                 rules: LogicalRules = SINGLE_DEVICE_RULES,
                 opts: Optional[M.RunOptions] = None,
                 sampler: Optional[Sampler] = None,
                 prefix_cache: bool = True, lazy_pages: bool = True,
                 watermark: float = 0.05, admission="fcfs",
                 aging_ticks: int = 64,
                 class_precision: Optional[Dict[str, str]] = None,
                 clock=None, record_trace: bool = True,
                 telemetry=None, policy_cls: Optional[type] = None):
        """Build one engine per (model, replica) and carve the budget.

        Args:
          models: :class:`FleetModel` entries; names must be unique and
              every cfg must support the paged KV layout.
          total_pages: the host's total live-page budget, shared across
              every engine in the fleet.  When replicas differ in KV
              precision the budget is denominated in bytes — a
              ``total_pages`` figure of the fleet's most expensive page
              kind — and cheaper (quantized) pages draw
              proportionally less from it (see :class:`HostBudget`).
          selection: replica selection policy — ``"least-loaded"``
              (default), ``"round-robin"``, ``"slo-aware"``, or an
              object with ``select(group) -> int``.
          class_precision: SLO-class → minimum KV precision map applied
              fleet-wide (e.g. ``{"premium": "f32"}``); routing only
              considers replicas whose pool meets the class's floor,
              and every engine enforces the same floor at submit.
          clock: zero-arg time source shared by every engine (None =
              wall time); the load harness injects a virtual clock.
          record_trace: keep per-engine event traces (default); the
              load harness disables them to bound memory at 10⁵⁻⁶
              requests.
          telemetry: one shared
              :class:`~repro.runtime.telemetry.Telemetry` for the whole
              fleet — every engine emits into its flight recorder with
              its ``"model/replica"`` engine id, the router's outer
              loop adds stride-gated ``fleet_tick`` heartbeat events
              (queue depth / active seats / pages per engine), and a
              fleet stall dumps a postmortem covering every engine
              plus the :class:`HostBudget` grants.  None = off.
          policy_cls: placement-policy class per engine (None =
              :class:`~repro.runtime.serving.PagedPolicy`); the load
              harness passes ``workload.OraclePolicy``.
          (remaining args: per-engine knobs, as on
              :class:`PagedServingEngine`.)

        Raises:
          ValueError: no models, duplicate names, replicas < 1, a floor
              too small to hold one max-length request, floors that
              exceed ``total_pages``, or a ``class_precision`` floor no
              replica of some model can meet (the class would be
              unroutable there).
        """
        if not models:
            raise ValueError("a fleet needs at least one model")
        names = [m.name for m in models]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate model names in fleet: {names}")
        n_tables = max(1, -(-max_seq_len // page_size))
        floors: List[Tuple[FleetModel, int]] = []
        for fm in models:
            if fm.replicas < 1:
                raise ValueError(
                    f"model {fm.name!r}: replicas must be >= 1, "
                    f"got {fm.replicas}")
            floor = n_tables if fm.floor is None else fm.floor
            if floor < n_tables:
                raise ValueError(
                    f"model {fm.name!r}: floor {floor} pages cannot hold "
                    f"one max-length request ({n_tables} pages at "
                    f"max_seq_len={max_seq_len}, page_size={page_size}); "
                    "preempt-and-recompute could never converge")
            floors.append((fm, floor))
        total_floor = sum(f * fm.replicas for fm, f in floors)
        if total_floor > total_pages:
            raise ValueError(
                f"per-model floors need {total_floor} pages > "
                f"total_pages={total_pages}; raise the budget or lower "
                "replicas/floors")

        # byte-denominate the budget against the fleet's most expensive
        # page: a uniform-precision fleet collapses to page counting,
        # while quantized replicas' cheaper pages draw proportionally
        # less, so the same surplus grants them ~4x the pages
        page_costs = {
            (fm.name, i): M.paged_page_bytes(fm.cfg, page_size, dt)
            for fm, _ in floors
            for i, dt in enumerate(fm.replica_dtypes())}
        ref_bytes = max(page_costs.values())
        self.budget = HostBudget(total_pages, page_bytes=ref_bytes)
        self.page_size = page_size
        self.max_seq_len = max_seq_len
        self.selection = _make_selection(selection)
        self.class_precision = dict(class_precision or {})
        self._groups: Dict[str, ReplicaGroup] = {}
        self._sessions: Dict[Tuple[str, str], int] = {}
        self._routes: Dict[int, Tuple[str, int]] = {}   # rid -> (model, idx)
        self._next_rid = 0
        self._tick = 0
        self.telemetry = telemetry
        surplus_bytes = (total_pages - total_floor) * ref_bytes
        for fm, floor in floors:
            engines = []
            for i, dt in enumerate(fm.replica_dtypes()):
                # physical pool big enough to absorb the whole surplus
                # at THIS replica's page cost (cheap pages -> more of
                # them); the budget caps the live total in bytes
                surplus_i = surplus_bytes // page_costs[(fm.name, i)]
                eng = PagedServingEngine(
                    fm.cfg, fm.params, page_size=page_size,
                    num_pages=floor + surplus_i + 1,   # +1: scratch page
                    max_seats=max_seats, max_seq_len=max_seq_len,
                    prefill_chunk=prefill_chunk, rules=rules, opts=opts,
                    sampler=sampler, prefix_cache=prefix_cache,
                    lazy_pages=lazy_pages, watermark=watermark,
                    admission=admission, aging_ticks=aging_ticks,
                    kv_dtype=dt, class_precision=self.class_precision,
                    clock=clock, record_trace=record_trace,
                    telemetry=telemetry, policy_cls=policy_cls)
                eng.engine_id = f"{fm.name}/{i}"
                self.budget.register((fm.name, i), eng.bm, floor)
                engines.append(eng)
            group = ReplicaGroup(fm.name, fm.cfg, engines, floor)
            for cls, want in self.class_precision.items():
                if not any(self._replica_meets(eng, want)
                           for eng in engines):
                    raise ValueError(
                        f"class_precision requires {want} for class "
                        f"{cls!r} but no replica of model {fm.name!r} "
                        f"stores KV at >= {want}; add a full-precision "
                        "replica or drop the floor")
            self._groups[fm.name] = group

    @staticmethod
    def _replica_meets(eng: PagedServingEngine, want: Optional[str]) -> bool:
        """Whether ``eng``'s pool meets the precision floor ``want``."""
        if want is None:
            return True
        return (mixed_precision.kv_precision_bits(eng.kv_dtype)
                >= mixed_precision.kv_precision_bits(want))

    # -- routing ---------------------------------------------------------------

    @property
    def models(self) -> List[str]:
        """Routing keys, registration order."""
        return list(self._groups)

    def group(self, model: str) -> ReplicaGroup:
        """The replica group serving ``model``.

        Raises:
          ValueError: unknown model name."""
        try:
            return self._groups[model]
        except KeyError:
            raise ValueError(f"unknown model {model!r}; fleet serves "
                             f"{sorted(self._groups)}") from None

    def home_replica(self, model: str, session_id: str) -> Optional[int]:
        """The replica index ``session_id`` is pinned to, or None when
        the session has not been seen on ``model``."""
        self.group(model)
        return self._sessions.get((model, session_id))

    def submit(self, *, model: str, prompt, max_new_tokens: int = 16,
               eos_id: Optional[int] = None,
               sampling: Optional[SamplingParams] = None,
               priority: str = DEFAULT_PRIORITY,
               deadline_ms: Optional[float] = None,
               tbt_deadline_ms: Optional[float] = None,
               session_id: Optional[str] = None) -> int:
        """Route one request to a replica of ``model``; returns its
        fleet-global rid.

        A ``session_id``'s first turn picks a replica via the selection
        policy and pins the session to it; follow-up turns go to that
        home replica, where the session's earlier prompt pages are
        still registered in the prefix index (the multi-turn cache is
        replica-local).  When ``class_precision`` floors the request's
        class, only replicas whose pool meets the floor are considered
        — a pinned home replica that falls short is bypassed for this
        request (the pin is kept for the session's other classes).
        The rid comes from the fleet-global counter — see the module
        docstring for why that makes routing token-transparent.

        Raises:
          ValueError: unknown model, or any :meth:`Scheduler.submit`
              validation failure (priority, deadline, placement)."""
        group = self.group(model)
        want = self.class_precision.get(priority)
        eligible = [i for i, eng in enumerate(group.engines)
                    if self._replica_meets(eng, want)]
        if not eligible:        # unreachable: constructor validated floors
            raise ValueError(
                f"no replica of {model!r} stores KV at >= {want} as "
                f"class {priority!r} requires")
        idx = None
        if session_id is not None:
            idx = self._sessions.get((model, session_id))
            if idx is not None and idx not in eligible:
                idx = None                  # precision floor beats affinity
        if idx is None:
            if len(eligible) == 1:
                idx = eligible[0]
            elif len(eligible) == len(group.engines):
                idx = self.selection.select(group)
            else:
                sub = ReplicaGroup(group.name, group.cfg,
                                   [group.engines[i] for i in eligible],
                                   group.floor)
                idx = eligible[self.selection.select(sub)]
            if not 0 <= idx < len(group.engines):
                raise ValueError(
                    f"selection policy returned replica {idx} for "
                    f"{model!r} with {len(group.engines)} replicas")
        rid = self._next_rid
        group.engines[idx].submit(
            prompt, max_new_tokens=max_new_tokens, eos_id=eos_id,
            sampling=sampling, priority=priority, deadline_ms=deadline_ms,
            tbt_deadline_ms=tbt_deadline_ms, rid=rid)
        # commit routing state only after the engine accepted the
        # request: a validation failure must not pin the session to a
        # replica that holds none of its pages
        if session_id is not None:
            self._sessions[(model, session_id)] = idx
        self._next_rid = rid + 1
        self._routes[rid] = (model, idx)
        return rid

    def route(self, rid: int) -> Tuple[str, int]:
        """(model, replica index) a submitted rid was routed to."""
        return self._routes[rid]

    # -- the outer tick loop ---------------------------------------------------

    def _engines(self) -> List[Tuple[str, int, PagedServingEngine]]:
        return [(name, i, eng)
                for name, group in self._groups.items()
                for i, eng in enumerate(group.engines)]

    def pending(self) -> bool:
        """Any request still queued or on a seat anywhere in the fleet."""
        return any(eng.queue or eng.seats for _, _, eng in self._engines())

    def step(self) -> None:
        """One fleet tick: every engine with work gets one
        ``Scheduler.step()`` (admission, one prefill chunk, one decode
        round), in model-registration then replica order.  Idle engines
        are skipped — their jitted steps are not dispatched and their
        metrics windows are not diluted."""
        self._tick += 1
        for _, _, eng in self._engines():
            if eng.queue or eng.seats:
                eng.step()
        tel = self.telemetry
        if tel is not None and self._tick % tel.heartbeat_every == 0:
            # stride-gated heartbeat: one fleet_tick event per engine
            # feeds the Perfetto counter tracks (queue depth, seats,
            # pages) without growing the ring once per engine tick
            for name, i, eng in self._engines():
                tel.emit(self._tick, eng.clock(), f"{name}/{i}", -1,
                         "fleet_tick",
                         {"queued": len(eng.queue),
                          "active": len(eng.seats),
                          "pages_in_use": eng.policy.pages_in_use()})

    def run(self, max_ticks: Optional[int] = None) -> Dict[int, Request]:
        """Tick the fleet until every submitted request finishes.

        Returns:
          rid -> finished :class:`Request` for every request the fleet
          has completed (including earlier ``run`` calls).

        Raises:
          SchedulerStallError: ``max_ticks`` fleet ticks elapsed with
              work still pending; the message names each stalled
              request as ``model/replica:rid(priority)``."""
        if max_ticks is None:
            max_ticks = self.default_max_ticks
        t = 0
        while self.pending() and t < max_ticks:
            self.step()
            t += 1
        if self.pending():
            stalled = []
            for name, i, eng in self._engines():
                for r in sorted(list(eng.queue) + list(eng.seats.values()),
                                key=lambda r: r.rid):
                    stalled.append(f"{name}/{i}:{r.rid}({r.priority})")
            msg = (f"fleet run() exhausted max_ticks={max_ticks} with "
                   f"{len(stalled)} requests pending: " + ", ".join(stalled))
            if self.telemetry is not None:
                # full-fleet postmortem: ring events + every engine's
                # queue/seats/BlockManager partition + budget grants
                self.telemetry.write_postmortem(
                    "SchedulerStallError: " + msg,
                    engines={f"{name}/{i}": eng
                             for name, i, eng in self._engines()},
                    budget=self.budget.usage())
            raise SchedulerStallError(msg)
        return self.finished()

    def finished(self) -> Dict[int, Request]:
        """rid -> finished :class:`Request` across the whole fleet."""
        out: Dict[int, Request] = {}
        for _, _, eng in self._engines():
            for r in eng.finished:
                out[r.rid] = r
        return out

    # -- observability ---------------------------------------------------------

    def metrics_snapshot(self) -> Dict[str, object]:
        """Fleet observability: per-model and fleet-total
        ``EngineMetrics`` snapshots (tokens/s, TTFT percentiles,
        prefix-hit rate, preemptions, per-SLO-class breakdowns) plus
        per-replica snapshots and the :class:`HostBudget` accounting.
        Merged figures follow ``EngineMetrics.merged`` semantics (peaks
        are sums of per-replica peaks)."""
        per_model: Dict[str, object] = {}
        for name, group in self._groups.items():
            merged = EngineMetrics.merged([e.metrics for e in group.engines])
            snap = merged.snapshot()
            snap["replicas"] = [e.metrics.snapshot() for e in group.engines]
            per_model[name] = snap
        fleet = EngineMetrics.merged(
            [eng.metrics for _, _, eng in self._engines()]).snapshot()
        return {"models": per_model, "fleet": fleet,
                "budget": self.budget.usage(), "ticks": self._tick}


def parse_models_spec(spec: str) -> List[Tuple[str, int, Optional[str]]]:
    """Parse a ``--models`` fleet spec: comma-separated
    ``name[:replicas[:kv_dtype]]`` entries, e.g.
    ``llama3-8b:2:fp8,qwen3-1.7b`` (the registry's module-style aliases
    like ``llama3_8b`` work too — resolution happens in the caller via
    ``configs.resolve_arch``).  The optional third field picks the
    model's paged-KV storage precision (one of
    :data:`repro.core.mixed_precision.KV_DTYPES`); omitted means the
    engine default (full compute precision).

    Returns:
      [(name, replicas, kv_dtype_or_None), ...] in spec order (names
      unresolved).

    Raises:
      ValueError: empty spec/entry, a non-integer or < 1 replica
          count, an unknown kv dtype, or a duplicated name."""
    entries: List[Tuple[str, int, Optional[str]]] = []
    if not spec.strip():
        raise ValueError("empty --models spec")
    for part in spec.split(","):
        part = part.strip()
        if not part:
            raise ValueError(f"empty entry in --models spec {spec!r}")
        fields = [f.strip() for f in part.split(":")]
        if len(fields) > 3:
            raise ValueError(
                f"too many ':' fields in --models entry {part!r}; "
                "expected name[:replicas[:kv_dtype]]")
        name = fields[0]
        if not name:
            raise ValueError(f"missing model name in entry {part!r}")
        count = fields[1] if len(fields) > 1 else ""
        if count:
            try:
                replicas = int(count)
            except ValueError:
                raise ValueError(
                    f"bad replica count {count!r} in --models entry "
                    f"{part!r}; expected name[:replicas[:kv_dtype]]"
                ) from None
        else:
            replicas = 1
        if replicas < 1:
            raise ValueError(
                f"replica count must be >= 1 in --models entry {part!r}")
        kv_dtype: Optional[str] = None
        if len(fields) > 2 and fields[2]:
            kv_dtype = fields[2]
            if kv_dtype not in mixed_precision.KV_DTYPES:
                raise ValueError(
                    f"unknown kv dtype {kv_dtype!r} in --models entry "
                    f"{part!r}; expected one of "
                    f"{', '.join(mixed_precision.KV_DTYPES)}")
        if name in [n for n, _, _ in entries]:
            raise ValueError(f"model {name!r} appears twice in --models "
                             f"spec {spec!r}")
        entries.append((name, replicas, kv_dtype))
    return entries
