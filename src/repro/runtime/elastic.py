"""Fault tolerance at 1000+-node scale: heartbeats, stragglers, re-meshing.

SAKURAONE operates 100 nodes under Slurm with shared-Lustre checkpoints;
the recovery contract this module provides is the same one scaled up:

  - ``HeartbeatMonitor``: miss a deadline -> the node is dead.
  - ``StragglerDetector``: per-host step-time EWMA; hosts slower than
    k× the cluster median get their shards re-assigned (backup workers).
  - ``plan_remesh``: given survivors, the largest valid (pod, data, model)
    mesh — model groups must stay whole (TP members are not substitutable),
    so capacity drops in units of whole model groups; training restores
    from the last committed checkpoint onto the new mesh (elastic restore).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple


class HeartbeatMonitor:
    def __init__(self, timeout_s: float = 30.0):
        self.timeout = timeout_s
        self._last: Dict[str, float] = {}

    def register(self, host: str, now: Optional[float] = None):
        self._last[host] = time.monotonic() if now is None else now

    def beat(self, host: str, now: Optional[float] = None):
        if host not in self._last:
            raise KeyError(f"unregistered host {host}")
        self._last[host] = time.monotonic() if now is None else now

    def dead(self, now: Optional[float] = None) -> List[str]:
        now = time.monotonic() if now is None else now
        return sorted(h for h, t in self._last.items()
                      if now - t > self.timeout)

    def alive(self, now: Optional[float] = None) -> List[str]:
        now = time.monotonic() if now is None else now
        return sorted(h for h, t in self._last.items()
                      if now - t <= self.timeout)

    def evict(self, host: str):
        self._last.pop(host, None)


class StragglerDetector:
    """EWMA of per-host step times; flag hosts beyond `ratio`× the median."""

    def __init__(self, alpha: float = 0.3, ratio: float = 1.5,
                 min_samples: int = 3):
        self.alpha = alpha
        self.ratio = ratio
        self.min_samples = min_samples
        self._ewma: Dict[str, float] = {}
        self._n: Dict[str, int] = {}

    def record(self, host: str, step_time_s: float):
        prev = self._ewma.get(host)
        self._ewma[host] = (step_time_s if prev is None
                            else self.alpha * step_time_s + (1 - self.alpha) * prev)
        self._n[host] = self._n.get(host, 0) + 1

    def stragglers(self) -> List[str]:
        ready = {h: v for h, v in self._ewma.items()
                 if self._n[h] >= self.min_samples}
        if len(ready) < 2:
            return []
        vals = sorted(ready.values())
        median = vals[len(vals) // 2]
        return sorted(h for h, v in ready.items() if v > self.ratio * median)


@dataclasses.dataclass(frozen=True)
class RemeshPlan:
    mesh_shape: Tuple[int, ...]
    axis_names: Tuple[str, ...]
    hosts_used: Tuple[str, ...]
    hosts_idle: Tuple[str, ...]
    dropped_capacity_frac: float


def plan_remesh(survivors: Sequence[str], devices_per_host: int,
                model_parallel: int, *, num_pods: int = 2,
                multi_pod: bool = True) -> RemeshPlan:
    """Largest (pod, data, model) mesh from surviving hosts.

    Model-parallel groups must be whole; the data axis shrinks to what the
    survivors support.  If fewer than one whole model group per pod
    survives, the pod axis collapses to single-pod.
    """
    survivors = sorted(survivors)
    total = len(survivors) * devices_per_host
    if total < model_parallel:
        raise RuntimeError(
            f"{total} surviving devices < model_parallel={model_parallel}; "
            "cannot form even one model group")
    groups = total // model_parallel
    pods = num_pods if (multi_pod and groups >= num_pods) else 1
    data = groups // pods
    used_devices = pods * data * model_parallel
    used_hosts = used_devices // devices_per_host
    shape = (pods, data, model_parallel) if pods > 1 else (data, model_parallel)
    names = ("pod", "data", "model") if pods > 1 else ("data", "model")
    return RemeshPlan(
        mesh_shape=shape, axis_names=names,
        hosts_used=tuple(survivors[:used_hosts]),
        hosts_idle=tuple(survivors[used_hosts:]),
        dropped_capacity_frac=1.0 - used_devices / max(total, 1),
    )


@dataclasses.dataclass
class FailureEvent:
    step: int
    kind: str          # 'dead' | 'straggler'
    hosts: Tuple[str, ...]
    action: str        # 'remesh' | 'reassign_shards'


class ElasticCoordinator:
    """Glue: monitors -> events -> remesh/reassign decisions.

    Drives the recovery loop in launch/train.py: on death, training stops,
    a new mesh is planned from survivors, state restores from the last
    committed checkpoint, and the data pipeline resumes at the restored
    step (determinism makes the replay exact).
    """

    def __init__(self, hosts: Sequence[str], devices_per_host: int,
                 model_parallel: int, *, timeout_s: float = 30.0,
                 num_pods: int = 2):
        self.hb = HeartbeatMonitor(timeout_s)
        self.straggle = StragglerDetector()
        self.devices_per_host = devices_per_host
        self.model_parallel = model_parallel
        self.num_pods = num_pods
        self.events: List[FailureEvent] = []
        for h in hosts:
            self.hb.register(h)

    def check(self, step: int, now: Optional[float] = None) -> Optional[RemeshPlan]:
        dead = self.hb.dead(now)
        if dead:
            for h in dead:
                self.hb.evict(h)
            plan = plan_remesh(self.hb.alive(now), self.devices_per_host,
                               self.model_parallel, num_pods=self.num_pods)
            self.events.append(FailureEvent(step, "dead", tuple(dead), "remesh"))
            return plan
        lag = self.straggle.stragglers()
        if lag:
            self.events.append(
                FailureEvent(step, "straggler", tuple(lag), "reassign_shards"))
        return None
