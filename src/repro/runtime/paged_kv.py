"""Paged KV-cache bookkeeping: refcounted block manager + serving metrics.

The KV cache is a shared pool of fixed-size pages (``page_size`` tokens
each).  A request's cache is whatever pages its page table names — pages
are handed out by the :class:`BlockManager` and released when the request
completes, so short requests stop paying for the longest request's
``max_len``.  Physical page 0 is *reserved scratch*: idle seats and
chunk-padding tokens write there, live requests never own it.

Pages are refcounted so shared prompt prefixes are free: the serving
engine registers every page that fills with prompt tokens in an
exact-token *prefix index*; a later request whose prompt starts with the
same page-aligned token run points its leading page-table entries at
those physical pages (``acquire`` → refcount++) instead of re-prefilling
them, and copy-on-writes only the last partially matching page.

Page lifecycle::

    free ──alloc / try_grow (ref=1)──► live ──acquire──► shared (ref+=1)
      ▲                      │ release/preempt (ref-=1) ... ref==0:
      │                      ├─ registered in prefix index ─► reclaimable
      └──────────────────────┴─ unregistered ────────────────┘   (LRU)

    reclaimable ──prefix hit (acquire)──► live again, content intact
    reclaimable ──alloc under pressure──► evicted + unregistered

Lazy serving (``PagedPolicy`` with ``lazy_pages=True``, the default)
allocates only the prompt's pages at admission and calls
:meth:`BlockManager.try_grow` for one page whenever a request's decode
crosses a page boundary; a low-watermark admission gate (default: 5% of
capacity, at least one page) keeps enough headroom that live requests
usually grow without conflict.  When growth still fails, the scheduler
*preempts* the youngest decoding request: ``free`` drops its refcounts
(shared prefix pages stay live for their other holders; its registered
full prompt pages park reclaimable, content intact), and on re-admission
the request recomputes by re-prefilling ``prompt + generated[:-1]``
(the last generated token re-enters through the normal decode feed) —
the prompt part usually a prefix hit against those reclaimable pages, so
recompute costs roughly the generated tokens only.

Only *full prefill pages* are ever registered — prompt pages normally,
plus replayed generated-token pages after a preemption (still keyed by
their exact tokens) — and full pages are never written again (all
writes are positional), so a reclaimable page's content is immutable
and a prefix hit can revive it as-is.

Known scale limit: the index keys chains by their full parent-token
tuple (exactness over compactness), so one cached L-token chain holds
O(L^2 / page_size) ints of bookkeeping.  Fine for the prompt lengths
this repo serves today; re-keying children by parent page id (with
subtree invalidation on eviction) is the planned fix for multi-k-token
system prompts — see ROADMAP "Serving".

The page lifecycle, prefix-cache CoW and the scheduler that drives all
of this are documented end-to-end in docs/serving.md.
"""
from __future__ import annotations

import dataclasses
import math
import time
from collections import OrderedDict
from typing import Dict, List, Optional, Tuple

from repro.runtime.telemetry import Histogram


TokenTuple = Tuple[int, ...]


@dataclasses.dataclass(frozen=True)
class PrefixMatch:
    """Result of :meth:`BlockManager.match_prefix`.

    pages: physical pages holding the matched *full* page-aligned prefix
        (not yet acquired — the caller takes the refs).
    cow_src: physical page whose leading ``n_cached - len(pages)*page``
        tokens extend the match; the caller copies it (copy-on-write)
        rather than sharing, because it will write its own tokens into
        the remainder of that page.  None when no partial match.
    n_cached: total prompt tokens covered (always < len(prompt): the
        final prompt token is recomputed so admission has logits to
        sample the first output token from).
    """
    pages: List[int]
    cow_src: Optional[int]
    n_cached: int


class BlockManager:
    """Refcounted allocator over physical KV pages 1..num_pages-1
    (page 0 = scratch) with an exact-token prefix index.

    Invariants (exercised by tests/test_paged_kv.py and
    tests/test_prefix_cache.py):
      - every usable page is in exactly one of {live (ref > 0), free,
        reclaimable}; page 0 is never handed out
      - ``free``/``release`` of a page whose refcount is already 0
        raises (double-free protection)
      - a page's refcount equals the number of live requests whose page
        tables name it
    """

    def __init__(self, num_pages: int, page_size: int, *,
                 prefix_cache: bool = True, page_bytes: int = 1):
        assert num_pages >= 2, "need at least scratch + one usable page"
        self.num_pages = num_pages
        self.page_size = page_size
        self.prefix_cache = prefix_cache
        # bytes one physical page costs (models.model.paged_page_bytes);
        # lets byte-denominated budgets (runtime.router.HostBudget)
        # compare pools of different KV precisions.  1 = unit weight:
        # plain page counting, the single-precision default.
        self.page_bytes = page_bytes
        self._free: List[int] = list(range(num_pages - 1, 0, -1))
        self._ref: Dict[int, int] = {}           # page -> live refcount
        # debugging aid only: SOME current holder (the allocating/reviving
        # rid — NOT updated by acquire-for-sharing, dropped at refcount 0)
        self._owner: Dict[int, int] = {}
        self._reclaim: "OrderedDict[int, None]" = OrderedDict()  # LRU order
        # prefix index: parent prefix tokens -> {page's tokens -> page}
        self._children: Dict[TokenTuple, Dict[TokenTuple, int]] = {}
        self._page_key: Dict[int, Tuple[TokenTuple, TokenTuple]] = {}
        self.peak_in_use = 0
        self.evictions = 0
        self.grows = 0          # pages handed out by try_grow (lazy decode)
        # bumped on any state change that could alter a future alloc or
        # match — admission caches its failed attempt against this
        self.version = 0
        # optional shared host budget (runtime.router.HostBudget): when
        # set, can_alloc also asks the budget whether THIS manager may
        # take n more live pages, and local state changes invalidate the
        # sibling managers' versions (a starved head in another engine
        # must re-attempt admission when pages free up here)
        self._budget = None
        self._budget_key = None

    # -- accounting -----------------------------------------------------------

    @property
    def capacity(self) -> int:
        """Usable pages (excludes the scratch page)."""
        return self.num_pages - 1

    @property
    def available(self) -> int:
        """Pages an ``alloc`` can hand out: free + reclaimable cached."""
        return len(self._free) + len(self._reclaim)

    @property
    def in_use(self) -> int:
        """Pages referenced by at least one live request."""
        return len(self._ref)

    @property
    def cached(self) -> int:
        """Reclaimable pages kept only for their cached prefix content."""
        return len(self._reclaim)

    @property
    def bytes_in_use(self) -> int:
        """Live-page footprint in bytes (``in_use * page_bytes``)."""
        return self.in_use * self.page_bytes

    @property
    def capacity_tokens(self) -> int:
        """Effective token capacity of the usable pool — the figure a
        quantized pool roughly multiplies at equal byte budget."""
        return self.capacity * self.page_size

    def refcount(self, page: int) -> int:
        return self._ref.get(page, 0)

    def pages_needed(self, tokens: int) -> int:
        return max(1, math.ceil(tokens / self.page_size))

    def can_alloc(self, n: int) -> bool:
        """Whether ``alloc(n)`` would succeed: enough local pages AND —
        when this manager is registered with a shared
        :class:`~repro.runtime.router.HostBudget` — the host budget
        grants this engine ``n`` more live pages (its floor plus
        whatever surplus its siblings have not borrowed)."""
        if n > self.available:
            return False
        return self._budget is None or self._budget.allows(self._budget_key, n)

    def attach_budget(self, budget, key) -> None:
        """Register this manager under a shared host budget (called by
        ``HostBudget.register``).  Must happen before any allocation —
        the budget gate assumes it has seen every live page.

        Raises:
          ValueError: a budget is already attached, or pages are
              already live (either would corrupt the budget's floor /
              borrowed accounting)."""
        if self._budget is not None:
            raise ValueError(
                f"BlockManager already answers to a budget as "
                f"{self._budget_key!r}; cannot attach a second one")
        if self._ref:
            raise ValueError(
                f"attach_budget requires a pristine manager; {len(self._ref)} "
                "pages are already live and would escape budget accounting")
        self._budget = budget
        self._budget_key = key

    def _bump(self) -> None:
        """Version bump on any state change that could alter a future
        alloc or prefix match; with a shared budget, sibling managers
        are invalidated too (pages freed here may unblock admission
        there)."""
        self.version += 1
        if self._budget is not None:
            self._budget.invalidate(self)

    def owner(self, page: int) -> Optional[int]:
        """One current holder of ``page`` (debugging aid): the rid that
        alloc'd or revived it.  Shared pages have more holders than this
        reports — use :meth:`refcount` for sharing questions."""
        return self._owner.get(page)

    def utilization(self) -> float:
        return self.in_use / max(self.capacity, 1)

    # -- alloc / share / release ----------------------------------------------

    def alloc(self, n: int, rid: int) -> Optional[List[int]]:
        """Take ``n`` fresh pages for request ``rid``.

        Args:
          n: pages wanted (0 returns an empty list).
          rid: requesting id, recorded as the debugging ``owner``.

        Returns:
          ``n`` page ids, each at refcount 1 — LRU reclaimable cached
          pages are evicted (and unregistered) under pressure — or
          None when fewer than ``n`` are available: callers queue
          instead of crashing, and no state changes on None."""
        if not self.can_alloc(n):
            return None
        pages = []
        for _ in range(n):
            if self._free:
                pg = self._free.pop()
            else:
                pg, _ = self._reclaim.popitem(last=False)   # LRU victim
                self._unregister(pg)
                self.evictions += 1
            pages.append(pg)
        for pg in pages:
            self._ref[pg] = 1
            self._owner[pg] = rid
        self.peak_in_use = max(self.peak_in_use, self.in_use)
        self._bump()
        return pages

    def try_grow(self, rid: int) -> Optional[int]:
        """One more page (refcount 1) for a live request whose decode is
        about to cross a page boundary (lazy on-demand growth).

        Returns:
          The page id (the ``grows`` counter increments), or None under
          pressure — the caller preempts instead of crashing."""
        pages = self.alloc(1, rid)
        if pages is None:
            return None
        self.grows += 1
        return pages[0]

    def acquire(self, page: int, rid: Optional[int] = None) -> None:
        """Add a reference to a live or reclaimable page (prefix hit).

        Args:
          page: page id to share; a reclaimable page revives with its
              content intact.
          rid: recorded as the debugging ``owner`` when reviving.

        Raises:
          ValueError: ``page`` is neither live nor reclaimable."""
        if page in self._ref:
            self._ref[page] += 1
        elif page in self._reclaim:
            del self._reclaim[page]
            self._ref[page] = 1
            if rid is not None:
                self._owner[page] = rid
        else:
            raise ValueError(f"acquire of unallocated page {page}")
        self.peak_in_use = max(self.peak_in_use, self.in_use)
        self._bump()

    def free(self, pages: List[int]) -> None:
        """Drop one reference per page.  At refcount 0 a page returns to
        the free list — or to the reclaimable LRU list if it is registered
        in the prefix index (its content stays revivable).

        Raises:
          ValueError: a page's refcount is already 0 (double free /
              foreign page)."""
        for pg in pages:
            if self._ref.get(pg, 0) <= 0:
                raise ValueError(f"double free / foreign page {pg}")
            self._ref[pg] -= 1
            if self._ref[pg] == 0:
                del self._ref[pg]
                self._owner.pop(pg, None)
                if pg in self._page_key:
                    self._reclaim[pg] = None      # most-recently released
                else:
                    self._free.append(pg)
        self._bump()

    release = free      # refcount-decrement reading of the same operation

    # -- prefix index ---------------------------------------------------------

    def register_prefix(self, prefix_tokens, page: int) -> None:
        """Record that ``page`` holds the K/V of the last ``page_size``
        tokens of ``prefix_tokens`` (whose length must be page-aligned).
        No-op if that chain position is already registered, or if the
        page already serves another chain, or caching is off."""
        if not self.prefix_cache:
            return
        toks = tuple(int(t) for t in prefix_tokens)
        assert toks and len(toks) % self.page_size == 0, len(toks)
        parent, tail = toks[:-self.page_size], toks[-self.page_size:]
        kids = self._children.setdefault(parent, {})
        if tail in kids or page in self._page_key:
            return
        kids[tail] = page
        self._page_key[page] = (parent, tail)
        self._bump()

    def match_prefix(self, prompt) -> PrefixMatch:
        """Longest cached page-aligned prefix of ``prompt`` (plus an
        optional partial-page copy-on-write source), capped at
        ``len(prompt) - 1`` so at least the final prompt token is always
        recomputed."""
        if not self.prefix_cache:
            return PrefixMatch([], None, 0)
        toks = tuple(int(t) for t in prompt)
        limit = len(toks) - 1
        pages: List[int] = []
        key: TokenTuple = ()
        i = 0
        while (i + 1) * self.page_size <= limit:
            tail = toks[i * self.page_size:(i + 1) * self.page_size]
            pg = self._children.get(key, {}).get(tail)
            if pg is None:
                break
            pages.append(pg)
            key = key + tail
            i += 1
        n_cached = i * self.page_size
        want = toks[n_cached:limit][:self.page_size]
        cow, cow_len = None, 0
        for tail, pg in self._children.get(key, {}).items():
            r = 0
            for a, b in zip(tail, want):
                if a != b:
                    break
                r += 1
            if r > cow_len:
                cow, cow_len = pg, r
        return PrefixMatch(pages, cow, n_cached + cow_len)

    def _unregister(self, page: int) -> None:
        parent, tail = self._page_key.pop(page)
        kids = self._children[parent]
        del kids[tail]
        if not kids:
            del self._children[parent]


def _quantile(xs: List[float], q: float) -> float:
    """Nearest-rank quantile of ``xs`` (0.0 when empty) — enough for
    the per-class TTFT/TBT p50/p95 the serving metrics report without
    pulling numpy into this module.

    Contract (tests/test_load_harness.py pins it): the result is the
    element at 1-based rank ``ceil(q * n)`` of the sorted sample —
    order-insensitive, always an element of ``xs``, ``s[0]`` for
    ``q <= 1/n`` and ``s[-1]`` for ``q = 1`` — i.e. the classic
    nearest-rank percentile ``statistics`` texts define.  The rank is
    computed on a rounded product because binary float can overshoot
    an exact integer (``0.95 * 20 == 19.000000000000004``; a raw
    ``ceil`` would skip rank 19 and report the sample maximum as
    p95)."""
    if not xs:
        return 0.0
    s = sorted(xs)
    n = len(s)
    rank = max(1, min(n, math.ceil(round(q * n, 9))))
    return s[rank - 1]


@dataclasses.dataclass
class EngineMetrics:
    """Counters the serving engine updates in place; ``snapshot`` derives
    the headline serving numbers (TTFT, tokens/s, page utilization,
    prefix-hit rate) plus a per-priority-class breakdown (TTFT
    percentiles, preemption counts, deadline-miss rate, peak pages) —
    the observable side of the SLO classes described in
    docs/serving.md."""
    page_capacity: int = 0
    # KV storage precision of the pool behind these counters ("f32" /
    # "bf16" / "fp8" / "int8"; "mixed" after merging differing engines)
    # and bytes per physical page — the byte-denominated view of the
    # pool that makes cross-precision comparisons honest
    kv_dtype: Optional[str] = None
    page_bytes: int = 1
    submitted: int = 0
    admitted: int = 0
    completed: int = 0
    ticks: int = 0
    prefill_tokens: int = 0
    cached_prompt_tokens: int = 0  # prompt tokens served from the prefix cache
    first_tokens: int = 0        # one per completed prefill (the TTFT token)
    decode_tokens: int = 0
    preemptions: int = 0         # decoding requests evicted under pressure
    pages_in_use: int = 0
    bytes_in_use: int = 0        # pages_in_use * page_bytes, kept in tick()
    peak_pages_in_use: int = 0
    cached_pages: int = 0        # reclaimable prefix-cache pages (ref 0)
    evictions: int = 0           # cached pages reclaimed under pressure
    queued: int = 0
    active: int = 0
    peak_active: int = 0         # admitted concurrency high-water mark
    ttft_s: List[float] = dataclasses.field(default_factory=list)
    # per-priority-class accounting (keys are class names; only classes
    # actually seen appear — a uniform-priority run reports one class)
    ttft_s_by_class: Dict[str, List[float]] = \
        dataclasses.field(default_factory=dict)
    completed_by_class: Dict[str, int] = \
        dataclasses.field(default_factory=dict)
    preemptions_by_class: Dict[str, int] = \
        dataclasses.field(default_factory=dict)
    deadline_requests_by_class: Dict[str, int] = \
        dataclasses.field(default_factory=dict)
    deadline_misses_by_class: Dict[str, int] = \
        dataclasses.field(default_factory=dict)
    peak_pages_by_class: Dict[str, int] = \
        dataclasses.field(default_factory=dict)
    # per-token decode latency (TBT = gap between consecutive token
    # emissions, preemption replay gaps included); samples per class
    # plus miss accounting for requests carrying a TBT deadline
    tbt_s_by_class: Dict[str, List[float]] = \
        dataclasses.field(default_factory=dict)
    tbt_deadline_tokens_by_class: Dict[str, int] = \
        dataclasses.field(default_factory=dict)
    tbt_misses_by_class: Dict[str, int] = \
        dataclasses.field(default_factory=dict)
    # log-bucketed mergeable latency histograms (telemetry.Histogram),
    # maintained next to the raw sample lists: replica histograms merge
    # by pure bucket addition into fleet aggregates, and they are what
    # the Prometheus exposition publishes as repro_{ttft,tbt}_seconds
    ttft_hist_by_class: Dict[str, Histogram] = \
        dataclasses.field(default_factory=dict)
    tbt_hist_by_class: Dict[str, Histogram] = \
        dataclasses.field(default_factory=dict)
    _t_start: Optional[float] = None
    _t_last: Optional[float] = None

    def begin(self, now: Optional[float] = None) -> None:
        """Call at the START of the first tick so the throughput window
        includes the first tick's work (jit compile, first prefill).
        ``now`` lets an engine on a virtual clock stamp the window
        deterministically (None = wall time).

        Clock contract: the Scheduler always passes ``now=self.clock()``
        here and in :meth:`tick`, so under an injected clock the
        ``perf_counter`` fallback is never reached — it exists only for
        callers driving EngineMetrics standalone."""
        if self._t_start is None:
            self._t_start = time.perf_counter() if now is None else now

    def note_first_token(self, priority: str, ttft: float, *,
                         deadlined: bool = False,
                         missed: bool = False) -> None:
        """Record one TTFT emission: ``ttft`` seconds for a request of
        class ``priority``; ``deadlined`` marks the request as carrying
        a TTFT deadline and ``missed`` that the deadline was blown
        (per-class miss *rate* = misses / deadlined requests)."""
        self.ttft_s.append(ttft)
        self.first_tokens += 1
        self.ttft_s_by_class.setdefault(priority, []).append(ttft)
        h = self.ttft_hist_by_class.get(priority)
        if h is None:
            h = self.ttft_hist_by_class[priority] = Histogram()
        h.observe(ttft)
        if deadlined:
            self.deadline_requests_by_class[priority] = \
                self.deadline_requests_by_class.get(priority, 0) + 1
            if missed:
                self.deadline_misses_by_class[priority] = \
                    self.deadline_misses_by_class.get(priority, 0) + 1

    def note_decode_token(self, priority: str, tbt: float, *,
                          deadlined: bool = False,
                          missed: bool = False) -> None:
        """Record one decode-token emission: ``tbt`` seconds since the
        request's previous emission, for a request of class
        ``priority``; ``deadlined`` marks the token as governed by a
        per-token TBT deadline and ``missed`` that the gap blew it
        (per-class miss *rate* = misses / deadlined tokens).  The
        ``decode_tokens`` counter is maintained by the engine itself —
        this method owns only the latency/deadline tallies."""
        self.tbt_s_by_class.setdefault(priority, []).append(tbt)
        h = self.tbt_hist_by_class.get(priority)
        if h is None:
            h = self.tbt_hist_by_class[priority] = Histogram()
        h.observe(tbt)
        if deadlined:
            self.tbt_deadline_tokens_by_class[priority] = \
                self.tbt_deadline_tokens_by_class.get(priority, 0) + 1
            if missed:
                self.tbt_misses_by_class[priority] = \
                    self.tbt_misses_by_class.get(priority, 0) + 1

    def note_completion(self, priority: str) -> None:
        """Record one finished request of class ``priority``."""
        self.completed += 1
        self.completed_by_class[priority] = \
            self.completed_by_class.get(priority, 0) + 1

    def note_preemption(self, priority: str) -> None:
        """Record one preemption of a request of class ``priority``."""
        self.preemptions += 1
        self.preemptions_by_class[priority] = \
            self.preemptions_by_class.get(priority, 0) + 1

    def tick(self, *, queued: int, active: int, pages_in_use: int,
             cached_pages: int = 0, evictions: int = 0,
             pages_by_class: Optional[Dict[str, int]] = None,
             now: Optional[float] = None) -> None:
        # same clock contract as begin(): engine callers inject
        # now=clock(); the wall-time fallback is for standalone use
        if now is None:
            now = time.perf_counter()
        if self._t_start is None:
            self._t_start = now
        self._t_last = now
        self.ticks += 1
        self.queued = queued
        self.active = active
        self.peak_active = max(self.peak_active, active)
        self.pages_in_use = pages_in_use
        self.bytes_in_use = pages_in_use * self.page_bytes
        self.peak_pages_in_use = max(self.peak_pages_in_use, pages_in_use)
        self.cached_pages = cached_pages
        self.evictions = evictions
        for cls, n in (pages_by_class or {}).items():
            self.peak_pages_by_class[cls] = \
                max(self.peak_pages_by_class.get(cls, 0), n)

    @classmethod
    def merged(cls, parts: List["EngineMetrics"]) -> "EngineMetrics":
        """Aggregate metrics across several engines (a replica group or
        a whole :class:`~repro.runtime.router.ModelFleet`): counters and
        per-class tallies sum, TTFT samples concatenate (so the merged
        ``snapshot()`` reports fleet-level percentiles), and the
        throughput window spans the earliest start to the latest
        activity across the parts.

        ``peak_*`` figures are the SUM of per-engine peaks — an upper
        bound on concurrent fleet-wide usage (per-engine peaks need not
        be simultaneous); ``ticks`` is the max (fleet engines tick in
        lockstep, idle engines skip).  The parts are not mutated."""
        out = cls()
        dtypes = {m.kv_dtype for m in parts if m.kv_dtype is not None}
        if dtypes:
            out.kv_dtype = dtypes.pop() if len(dtypes) == 1 else "mixed"
        for m in parts:
            out.page_capacity += m.page_capacity
            out.submitted += m.submitted
            out.admitted += m.admitted
            out.completed += m.completed
            out.ticks = max(out.ticks, m.ticks)
            out.prefill_tokens += m.prefill_tokens
            out.cached_prompt_tokens += m.cached_prompt_tokens
            out.first_tokens += m.first_tokens
            out.decode_tokens += m.decode_tokens
            out.preemptions += m.preemptions
            out.pages_in_use += m.pages_in_use
            out.bytes_in_use += m.bytes_in_use
            out.peak_pages_in_use += m.peak_pages_in_use
            out.cached_pages += m.cached_pages
            out.evictions += m.evictions
            out.queued += m.queued
            out.active += m.active
            out.peak_active += m.peak_active
            out.ttft_s.extend(m.ttft_s)
            for cls_name, ts in m.ttft_s_by_class.items():
                out.ttft_s_by_class.setdefault(cls_name, []).extend(ts)
            for cls_name, ts in m.tbt_s_by_class.items():
                out.tbt_s_by_class.setdefault(cls_name, []).extend(ts)
            for acc, src in ((out.ttft_hist_by_class, m.ttft_hist_by_class),
                             (out.tbt_hist_by_class, m.tbt_hist_by_class)):
                for cls_name, h in src.items():
                    prev = acc.get(cls_name)
                    acc[cls_name] = h.merge(prev) if prev is not None \
                        else h.merge(Histogram(h.base))
            for acc, src in (
                    (out.completed_by_class, m.completed_by_class),
                    (out.preemptions_by_class, m.preemptions_by_class),
                    (out.deadline_requests_by_class,
                     m.deadline_requests_by_class),
                    (out.deadline_misses_by_class,
                     m.deadline_misses_by_class),
                    (out.tbt_deadline_tokens_by_class,
                     m.tbt_deadline_tokens_by_class),
                    (out.tbt_misses_by_class, m.tbt_misses_by_class),
                    (out.peak_pages_by_class, m.peak_pages_by_class)):
                for k, v in src.items():
                    acc[k] = acc.get(k, 0) + v
            if m._t_start is not None:
                out._t_start = (m._t_start if out._t_start is None
                                else min(out._t_start, m._t_start))
            if m._t_last is not None:
                out._t_last = (m._t_last if out._t_last is None
                               else max(out._t_last, m._t_last))
        return out

    def class_snapshot(self) -> Dict[str, Dict[str, float]]:
        """Per-priority-class summary: completed count, TTFT mean /
        p50 / p95, TBT mean / p50 / p95 with per-token deadline-miss
        accounting, preemptions, TTFT deadline totals and miss rate,
        and the class's peak concurrent page footprint.  Classes appear
        once any request of theirs reaches a counter."""
        classes = (set(self.ttft_s_by_class) | set(self.completed_by_class)
                   | set(self.preemptions_by_class)
                   | set(self.tbt_s_by_class)
                   | set(self.peak_pages_by_class))
        out: Dict[str, Dict[str, float]] = {}
        for cls in sorted(classes):
            ttfts = self.ttft_s_by_class.get(cls, [])
            tbts = self.tbt_s_by_class.get(cls, [])
            dl_n = self.deadline_requests_by_class.get(cls, 0)
            dl_miss = self.deadline_misses_by_class.get(cls, 0)
            tbt_n = self.tbt_deadline_tokens_by_class.get(cls, 0)
            tbt_miss = self.tbt_misses_by_class.get(cls, 0)
            out[cls] = {
                "completed": self.completed_by_class.get(cls, 0),
                "ttft_avg_s": sum(ttfts) / len(ttfts) if ttfts else 0.0,
                "ttft_p50_s": _quantile(ttfts, 0.50),
                "ttft_p95_s": _quantile(ttfts, 0.95),
                "tbt_avg_s": sum(tbts) / len(tbts) if tbts else 0.0,
                "tbt_p50_s": _quantile(tbts, 0.50),
                "tbt_p95_s": _quantile(tbts, 0.95),
                "tbt_deadline_tokens": tbt_n,
                "tbt_misses": tbt_miss,
                "tbt_miss_rate": tbt_miss / max(tbt_n, 1),
                "preemptions": self.preemptions_by_class.get(cls, 0),
                "deadline_requests": dl_n,
                "deadline_misses": dl_miss,
                "deadline_miss_rate": dl_miss / max(dl_n, 1),
                "peak_pages": self.peak_pages_by_class.get(cls, 0),
            }
        return out

    def snapshot(self) -> Dict[str, object]:
        """Headline serving numbers derived from the live counters —
        scalar rates/totals plus the dict-valued ``classes`` per-class
        breakdown.  Safe to call at any point; benchmarks diff two
        snapshots to exclude warmup."""
        wall = ((self._t_last - self._t_start)
                if self._t_start is not None and self._t_last is not None
                else 0.0)
        gen = self.decode_tokens + self.first_tokens
        prompt_toks = self.prefill_tokens + self.cached_prompt_tokens
        return {
            "submitted": self.submitted,
            "admitted": self.admitted,
            "completed": self.completed,
            "queued": self.queued,
            "active": self.active,
            "ticks": self.ticks,
            "prefill_tokens": self.prefill_tokens,
            "cached_prompt_tokens": self.cached_prompt_tokens,
            "prefix_hit_rate": self.cached_prompt_tokens / max(prompt_toks, 1),
            "decode_tokens": self.decode_tokens,
            "generated_tokens": gen,
            "preemptions": self.preemptions,
            "peak_active": self.peak_active,
            "page_capacity": self.page_capacity,
            "kv_dtype": self.kv_dtype,
            "page_bytes": self.page_bytes,
            "kv_bytes_in_use": self.bytes_in_use,
            "pages_in_use": self.pages_in_use,
            "peak_pages_in_use": self.peak_pages_in_use,
            "cached_pages": self.cached_pages,
            "evictions": self.evictions,
            "page_utilization": self.pages_in_use / max(self.page_capacity, 1),
            # live + cached prefix content: how full the pool really is
            "kv_occupancy": (self.pages_in_use + self.cached_pages)
                / max(self.page_capacity, 1),
            "peak_page_utilization":
                self.peak_pages_in_use / max(self.page_capacity, 1),
            "ttft_avg_s": (sum(self.ttft_s) / len(self.ttft_s)
                           if self.ttft_s else 0.0),
            "ttft_max_s": max(self.ttft_s) if self.ttft_s else 0.0,
            "wall_s": wall,
            "tokens_per_s": gen / wall if wall > 0 else 0.0,
            # per-priority-class breakdown (dict-valued — the one
            # non-scalar entry; see class_snapshot)
            "classes": self.class_snapshot(),
        }
