"""Paged KV-cache bookkeeping: block manager + serving metrics.

The KV cache is a shared pool of fixed-size pages (``page_size`` tokens
each).  A request's cache is whatever pages its page table names — pages
are handed out by the :class:`BlockManager` and returned when the request
completes, so short requests stop paying for the longest request's
``max_len``.  Physical page 0 is *reserved scratch*: idle seats and
chunk-padding tokens write there, live requests never own it.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Dict, List, Optional


class BlockManager:
    """Allocator over physical KV pages 1..num_pages-1 (page 0 = scratch).

    Invariants (exercised by tests/test_paged_kv.py):
      - a page is owned by at most one live request at a time
      - page 0 is never allocated
      - ``free`` rejects pages that are not currently allocated
    """

    def __init__(self, num_pages: int, page_size: int):
        assert num_pages >= 2, "need at least scratch + one usable page"
        self.num_pages = num_pages
        self.page_size = page_size
        self._free: List[int] = list(range(num_pages - 1, 0, -1))
        self._owner: Dict[int, int] = {}         # page -> rid
        self.peak_in_use = 0

    @property
    def capacity(self) -> int:
        """Usable pages (excludes the scratch page)."""
        return self.num_pages - 1

    @property
    def available(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return len(self._owner)

    def pages_needed(self, tokens: int) -> int:
        return max(1, math.ceil(tokens / self.page_size))

    def can_alloc(self, n: int) -> bool:
        return n <= self.available

    def alloc(self, n: int, rid: int) -> Optional[List[int]]:
        """Take ``n`` pages for request ``rid``; None if not enough free
        (callers queue instead of crashing)."""
        if not self.can_alloc(n):
            return None
        pages = [self._free.pop() for _ in range(n)]
        for pg in pages:
            self._owner[pg] = rid
        self.peak_in_use = max(self.peak_in_use, self.in_use)
        return pages

    def free(self, pages: List[int]) -> None:
        for pg in pages:
            if pg not in self._owner:
                raise ValueError(f"double free / foreign page {pg}")
            del self._owner[pg]
            self._free.append(pg)

    def owner(self, page: int) -> Optional[int]:
        return self._owner.get(page)

    def utilization(self) -> float:
        return self.in_use / max(self.capacity, 1)


@dataclasses.dataclass
class EngineMetrics:
    """Counters the serving engine updates in place; ``snapshot`` derives
    the headline serving numbers (TTFT, tokens/s, page utilization)."""
    page_capacity: int = 0
    submitted: int = 0
    admitted: int = 0
    completed: int = 0
    ticks: int = 0
    prefill_tokens: int = 0
    first_tokens: int = 0        # one per completed prefill (the TTFT token)
    decode_tokens: int = 0
    pages_in_use: int = 0
    peak_pages_in_use: int = 0
    queued: int = 0
    active: int = 0
    ttft_s: List[float] = dataclasses.field(default_factory=list)
    _t_start: Optional[float] = None
    _t_last: Optional[float] = None

    def begin(self) -> None:
        """Call at the START of the first tick so the throughput window
        includes the first tick's work (jit compile, first prefill)."""
        if self._t_start is None:
            self._t_start = time.perf_counter()

    def tick(self, *, queued: int, active: int, pages_in_use: int) -> None:
        now = time.perf_counter()
        if self._t_start is None:
            self._t_start = now
        self._t_last = now
        self.ticks += 1
        self.queued = queued
        self.active = active
        self.pages_in_use = pages_in_use
        self.peak_pages_in_use = max(self.peak_pages_in_use, pages_in_use)

    def snapshot(self) -> Dict[str, float]:
        wall = ((self._t_last - self._t_start)
                if self._t_start is not None and self._t_last is not None
                else 0.0)
        gen = self.decode_tokens + self.first_tokens
        return {
            "submitted": self.submitted,
            "admitted": self.admitted,
            "completed": self.completed,
            "queued": self.queued,
            "active": self.active,
            "ticks": self.ticks,
            "prefill_tokens": self.prefill_tokens,
            "decode_tokens": self.decode_tokens,
            "generated_tokens": gen,
            "page_capacity": self.page_capacity,
            "pages_in_use": self.pages_in_use,
            "peak_pages_in_use": self.peak_pages_in_use,
            "page_utilization": self.pages_in_use / max(self.page_capacity, 1),
            "peak_page_utilization":
                self.peak_pages_in_use / max(self.page_capacity, 1),
            "ttft_avg_s": (sum(self.ttft_s) / len(self.ttft_s)
                           if self.ttft_s else 0.0),
            "ttft_max_s": max(self.ttft_s) if self.ttft_s else 0.0,
            "wall_s": wall,
            "tokens_per_s": gen / wall if wall > 0 else 0.0,
        }
