"""Trace-driven workload model + token-oracle stub for load testing.

The serving stack's CI workloads top out at tens of requests — enough
to pin scheduler semantics, far too small to exercise aging, budget
contention, TBT deadlines, or replica placement at the request volumes
the ROADMAP's north star implies.  This module provides the two halves
of a load harness that drives the REAL scheduling machinery at
10⁵–10⁶ requests in seconds of wall time:

**A parameterized workload generator** (:class:`WorkloadSpec` /
:func:`generate_workload`) modeled on observed LLM-platform traffic
(PAPERS.md: the SAKURAONE follow-up's workload characterization —
diurnal, bursty, session-chained, heavy-tailed):

- arrivals: Gamma-renewal process (``burstiness`` inflates the
  inter-arrival coefficient of variation past Poisson) modulated by a
  sinusoidal diurnal rate envelope;
- sessions: geometric turn counts with exponential think time between
  turns; each turn's prompt extends the session's context, so
  follow-up turns hit the home replica's prefix cache;
- shared prefixes: sessions draw a system prompt from a Zipf-weighted
  catalog — a few prefixes dominate, exercising refcounted sharing;
- lengths: lognormal prompt and output tokens (heavy-tailed);
- classes: premium / standard / batch mix with per-class TTFT and TBT
  deadlines.

**A model-free oracle engine** (:class:`OracleModel` /
:class:`OraclePolicy`): the paged serving stack with the model
arithmetic replaced by O(1)-per-token hash-derived logits.
``OraclePolicy`` subclasses the real
:class:`~repro.runtime.serving.PagedPolicy` and overrides ONLY the
two tick methods that touch the device — admission, placement, prefix
caching, copy-on-write accounting, lazy growth, preemption, budget
checks, and every Scheduler behavior run unmodified (byte-identical
code paths), so harness results transfer to the real engine
(tests/test_load_harness.py pins the trace-event parity).

The oracle's "logits" for a decode position are a pure function of
``(rid, step, last_token)`` — NOT of the schedule — so a request's
token stream is exactly reproducible across runs, seeds permitting,
and survives preempt-and-recompute bit-for-bit just like the real
engine's (the replay feeds the same ``(rid, step, last)`` keys).

Determinism contract: one seed fixes the workload trace exactly
(:func:`generate_workload` draws everything from one
``np.random.default_rng(seed)``), and a fleet on a
:class:`VirtualClock` stepped by the harness produces bit-identical
metrics, token streams, and deadline verdicts on every run — no wall
clock anywhere in the loop.  See docs/benchmarks.md §"Workload 8".
"""
from __future__ import annotations

import argparse
import dataclasses
import math
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.runtime.router import FleetModel, ModelFleet
from repro.runtime.sampler import (SamplingParams, _MASK32, _mix_np,
                                   sample_tokens_np)
from repro.runtime.serving import PRIORITIES, PagedPolicy

#: salt separating oracle-logit hashing from the sampler's Gumbel keys
#: (same fmix32 mixer; a shared key would correlate logits with noise)
_ORACLE_SALT = 0x27220A95


class VirtualClock:
    """Deterministic time source for engines under test.

    A zero-arg callable (the :class:`~repro.runtime.serving.Scheduler`
    ``clock`` contract) returning seconds; the load harness advances it
    explicitly per fleet tick from its cost model, so TTFT/TBT values
    and deadline verdicts are functions of the schedule alone."""

    def __init__(self, start: float = 0.0):
        self.now = float(start)

    def __call__(self) -> float:
        return self.now

    def advance(self, dt: float) -> None:
        """Move time forward by ``dt`` seconds (>= 0)."""
        if dt < 0:
            raise ValueError(f"cannot advance a clock backwards: {dt}")
        self.now += dt


class OracleModel:
    """Hash-derived logits: the model-arithmetic stub behind
    :class:`OraclePolicy`.

    Each (request, step) position's logit row derives from fmix32
    avalanches of ``(rid, step, last_token)`` — O(vocab) integer work
    per token, no parameters, no device.  The row is schedule- and
    batch-independent, so token streams replay exactly under
    preemption and are identical across engine/replica placements
    (the same properties the real model provides via its KV cache,
    delivered here by construction)."""

    def __init__(self, vocab: int = 64, scale: float = 6.0):
        if vocab < 2:
            raise ValueError(f"vocab must be >= 2, got {vocab}")
        self.vocab = vocab
        self.scale = np.float32(scale)
        self._lanes = np.arange(vocab, dtype=np.uint32)

    def logits_batch(self, rids, steps, last) -> np.ndarray:
        """(B, vocab) float32 logits for (B,) integer key arrays."""
        k = _mix_np(np.asarray(rids, np.uint32) ^ np.uint32(_ORACLE_SALT))
        k = _mix_np(k ^ np.asarray(steps, np.uint32))
        k = _mix_np(k ^ np.asarray(last, np.uint32))
        u32 = _mix_np(k[:, None] ^ self._lanes[None, :])
        u = (u32 >> np.uint32(8)).astype(np.float32) * np.float32(2.0 ** -24)
        return u * self.scale

    def logits_row(self, rid: int, step: int, last: int) -> np.ndarray:
        """(vocab,) float32 logits for one scalar key."""
        return self.logits_batch(
            np.asarray([rid & _MASK32], np.uint32),
            np.asarray([step & _MASK32], np.uint32),
            np.asarray([last & _MASK32], np.uint32))[0]


class OraclePolicy(PagedPolicy):
    """The real paged placement policy with the device replaced by
    :class:`OracleModel` — the load harness's engine core.

    Inherits ``try_admit`` / ``release`` / ``preempt`` / ``validate`` /
    ``_grow_tick`` / ``_register_full_pages`` (and through them every
    BlockManager / HostBudget interaction) unmodified; overrides the
    model-state constructor hook plus ``prefill_tick`` / ``decode_tick``
    with pure-numpy equivalents that preserve the real ticks' event
    order exactly: one prompt chunk per tick for the lowest-rid
    mid-prefill request, then one decode token per completed seat in
    seat order.  Pass it to
    :class:`~repro.runtime.serving.PagedServingEngine` or
    :class:`~repro.runtime.router.ModelFleet` via ``policy_cls``."""

    #: oracle vocabulary width — small so per-token work stays O(1)-ish
    vocab = 64

    def _init_model_state(self, num_pages: int) -> None:
        # no KV pool, no jit: pages are pure bookkeeping entries here.
        # CoW degrades to the identity — the BlockManager still tracks
        # the copy, which is all the harness measures.
        self.cache = None
        self._cow_fn = lambda cache, src, dst: cache
        self.model = OracleModel(self.vocab)

    def prefill_tick(self) -> None:
        """Numpy twin of ``PagedPolicy.prefill_tick``: same candidate
        choice (lowest rid), same chunking, same page registration and
        trace events — minus the device prefill."""
        sched = self.sched
        cands = [r for r in sched.seats.values()
                 if r.prefill_pos < len(r.prefill_src)]
        if not cands:
            return
        req = min(cands, key=lambda r: r.rid)
        src = req.prefill_src
        c = min(self.prefill_chunk, len(src) - req.prefill_pos)
        req.prefill_pos += c
        sched.metrics.prefill_tokens += c
        sched._trace("prefill_chunk", req.rid)
        self._register_full_pages(req)
        if req.prefill_pos == len(src):
            self.pos[req.slot] = len(src)
            self._dirty = True           # seat joins the decoding set
            if req.resume_tokens is None:
                row = self.model.logits_row(req.rid, 0, int(src[-1]))
                sched._emit_first_tokens([(req, row)])
            # else: replay — TTFT token already emitted before the
            # preemption; decode resumes by feeding generated[-1]

    def decode_tick(self) -> None:
        """Numpy twin of the real decode tick: lazy growth first, then
        one token per decoding seat via batched hash logits + the
        batched host sampler (bit-identical to per-row
        ``Sampler.sample`` — tests/test_workload.py pins it)."""
        sched = self.sched
        if self.lazy:
            self._grow_tick()
        decoding = self._decoding_seats()
        if not decoding:
            return
        reqs = [sched.seats[s] for s in decoding]
        rids = np.asarray([r.rid & _MASK32 for r in reqs], np.uint32)
        steps = np.asarray([len(r.generated) & _MASK32 for r in reqs],
                           np.uint32)
        last = np.asarray([r.generated[-1] & _MASK32 for r in reqs],
                          np.uint32)
        logits = self.model.logits_batch(rids, steps, last)
        toks = sample_tokens_np(
            logits,
            np.asarray([r.sampling.temperature for r in reqs], np.float32),
            np.asarray([r.sampling.top_k for r in reqs], np.int32),
            np.asarray([r.sampling.top_p for r in reqs], np.float32),
            np.asarray([r.sampling.seed & _MASK32 for r in reqs], np.uint32),
            rids, steps)
        for i, s in enumerate(decoding):
            self.pos[s] += 1
            sched._emit_decode_token(reqs[i], int(toks[i]))


def tiny_paged_cfg():
    """A reduced real config whose paged-KV surface the oracle reuses
    (page-byte arithmetic, layout validation) — no params are ever
    initialized for it."""
    from repro.configs import get_config, reduced_config
    return reduced_config(get_config("qwen3-1.7b"))


# ---------------------------------------------------------------------------
# Workload model
# ---------------------------------------------------------------------------

def _class_deadlines() -> Dict[str, Optional[float]]:
    return {"premium": 200.0, "standard": 1000.0, "batch": None}


def _class_tbt_deadlines() -> Dict[str, Optional[float]]:
    return {"premium": 100.0, "standard": None, "batch": None}


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    """Knobs of the synthetic traffic model (see module docstring).

    Every distribution is drawn from ONE seeded generator inside
    :func:`generate_workload`, so (spec, seed) fixes the trace exactly.

    requests: total turns to generate (sessions are truncated at the
        boundary).
    arrival_rate: mean REQUEST arrivals (turns) per second of virtual
        time, before the diurnal envelope.  Session starts are paced at
        ``arrival_rate / (1 + session_extra_turns)`` so the offered
        request rate stays ``arrival_rate`` regardless of the turn mix
        — capacity curves sweep a quantity the fleet actually serves.
    burstiness: Gamma inter-arrival scale — 1.0 is Poisson; larger
        values clump arrivals into bursts (variance grows, mean stays).
    diurnal_amplitude / diurnal_period_s: sinusoidal rate envelope
        ``rate * (1 + A sin(2πt/T))`` — a compressed "day".
    session_extra_turns: mean FOLLOW-UP turns per session (geometric);
        0 disables multi-turn traffic.
    think_time_s: mean exponential pause between a session's turns.
    num_prefixes / prefix_zipf / prefix_len: shared system-prompt
        catalog size, Zipf exponent (> 1; lower = heavier head) and
        tokens per prefix.
    prompt_median / prompt_sigma: lognormal NEW prompt tokens per turn
        (on top of the session context).
    out_median / out_sigma: lognormal output-token budget per turn.
    max_total_len: hard per-request ``prompt + output`` cap; session
        context beyond it is truncated back to the shared prefix
        (models the platform's context-window management).
    class_mix: (premium, standard, batch) probabilities, sum 1.
    ttft_deadline_ms / tbt_deadline_ms: per-class deadlines (None =
        the class carries none).
    stochastic_fraction: fraction of requests sampling at
        ``temperature``/``top_p`` instead of greedy.
    models: routing keys; each session picks one uniformly.
    """
    requests: int = 10_000
    arrival_rate: float = 125.0
    burstiness: float = 2.0
    diurnal_amplitude: float = 0.4
    diurnal_period_s: float = 300.0
    session_extra_turns: float = 1.0
    think_time_s: float = 0.5
    num_prefixes: int = 32
    prefix_zipf: float = 1.3
    prefix_len: int = 24
    prompt_median: int = 24
    prompt_sigma: float = 0.7
    out_median: int = 10
    out_sigma: float = 0.6
    max_total_len: int = 192
    class_mix: Tuple[float, float, float] = (0.2, 0.5, 0.3)
    ttft_deadline_ms: Dict[str, Optional[float]] = \
        dataclasses.field(default_factory=_class_deadlines)
    tbt_deadline_ms: Dict[str, Optional[float]] = \
        dataclasses.field(default_factory=_class_tbt_deadlines)
    stochastic_fraction: float = 0.15
    temperature: float = 0.8
    top_p: float = 0.95
    vocab: int = 64
    models: Tuple[str, ...] = ("m0",)

    def __post_init__(self):
        if self.requests < 1:
            raise ValueError(f"requests must be >= 1, got {self.requests}")
        if self.arrival_rate <= 0:
            raise ValueError(
                f"arrival_rate must be > 0, got {self.arrival_rate}")
        if self.burstiness <= 0:
            raise ValueError(
                f"burstiness must be > 0, got {self.burstiness}")
        if self.prefix_zipf <= 1.0:
            raise ValueError(
                f"prefix_zipf must be > 1, got {self.prefix_zipf}")
        if abs(sum(self.class_mix) - 1.0) > 1e-6:
            raise ValueError(
                f"class_mix must sum to 1, got {self.class_mix}")
        for d in (self.ttft_deadline_ms, self.tbt_deadline_ms):
            for cls in d:
                if cls not in PRIORITIES:
                    raise ValueError(f"unknown class {cls!r} in deadlines")
        if not self.models:
            raise ValueError("models must name at least one routing key")
        if self.max_total_len < self.prefix_len + 2:
            raise ValueError(
                f"max_total_len={self.max_total_len} cannot fit a "
                f"{self.prefix_len}-token prefix plus one new token and "
                "one output token")


@dataclasses.dataclass(frozen=True)
class ArrivalEvent:
    """One request of the generated trace, in submission terms."""
    t: float                        # virtual arrival time (seconds)
    model: str
    session_id: Optional[str]
    prompt: np.ndarray              # (L,) int32
    max_new_tokens: int
    priority: str
    deadline_ms: Optional[float]
    tbt_deadline_ms: Optional[float]
    sampling: SamplingParams


def _lognormal_int(rng: np.random.Generator, median: float,
                   sigma: float, lo: int, hi: int) -> int:
    """Heavy-tailed integer draw: ``round(median * e^{N(0, sigma)})``
    clipped to [lo, hi]."""
    return int(min(hi, max(lo, round(median * math.exp(
        rng.normal(0.0, sigma))))))


def generate_workload(spec: WorkloadSpec, seed: int = 0,
                      ) -> List[ArrivalEvent]:
    """Draw the full arrival trace for ``spec`` — exactly
    ``spec.requests`` events sorted by arrival time, all randomness
    from one ``default_rng(seed)`` (the determinism contract).

    Sessions arrive as a Gamma renewal process under the diurnal
    envelope; each session carries 1 + Geometric(extra) turns spaced by
    exponential think time, every turn's prompt extending the session
    context (truncated back to its shared prefix past
    ``max_total_len``)."""
    rng = np.random.default_rng(seed)
    prefixes = [rng.integers(0, spec.vocab, size=spec.prefix_len,
                             dtype=np.int32)
                for _ in range(spec.num_prefixes)]
    classes = sorted(PRIORITIES, key=PRIORITIES.get)   # premium, std, batch
    shape = 1.0 / spec.burstiness        # Gamma(k=1/b, θ=b): mean 1, CV²=b
    events: List[ArrivalEvent] = []
    t = 0.0
    session = 0
    while len(events) < spec.requests:
        gap = rng.gamma(shape, spec.burstiness)
        rate = spec.arrival_rate * (
            1.0 + spec.diurnal_amplitude
            * math.sin(2.0 * math.pi * t / spec.diurnal_period_s))
        rate = max(rate, 0.05 * spec.arrival_rate)     # envelope floor
        # arrival_rate counts requests; sessions start slower by the
        # mean turns-per-session factor so offered req/s == arrival_rate
        t += gap * (1.0 + spec.session_extra_turns) / rate
        model = spec.models[int(rng.integers(len(spec.models)))]
        priority = classes[int(rng.choice(3, p=list(spec.class_mix)))]
        extra = spec.session_extra_turns
        turns = 1 + (int(rng.geometric(1.0 / (1.0 + extra))) - 1
                     if extra > 0 else 0)
        pid = min(int(rng.zipf(spec.prefix_zipf)),
                  spec.num_prefixes) - 1
        sid = f"s{session}" if turns > 1 else None
        session += 1
        ctx = prefixes[pid]
        tt = t
        for _ in range(turns):
            if len(events) >= spec.requests:
                break
            out = _lognormal_int(rng, spec.out_median, spec.out_sigma,
                                 1, max(1, spec.max_total_len // 3))
            if len(ctx) + 1 + out >= spec.max_total_len:
                ctx = prefixes[pid]      # context-window truncation
            room = spec.max_total_len - out - len(ctx)
            new = _lognormal_int(rng, spec.prompt_median,
                                 spec.prompt_sigma, 1, max(1, room))
            prompt = np.concatenate(
                [ctx, rng.integers(0, spec.vocab, size=new,
                                   dtype=np.int32)])
            if rng.random() < spec.stochastic_fraction:
                sampling = SamplingParams(
                    temperature=spec.temperature, top_p=spec.top_p,
                    seed=int(rng.integers(2 ** 31)))
            else:
                sampling = SamplingParams()
            events.append(ArrivalEvent(
                t=tt, model=model, session_id=sid, prompt=prompt,
                max_new_tokens=out, priority=priority,
                deadline_ms=spec.ttft_deadline_ms.get(priority),
                tbt_deadline_ms=spec.tbt_deadline_ms.get(priority),
                sampling=sampling))
            ctx = prompt                 # next turn extends this one
            tt += float(rng.exponential(spec.think_time_s))
    events.sort(key=lambda e: (e.t, e.session_id or ""))
    return events


def oracle_fleet(spec: WorkloadSpec, *, replicas: int = 1,
                 total_pages: int = 256, page_size: int = 8,
                 max_seats: int = 8, prefill_chunk: int = 32,
                 selection: str = "slo-aware", admission: str = "slo",
                 aging_ticks: int = 64,
                 clock: Optional[VirtualClock] = None,
                 record_trace: bool = False,
                 telemetry=None) -> ModelFleet:
    """A :class:`~repro.runtime.router.ModelFleet` of oracle engines
    sized for ``spec`` — one model entry per ``spec.models`` key,
    ``replicas`` engines each, sharing ``total_pages`` under one
    :class:`~repro.runtime.router.HostBudget`.  Traces default OFF
    (memory at 10⁵⁻⁶ requests) and the clock defaults to a fresh
    :class:`VirtualClock`.  ``telemetry`` (a
    :class:`~repro.runtime.telemetry.Telemetry`) attaches the flight
    recorder / postmortem plane; under the virtual clock every
    telemetry timestamp is deterministic virtual time, so span
    timelines are exact functions of the schedule."""
    cfg = tiny_paged_cfg()
    models = [FleetModel(name=m, cfg=cfg, params=None, replicas=replicas)
              for m in spec.models]
    return ModelFleet(
        models, total_pages=total_pages, page_size=page_size,
        max_seats=max_seats, max_seq_len=spec.max_total_len,
        prefill_chunk=prefill_chunk, selection=selection,
        admission=admission, aging_ticks=aging_ticks,
        clock=clock if clock is not None else VirtualClock(),
        record_trace=record_trace, telemetry=telemetry,
        policy_cls=OraclePolicy)


# ---------------------------------------------------------------------------
# CLI plumbing (shared by benchmarks/load_harness.py and launch/serve.py)
# ---------------------------------------------------------------------------

def add_workload_args(p: argparse.ArgumentParser) -> None:
    """Register the ``--workload-*`` flags mapping 1:1 onto
    :class:`WorkloadSpec` (documented in docs/serving.md)."""
    g = p.add_argument_group("workload model")
    g.add_argument("--workload-seed", type=int, default=0,
                   help="RNG seed fixing the whole trace (default 0)")
    g.add_argument("--workload-arrival-rate", type=float, default=125.0,
                   help="mean request arrivals/s of virtual time "
                        "(sessions pace slower by the mean turn count)")
    g.add_argument("--workload-burstiness", type=float, default=2.0,
                   help="Gamma inter-arrival burstiness (1.0 = Poisson)")
    g.add_argument("--workload-diurnal-amplitude", type=float, default=0.4,
                   help="sinusoidal rate envelope amplitude (0 = flat)")
    g.add_argument("--workload-diurnal-period", type=float, default=300.0,
                   help="rate envelope period in virtual seconds")
    g.add_argument("--workload-session-turns", type=float, default=1.0,
                   help="mean follow-up turns per session (geometric)")
    g.add_argument("--workload-think-time", type=float, default=0.5,
                   help="mean think time between session turns (s)")
    g.add_argument("--workload-prefixes", type=int, default=32,
                   help="shared system-prompt catalog size")
    g.add_argument("--workload-zipf", type=float, default=1.3,
                   help="Zipf exponent over the prefix catalog (> 1)")
    g.add_argument("--workload-prompt-median", type=int, default=24,
                   help="lognormal median of new prompt tokens per turn")
    g.add_argument("--workload-out-median", type=int, default=10,
                   help="lognormal median of output tokens per request")
    g.add_argument("--workload-max-total-len", type=int, default=192,
                   help="hard prompt+output cap per request")
    g.add_argument("--workload-class-mix", type=str, default="0.2,0.5,0.3",
                   help="premium,standard,batch probabilities (sum 1)")
    g.add_argument("--workload-stochastic-fraction", type=float,
                   default=0.15,
                   help="fraction of requests sampling stochastically")
    g.add_argument("--tbt-deadline-ms", type=float, default=100.0,
                   help="premium per-token decode (TBT) deadline in ms")
    g.add_argument("--ttft-deadline-ms", type=float, default=200.0,
                   help="premium TTFT deadline in ms")


def spec_from_args(args: argparse.Namespace, *,
                   requests: int) -> WorkloadSpec:
    """Build a :class:`WorkloadSpec` from :func:`add_workload_args`
    flags plus an explicit request count."""
    mix = tuple(float(x) for x in args.workload_class_mix.split(","))
    if len(mix) != 3:
        raise ValueError(
            f"--workload-class-mix needs 3 comma-separated values, "
            f"got {args.workload_class_mix!r}")
    return WorkloadSpec(
        requests=requests,
        arrival_rate=args.workload_arrival_rate,
        burstiness=args.workload_burstiness,
        diurnal_amplitude=args.workload_diurnal_amplitude,
        diurnal_period_s=args.workload_diurnal_period,
        session_extra_turns=args.workload_session_turns,
        think_time_s=args.workload_think_time,
        num_prefixes=args.workload_prefixes,
        prefix_zipf=args.workload_zipf,
        prompt_median=args.workload_prompt_median,
        out_median=args.workload_out_median,
        max_total_len=args.workload_max_total_len,
        class_mix=mix,  # type: ignore[arg-type]
        stochastic_fraction=args.workload_stochastic_fraction,
        ttft_deadline_ms={"premium": args.ttft_deadline_ms,
                          "standard": 5 * args.ttft_deadline_ms,
                          "batch": None},
        tbt_deadline_ms={"premium": args.tbt_deadline_ms,
                         "standard": None, "batch": None})
