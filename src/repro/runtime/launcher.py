"""Cluster launcher: Slurm/env-var plumbing -> jax.distributed -> mesh.

SAKURAONE schedules through Slurm (paper §3); this module is the analogous
entry path for a TPU/CPU fleet: every process calls ``bootstrap()``, which
reads the scheduler environment (Slurm or explicit JAX_* vars), initializes
``jax.distributed``, and returns the production mesh + this process's
coordinates.  Single-process runs degrade gracefully (no init).

Launch scripts: launch/slurm_train.sbatch (template) drives
``python -m repro.launch.train`` under ``srun``.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Optional, Tuple

import jax


@dataclasses.dataclass(frozen=True)
class ClusterEnv:
    coordinator: str
    num_processes: int
    process_id: int
    local_devices: Optional[int] = None

    @property
    def is_distributed(self) -> bool:
        return self.num_processes > 1


def detect_cluster() -> ClusterEnv:
    """Slurm first (paper's scheduler), then JAX_* overrides, else local."""
    env = os.environ
    if "SLURM_JOB_ID" in env and "SLURM_NTASKS" in env:
        nodelist = env.get("SLURM_STEP_NODELIST", env.get("SLURM_NODELIST", ""))
        head = nodelist.split(",")[0].replace("[", "").split("-")[0] or "localhost"
        port = env.get("REPRO_COORD_PORT", "12345")
        return ClusterEnv(
            coordinator=f"{head}:{port}",
            num_processes=int(env["SLURM_NTASKS"]),
            process_id=int(env.get("SLURM_PROCID", 0)),
        )
    if "JAX_COORDINATOR" in env:
        return ClusterEnv(
            coordinator=env["JAX_COORDINATOR"],
            num_processes=int(env.get("JAX_NUM_PROCESSES", 1)),
            process_id=int(env.get("JAX_PROCESS_ID", 0)),
        )
    return ClusterEnv(coordinator="localhost:0", num_processes=1, process_id=0)


def bootstrap(*, multi_pod: bool = False, require_chips: Optional[int] = None
              ) -> Tuple["jax.sharding.Mesh", ClusterEnv]:
    """Initialize distribution (if any) and build the production mesh.

    require_chips: fail fast if the fleet is smaller than expected — the
    launcher-level guard that turns silent degraded runs into restarts
    (the elastic coordinator then decides the remesh).
    """
    cluster = detect_cluster()
    if cluster.is_distributed:
        jax.distributed.initialize(
            coordinator_address=cluster.coordinator,
            num_processes=cluster.num_processes,
            process_id=cluster.process_id)
    n = len(jax.devices())
    if require_chips is not None and n < require_chips:
        raise RuntimeError(
            f"fleet has {n} chips < required {require_chips}; "
            "run the elastic planner (repro.runtime.elastic.plan_remesh) "
            "or relaunch with more nodes")
    if n >= 512 and multi_pod:
        from repro.launch.mesh import make_production_mesh
        mesh = make_production_mesh(multi_pod=True)
    elif n >= 256:
        from repro.launch.mesh import make_production_mesh
        mesh = make_production_mesh(multi_pod=False)
    else:
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh()
    return mesh, cluster
