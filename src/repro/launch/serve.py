"""Serving drivers: static-batch loop and the paged continuous engine.

``serve`` is the static path: one batch of equal-length prompts prefilled
in one shot, then lockstep decode (the decode_32k / long_500k dry-run
cells lower the same ``decode_step``).  ``serve_paged`` drives the
paged-KV continuous-batching engine (``runtime.serving.PagedServing
Engine`` — unified scheduler + refcounted prefix caching) over a
mixed-length request stream and reports engine metrics (TTFT, tokens/s,
page utilization, prefix-hit rate).

Both paths sample through ``runtime.sampler``: ``--temperature 0`` (the
default) is exact greedy argmax; ``--temperature/--top-k/--top-p/--seed``
select stochastic sampling, deterministic per (seed, request, step).
``--eos-id`` stops engine requests early (static batch decodes lockstep
and ignores it).

``serve_fleet`` (``--fleet``) drives a ``runtime.router.ModelFleet``:
several models — ``--models name[:replicas],...`` — served from one
process under one shared ``--total-pages`` host budget, with fleet-wide
metrics per model (see docs/serving.md §"Multi-model fleet").

``--tuning-preset alloc|full`` applies the host allocator / XLA
environment preset (tcmalloc ``LD_PRELOAD``, step-marker and
host-device-count ``XLA_FLAGS``) by re-exec'ing the interpreter once —
see :func:`build_tuning_env`.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b \
      --batch 4 --prompt-len 32 --gen 16
  PYTHONPATH=src python -m repro.launch.serve --engine paged \
      --arch qwen3-1.7b --requests 8 --gen 16 --temperature 0.8 --top-p 0.95
  PYTHONPATH=src python -m repro.launch.serve --fleet \
      --models qwen3-1.7b:2,llama3-8b --total-pages 64 --requests 12
"""
from __future__ import annotations

import argparse
import os
import sys
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import (get_config, make_example_batch, reduced_config,
                           resolve_arch)
from repro.core.mixed_precision import KV_DTYPES
from repro.launch.mesh import make_host_mesh
from repro.models import model as M
from repro.parallel.sharding import rules_for_mesh, DEFAULT_RULES
from repro.runtime.router import FleetModel, ModelFleet, parse_models_spec
from repro.runtime.sampler import Sampler, SamplingParams
from repro.runtime.serving import PagedServingEngine
from repro.runtime.telemetry import (MetricsServer, Telemetry,
                                     prometheus_text, write_perfetto)


# ---------------------------------------------------------------------------
# Allocator / XLA tuning presets
# ---------------------------------------------------------------------------
#
# The serving hot loop allocates host memory every tick (token vectors,
# metrics); the default glibc malloc serializes those on a global lock and
# XLA's default step-marker placement re-marks every dispatch.  The presets
# below bake the standard JAX-serving environment (tcmalloc preload, large-
# alloc report silencing, step marker on the outer loop, explicit host
# device count) into the launcher: LD_PRELOAD and XLA_FLAGS are read at
# process / backend init, so applying a preset re-execs the interpreter
# once with the adjusted environment.

TCMALLOC_PATH = "/usr/lib/x86_64-linux-gnu/libtcmalloc.so.4"
_TUNED_MARKER = "_REPRO_TUNED"          # guards against re-exec loops
_XLA_PRESET_FLAGS = ("--xla_step_marker_location=STEP_MARK_AT_TOP_LEVEL_WHILE_LOOP",
                     "--xla_force_host_platform_device_count=1")


def build_tuning_env(preset: str, env: Dict[str, str], *,
                     tcmalloc_path: str = TCMALLOC_PATH) -> Dict[str, str]:
    """Environment additions for a ``--tuning-preset`` (pure — no exec).

    ``off`` returns {}.  ``alloc`` preloads tcmalloc (skipped with no
    effect when the library is absent) and silences its large-allocation
    reports.  ``full`` adds the XLA flags on top: step marker on the
    outer while loop and a pinned host platform device count.  Existing
    ``LD_PRELOAD`` entries and ``XLA_FLAGS`` are appended to, never
    clobbered, and already-present values are left alone (idempotent)."""
    if preset == "off":
        return {}
    if preset not in ("alloc", "full"):
        raise ValueError(f"unknown tuning preset {preset!r}; "
                         "expected off/alloc/full")
    add: Dict[str, str] = {}
    if os.path.exists(tcmalloc_path):
        prior = env.get("LD_PRELOAD", "")
        if tcmalloc_path not in prior.split(":"):
            add["LD_PRELOAD"] = ":".join(
                p for p in (prior, tcmalloc_path) if p)
        if "TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD" not in env:
            add["TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD"] = "60000000000"
    if preset == "full":
        flags = env.get("XLA_FLAGS", "")
        for flag in _XLA_PRESET_FLAGS:
            if flag.split("=")[0] not in flags:
                flags = " ".join(f for f in (flags, flag) if f)
        if flags != env.get("XLA_FLAGS", ""):
            add["XLA_FLAGS"] = flags
    return add


def apply_tuning_preset(preset: str) -> None:
    """Re-exec the interpreter with the preset environment applied.

    Must run before the first jax dispatch: ``LD_PRELOAD`` is consumed
    by the dynamic loader at process start and ``XLA_FLAGS`` at backend
    init, so neither can be changed in-process.  No-op (returns) when
    the preset is ``off``, the environment is already tuned (the
    ``_REPRO_TUNED`` marker — set on exec — breaks the exec loop), or
    the preset adds nothing."""
    if preset == "off" or os.environ.get(_TUNED_MARKER):
        return
    add = build_tuning_env(preset, dict(os.environ))
    env = {**os.environ, **add, _TUNED_MARKER: "1"}
    if not add:                          # nothing to change; just mark
        os.environ[_TUNED_MARKER] = "1"
        return
    os.execve(sys.executable, [sys.executable] + sys.argv, env)


def serve(arch: str, *, batch: int = 4, prompt_len: int = 32, gen: int = 16,
          reduced: bool = True, seed: int = 0,
          sampling: Optional[SamplingParams] = None):
    cfg = get_config(arch)
    if reduced:
        cfg = reduced_config(cfg)
    mesh = make_host_mesh()
    rules = rules_for_mesh(mesh, DEFAULT_RULES)
    opts = M.RunOptions(q_chunk=min(prompt_len, 512), mesh=None)
    max_len = prompt_len + gen

    params = M.init_params(M.param_specs(cfg), jax.random.PRNGKey(seed),
                           dtype=jnp.float32)
    req = make_example_batch(cfg, "prefill", batch, prompt_len,
                             key=jax.random.PRNGKey(seed + 1))

    prefill_fn = jax.jit(lambda p, b: M.prefill(p, cfg, b, rules, opts))
    decode_fn = jax.jit(lambda p, c, t, q: M.decode_step(p, cfg, c, t, q,
                                                         rules, opts))
    sampler = Sampler()

    def pick(logits_last, step):
        """logits_last: (B, V) -> (B, 1) int32 via the shared sampler."""
        if sampling is None or sampling.greedy:
            return jnp.argmax(logits_last, axis=-1).astype(jnp.int32)[:, None]
        rows = np.asarray(logits_last)
        toks = [sampler.sample(rows[b], sampling, rid=b, step=step)
                for b in range(rows.shape[0])]
        return jnp.asarray(toks, jnp.int32)[:, None]

    t0 = time.perf_counter()
    logits, cache = prefill_fn(params, req)
    # grow cache to max_len along the KV seq dim
    def grow(pos_ent):
        out = {}
        for k, v in pos_ent.items():
            if k in ("k", "v"):
                pad = jnp.zeros(v.shape[:2] + (gen,) + v.shape[3:], v.dtype)
                out[k] = jnp.concatenate([v, pad], axis=2)
            else:
                out[k] = v
        return out
    cache = {pos: grow(ent) for pos, ent in cache.items()}
    t_prefill = time.perf_counter() - t0

    tok = pick(logits[:, -1], 0)
    out_tokens = [tok]
    t0 = time.perf_counter()
    for i in range(gen - 1):
        pos = jnp.full((batch,), prompt_len + i, jnp.int32)
        logits, cache = decode_fn(params, cache, tok, pos)
        tok = pick(logits[:, -1], i + 1)
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0

    gen_arr = jnp.concatenate(out_tokens, axis=1)
    return {
        "generated": gen_arr,
        "prefill_s": t_prefill,
        "decode_s": t_decode,
        "tokens_per_s": batch * (gen - 1) / max(t_decode, 1e-9),
    }


def serve_paged(arch: str, *, requests: int = 8, gen: int = 16,
                page_size: int = 16, num_pages: int = 128,
                max_seats: int = 8, prefill_chunk: int = 32,
                reduced: bool = True, seed: int = 0,
                eos_id: Optional[int] = None,
                sampling: Optional[SamplingParams] = None,
                prefix_cache: bool = True,
                max_seq_len: Optional[int] = None,
                prompt_len: Optional[int] = None,
                lazy_pages: bool = True, watermark: float = 0.05,
                priority: str = "standard",
                deadline_ms: Optional[float] = None,
                tbt_deadline_ms: Optional[float] = None,
                admission: str = "fcfs", aging_ticks: int = 64,
                kv_dtype: Optional[str] = None,
                class_precision: Optional[Dict[str, str]] = None,
                telemetry: Optional[Telemetry] = None,
                metrics_port: Optional[int] = None):
    """Drive the paged engine over a request stream.

    ``max_seq_len`` bounds prompt + generation per request and defaults
    to ``(prompt_len or 3 * page_size) + gen``.  ``prompt_len`` fixes
    every prompt's length; when None, lengths are sampled to fit
    ``max_seq_len`` minus the generation budget.  Infeasible
    combinations raise here with the offending flags named instead of
    crashing inside ``submit``.

    ``kv_dtype`` picks the KV pool storage precision (``fp8``/``int8``
    quantize pages with per-token scales — see docs/serving.md
    §"Quantized KV pages"); ``class_precision`` maps SLO classes to
    minimum precisions, rejecting requests this pool cannot honor.

    ``admission`` picks the scheduler queue policy (``fcfs`` default,
    ``slo`` = priority + earliest-deadline-first with an ``aging_ticks``
    anti-starvation bound); ``priority`` (premium/standard/batch) and
    ``deadline_ms`` (TTFT deadline) are applied to every submitted
    request — one-class streams are plumbing demos; see
    benchmarks/serving_paged.py workload 4 for a mixed-class stream.

    ``telemetry`` attaches the observability plane (flight recorder /
    tick profiler — see docs/observability.md); ``metrics_port`` serves
    Prometheus text exposition of the live engine metrics on
    127.0.0.1 for the duration of the run (0 = ephemeral port)."""
    if max_seq_len is None:
        max_seq_len = (prompt_len if prompt_len else 3 * page_size) + gen
    if prompt_len is not None and prompt_len + gen > max_seq_len:
        raise ValueError(
            f"--prompt-len {prompt_len} + --gen {gen} exceeds "
            f"--max-seq-len {max_seq_len}")
    if prompt_len is None and max_seq_len - gen < 2:
        raise ValueError(
            f"--max-seq-len {max_seq_len} leaves no room for prompts "
            f"after --gen {gen}; raise it or pass --prompt-len")
    cfg = get_config(arch)
    if reduced:
        cfg = reduced_config(cfg)
    params = M.init_params(M.param_specs(cfg), jax.random.PRNGKey(seed),
                           dtype=jnp.float32)
    eng = PagedServingEngine(cfg, params, page_size=page_size,
                             num_pages=num_pages, max_seats=max_seats,
                             max_seq_len=max_seq_len,
                             prefill_chunk=prefill_chunk,
                             prefix_cache=prefix_cache,
                             lazy_pages=lazy_pages, watermark=watermark,
                             admission=admission, aging_ticks=aging_ticks,
                             kv_dtype=kv_dtype,
                             class_precision=class_precision,
                             telemetry=telemetry)
    rng = np.random.default_rng(seed)
    for _ in range(requests):
        plen = (prompt_len if prompt_len
                else int(rng.integers(1, max_seq_len - gen)))
        eng.submit(rng.integers(0, cfg.vocab_size, plen).astype(np.int32),
                   max_new_tokens=int(rng.integers(2, gen + 1)),
                   eos_id=eos_id, sampling=sampling,
                   priority=priority, deadline_ms=deadline_ms,
                   tbt_deadline_ms=tbt_deadline_ms)
    server = None
    if metrics_port is not None:
        server = MetricsServer(
            lambda: prometheus_text({arch: eng.metrics}),
            port=metrics_port)
        print(f"[serve.paged] metrics: {server.url}")
    try:
        done = eng.run()
    finally:
        if server is not None:
            server.close()
    return {"finished": done, "metrics": eng.metrics.snapshot()}


def serve_fleet(models, *, requests: int = 12, gen: int = 8,
                page_size: int = 16, total_pages: int = 64,
                max_seats: int = 4, prefill_chunk: int = 16,
                reduced: bool = True, seed: int = 0,
                eos_id: Optional[int] = None,
                sampling: Optional[SamplingParams] = None,
                prefix_cache: bool = True,
                max_seq_len: Optional[int] = None,
                prompt_len: Optional[int] = None,
                lazy_pages: bool = True, watermark: float = 0.05,
                priority: str = "standard",
                deadline_ms: Optional[float] = None,
                tbt_deadline_ms: Optional[float] = None,
                admission: str = "fcfs", aging_ticks: int = 64,
                selection: str = "least-loaded",
                kv_dtype: Optional[str] = None,
                class_precision: Optional[Dict[str, str]] = None,
                telemetry: Optional[Telemetry] = None,
                metrics_port: Optional[int] = None):
    """Drive a multi-model fleet over one mixed request stream.

    ``models`` is a ``--models``-style spec string
    (``llama3-8b:2:fp8,qwen3-1.7b``; module-style aliases like
    ``llama3_8b`` resolve too) or a pre-parsed
    [(name, replicas[, kv_dtype]), ...] list.  ``kv_dtype`` is the
    fleet-wide KV storage default for models whose spec entry leaves it
    unset; ``class_precision`` maps SLO classes to minimum precisions,
    steering those classes to replicas whose pool qualifies.  Every
    engine in the fleet shares one ``total_pages`` host budget —
    denominated in bytes when precisions are mixed, so quantized
    replicas' cheaper pages draw proportionally less; requests cycle
    across the models round-robin and rids are fleet-global, so
    per-request outputs match dedicated solo engines.  Returns the
    finished requests plus the fleet metrics snapshot (per-model
    tokens/s, TTFT, prefix hits, preemptions, SLO classes, budget
    accounting).

    ``telemetry`` attaches one shared observability plane (flight
    recorder tagged per ``model/replica`` engine, ``fleet_tick``
    heartbeat counters — docs/observability.md); ``metrics_port``
    serves per-replica Prometheus exposition during the run."""
    if isinstance(models, str):
        try:
            models = parse_models_spec(models)
        except ValueError as e:
            raise ValueError(f"--models: {e}") from None
    try:
        models = [(resolve_arch(m[0]), m[1],
                   m[2] if len(m) > 2 and m[2] is not None else kv_dtype)
                  for m in models]
    except KeyError as e:
        raise ValueError(f"--models: {e.args[0]}") from None
    if max_seq_len is None:
        max_seq_len = (prompt_len if prompt_len else 3 * page_size) + gen
    if prompt_len is not None and prompt_len + gen > max_seq_len:
        raise ValueError(
            f"--prompt-len {prompt_len} + --gen {gen} exceeds "
            f"--max-seq-len {max_seq_len}")
    if prompt_len is None and max_seq_len - gen < 2:
        raise ValueError(
            f"--max-seq-len {max_seq_len} leaves no room for prompts "
            f"after --gen {gen}; raise it or pass --prompt-len")
    entries = []
    for i, (name, reps, dt) in enumerate(models):
        cfg = get_config(name)
        if reduced:
            cfg = reduced_config(cfg)
        params = M.init_params(M.param_specs(cfg),
                               jax.random.PRNGKey(seed + i),
                               dtype=jnp.float32)
        entries.append(FleetModel(name, cfg, params, replicas=reps,
                                  kv_dtype=dt))
    fleet = ModelFleet(entries, total_pages=total_pages,
                       page_size=page_size, max_seats=max_seats,
                       max_seq_len=max_seq_len,
                       prefill_chunk=prefill_chunk, selection=selection,
                       prefix_cache=prefix_cache, lazy_pages=lazy_pages,
                       watermark=watermark, admission=admission,
                       aging_ticks=aging_ticks,
                       class_precision=class_precision,
                       telemetry=telemetry)
    rng = np.random.default_rng(seed)
    for i in range(requests):
        name = models[i % len(models)][0]
        cfg = fleet.group(name).cfg
        plen = (prompt_len if prompt_len
                else int(rng.integers(1, max_seq_len - gen)))
        fleet.submit(model=name,
                     prompt=rng.integers(0, cfg.vocab_size,
                                         plen).astype(np.int32),
                     max_new_tokens=int(rng.integers(2, gen + 1)),
                     eos_id=eos_id, sampling=sampling,
                     priority=priority, deadline_ms=deadline_ms,
                     tbt_deadline_ms=tbt_deadline_ms)
    server = None
    if metrics_port is not None:
        server = MetricsServer(
            lambda: prometheus_text(
                {f"{n}/{i}": e.metrics
                 for n, i, e in fleet._engines()}),
            port=metrics_port)
        print(f"[serve.fleet] metrics: {server.url}")
    try:
        done = fleet.run()
    finally:
        if server is not None:
            server.close()
    return {"finished": done, "metrics": fleet.metrics_snapshot()}


def add_sampling_args(ap: argparse.ArgumentParser) -> None:
    """Shared CLI sampling/termination flags (also used by examples)."""
    ap.add_argument("--eos-id", type=int, default=None,
                    help="stop a request early on this token id")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy argmax (default)")
    ap.add_argument("--top-k", type=int, default=0, help="0 = off")
    ap.add_argument("--top-p", type=float, default=1.0, help="1.0 = off")
    ap.add_argument("--seed", type=int, default=0,
                    help="base seed for params init and sampling streams")


def add_slo_args(ap: argparse.ArgumentParser) -> None:
    """Shared CLI SLO-class flags (also used by the examples): request
    priority/deadline plus the scheduler admission policy."""
    ap.add_argument("--priority", choices=("premium", "standard", "batch"),
                    default="standard",
                    help="SLO class applied to every submitted request")
    ap.add_argument("--tbt-deadline-ms", type=float, default=None,
                    help="per-decode-token deadline in ms: tightens EDF "
                         "rank to the next-token due time under "
                         "--admission slo, shields the request from "
                         "preemption within its class, and lands "
                         "tbt_p95_s / tbt_miss_rate in the metrics "
                         "snapshot")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="TTFT deadline per request in ms (EDF ordering "
                         "under --admission slo; misses are counted and "
                         "traced under either policy)")
    ap.add_argument("--admission", choices=("fcfs", "slo"), default="fcfs",
                    help="queue policy: fcfs (default) or slo = priority + "
                         "earliest-deadline-first with aging")
    ap.add_argument("--aging-ticks", type=int, default=64,
                    help="slo anti-starvation bound: a queued request "
                         "gains one priority class per this many ticks")


def parse_class_precision(spec: str) -> Dict[str, str]:
    """Parse a ``--class-precision`` map: comma-separated
    ``class=dtype`` entries, e.g. ``premium=bf16,standard=fp8``.
    Values must come from :data:`~repro.core.mixed_precision.KV_DTYPES`
    (deeper validation — class names, floor feasibility — happens in
    the engine/fleet constructors, which name the offending class).

    Raises:
      ValueError: malformed entry or an unknown dtype name."""
    out: Dict[str, str] = {}
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        cls, sep, dt = part.partition("=")
        cls, dt = cls.strip(), dt.strip()
        if not sep or not cls or not dt:
            raise ValueError(
                f"bad --class-precision entry {part!r}; expected "
                "class=dtype, e.g. premium=bf16,standard=fp8")
        if dt not in KV_DTYPES:
            raise ValueError(
                f"unknown kv dtype {dt!r} in --class-precision entry "
                f"{part!r}; expected one of {', '.join(KV_DTYPES)}")
        out[cls] = dt
    return out


def add_telemetry_args(ap: argparse.ArgumentParser) -> None:
    """Shared observability flags (paged engine and fleet modes) — see
    docs/observability.md for the workflows behind them."""
    ap.add_argument("--metrics-port", type=int, default=None,
                    metavar="PORT",
                    help="serve Prometheus text exposition of the live "
                         "engine metrics on 127.0.0.1:PORT for the "
                         "duration of the run (0 = ephemeral port, "
                         "printed at startup)")
    ap.add_argument("--flight-recorder", type=int, default=0,
                    metavar="N",
                    help="keep the last N structured trace events in a "
                         "ring buffer; a scheduler stall dumps them "
                         "plus a full engine-state snapshot as "
                         "postmortem JSON (0 = off unless another "
                         "telemetry flag turns telemetry on)")
    ap.add_argument("--trace-export", default=None, metavar="PATH",
                    help="after the run, write the recorded events as "
                         "Chrome trace-event JSON (open in "
                         "https://ui.perfetto.dev — one track per "
                         "engine seat)")
    ap.add_argument("--profile-ticks", action="store_true",
                    help="time the tick phases (admission / prefill / "
                         "decode, plus the fused tick's sync / dispatch "
                         "/ host / sample sub-phases) and print the "
                         "breakdown after the run")
    ap.add_argument("--postmortem", default=None, metavar="PATH",
                    help="where a stall postmortem JSON is written "
                         "(default: postmortem.json next to the run)")


def telemetry_from_args(args) -> Optional[Telemetry]:
    """Build one :class:`Telemetry` from ``add_telemetry_args`` flags,
    or None when every flag is at its off default (keeping the engines
    on the zero-overhead path)."""
    wanted = (args.flight_recorder or args.trace_export
              or args.profile_ticks or args.metrics_port is not None)
    if not wanted:
        return None
    return Telemetry(ring=args.flight_recorder or 4096,
                     profile=args.profile_ticks,
                     postmortem_path=args.postmortem or "postmortem.json")


def report_telemetry(args, telemetry: Optional[Telemetry],
                     tag: str) -> None:
    """Post-run telemetry outputs: the Perfetto export and the
    tick-phase profile table."""
    if telemetry is None:
        return
    rec = telemetry.recorder
    if args.trace_export:
        write_perfetto(args.trace_export, telemetry.events())
        print(f"[{tag}] wrote Perfetto trace {args.trace_export} "
              f"({rec.total} events recorded, {rec.dropped} aged out "
              f"of the {rec.capacity}-event ring)")
    if telemetry.profiler is not None:
        snap = telemetry.profiler.snapshot()
        print(f"[{tag}] tick-phase profile over {snap['ticks']} ticks:")
        for phase, ph in snap["phases"].items():
            print(f"[{tag}]   {phase:<16} {ph['total_s'] * 1e3:8.2f} ms "
                  f"total  {ph['share'] * 100:5.1f}%")


def add_kv_precision_args(ap: argparse.ArgumentParser) -> None:
    """Shared CLI KV-precision flags (paged engine and fleet)."""
    ap.add_argument("--kv-dtype", choices=KV_DTYPES, default=None,
                    help="KV pool storage precision; fp8/int8 quantize "
                         "pages with per-token scales for ~4x the tokens "
                         "per byte (default: the compute dtype). In "
                         "--fleet mode this is the default for models "
                         "whose --models entry has no :kv_dtype field")
    ap.add_argument("--class-precision", default=None,
                    help="SLO class -> minimum KV precision map, e.g. "
                         "premium=bf16,standard=fp8; requests of a "
                         "floored class only run on pools storing at "
                         "least that precision")


def sampling_from_args(args) -> SamplingParams:
    """Build :class:`SamplingParams` from ``add_sampling_args`` flags."""
    return SamplingParams(temperature=args.temperature, top_k=args.top_k,
                          top_p=args.top_p, seed=args.seed)


def model_name(name: str) -> str:
    """argparse ``type=`` resolver for ``--model``/``--arch`` flags:
    canonicalizes registry ids and module-style aliases, and turns an
    unknown name into an argparse error that names the offending flag
    (``argument --model/--arch: ...``) and lists every known model."""
    try:
        return resolve_arch(name)
    except KeyError as e:
        raise argparse.ArgumentTypeError(e.args[0]) from None


def add_model_arg(ap: argparse.ArgumentParser,
                  default: str = "qwen3-1.7b") -> None:
    """Shared ``--model`` (alias ``--arch``) flag resolving through the
    config registry — also used by the serving examples."""
    ap.add_argument("--model", "--arch", dest="arch", type=model_name,
                    default=default,
                    help="registry model name (module-style aliases like "
                         f"llama3_8b work; default {default})")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--engine", choices=("batch", "paged"), default="batch")
    ap.add_argument("--fleet", action="store_true",
                    help="serve a multi-model fleet (--models) instead of "
                         "one engine; implies the paged engine")
    ap.add_argument("--models", default="qwen3-1.7b:2,llama3-8b",
                    help="fleet spec: comma-separated "
                         "name[:replicas[:kv_dtype]], e.g. "
                         "llama3-8b:2:fp8,qwen3-1.7b (--fleet mode)")
    ap.add_argument("--selection",
                    choices=("least-loaded", "round-robin", "slo-aware"),
                    default="least-loaded",
                    help="replica selection policy (--fleet mode); "
                         "slo-aware folds premium queue depth into the "
                         "least-loaded key")
    ap.add_argument("--total-pages", type=int, default=64,
                    help="shared host page budget across all fleet "
                         "engines (--fleet mode)")
    add_model_arg(ap)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=None,
                    help="fixed prompt length (batch default 32; the "
                         "paged engine samples lengths when unset)")
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--num-pages", type=int, default=128)
    ap.add_argument("--max-seq-len", type=int, default=None,
                    help="per-request prompt+generation bound (paged; "
                         "default (prompt_len or 3*page_size) + gen)")
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="disable prefix-cache page sharing (paged engine)")
    ap.add_argument("--lazy-pages", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="allocate KV pages on demand during decode and "
                         "preempt under pressure (--no-lazy-pages restores "
                         "up-front full reservation)")
    ap.add_argument("--watermark", type=float, default=0.05,
                    help="lazy admission gate: free-page headroom kept at "
                         "admission, as a fraction of pool capacity")
    ap.add_argument("--tuning-preset", choices=("off", "alloc", "full"),
                    default="off",
                    help="host allocator / XLA environment preset: alloc "
                         "preloads tcmalloc; full adds XLA step-marker + "
                         "host-device-count flags (re-execs once to apply)")
    add_sampling_args(ap)
    add_slo_args(ap)
    add_kv_precision_args(ap)
    add_telemetry_args(ap)
    args = ap.parse_args()
    apply_tuning_preset(args.tuning_preset)
    sampling = sampling_from_args(args)
    try:
        class_precision = (parse_class_precision(args.class_precision)
                           if args.class_precision else None)
    except ValueError as e:
        ap.error(str(e))
    telemetry = telemetry_from_args(args)
    if telemetry is not None and not args.fleet and args.engine != "paged":
        ap.error("--metrics-port/--flight-recorder/--trace-export/"
                 "--profile-ticks need --engine paged or --fleet (the "
                 "static batch path has no scheduler to observe)")
    if args.fleet:
        try:
            r = serve_fleet(args.models, requests=args.requests,
                            gen=args.gen, page_size=args.page_size,
                            total_pages=args.total_pages, seed=args.seed,
                            eos_id=args.eos_id, sampling=sampling,
                            prefix_cache=not args.no_prefix_cache,
                            max_seq_len=args.max_seq_len,
                            prompt_len=args.prompt_len,
                            lazy_pages=args.lazy_pages,
                            watermark=args.watermark,
                            priority=args.priority,
                            deadline_ms=args.deadline_ms,
                            tbt_deadline_ms=args.tbt_deadline_ms,
                            admission=args.admission,
                            aging_ticks=args.aging_ticks,
                            selection=args.selection,
                            kv_dtype=args.kv_dtype,
                            class_precision=class_precision,
                            telemetry=telemetry,
                            metrics_port=args.metrics_port)
        except ValueError as e:
            ap.error(str(e))
        m = r["metrics"]
        f = m["fleet"]
        print(f"[serve.fleet] {f['completed']:.0f} requests "
              f"{f['generated_tokens']:.0f} tokens in "
              f"{f['wall_s'] * 1e3:.0f}ms ({f['tokens_per_s']:.1f} tok/s) "
              f"across {len(m['models'])} models; "
              f"budget {m['budget']['total_pages']} pages "
              f"(surplus {m['budget']['surplus_pages']})")
        for name, mm in m["models"].items():
            print(f"[serve.fleet]   model={name} "
                  f"replicas={len(mm['replicas'])} "
                  f"completed={mm['completed']:.0f} "
                  f"tok/s={mm['tokens_per_s']:.1f} "
                  f"ttft_avg={mm['ttft_avg_s'] * 1e3:.0f}ms "
                  f"prefix_hit_rate={mm['prefix_hit_rate']:.2f} "
                  f"preemptions={mm['preemptions']:.0f}")
        rid0 = min(r["finished"])
        print("[serve.fleet] sample tokens:",
              r["finished"][rid0].generated[:12])
        report_telemetry(args, telemetry, "serve.fleet")
        return
    if args.engine == "paged":
        r = serve_paged(args.arch, requests=args.requests, gen=args.gen,
                        page_size=args.page_size, num_pages=args.num_pages,
                        seed=args.seed, eos_id=args.eos_id, sampling=sampling,
                        prefix_cache=not args.no_prefix_cache,
                        max_seq_len=args.max_seq_len,
                        prompt_len=args.prompt_len,
                        lazy_pages=args.lazy_pages, watermark=args.watermark,
                        priority=args.priority, deadline_ms=args.deadline_ms,
                        tbt_deadline_ms=args.tbt_deadline_ms,
                        admission=args.admission,
                        aging_ticks=args.aging_ticks,
                        kv_dtype=args.kv_dtype,
                        class_precision=class_precision,
                        telemetry=telemetry,
                        metrics_port=args.metrics_port)
        m = r["metrics"]
        print(f"[serve.paged] kv_dtype={m['kv_dtype']} "
              f"page_bytes={m['page_bytes']:.0f}")
        print(f"[serve.paged] {m['completed']:.0f} requests "
              f"{m['generated_tokens']:.0f} tokens in {m['wall_s'] * 1e3:.0f}ms "
              f"({m['tokens_per_s']:.1f} tok/s) "
              f"ttft_avg={m['ttft_avg_s'] * 1e3:.0f}ms "
              f"peak_page_util={m['peak_page_utilization']:.2f} "
              f"prefix_hit_rate={m['prefix_hit_rate']:.2f} "
              f"preemptions={m['preemptions']:.0f}")
        for cls, cm in m["classes"].items():
            print(f"[serve.paged]   class={cls} "
                  f"completed={cm['completed']:.0f} "
                  f"ttft_avg={cm['ttft_avg_s'] * 1e3:.0f}ms "
                  f"ttft_p95={cm['ttft_p95_s'] * 1e3:.0f}ms "
                  f"preemptions={cm['preemptions']:.0f} "
                  f"deadline_misses={cm['deadline_misses']:.0f}")
        print("[serve.paged] sample tokens:",
              r["finished"][0].generated[:12])
        report_telemetry(args, telemetry, "serve.paged")
        return
    r = serve(args.arch, batch=args.batch,
              prompt_len=args.prompt_len or 32,
              gen=args.gen, seed=args.seed, sampling=sampling)
    print(f"[serve] prefill={r['prefill_s'] * 1e3:.0f}ms "
          f"decode={r['decode_s'] * 1e3:.0f}ms "
          f"throughput={r['tokens_per_s']:.1f} tok/s")
    print("[serve] sample tokens:", r["generated"][0][:12].tolist())


if __name__ == "__main__":
    main()
