"""Step-function builders shared by train.py / serve.py / dryrun.py.

One place defines, for every (arch × shape × mesh) cell:
  - the step callable (train_step / prefill_step / decode_step),
  - abstract arguments (ShapeDtypeStructs — nothing allocated),
  - in/out shardings derived from the logical rule table.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map

from repro.configs.base import ModelConfig, ShapeConfig
from repro.configs.registry import input_specs, input_axes
from repro.models import model as M
from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state
from repro.optim.schedules import wsd_schedule
from repro.parallel.sharding import (LogicalRules, DEFAULT_RULES,
                                     activation_rules, rules_for_mesh,
                                     spec_for, spec_for_shape)


@dataclasses.dataclass(frozen=True)
class Cell:
    """Everything the dry-run / launcher needs for one (arch × shape)."""
    name: str
    fn: Any                      # jittable step callable
    abstract_args: Tuple         # pytree of ShapeDtypeStruct
    in_shardings: Tuple
    donate_argnums: Tuple[int, ...]
    rules: LogicalRules
    cfg: ModelConfig
    shape: ShapeConfig


def _tree_shardings(mesh: Mesh, axes_tree, abs_tree, rules: LogicalRules):
    """Shape-aware shardings: axes that don't divide a dim are dropped."""
    is_axes = lambda x: isinstance(x, tuple) and all(
        a is None or isinstance(a, str) for a in x)
    return jax.tree.map(
        lambda axes, ab: NamedSharding(
            mesh, spec_for_shape(axes, ab.shape, rules, mesh)),
        axes_tree, abs_tree, is_leaf=is_axes)


def build_cell(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
               opts: Optional[M.RunOptions] = None,
               base_rules: Optional[LogicalRules] = None,
               lr_peak: float = 3e-4, total_steps: int = 10_000,
               pad_heads: Optional[int] = None) -> Cell:
    if pad_heads is not None:
        cfg = dataclasses.replace(cfg, pad_heads_to=pad_heads)
    rules = rules_for_mesh(mesh, base_rules or DEFAULT_RULES)
    rules, seq_sharded = activation_rules(rules, shape.global_batch, mesh)
    opts = opts or M.RunOptions()
    opts = dataclasses.replace(opts, mesh=mesh)
    if shape.is_decode and opts.decode_kv_seq_axis:
        # flash-decoding-style KV partition: the cache seq dim takes every
        # mesh axis the batch doesn't occupy (spec_for_shape auto-drops
        # conflicts), turning the idle model axis into KV capacity.
        rules = rules.with_overrides(seq_shard=("data", "model"))

    from repro.parallel.pipeline import pp_loss_fn, pp_supported
    use_pp = (opts.pipeline and shape.kind == "train"
              and pp_supported(cfg, mesh))
    if use_pp:
        # pipeline stages across the thin 'pod' axis: layer groups shard
        # over pod (layer grads never cross the spine); DP stays on 'data'
        rules = rules.with_overrides(
            layers="pod",
            batch=tuple(a for a in ("data",) if a in mesh.axis_names))

    batch_abs = input_specs(cfg, shape)
    batch_axes = input_axes(cfg, shape, seq_sharded=seq_sharded)
    batch_sh = {k: NamedSharding(mesh, spec_for(batch_axes[k], rules))
                for k in batch_abs}

    specs = M.param_specs(cfg)
    p_axes = M.axes_tree(specs)
    param_dtype = jnp.float32 if shape.kind == "train" else jnp.bfloat16
    params_abs = M.abstract_params(specs, dtype=param_dtype)
    params_sh = _tree_shardings(mesh, p_axes, params_abs, rules)

    name = f"{cfg.name}:{shape.name}"

    if shape.kind == "train":
        compressed = (opts.grad_sync == "compressed"
                      and "pod" in mesh.axis_names)
        opt_abs = {"mu": params_abs, "nu": params_abs,
                   "count": jax.ShapeDtypeStruct((), jnp.int32)}
        opt_sh = {"mu": params_sh, "nu": params_sh,
                  "count": NamedSharding(mesh, P())}
        if compressed:
            # error-feedback residual per parameter shard (fp32)
            opt_abs["ef"] = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), params_abs)
            opt_sh["ef"] = params_sh

        def _value_and_grad(params, batch, inner_rules):
            """Loss+grads, optionally accumulated over k microbatches (scan):
            (or pipelined over the pod axis when opts.pipeline)."""
            if use_pp:
                fn = pp_loss_fn(cfg, mesh, inner_rules, opts,
                                opts.pp_microbatches)
                return jax.value_and_grad(fn, has_aux=True)(params, batch)
            return _value_and_grad_mb(params, batch, inner_rules)

        def _value_and_grad_mb(params, batch, inner_rules):
            """Loss+grads, optionally accumulated over k microbatches (scan):
            peak activation memory ÷k, and the XLA scheduler can overlap
            microbatch i+1's forward with microbatch i's gradient
            reduce-scatters (compute/comm overlap, DESIGN.md §8)."""
            k = opts.microbatches
            if k <= 1 or shape.global_batch % k != 0:
                return jax.value_and_grad(M.lm_loss, has_aux=True)(
                    params, cfg, batch, inner_rules, opts)
            mb = shape.global_batch // k

            def split(x):
                return x.reshape(k, mb, *x.shape[1:])

            batches = jax.tree.map(split, batch)

            def body(acc, mbatch):
                (loss, metrics), grads = jax.value_and_grad(
                    M.lm_loss, has_aux=True)(params, cfg, mbatch,
                                             inner_rules, opts)
                acc_g, acc_l, acc_m = acc
                acc_g = jax.tree.map(lambda a, g: a + g / k, acc_g, grads)
                acc_m = jax.tree.map(lambda a, v: a + v / k, acc_m, metrics)
                return (acc_g, acc_l + loss / k, acc_m), None

            zeros_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            zeros_m = {"xent": jnp.zeros((), jnp.float32),
                       "aux_loss": jnp.zeros((), jnp.float32)}
            (grads, loss, metrics), _ = jax.lax.scan(
                body, (zeros_g, jnp.zeros((), jnp.float32), zeros_m), batches)
            return (loss, metrics), grads

        def _step_body(params, opt_state, batch, inner_rules):
            (loss, metrics), grads = _value_and_grad(params, batch,
                                                     inner_rules)
            lr = wsd_schedule(opt_state["count"], peak=lr_peak,
                              warmup_steps=total_steps // 100,
                              total_steps=total_steps)
            new_p, new_opt, om = adamw_update(grads, opt_state, params, lr)
            return new_p, new_opt, {**metrics, **om, "loss": loss, "lr": lr}

        if compressed:
            # SAKURAONE rail-optimized sync: in-pod reduction happens inside
            # GSPMD (fat ICI links, full precision); the thin cross-pod hop
            # carries int8 payloads + one fp32 scale per tensor, with error
            # feedback (DESIGN.md §8).  The token-embedding gather/scatter is
            # hoisted OUT of the pod-manual region (XLA cannot partition
            # gathers inside manual subgroups); its input-path gradient is
            # chain-ruled outside and synced by XLA's own collective.
            from repro.core.collectives import int8_compress
            inner = rules.with_overrides(
                batch=tuple(a for a in ("data",) if a in mesh.axis_names))
            npods = mesh.shape["pod"]

            def body(params, ef, batch):
                def loss_fn(pp, xe):
                    bb = dict(batch, tok_embeds=xe)
                    return M.lm_loss(pp, cfg, bb, inner, opts)

                (loss, metrics), (gp, gx) = jax.value_and_grad(
                    loss_fn, argnums=(0, 1), has_aux=True)(
                    params, batch["tok_embeds"])

                def sync(g, e):
                    g32 = g.astype(jnp.float32) / npods + e
                    q, s = int8_compress(g32)
                    qs = jax.lax.all_gather(q, "pod", axis=0, tiled=False)
                    ss = jax.lax.all_gather(s, "pod", axis=0, tiled=False)
                    summed = jnp.einsum("p...,p->...",
                                        qs.astype(jnp.float32), ss)
                    return summed.astype(g.dtype), g32 - q.astype(jnp.float32) * s

                flat_g, tdef = jax.tree.flatten(gp)
                flat_e = tdef.flatten_up_to(ef)
                pairs = [sync(g, e) for g, e in zip(flat_g, flat_e)]
                gp = jax.tree.unflatten(tdef, [x[0] for x in pairs])
                new_ef = jax.tree.unflatten(tdef, [x[1] for x in pairs])
                loss = jax.lax.pmean(loss, "pod")
                metrics = jax.tree.map(lambda v: jax.lax.pmean(v, "pod"),
                                       metrics)
                return loss, metrics, gp, new_ef, gx

            def train_step(params, opt_state, batch):
                x_emb = jnp.take(params["embed"], batch["tokens"], axis=0)
                bb = dict(batch, tok_embeds=x_emb)
                in_batch_specs = {k: P("pod") for k in bb}
                fn = shard_map(
                    body, mesh=mesh, axis_names={"pod"},
                    in_specs=(P(), P(), in_batch_specs),
                    out_specs=(P(), P(), P(), P(), P("pod")),
                    check_vma=False)
                loss, metrics, grads, new_ef, gx = fn(
                    params, opt_state["ef"], bb)
                # input-path embedding gradient (global scatter, auto region)
                emb_in = jnp.zeros_like(params["embed"]).at[
                    batch["tokens"].reshape(-1)].add(
                    (gx / npods).reshape(-1, gx.shape[-1]).astype(
                        params["embed"].dtype))
                grads = dict(grads)
                grads["embed"] = grads["embed"] + emb_in
                lr = wsd_schedule(opt_state["count"], peak=lr_peak,
                                  warmup_steps=total_steps // 100,
                                  total_steps=total_steps)
                base_opt = {k: opt_state[k] for k in ("mu", "nu", "count")}
                new_p, new_opt, om = adamw_update(grads, base_opt, params, lr)
                new_opt["ef"] = new_ef
                return new_p, new_opt, {**metrics, **om, "loss": loss, "lr": lr}
        else:
            def train_step(params, opt_state, batch):
                return _step_body(params, opt_state, batch, rules)

        return Cell(name, train_step, (params_abs, opt_abs, batch_abs),
                    (params_sh, opt_sh, batch_sh), (0, 1), rules, cfg, shape)

    if shape.kind == "prefill":
        def prefill_step(params, batch):
            return M.prefill(params, cfg, batch, rules, opts)

        return Cell(name, prefill_step, (params_abs, batch_abs),
                    (params_sh, batch_sh), (), rules, cfg, shape)

    # decode
    cache_abs, cache_axes = M.cache_specs(cfg, shape.global_batch,
                                          shape.seq_len, opts)
    cache_sh = _tree_shardings(mesh, cache_axes, cache_abs, rules)

    def decode_fn(params, cache, tokens, pos):
        return M.decode_step(params, cfg, cache, tokens, pos, rules, opts)

    tok_sh = NamedSharding(mesh, spec_for(("batch", None), rules))
    pos_sh = NamedSharding(mesh, spec_for(("batch",), rules))
    return Cell(name, decode_fn,
                (params_abs, cache_abs, batch_abs["tokens"],
                 jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)),
                (params_sh, cache_sh, tok_sh, pos_sh), (1,), rules, cfg, shape)


def lower_cell(cell: Cell):
    """jit + lower with abstract args (no allocation)."""
    fn = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                 donate_argnums=cell.donate_argnums)
    return fn.lower(*cell.abstract_args)
