import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above run before ANY other import — jax locks the device
count on first init, and the dry-run needs 512 placeholder host devices to
build the production meshes.  (Do NOT import this module from tests or
benchmarks: they must see 1 device.)

For each cell this records, into experiments/dryrun/<cell>.json:
  - memory_analysis (per-device argument/output/temp/code bytes),
  - cost_analysis (per-device HLO flops / bytes accessed),
  - collective operand bytes parsed from the compiled HLO, by op kind,
  - lowering/compile wall times,
and prints the roofline terms (repro.core.topology.roofline).

Usage:
  python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--skip-existing]
"""
import argparse
import json
import re
import time
import traceback

import jax

from repro.configs import ARCH_IDS, SHAPES, cell_supported, get_config, get_shape
from repro.core import topology
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_cell, lower_cell
from repro.models.model import RunOptions

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute")
_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1}
_ARRAY_RE = re.compile(r"\b([a-z]\w*?)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s+(\([^=]*?\)|\S+)\s+(all-reduce-start|all-gather-start|"
    r"all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute-start|"
    r"collective-permute)\(")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _arr_bytes(dtype: str, dims: str) -> int:
    size = _DTYPE_BYTES.get(dtype)
    if size is None:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * size


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))            # replica_groups=[G,S]<=[N]
    m = _GROUPS_BRACE_RE.search(line)
    if m:                                  # replica_groups={{0,1},...}
        return len(m.group(1).split(","))
    return 1


def collective_bytes(hlo_text: str) -> dict:
    """Per-device *operand* bytes of every collective, by op kind.

    Optimized HLO prints operand references without inline types, so operand
    sizes are derived from the (typed) result + op semantics:
      all-reduce / all-to-all / collective-permute: operand == result
      all-gather: operand = result / group_size
      reduce-scatter: operand = result × group_size
    """
    out = {k: 0 for k in COLLECTIVE_OPS}
    counts = {k: 0 for k in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        result_type, kind = m.group(1), m.group(2).replace("-start", "")
        rbytes = sum(_arr_bytes(d, s) for d, s in _ARRAY_RE.findall(result_type))
        g = _group_size(line)
        if kind == "all-gather":
            ob = rbytes // max(g, 1)
        elif kind == "reduce-scatter":
            ob = rbytes * g
        else:
            ob = rbytes
        out[kind] += ob
        counts[kind] += 1
    return {"bytes_by_op": {k: v for k, v in out.items() if counts[k]},
            "counts": {k: v for k, v in counts.items() if v},
            "total": sum(out.values())}


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             opts: RunOptions = None, out_dir: str = "experiments/dryrun",
             tag: str = "", base_rules=None, verbose: bool = True,
             pad_heads=None) -> dict:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    ok, reason = cell_supported(cfg, shape)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cell_id = f"{arch}__{shape_name}__{mesh_name}" + (f"__{tag}" if tag else "")
    record = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
              "tag": tag, "supported": ok}
    if not ok:
        record["skip_reason"] = reason
        _write(out_dir, cell_id, record)
        if verbose:
            print(f"[dryrun] {cell_id}: SKIP ({reason})")
        return record

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    t0 = time.perf_counter()
    cell = build_cell(cfg, shape, mesh, opts=opts, base_rules=base_rules,
                      pad_heads=pad_heads)
    with mesh:
        lowered = lower_cell(cell)
        t_lower = time.perf_counter() - t0
        t0 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)          # flat (loop bodies counted once)
    loop_aware = hlo_analysis.analyze(hlo)  # trip-count-corrected

    flops_dev = float(loop_aware["flops"])
    bytes_dev = float(loop_aware["bytes_accessed"])
    coll_dev = float(loop_aware["collective_total"])
    rt = topology.roofline(flops_dev * n_chips, bytes_dev * n_chips,
                           coll_dev * n_chips, n_chips)
    record.update({
        "n_chips": n_chips,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
        },
        "per_device": {"hlo_flops": flops_dev, "hlo_bytes": bytes_dev,
                       "collective_bytes": coll_dev},
        "cost_analysis_raw": {"flops": float(cost.get("flops", 0.0)),
                              "bytes": float(cost.get("bytes accessed", 0.0))},
        "collectives": {
            "loop_aware_bytes_by_op": loop_aware["collective_bytes"],
            "loop_aware_counts": loop_aware["collective_counts"],
            "flat_bytes_by_op": coll["bytes_by_op"],
        },
        "bytes_by_op": loop_aware["bytes_by_op"],
        "roofline": {"compute_s": rt.compute_s, "memory_s": rt.memory_s,
                     "collective_s": rt.collective_s, "dominant": rt.dominant,
                     "step_s": rt.step_s},
    })
    _write(out_dir, cell_id, record)
    if verbose:
        mb = (record["memory"]["argument_bytes"] or 0) / (1 << 30)
        print(f"[dryrun] {cell_id}: OK lower={t_lower:.1f}s "
              f"compile={t_compile:.1f}s args={mb:.2f}GiB/dev "
              f"flops/dev={flops_dev:.3e} coll/dev={coll_dev:.3e}B "
              f"dominant={rt.dominant} step={rt.step_s * 1e3:.2f}ms")
    return record


def _write(out_dir: str, cell_id: str, record: dict):
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, f"{cell_id}.json"), "w") as f:
        json.dump(record, f, indent=1)


def run_hpl_cell(*, n: int = 131_072, nb: int = 1024, matmul: str = "fp32",
                 multi_pod: bool = False, out_dir: str = "experiments/dryrun",
                 verbose: bool = True) -> dict:
    """Dry-run the paper's own benchmark: distributed HPL (blocked LU with
    the matrix 2-D sharded over the production mesh).  N is chosen so the
    local tile (N/16 × N/16 fp32 = 256 MiB at N=131072) fits v5e HBM with
    room for the trailing-update temporaries."""
    from repro.core.hpl import distributed_hpl_setup
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cell_id = f"hpl-{matmul}-n{n}__{mesh_name}"
    t0 = time.perf_counter()
    fn, abstract, _ = distributed_hpl_setup(mesh, n, nb=nb, matmul=matmul)
    with mesh:
        lowered = fn.lower(abstract)
        t_lower = time.perf_counter() - t0
        t0 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0
    mem = compiled.memory_analysis()
    loop_aware = hlo_analysis.analyze(compiled.as_text())
    n_chips = mesh.size
    rt = topology.roofline(loop_aware["flops"] * n_chips,
                           loop_aware["bytes_accessed"] * n_chips,
                           loop_aware["collective_total"] * n_chips, n_chips)
    from repro.core.hpl import hpl_flops
    record = {
        "arch": f"hpl-{matmul}", "shape": f"n{n}_nb{nb}", "mesh": mesh_name,
        "supported": True, "n_chips": n_chips,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": {"argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                   "temp_bytes": getattr(mem, "temp_size_in_bytes", None)},
        "per_device": {"hlo_flops": loop_aware["flops"],
                       "hlo_bytes": loop_aware["bytes_accessed"],
                       "collective_bytes": loop_aware["collective_total"]},
        "collectives": {"loop_aware_bytes_by_op": loop_aware["collective_bytes"]},
        "hpl_flops_analytic": hpl_flops(n),
        "roofline": {"compute_s": rt.compute_s, "memory_s": rt.memory_s,
                     "collective_s": rt.collective_s, "dominant": rt.dominant,
                     "step_s": rt.step_s},
    }
    _write(out_dir, cell_id, record)
    if verbose:
        print(f"[dryrun] {cell_id}: OK lower={t_lower:.1f}s "
              f"compile={t_compile:.1f}s flops/dev={loop_aware['flops']:.3e} "
              f"coll/dev={loop_aware['collective_total']:.3e}B "
              f"dominant={rt.dominant} time~{rt.step_s:.1f}s "
              f"(analytic 2/3·n³: {hpl_flops(n):.3e} total)")
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--optimized", action="store_true",
                    help="run with the post-hillclimb option set "
                         "(EXPERIMENTS.md §Perf) instead of the "
                         "paper-faithful baseline")
    ap.add_argument("--hpl", action="store_true",
                    help="dry-run the distributed HPL benchmark instead of "
                         "the architecture cells")
    ap.add_argument("--hpl-n", type=int, default=131_072)
    ap.add_argument("--hpl-matmul", default="fp32",
                    choices=["fp32", "bf16", "fp8"])
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    if args.hpl:
        run_hpl_cell(n=args.hpl_n, matmul=args.hpl_matmul,
                     multi_pod=args.multi_pod, out_dir=args.out)
        raise SystemExit(0)

    cells = []
    archs = ARCH_IDS if (args.all or not args.arch) else (args.arch,)
    shapes = tuple(SHAPES) if (args.all or not args.shape) else (args.shape,)
    meshes = (False, True) if args.both_meshes else (args.multi_pod,)
    for a in archs:
        for s in shapes:
            for mp in meshes:
                cells.append((a, s, mp))

    failures = []
    for a, s, mp in cells:
        mesh_name = "pod2x16x16" if mp else "pod16x16"
        tag = "opt" if args.optimized else ""
        fname = f"{a}__{s}__{mesh_name}" + (f"__{tag}" if tag else "")
        path = os.path.join(args.out, fname + ".json")
        if args.skip_existing and os.path.exists(path):
            existing = json.load(open(path))
            if existing.get("supported") is False or "roofline" in existing:
                print(f"[dryrun] {fname}: cached")
                continue
        opts = None
        pad_heads = None
        if args.optimized:
            opts = RunOptions(ring_local_cache=True, decode_kv_seq_axis=True,
                              moe_impl="capacity")
            if a == "minicpm-2b":
                pad_heads = 48
        try:
            run_cell(a, s, multi_pod=mp, out_dir=args.out, tag=tag,
                     opts=opts, pad_heads=pad_heads)
        except Exception as e:  # noqa: BLE001 — record and continue
            failures.append((a, s, mp, repr(e)))
            print(f"[dryrun] {fname}: FAIL {e}")
            traceback.print_exc()
    print(f"\n[dryrun] done; {len(failures)} failures")
    for f in failures:
        print("  FAIL:", f)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
