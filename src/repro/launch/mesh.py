"""Production mesh factory (the SAKURAONE 2-pod layout, TPU-adapted).

A FUNCTION, not a module-level constant — importing this module never
touches jax device state (smoke tests must keep seeing 1 CPU device).
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

try:  # jax >= 0.5: explicit-sharding axis types
    from jax.sharding import AxisType
except ImportError:  # jax 0.4.x: plain meshes only
    AxisType = None


def _make_mesh(shape, axes) -> Mesh:
    """jax.make_mesh with axis_types when the installed JAX supports it."""
    if AxisType is not None:
        try:
            return jax.make_mesh(shape, axes,
                                 axis_types=(AxisType.Auto,) * len(axes))
        except TypeError:
            pass
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16×16 single-pod (256 chips) or 2×16×16 multi-pod (512 chips).

    Axis order mirrors the paper's bandwidth hierarchy: "pod" is the thin
    cross-pod (DCN/spine) layer, "data"/"model" the fat in-pod layer, with
    "model" innermost on the highest-bandwidth links.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh() -> Mesh:
    """Whatever this host has (smoke tests / examples): (1, N) data×model."""
    n = len(jax.devices())
    return _make_mesh((n, 1), ("data", "model"))
