"""End-to-end training driver: data pipeline -> sharded train loop ->
striped async checkpoints -> elastic recovery.

Runs real steps on whatever devices exist (a reduced config on the CPU
container; the full config on a TPU slice).  The recovery loop follows
DESIGN.md §8: on a (simulated or real) node failure the coordinator plans a
new mesh from survivors, state restores from the last committed manifest,
and the deterministic pipeline replays from the restored step.

Usage (CPU container):
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --reduced \
      --steps 20 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config, reduced_config
from repro.configs.base import ShapeConfig
from repro.data.pipeline import TokenPipeline
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import build_cell
from repro.models import model as M
from repro.optim.adamw import init_opt_state
from repro.runtime.elastic import ElasticCoordinator
from repro.parallel.sharding import spec_for


def make_train_state(cell, key):
    params = M.init_params(M.param_specs(cell.cfg), key)
    params = jax.device_put(params, cell.in_shardings[0])
    opt = init_opt_state(params)
    opt = jax.device_put(opt, cell.in_shardings[1])
    return params, opt


def train(arch: str, *, steps: int = 20, batch: int = 8, seq: int = 128,
          reduced: bool = True, ckpt_dir: str | None = None,
          ckpt_every: int = 10, resume: bool = True,
          fail_at_step: int | None = None, log_every: int = 1,
          opts: M.RunOptions | None = None, lr_peak: float = 1e-3,
          total_steps: int | None = None):
    cfg = get_config(arch)
    if reduced:
        cfg = reduced_config(cfg)
    shape = ShapeConfig("custom", seq, batch, "train")
    mesh = make_host_mesh()
    opts = opts or M.RunOptions(q_chunk=min(seq, 512), xent_chunk=min(seq, 512))
    cell = build_cell(cfg, shape, mesh, opts=opts, lr_peak=lr_peak,
                      total_steps=total_steps or max(10 * steps, 100))

    step_fn = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                      donate_argnums=cell.donate_argnums)
    pipe = TokenPipeline(cfg.vocab_size, seq, batch, mesh=mesh,
                         batch_spec=spec_for(("batch", None), cell.rules))
    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
    coord = ElasticCoordinator(
        hosts=[f"host{i}" for i in range(max(jax.process_count(), 1))],
        devices_per_host=jax.local_device_count(),
        model_parallel=mesh.shape.get("model", 1), num_pods=1)

    start = 0
    with mesh:
        params, opt = make_train_state(cell, jax.random.PRNGKey(0))
        if mgr and resume and mgr.latest_step() is not None:
            start, state = mgr.restore(
                {"params": params, "opt": opt},
                shardings={"params": cell.in_shardings[0],
                           "opt": cell.in_shardings[1]})
            params, opt = state["params"], state["opt"]
            print(f"[train] restored from step {start}")

        losses = []
        try:
            for step in range(start, steps):
                if fail_at_step is not None and step == fail_at_step:
                    raise RuntimeError(f"injected failure at step {step}")
                t0 = time.perf_counter()
                batch_arrs = pipe.get_batch(step)
                params, opt, metrics = step_fn(params, opt, batch_arrs)
                loss = float(metrics["loss"])
                dt = time.perf_counter() - t0
                coord.straggle.record("host0", dt)
                coord.hb.beat("host0")
                losses.append(loss)
                if step % log_every == 0:
                    print(f"[train] step={step} loss={loss:.4f} "
                          f"gnorm={float(metrics['grad_norm']):.3f} "
                          f"lr={float(metrics['lr']):.2e} {dt * 1e3:.0f}ms")
                if mgr and (step + 1) % ckpt_every == 0:
                    mgr.save_async(step + 1, {"params": params, "opt": opt})
        finally:
            # a training-step failure must not kill an in-flight async
            # save: flush it so restart sees the last issued checkpoint
            if mgr:
                mgr.wait()
        if mgr:
            mgr.save(steps, {"params": params, "opt": opt})
    return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--fail-at-step", type=int, default=None)
    args = ap.parse_args()
    losses = train(args.arch, steps=args.steps, batch=args.batch,
                   seq=args.seq, reduced=args.reduced,
                   ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                   fail_at_step=args.fail_at_step)
    print(f"[train] done; loss {losses[0]:.4f} -> {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
