"""Loop-aware cost analysis of compiled (post-optimization) HLO text.

``compiled.cost_analysis()`` counts each while-loop body ONCE, which
undercounts a scanned-layer-stack program by the layer count (28-64× here)
— for FLOPs, bytes, and collectives alike.  This module re-derives the
three roofline inputs from the HLO text with loop trip-count multipliers
propagated through the call graph:

  - dot FLOPs: 2 × |output| × (contracted dims)  per dot/matmul custom-call
  - memory bytes: Σ (operand bytes + result bytes) per instruction
    (fusion-internal traffic excluded — fusions count at their interface,
    matching how VMEM-resident fusion temporaries behave on TPU)
  - collective operand bytes, by op kind

Everything is per-device (SPMD module).  Used by launch/dryrun.py; unit
tested against hand-built HLO programs in tests/test_hlo_analysis.py.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Tuple

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "f8e4m3": 1, "s64": 8, "u64": 8, "s32": 4,
                "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
                "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"\b([a-z]\w*?)\[([\d,]*)\]")
_COMP_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{")
_INSTR_RE = re.compile(r"^\s+(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(.*?\)|\S+)\s+([\w\-]+)")
_CALLED_RE = re.compile(r"(?:body|condition|to_apply|calls)=%?([\w.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count=\{"?n"?[:=]"?(\d+)"?\}|"known_trip_count":\{"n":"(\d+)"\}')
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

COLLECTIVE_KINDS = ("all-reduce", "all-gather", "reduce-scatter",
                    "all-to-all", "collective-permute")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        sz = _DTYPE_BYTES.get(dt)
        if sz is None:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * sz
    return total


def _first_shape(type_str: str) -> Tuple[str, List[int]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return "", []
    dims = [int(d) for d in m.group(2).split(",") if d]
    return m.group(1), dims


class Instruction:
    __slots__ = ("name", "type_str", "op", "line")

    def __init__(self, name, type_str, op, line):
        self.name, self.type_str, self.op, self.line = name, type_str, op, line


def parse_computations(text: str) -> Dict[str, List[Instruction]]:
    comps: Dict[str, List[Instruction]] = {}
    cur: List[Instruction] | None = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HEADER_RE.match(line.strip()) if ("{" in line and "->" in line) else None
            if m and not line.lstrip().startswith("//"):
                comps[m.group(1)] = cur = []
            continue
        if line.startswith("}") or line.strip() == "}":
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if m:
            cur.append(Instruction(m.group(1), m.group(2), m.group(3), line))
    return comps


def _multipliers(comps: Dict[str, List[Instruction]]) -> Dict[str, float]:
    """Execution-count multiplier per computation (while trip counts
    propagated transitively through body/condition/to_apply/calls edges)."""
    # edges: (caller, callee, factor)
    edges: List[Tuple[str, str, float]] = []
    for cname, instrs in comps.items():
        for ins in instrs:
            callees = _CALLED_RE.findall(ins.line)
            trip = 1.0
            if ins.op == "while":
                tm = _TRIP_RE.search(ins.line)
                if tm:
                    trip = float(tm.group(1) or tm.group(2))
            for callee in callees:
                edges.append((cname, callee, trip if ins.op == "while" else 1.0))

    mult: Dict[str, float] = defaultdict(float)
    # roots: computations never called
    called = {c for _, c, _ in edges}
    for c in comps:
        if c not in called:
            mult[c] = 1.0
    # propagate (graph is a DAG; iterate to fixpoint bounded by |comps|)
    for _ in range(len(comps)):
        changed = False
        new = defaultdict(float)
        for c, m in mult.items():
            new[c] = max(new[c], m)
        for caller, callee, f in edges:
            if caller in mult:
                cand = mult[caller] * f
                if cand > new[callee]:
                    new[callee] = cand
                    changed = True
        mult = new
        if not changed:
            break
    return dict(mult)


def _dot_flops(ins: Instruction, symbols: Dict[str, str]) -> float:
    """2 × |out| × Π(contracting dims of lhs)."""
    _, out_dims = _first_shape(ins.type_str)
    out_elems = 1
    for d in out_dims:
        out_elems *= d
    cm = _CONTRACT_RE.search(ins.line)
    operands = [o for o in _OPERAND_RE.findall(
        ins.line.split("(", 1)[1]) if o in symbols]
    contract = 1
    if cm is not None and operands:
        _, lhs_dims = _first_shape(symbols[operands[0]])
        for idx in (int(i) for i in cm.group(1).split(",") if i):
            if idx < len(lhs_dims):
                contract *= lhs_dims[idx]
    return 2.0 * out_elems * contract


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


def analyze(text: str) -> dict:
    comps = parse_computations(text)
    mult = _multipliers(comps)

    flops = 0.0
    bytes_accessed = 0.0
    bytes_by_op: Dict[str, float] = defaultdict(float)
    coll_bytes = {k: 0.0 for k in COLLECTIVE_KINDS}
    coll_counts = {k: 0.0 for k in COLLECTIVE_KINDS}

    # fusion bodies whose root is a dynamic-update-slice run in place on
    # TPU: the call site's traffic is the update slice, not the buffer.
    dus_root_update_bytes: Dict[str, int] = {}
    slice_root_comps = set()
    for cname, instrs in comps.items():
        syms = {i.name: i.type_str for i in instrs}
        for ins in instrs:
            if "ROOT" not in ins.line:
                continue
            if ins.op == "dynamic-update-slice":
                ops = _OPERAND_RE.findall(ins.line.split("(", 1)[1])
                if len(ops) >= 2 and ops[1] in syms:
                    dus_root_update_bytes[cname] = _type_bytes(syms[ops[1]])
            elif ins.op in ("dynamic-slice", "gather", "slice"):
                slice_root_comps.add(cname)

    for cname, instrs in comps.items():
        m = mult.get(cname, 1.0)
        if m == 0.0:
            continue
        symbols = {ins.name: ins.type_str for ins in instrs}
        is_fusion_body = cname.startswith("fused")
        for ins in instrs:
            kind = ins.op.replace("-start", "")
            if ins.op in ("dot", "dot-general") or (
                    ins.op == "custom-call" and "matmul" in ins.line):
                flops += m * _dot_flops(ins, symbols)
            if kind in coll_bytes:
                rbytes = _type_bytes(ins.type_str)
                g = _group_size(ins.line)
                if kind == "all-gather":
                    ob = rbytes / max(g, 1)
                elif kind == "reduce-scatter":
                    ob = rbytes * g
                else:
                    ob = rbytes
                coll_bytes[kind] += m * ob
                coll_counts[kind] += m
            # memory traffic at instruction interfaces (skip fusion internals)
            if not is_fusion_body and ins.op not in (
                    "parameter", "constant", "tuple", "get-tuple-element",
                    "bitcast", "while", "call"):
                rbytes = _type_bytes(ins.type_str)
                args = ins.line.split("(", 1)
                operands = (_OPERAND_RE.findall(args[1].split("),")[0])
                            if len(args) > 1 else [])
                fused_callee = None
                if ins.op == "fusion":
                    cm = _CALLED_RE.search(ins.line)
                    fused_callee = cm.group(1) if cm else None
                if ins.op == "dynamic-update-slice" and len(operands) >= 2 \
                        and operands[1] in symbols:
                    # in-place on TPU: traffic = read update + write region,
                    # NOT the whole buffer
                    ub = _type_bytes(symbols[operands[1]])
                    cost = 2 * ub
                elif fused_callee in dus_root_update_bytes:
                    # in-place fusion: update-slice traffic + non-buffer
                    # operands (approximate: update read+write only)
                    cost = 2 * dus_root_update_bytes[fused_callee]
                elif (ins.op in ("dynamic-slice", "gather", "slice")
                      or fused_callee in slice_root_comps):
                    # slicing reads only the slice, not the source buffer
                    cost = 2 * rbytes
                else:
                    obytes = sum(_type_bytes(symbols[o]) for o in operands
                                 if o in symbols)
                    cost = rbytes + obytes
                bytes_accessed += m * cost
                bytes_by_op[ins.op] += m * cost

    total_coll = sum(coll_bytes.values())
    top_bytes = dict(sorted(bytes_by_op.items(), key=lambda kv: -kv[1])[:12])
    return {
        "flops": flops,
        "bytes_accessed": bytes_accessed,
        "bytes_by_op": top_bytes,
        "collective_bytes": {k: v for k, v in coll_bytes.items() if v},
        "collective_counts": {k: v for k, v in coll_counts.items() if v},
        "collective_total": total_coll,
        "num_computations": len(comps),
    }
