"""IO500-like storage benchmark (paper Table 10) over the checkpoint store.

Mirrors IO500's phase structure against the local filesystem through the
same code path production checkpoints use (repro.checkpoint):

  ior-easy   — large sequential striped writes/reads (bandwidth, GiB/s)
  ior-hard   — small unaligned interleaved writes (worst-case bandwidth)
  mdtest     — many tiny files create/stat/delete (metadata kIOPS)
  find       — tree traversal rate

Scores combine exactly like IO500: bandwidth score = geometric mean of the
ior phases, IOPS score = geometric mean of the mdtest/find phases, total =
sqrt(bw · iops).  The paper's 10-node-vs-96-node observation (bandwidth
saturates, metadata scales) is reproduced by sweeping `nproc` workers.
"""
from __future__ import annotations

import math
import os
import shutil
import tempfile
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Dict

import numpy as np


def _gib(nbytes: float) -> float:
    return nbytes / (1 << 30)


def ior_easy(root: str, *, nproc: int = 4, mb_per_proc: int = 64,
             stripe_mb: int = 8) -> Dict[str, float]:
    """Sequential striped I/O, one file per process (IOR easy mode)."""
    data = np.random.default_rng(0).bytes(stripe_mb << 20)
    stripes = mb_per_proc // stripe_mb

    def write_one(i):
        with open(os.path.join(root, f"ior_easy_{i}"), "wb") as f:
            for _ in range(stripes):
                f.write(data)
            f.flush()
            os.fsync(f.fileno())

    def read_one(i):
        total = 0
        with open(os.path.join(root, f"ior_easy_{i}"), "rb") as f:
            while True:
                buf = f.read(stripe_mb << 20)
                if not buf:
                    return total
                total += len(buf)

    with ThreadPoolExecutor(nproc) as ex:
        t0 = time.perf_counter()
        list(ex.map(write_one, range(nproc)))
        t_w = time.perf_counter() - t0
        t0 = time.perf_counter()
        list(ex.map(read_one, range(nproc)))
        t_r = time.perf_counter() - t0
    total = nproc * mb_per_proc << 20
    return {"write_gibs": _gib(total) / t_w, "read_gibs": _gib(total) / t_r}


def ior_hard(root: str, *, nproc: int = 4, blocks: int = 512,
             block_size: int = 47_008) -> Dict[str, float]:
    """Small unaligned interleaved records into a shared file (IOR hard)."""
    payload = np.random.default_rng(1).bytes(block_size)
    path = os.path.join(root, "ior_hard")
    with open(path, "wb") as f:
        f.truncate(nproc * blocks * block_size)

    def write_one(rank):
        with open(path, "r+b") as f:
            for i in range(blocks):
                f.seek((i * nproc + rank) * block_size)
                f.write(payload)
            f.flush()
            os.fsync(f.fileno())

    def read_one(rank):
        with open(path, "rb") as f:
            for i in range(blocks):
                f.seek((i * nproc + rank) * block_size)
                f.read(block_size)

    with ThreadPoolExecutor(nproc) as ex:
        t0 = time.perf_counter()
        list(ex.map(write_one, range(nproc)))
        t_w = time.perf_counter() - t0
        t0 = time.perf_counter()
        list(ex.map(read_one, range(nproc)))
        t_r = time.perf_counter() - t0
    total = nproc * blocks * block_size
    return {"write_gibs": _gib(total) / t_w, "read_gibs": _gib(total) / t_r}


def mdtest(root: str, *, nproc: int = 4, files_per_proc: int = 500) -> Dict[str, float]:
    """Create/stat/delete many tiny files (metadata kIOPS)."""
    def create(rank):
        d = os.path.join(root, f"md{rank}")
        os.makedirs(d, exist_ok=True)
        for i in range(files_per_proc):
            with open(os.path.join(d, f"f{i}"), "wb") as f:
                f.write(b"x")

    def stat(rank):
        d = os.path.join(root, f"md{rank}")
        for i in range(files_per_proc):
            os.stat(os.path.join(d, f"f{i}"))

    def delete(rank):
        d = os.path.join(root, f"md{rank}")
        for i in range(files_per_proc):
            os.unlink(os.path.join(d, f"f{i}"))

    out = {}
    total = nproc * files_per_proc
    with ThreadPoolExecutor(nproc) as ex:
        for name, fn in (("create", create), ("stat", stat), ("delete", delete)):
            t0 = time.perf_counter()
            list(ex.map(fn, range(nproc)))
            out[f"{name}_kiops"] = total / (time.perf_counter() - t0) / 1e3
    return out


def find_phase(root: str) -> Dict[str, float]:
    t0 = time.perf_counter()
    count = sum(len(files) for _, _, files in os.walk(root))
    dt = time.perf_counter() - t0
    return {"found": count, "find_kiops": count / max(dt, 1e-9) / 1e3}


def run_io500(*, nproc: int = 4, mb_per_proc: int = 32, files_per_proc: int = 300,
              workdir: str | None = None) -> dict:
    root = workdir or tempfile.mkdtemp(prefix="io500_")
    os.makedirs(root, exist_ok=True)
    try:
        easy = ior_easy(root, nproc=nproc, mb_per_proc=mb_per_proc,
                        stripe_mb=min(8, mb_per_proc))
        hard = ior_hard(root, nproc=nproc)
        md = mdtest(root, nproc=nproc, files_per_proc=files_per_proc)
        fnd = find_phase(root)
        bw_phases = [easy["write_gibs"], easy["read_gibs"],
                     hard["write_gibs"], hard["read_gibs"]]
        iops_phases = [md["create_kiops"], md["stat_kiops"], md["delete_kiops"],
                       fnd["find_kiops"]]
        bw_score = math.exp(sum(math.log(max(p, 1e-9)) for p in bw_phases) / len(bw_phases))
        iops_score = math.exp(sum(math.log(max(p, 1e-9)) for p in iops_phases) / len(iops_phases))
        return {
            "nproc": nproc,
            "ior_easy": easy, "ior_hard": hard, "mdtest": md, "find": fnd,
            "bandwidth_score_gibs": bw_score,
            "iops_score_kiops": iops_score,
            "total_score": math.sqrt(bw_score * iops_score),
        }
    finally:
        if workdir is None:
            shutil.rmtree(root, ignore_errors=True)
