"""HPL-MxP: low-precision blocked LU + iterative refinement (paper Table 9).

The benchmark's method (Haidar et al. 2019): factor A once in LOW precision
(the paper uses "sloppy FP8" on H100 tensor cores; we use fp8-emulated /
bf16 GEMMs on the MXU), then recover fp32 accuracy with cheap refinement
iterations — each iteration is O(n²) vs the O(n³) factorization.  The
validation criterion matches the paper: scaled residual < 16.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.hpl import (blocked_lu, lu_solve, make_test_matrix,
                            hpl_residual, hpl_flops)
from repro.core.mixed_precision import iterative_refinement


def run_hplmxp(n: int = 1024, nb: int = 128, *, lowprec: str = "fp8",
               ir_iters: int = 8) -> dict:
    """LU in low precision + IR to fp32; Table-9-shaped record."""
    a, b = make_test_matrix(n)

    lu_fn = jax.jit(lambda m: blocked_lu(m, nb=nb, matmul=lowprec))
    lu = lu_fn(a)
    lu.block_until_ready()
    t0 = time.perf_counter()
    lu = lu_fn(a)
    lu.block_until_ready()
    t_lu = time.perf_counter() - t0

    solve = jax.jit(lambda r: lu_solve(lu, r))
    apply_a = jax.jit(lambda x: a.astype(jnp.float32) @ x)

    t0 = time.perf_counter()
    x, hist = iterative_refinement(apply_a, solve, b, iters=ir_iters)
    x.block_until_ready()
    t_ir = time.perf_counter() - t0

    resid = float(hpl_residual(a, x, b))
    total = t_lu + t_ir
    return {
        "N": n, "NB": nb, "precision": lowprec,
        "lu_time_s": t_lu, "ir_time_s": t_ir, "time_s": total,
        "gflops": hpl_flops(n) / total / 1e9,
        "gflops_lu_only": hpl_flops(n) / t_lu / 1e9,
        "residual": resid, "passed": resid < 16.0,
        "ir_history": [float(h) for h in hist],
    }
