"""Topology-aware hierarchical collectives (the rail-optimized insight).

``hierarchical_psum`` implements the paper-faithful 3-phase all-reduce for
gradient synchronization across the 2-pod production mesh:

  1. reduce-scatter over the fat in-pod axis ("data", ICI),
  2. all-reduce of the 1/N shard over the thin cross-pod axis ("pod", DCN),
  3. all-gather back over "data".

Cross-pod traffic shrinks by the in-pod DP size (16× on the production
mesh) versus a flat all-reduce ring spanning both pods — the JAX rendering
of keeping traffic on the rails and off the spine.

``compressed_psum`` adds int8 gradient compression with error feedback on
the cross-pod hop only (DESIGN.md §8): the scarce link carries 1/4 the
bytes while in-pod reduction stays full precision.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import axis_size, shard_map


def _flatten_pad(x, n):
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % n
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    return flat, pad


def hierarchical_psum_local(x, *, in_axis: str = "data", cross_axis: str = "pod"):
    """Inside shard_map: hierarchical all-reduce of a local array.

    Equivalent to psum over (in_axis, cross_axis) but with the rail-optimized
    schedule: cross-axis hop moves only 1/|in_axis| of the bytes.
    """
    n = axis_size(in_axis)
    flat, pad = _flatten_pad(x, n)
    shard = flat.reshape(n, -1)
    # Phase 1: reduce-scatter in-pod.
    mine = jax.lax.psum_scatter(shard, in_axis, scatter_dimension=0, tiled=False)
    # Phase 2: all-reduce the shard across pods (thin layer).
    mine = jax.lax.psum(mine, cross_axis)
    # Phase 3: all-gather in-pod.
    full = jax.lax.all_gather(mine, in_axis, axis=0, tiled=False)
    flat = full.reshape(-1)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(x.shape)


def int8_compress(x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor int8 quantization. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compressed_cross_pod_psum_local(x, error_shard, *, in_axis: str = "data",
                                    cross_axis: str = "pod"):
    """Hierarchical all-reduce with int8 error-feedback compression on the
    cross-pod hop only (the in-pod phases stay full precision).

    ``error_shard``: (ceil(x.size/n),) float32 — this device's quantization
    residual from the previous step (error feedback keeps compressed SGD
    convergent).  Returns (result, new_error_shard).  The thin cross-pod
    link carries int8 payloads + one fp32 scale per pod: 4× fewer bytes.
    """
    n = axis_size(in_axis)
    flat, pad = _flatten_pad(x, n)
    shard = flat.reshape(n, -1)
    mine = jax.lax.psum_scatter(shard, in_axis, scatter_dimension=0,
                                tiled=False).astype(jnp.float32)
    mine = mine + error_shard
    q, scale = int8_compress(mine)
    new_error = mine - q.astype(jnp.float32) * scale
    # Exchange int8 payloads + scales across pods, dequantize-sum locally.
    qs = jax.lax.all_gather(q, cross_axis, axis=0, tiled=False)        # (P, M) int8
    scales = jax.lax.all_gather(scale, cross_axis, axis=0, tiled=False)  # (P,)
    mine_red = jnp.sum(qs.astype(jnp.float32) * scales[:, None], axis=0)
    full = jax.lax.all_gather(mine_red.astype(x.dtype), in_axis, axis=0,
                              tiled=False)
    flat_out = full.reshape(-1)
    if pad:
        flat_out = flat_out[:-pad]
    return flat_out.reshape(x.shape), new_error


def hierarchical_psum(x, mesh: Mesh, *, in_axis: str = "data",
                      cross_axis: str = "pod"):
    """jit-level wrapper: hierarchical all-reduce of a replicated-output
    gradient tree leaf laid out with batch sharding on (cross, in)."""
    if cross_axis not in mesh.axis_names:
        # single-pod mesh: plain psum over the in-pod axis
        fn = shard_map(
            lambda v: jax.lax.psum(v, in_axis), mesh=mesh,
            in_specs=P(*(None,) * x.ndim), out_specs=P(*(None,) * x.ndim),
            check_vma=False)
        return fn(x)
    fn = shard_map(
        partial(hierarchical_psum_local, in_axis=in_axis, cross_axis=cross_axis),
        mesh=mesh, in_specs=P(*(None,) * x.ndim),
        out_specs=P(*(None,) * x.ndim), check_vma=False)
    return fn(x)
