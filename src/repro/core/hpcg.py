"""HPCG: conjugate gradient on a 27-point 3-D stencil (paper Table 8).

HPCG complements HPL by stressing memory bandwidth and neighbor/global
communication instead of GEMM throughput.  We reproduce the benchmark's
structure: a 3-D Laplacian-like 27-point operator (matrix-free — TPU
adaptation: the stencil is applied as shifted adds, the idiomatic
memory-bound form for a vector unit, instead of HPCG's CSR SpMV), preconditioned
CG with a symmetric Gauss-Seidel-like (Jacobi on TPU — no sequential sweeps)
smoother, convergence tracking, and the same "fraction of peak" observation
the paper makes (§5: HPCG ≈ 0.8% of HPL on SAKURAONE).
"""
from __future__ import annotations

import time
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp


def stencil_apply(x):
    """27-point stencil: y = 26·x − Σ_{neighbors} x  (zero Dirichlet halo).

    x: (nx, ny, nz). Matrix-free; one pass reads/writes ≈ 27 shifted arrays —
    arithmetic intensity ~0.5 flop/byte => firmly memory-bound, as HPCG
    intends.
    """
    y = 26.0 * x
    for dx in (-1, 0, 1):
        for dy in (-1, 0, 1):
            for dz in (-1, 0, 1):
                if dx == dy == dz == 0:
                    continue
                shifted = x
                for ax, d in ((0, dx), (1, dy), (2, dz)):
                    if d:
                        pad = [(0, 0)] * 3
                        pad[ax] = (max(d, 0), max(-d, 0))
                        sl = [slice(None)] * 3
                        sl[ax] = slice(max(-d, 0), shifted.shape[ax] + min(-d, 0) or None)
                        shifted = jnp.pad(shifted[tuple(sl)], pad)
                y = y - shifted
    return y


def jacobi_precondition(r, *, iters: int = 1):
    """Jacobi smoother (diag = 26). HPCG uses symmetric Gauss-Seidel; GS's
    sequential sweeps have no efficient TPU form (DESIGN.md §2 hardware
    adaptation) so we use the Jacobi equivalent and validate convergence."""
    z = r / 26.0
    for _ in range(iters - 1):
        z = z + (r - stencil_apply(z)) / 26.0
    return z


@partial(jax.jit, static_argnames=("max_iters",))
def hpcg_cg(b, *, max_iters: int = 50) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Preconditioned CG. Returns (x, per-iter residual norms)."""
    x0 = jnp.zeros_like(b)
    r0 = b
    z0 = jacobi_precondition(r0)
    p0 = z0

    def body(carry, _):
        x, r, z, p = carry
        ap = stencil_apply(p)
        rz = jnp.vdot(r, z)
        alpha = rz / jnp.vdot(p, ap)
        x = x + alpha * p
        r_new = r - alpha * ap
        z_new = jacobi_precondition(r_new)
        beta = jnp.vdot(r_new, z_new) / rz
        p = z_new + beta * p
        return (x, r_new, z_new, p), jnp.linalg.norm(r_new.reshape(-1))

    (x, r, _, _), hist = jax.lax.scan(
        body, (x0, r0, z0, p0), None, length=max_iters)
    return x, hist


def hpcg_flops_per_iter(nnodes: int) -> float:
    """~27·2 flops per node for SpMV + 2 preconditioner + ~10 vector-op."""
    return nnodes * (27 * 2 + 27 * 2 + 10)


def hpcg_bytes_per_iter(nnodes: int, dtype_bytes: int = 4) -> float:
    """Dominant traffic: stencil reads + vector ops (~12 array passes)."""
    return nnodes * dtype_bytes * 12.0


def run_hpcg(nx: int = 64, ny: int = 64, nz: int = 64,
             max_iters: int = 50) -> dict:
    key = jax.random.PRNGKey(7)
    b = jax.random.uniform(key, (nx, ny, nz), jnp.float32, 0.0, 1.0)
    x, hist = hpcg_cg(b, max_iters=max_iters)
    x.block_until_ready()
    t0 = time.perf_counter()
    x, hist = hpcg_cg(b, max_iters=max_iters)
    x.block_until_ready()
    dt = time.perf_counter() - t0
    nnodes = nx * ny * nz
    r_final = float(hist[-1])
    r0 = float(jnp.linalg.norm(b.reshape(-1)))
    return {
        "dims": (nx, ny, nz), "equations": nnodes, "iters": max_iters,
        "time_s": dt,
        "gflops": hpcg_flops_per_iter(nnodes) * max_iters / dt / 1e9,
        "bandwidth_gbs": hpcg_bytes_per_iter(nnodes) * max_iters / dt / 1e9,
        "rel_residual": r_final / r0,
        "converged": r_final / r0 < 1e-4,
    }
