"""FP8 mixed-precision compute path (HPL-MxP adaptation, paper Table 9).

SAKURAONE's headline AI result is 339.86 PFLOP/s in "sloppy FP8" — low
precision GEMMs wrapped in iterative refinement so the *answer* is still
high precision.  This module provides the same structure for TPU:

  - ``quantize_fp8`` / ``fp8_matmul``: e4m3 storage with per-tensor (or
    per-tile, via the Pallas kernel) scaling, fp32 accumulation.
  - ``quantize_kv_page`` / ``dequantize_kv_page``: the KV-cache variant —
    fp8 or int8 values with one f32 scale per (token, head) vector, used
    by the quantized paged KV pool (docs/serving.md §"Quantized KV
    pages").
  - ``Fp8Linear`` training path: activations/weights quantized on the fly
    — the beyond-paper training-speed lever recorded in §Perf.
  - ``iterative_refinement``: generic Richardson iteration turning a
    low-precision solver into a high-precision one (used by core.hplmxp).
"""
from __future__ import annotations

from typing import Callable, Tuple

import jax
import jax.numpy as jnp

F8 = jnp.float8_e4m3fn
F8_MAX = 448.0
I8_MAX = 127.0

# KV-cache storage dtypes the serving stack accepts (--kv-dtype).
KV_DTYPES = ("f32", "bf16", "fp8", "int8")
# The subset stored quantized: pages carry values + per-(token, head)
# f32 scales and are dequantized inside the decode path.
KV_QUANTIZED = ("fp8", "int8")


def quantize_fp8(x, *, axis=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Scale x into e4m3 range. Returns (x_fp8, scale) with x ≈ x_fp8·scale.

    Scale-shape contract: with ``axis=None`` the reduction is global and
    ``scale`` is a 0-d scalar; with any ``axis`` the reduction ALWAYS
    keeps the reduced dimensions (``keepdims=True``), so ``scale``
    broadcasts against both ``x`` and ``x_fp8`` without reshaping —
    ``x ≈ x_fp8.astype(f32) * scale`` holds elementwise in every case.
    """
    amax = jnp.max(jnp.abs(x), axis=axis, keepdims=axis is not None)
    scale = jnp.maximum(amax, 1e-12) / F8_MAX
    q = (x / scale).astype(F8)
    return q, scale.astype(jnp.float32)


def kv_storage_dtype(kv_dtype: str):
    """jnp dtype that backs a KV pool stored as ``kv_dtype``.

    fp8 pools travel as **uint8 bit patterns** of the e4m3 values, not
    as ``float8_e4m3fn`` arrays: XLA CPU treats f8 as a storage-only
    type and legalizes every structural op on it (scatter, gather,
    scan carry dynamic-slice/update) through whole-array f16 round
    trips, which made an fp8 decode tick ~4x the cost of int8.  A
    uint8 pool takes the same native integer fast paths as int8;
    :func:`dequantize_kv_page` (and the kernel wrappers) bitcast back
    to e4m3 at the single point the numeric values are needed.

    Raises:
      ValueError: ``kv_dtype`` is not one of :data:`KV_DTYPES`."""
    if kv_dtype not in KV_DTYPES:
        raise ValueError(
            f"unknown kv_dtype {kv_dtype!r}; expected one of {KV_DTYPES}")
    return {"f32": jnp.float32, "bf16": jnp.bfloat16,
            "fp8": jnp.uint8, "int8": jnp.int8}[kv_dtype]


def kv_is_quantized(kv_dtype: str) -> bool:
    """Whether ``kv_dtype`` pages carry per-(token, head) scales."""
    kv_storage_dtype(kv_dtype)      # validate
    return kv_dtype in KV_QUANTIZED


def kv_token_bytes(kv_dtype: str, head_dim: int) -> int:
    """Bytes one token of one KV head costs in a ``kv_dtype`` pool
    (values plus the f32 scale for quantized dtypes).  The byte-
    denominated budget accounting (``BlockManager.page_bytes``,
    ``HostBudget``) is built on this figure."""
    per_value = jnp.dtype(kv_storage_dtype(kv_dtype)).itemsize
    scale = 4 if kv_is_quantized(kv_dtype) else 0
    return head_dim * per_value + scale


def kv_precision_bits(kv_dtype: str) -> int:
    """Fidelity rank of a KV storage dtype (value bits; the scale does
    not add fidelity to an individual value).  Per-class precision
    floors compare with this: a pool *satisfies* a class requiring
    dtype R iff ``kv_precision_bits(pool) >= kv_precision_bits(R)`` —
    premium's f32 floor rejects fp8 pools, while standard's fp8 floor
    is met by any pool."""
    kv_storage_dtype(kv_dtype)      # validate
    return {"f32": 32, "bf16": 16, "fp8": 8, "int8": 8}[kv_dtype]


def quantize_kv_page(x, kv_dtype: str) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Quantize K or V vectors for a paged pool stored as ``kv_dtype``.

    ``x`` is ``(..., head_dim)``; the amax reduction runs over the last
    axis only, so each (token, head) vector gets its own f32 scale —
    finer than one scale per page, deliberately: a token's quantized
    bytes depend only on its own exact values, never on what else was
    written to the page, which is what keeps copy-on-write and
    preemption replay bit-exact within a precision.

    Returns:
      ``(q, scale)`` with ``q`` shaped like ``x`` in the storage dtype
      and ``scale`` shaped ``x.shape[:-1]`` in f32, such that
      ``x ≈ q.astype(f32) * scale[..., None]``.

    Raises:
      ValueError: ``kv_dtype`` is not a quantized KV dtype."""
    if not kv_is_quantized(kv_dtype):
        raise ValueError(
            f"quantize_kv_page needs a quantized kv_dtype "
            f"{KV_QUANTIZED}, got {kv_dtype!r}")
    x = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(x), axis=-1)
    if kv_dtype == "fp8":
        scale = jnp.maximum(amax, 1e-12) / F8_MAX
        q = jax.lax.bitcast_convert_type(
            (x / scale[..., None]).astype(F8), jnp.uint8)
    else:
        scale = jnp.maximum(amax, 1e-12) / I8_MAX
        q = jnp.clip(jnp.round(x / scale[..., None]),
                     -I8_MAX, I8_MAX).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_kv_page(q, scale):
    """Inverse of :func:`quantize_kv_page`: f32 values from quantized
    K/V bytes and their per-(token, head) scales (``scale`` is
    ``q.shape[:-1]``).  uint8 inputs are fp8 bit patterns (see
    :func:`kv_storage_dtype`) and are bitcast back to e4m3 first;
    int8 (and raw e4m3, for callers that quantized directly) pass
    straight through the value cast."""
    if q.dtype == jnp.uint8:
        q = jax.lax.bitcast_convert_type(q, F8)
    return q.astype(jnp.float32) * scale[..., None].astype(jnp.float32)


def fp8_matmul(a, b, *, preferred=jnp.float32):
    """a @ b with e4m3 inputs and fp32 accumulation (jnp reference path;
    the Pallas kernel in repro.kernels.fp8_matmul is the TPU hot path)."""
    qa, sa = quantize_fp8(a)
    qb, sb = quantize_fp8(b)
    out = jax.lax.dot_general(
        qa, qb, (((a.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=preferred)
    return out * (sa * sb)


def fp8_einsum_2d(x, w):
    """(..., K) @ (K, N) through the fp8 path, reshaping to 2-D."""
    lead = x.shape[:-1]
    out = fp8_matmul(x.reshape(-1, x.shape[-1]), w)
    return out.reshape(*lead, w.shape[-1])


def iterative_refinement(apply_a: Callable, solve_lowprec: Callable, b,
                         *, iters: int = 5):
    """Solve A x = b given a low-precision solver (Richardson iteration).

    x_{k+1} = x_k + solve_lowprec(b - A x_k).  With an FP8/bf16 LU as the
    inner solver this recovers fp32-accurate solutions — the HPL-MxP method
    (Haidar et al. 2019) the paper benchmarks.
    Returns (x, residual_history).
    """
    x = solve_lowprec(b).astype(jnp.float32)

    def body(x, _):
        r = b.astype(jnp.float32) - apply_a(x)
        dx = solve_lowprec(r).astype(jnp.float32)
        return x + dx, jnp.linalg.norm(r) / jnp.maximum(jnp.linalg.norm(b), 1e-30)

    x, hist = jax.lax.scan(body, x, None, length=iters)
    return x, hist
