"""FP8 mixed-precision compute path (HPL-MxP adaptation, paper Table 9).

SAKURAONE's headline AI result is 339.86 PFLOP/s in "sloppy FP8" — low
precision GEMMs wrapped in iterative refinement so the *answer* is still
high precision.  This module provides the same structure for TPU:

  - ``quantize_fp8`` / ``fp8_matmul``: e4m3 storage with per-tensor (or
    per-tile, via the Pallas kernel) scaling, fp32 accumulation.
  - ``Fp8Linear`` training path: activations/weights quantized on the fly
    — the beyond-paper training-speed lever recorded in §Perf.
  - ``iterative_refinement``: generic Richardson iteration turning a
    low-precision solver into a high-precision one (used by core.hplmxp).
"""
from __future__ import annotations

from typing import Callable, Tuple

import jax
import jax.numpy as jnp

F8 = jnp.float8_e4m3fn
F8_MAX = 448.0


def quantize_fp8(x, *, axis=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Scale x into e4m3 range. Returns (x_fp8, scale) with x ≈ x_fp8·scale."""
    amax = jnp.max(jnp.abs(x), axis=axis, keepdims=axis is not None)
    scale = jnp.maximum(amax, 1e-12) / F8_MAX
    q = (x / scale).astype(F8)
    return q, scale.astype(jnp.float32)


def fp8_matmul(a, b, *, preferred=jnp.float32):
    """a @ b with e4m3 inputs and fp32 accumulation (jnp reference path;
    the Pallas kernel in repro.kernels.fp8_matmul is the TPU hot path)."""
    qa, sa = quantize_fp8(a)
    qb, sb = quantize_fp8(b)
    out = jax.lax.dot_general(
        qa, qb, (((a.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=preferred)
    return out * (sa * sb)


def fp8_einsum_2d(x, w):
    """(..., K) @ (K, N) through the fp8 path, reshaping to 2-D."""
    lead = x.shape[:-1]
    out = fp8_matmul(x.reshape(-1, x.shape[-1]), w)
    return out.reshape(*lead, w.shape[-1])


def iterative_refinement(apply_a: Callable, solve_lowprec: Callable, b,
                         *, iters: int = 5):
    """Solve A x = b given a low-precision solver (Richardson iteration).

    x_{k+1} = x_k + solve_lowprec(b - A x_k).  With an FP8/bf16 LU as the
    inner solver this recovers fp32-accurate solutions — the HPL-MxP method
    (Haidar et al. 2019) the paper benchmarks.
    Returns (x, residual_history).
    """
    x = solve_lowprec(b).astype(jnp.float32)

    def body(x, _):
        r = b.astype(jnp.float32) - apply_a(x)
        dx = solve_lowprec(r).astype(jnp.float32)
        return x + dx, jnp.linalg.norm(r) / jnp.maximum(jnp.linalg.norm(b), 1e-30)

    x, hist = jax.lax.scan(body, x, None, length=iters)
    return x, hist
