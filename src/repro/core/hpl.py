"""HPL: blocked right-looking LU with partial-pivot-free diagonal shift.

Reproduces the structure of the paper's Table 7 benchmark in JAX: a blocked
LU factorization (panel factor + triangular solve + trailing GEMM update),
the trailing update being the GEMM-dominated phase HPL measures.  The
distributed variant block-cycles panels over the mesh like HPL's P×Q
process grid; the single-host variant drives the benchmark table.

TPU adaptation: no fp64 MXU => fp32 is "high precision" here (DESIGN.md §3).
Diagonally-dominant test matrices make pivot-free LU numerically safe, as
HPL-NVIDIA's nopiv mode does.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp


def make_test_matrix(n: int, key=None, dtype=jnp.float32):
    """Random diagonally-dominant matrix (pivot-free-LU safe) + rhs."""
    key = key if key is not None else jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    a = jax.random.uniform(k1, (n, n), jnp.float32, -0.5, 0.5)
    # 0.3·n diagonal shift: still strictly dominant (E|row sum| ≈ 0.25·n)
    # so pivot-free LU is safe, but conditioned enough that low-precision
    # factorization needs genuine refinement iterations.
    a = a + 0.3 * n * jnp.eye(n, dtype=jnp.float32)
    b = jax.random.uniform(k2, (n,), jnp.float32, -0.5, 0.5)
    return a.astype(dtype), b.astype(dtype)


def _lu_panel(a):
    """Unblocked pivot-free LU of a small panel (fp32)."""
    n = a.shape[0]

    def body(i, a):
        col = a[:, i] / a[i, i]
        col = jnp.where(jnp.arange(n) > i, col, a[:, i])
        a = a.at[:, i].set(col)
        update = jnp.outer(
            jnp.where(jnp.arange(n) > i, col, 0.0),
            jnp.where(jnp.arange(n) > i, a[i, :], 0.0))
        return a - update

    return jax.lax.fori_loop(0, n, body, a)


@partial(jax.jit, static_argnames=("nb", "matmul"))
def blocked_lu(a, *, nb: int = 128, matmul: str = "fp32"):
    """Blocked right-looking LU (in-place packed LU factors).

    matmul: 'fp32' | 'bf16' | 'fp8' — precision of the trailing GEMM update,
    the knob HPL vs HPL-MxP turns.  The step loop is a Python loop (static
    per-step shapes) so the trailing GEMM does the canonical 2/3·n³ FLOPs,
    not a masked full-width 2·n³.
    """
    n = a.shape[0]
    assert n % nb == 0
    steps = n // nb

    from repro.core.mixed_precision import fp8_matmul

    def trailing_matmul(l_col, u_row):
        if matmul == "bf16":
            return jax.lax.dot_general(
                l_col.astype(jnp.bfloat16), u_row.astype(jnp.bfloat16),
                (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        if matmul == "fp8":
            return fp8_matmul(l_col, u_row)
        return l_col @ u_row

    for k in range(steps):
        off = k * nb
        rem = n - off - nb          # trailing size (static per step)
        diag = jax.lax.dynamic_slice(a, (off, off), (nb, nb))
        lu = _lu_panel(diag)
        a = jax.lax.dynamic_update_slice(a, lu, (off, off))
        if rem == 0:
            break
        l = jnp.tril(lu, -1) + jnp.eye(nb, dtype=a.dtype)
        u = jnp.triu(lu)

        a_col = jax.lax.dynamic_slice(a, (off + nb, off), (rem, nb))
        l_col = jax.lax.linalg.triangular_solve(
            u, a_col, left_side=False, lower=False)       # L21 = A21 U11^-1
        a = jax.lax.dynamic_update_slice(a, l_col, (off + nb, off))

        a_row = jax.lax.dynamic_slice(a, (off, off + nb), (nb, rem))
        u_row = jax.lax.linalg.triangular_solve(
            l, a_row, left_side=True, lower=True, unit_diagonal=True)
        a = jax.lax.dynamic_update_slice(a, u_row, (off, off + nb))

        # Trailing update: A22 -= L21 @ U12  (the GEMM HPL measures)
        a22 = jax.lax.dynamic_slice(a, (off + nb, off + nb), (rem, rem))
        a22 = a22 - trailing_matmul(l_col, u_row).astype(a.dtype)
        a = jax.lax.dynamic_update_slice(a, a22, (off + nb, off + nb))
    return a


def lu_solve(lu, b):
    """Solve with packed LU factors."""
    n = lu.shape[0]
    l = jnp.tril(lu, -1) + jnp.eye(n, dtype=lu.dtype)
    u = jnp.triu(lu)
    y = jax.lax.linalg.triangular_solve(
        l, b[:, None], left_side=True, lower=True, unit_diagonal=True)
    x = jax.lax.linalg.triangular_solve(u, y, left_side=True, lower=False)
    return x[:, 0]


def hpl_residual(a, x, b) -> jnp.ndarray:
    """HPL's scaled residual ||Ax-b|| / (eps·(||A||·||x||+||b||)·n)."""
    r = jnp.linalg.norm(a @ x - b, ord=jnp.inf)
    na = jnp.linalg.norm(a, ord=jnp.inf)
    nx = jnp.linalg.norm(x, ord=jnp.inf)
    nb = jnp.linalg.norm(b, ord=jnp.inf)
    eps = jnp.finfo(jnp.float32).eps
    return r / (eps * (na * nx + nb) * a.shape[0])


def hpl_flops(n: int) -> float:
    """Canonical HPL flop count 2/3 n^3 + 3/2 n^2."""
    return 2.0 / 3.0 * n ** 3 + 1.5 * n ** 2


def distributed_hpl_setup(mesh, n: int, nb: int = 1024, matmul: str = "fp32"):
    """Distributed HPL: the matrix 2-D-sharded over the mesh like HPL's
    P×Q process grid (paper Table 7: 16×49).  The trailing-update GEMM — the
    phase HPL measures — becomes a mesh-wide distributed GEMM; panels
    factor on the diagonal owners.  GSPMD inserts the panel broadcasts
    (row/column collectives) that HPL implements by hand.

    Returns (jitted_fn, abstract_A, sharding) ready for .lower() — used by
    the dry-run to prove the paper's own benchmark shards on the
    production mesh and to price its collective traffic.
    """
    import functools
    from jax.sharding import NamedSharding, PartitionSpec as P

    axes = [a for a in ("data", "model") if a in mesh.axis_names][:2]
    spec = P(*axes) if len(axes) == 2 else P(axes[0])
    sharding = NamedSharding(mesh, spec)
    fn = jax.jit(functools.partial(blocked_lu, nb=nb, matmul=matmul),
                 in_shardings=sharding, out_shardings=sharding)
    abstract = jax.ShapeDtypeStruct((n, n), jnp.float32)
    return fn, abstract, sharding


def run_hpl(n: int = 1024, nb: int = 128, matmul: str = "fp32") -> dict:
    """Factor + solve + validate; returns the Table-7-shaped record."""
    import time
    a, b = make_test_matrix(n)
    lu = blocked_lu(a, nb=nb, matmul=matmul)
    lu.block_until_ready()
    t0 = time.perf_counter()
    lu = blocked_lu(a, nb=nb, matmul=matmul)
    lu.block_until_ready()
    dt = time.perf_counter() - t0
    x = lu_solve(lu, b)
    resid = float(hpl_residual(a, x, b))
    return {
        "N": n, "NB": nb, "matmul": matmul,
        "time_s": dt, "flops": hpl_flops(n),
        "gflops": hpl_flops(n) / dt / 1e9,
        "residual": resid, "passed": resid < 16.0,
    }
