"""SAKURAONE rail-optimized topology model + collective cost model.

The paper's fabric (Fig. 2): 100 nodes × 8 GPUs; each GPU g on every node
hangs off *rail g* — a dedicated leaf switch per pod; 16 leaves (2 pods × 8
rails) × 8 spines, 800 GbE everywhere, full bisection in-pod, thinner
effective cross-pod capacity.  The transferable insight is a two-level
bandwidth hierarchy with a scarce cross-pod layer; this module captures it
as an explicit cost model that (a) sizes the production mesh, (b) prices
collectives for the roofline's collective term, and (c) justifies the
hierarchical all-reduce in ``core.collectives``.

TPU adaptation (DESIGN.md §2): in-pod links = ICI (~50 GB/s/link), cross-pod
= DCN (modeled thinner).  Axis order on the mesh mirrors the paper's rail
design: the innermost axis ("model") maps to the highest-bandwidth links.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Tuple

# --- TPU v5e hardware constants (per brief) --------------------------------
PEAK_BF16_FLOPS = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link per direction
DCN_BW_PER_CHIP = 6.25e9          # bytes/s per chip cross-pod (thin layer)

# --- SAKURAONE (paper) constants, for the faithful benchmark tables --------
H100_FP64_TC = 67e12              # FLOP/s (dense tensor core fp64)
H100_FP8_TC = 1979e12             # FLOP/s
GPUS = 800
LINK_800GBE = 100e9               # bytes/s per 800 GbE port


@dataclasses.dataclass(frozen=True)
class RailTopology:
    """Leaf/spine rail-optimized fabric (paper §2.2, Fig. 2)."""
    num_pods: int = 2
    nodes_per_pod: int = 50
    gpus_per_node: int = 8        # == rails per pod == leaves per pod
    spines: int = 8
    leaf_uplink_bw: float = LINK_800GBE     # leaf->spine per link
    nic_bw: float = 50e9                    # 400 GbE per GPU NIC

    @property
    def num_gpus(self) -> int:
        return self.num_pods * self.nodes_per_pod * self.gpus_per_node

    @property
    def leaves(self) -> int:
        return self.num_pods * self.gpus_per_node

    def rail_of(self, gpu_id: int) -> int:
        return gpu_id % self.gpus_per_node

    def pod_of(self, gpu_id: int) -> int:
        return gpu_id // (self.nodes_per_pod * self.gpus_per_node)

    def hops(self, src: int, dst: int) -> int:
        """Switch hops between two GPUs (0 = same node via NVLink)."""
        if src // self.gpus_per_node == dst // self.gpus_per_node:
            return 0
        same_rail = self.rail_of(src) == self.rail_of(dst)
        same_pod = self.pod_of(src) == self.pod_of(dst)
        if same_rail and same_pod:
            return 1              # one leaf (the rail switch)
        return 3                  # leaf -> spine -> leaf

    def bisection_bw(self) -> float:
        """Full-bisection bandwidth of the fabric (bytes/s)."""
        return self.leaves * self.spines * self.leaf_uplink_bw / 2


def allreduce_cost(bytes_per_chip: float, n_chips: int, link_bw: float) -> float:
    """Ring all-reduce time: 2·(n-1)/n · B / link_bw."""
    if n_chips <= 1:
        return 0.0
    return 2.0 * (n_chips - 1) / n_chips * bytes_per_chip / link_bw


def reduce_scatter_cost(bytes_per_chip: float, n_chips: int, link_bw: float) -> float:
    if n_chips <= 1:
        return 0.0
    return (n_chips - 1) / n_chips * bytes_per_chip / link_bw


def hierarchical_allreduce_cost(bytes_per_chip: float, in_pod: int,
                                num_pods: int, *, ici_bw: float = ICI_BW,
                                dcn_bw: float = DCN_BW_PER_CHIP) -> Tuple[float, Dict[str, float]]:
    """Rail-optimized (paper-faithful) hierarchical all-reduce cost.

    Phase 1: reduce-scatter in-pod over ICI; phase 2: cross-pod all-reduce of
    the 1/in_pod shard over DCN; phase 3: all-gather in-pod.  Cross-pod bytes
    shrink by the in-pod factor — the rail-optimized property.
    """
    rs = reduce_scatter_cost(bytes_per_chip, in_pod, ici_bw)
    xp = allreduce_cost(bytes_per_chip / max(in_pod, 1), num_pods, dcn_bw)
    ag = reduce_scatter_cost(bytes_per_chip, in_pod, ici_bw)  # all-gather ≡ rs cost
    return rs + xp + ag, {"reduce_scatter": rs, "cross_pod": xp, "all_gather": ag}


def flat_allreduce_cost(bytes_per_chip: float, in_pod: int, num_pods: int,
                        *, dcn_bw: float = DCN_BW_PER_CHIP) -> float:
    """Naive single-ring all-reduce spanning pods: every hop constrained by
    the thin cross-pod layer once the ring crosses pods."""
    n = in_pod * num_pods
    if num_pods > 1:
        return 2.0 * (n - 1) / n * bytes_per_chip / dcn_bw
    return allreduce_cost(bytes_per_chip, n, ICI_BW)


@dataclasses.dataclass(frozen=True)
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)


def roofline(hlo_flops: float, hlo_bytes: float, collective_bytes: float,
             n_chips: int) -> RooflineTerms:
    """Three-term roofline per the brief (all inputs are program totals):

      compute    = HLO_FLOPs / (chips × peak)
      memory     = HLO_bytes / (chips × HBM_bw)
      collective = collective_bytes / (chips × link_bw)
    """
    return RooflineTerms(
        compute_s=hlo_flops / (n_chips * PEAK_BF16_FLOPS),
        memory_s=hlo_bytes / (n_chips * HBM_BW),
        collective_s=collective_bytes / (n_chips * ICI_BW),
    )
