"""LR schedules. WSD (warmup-stable-decay) is minicpm-2b's assigned
signature feature (arXiv:2404.06395): linear warmup, long flat stable
phase, sharp (exponential-ish, here cosine) decay over the final ~10%."""
from __future__ import annotations

import jax.numpy as jnp


def linear_warmup(step, warmup_steps, peak):
    return peak * jnp.minimum(step / jnp.maximum(warmup_steps, 1), 1.0)


def wsd_schedule(step, *, peak: float, warmup_steps: int, total_steps: int,
                 decay_frac: float = 0.1, floor_frac: float = 0.01):
    """Warmup-Stable-Decay."""
    step = jnp.asarray(step, jnp.float32)
    decay_steps = decay_frac * total_steps
    decay_start = total_steps - decay_steps
    warm = linear_warmup(step, warmup_steps, peak)
    t = jnp.clip((step - decay_start) / jnp.maximum(decay_steps, 1), 0.0, 1.0)
    decay = peak * (floor_frac + (1 - floor_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
    return jnp.where(step < warmup_steps, warm,
                     jnp.where(step < decay_start, peak, decay))


def cosine_schedule(step, *, peak: float, warmup_steps: int, total_steps: int,
                    floor_frac: float = 0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = linear_warmup(step, warmup_steps, peak)
    t = jnp.clip((step - warmup_steps) /
                 jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
    cos = peak * (floor_frac + (1 - floor_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
    return jnp.where(step < warmup_steps, warm, cos)


def get_schedule(name: str, **kw):
    return {"wsd": wsd_schedule, "cosine": cosine_schedule}[name], kw
