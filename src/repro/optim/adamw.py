"""AdamW, dependency-free, with global-norm clipping.

Optimizer state is a pytree parallel to params (same logical axes => same
shardings — moments shard exactly like their parameters, so optimizer
memory scales down with TP/DP like the model does)."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0


def init_opt_state(params) -> Dict[str, Any]:
    zeros = lambda: jax.tree.map(jnp.zeros_like, params)
    return {"mu": zeros(), "nu": zeros(),
            "count": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(grads, opt_state, params, lr, cfg: AdamWConfig = AdamWConfig()):
    """Returns (new_params, new_opt_state, metrics)."""
    count = opt_state["count"] + 1
    gnorm = global_norm(grads)
    if cfg.clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
        grads = jax.tree.map(lambda g: g * scale, grads)

    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd(g, mu, nu, p):
        g = g.astype(jnp.float32)
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(g)
        step = (mu / b1c) / (jnp.sqrt(nu / b2c) + cfg.eps)
        p32 = p.astype(jnp.float32)
        new_p = p32 - lr * (step + cfg.weight_decay * p32)
        return new_p.astype(p.dtype), mu.astype(p.dtype), nu.astype(p.dtype)

    flat_g, treedef = jax.tree.flatten(grads)
    flat_mu = treedef.flatten_up_to(opt_state["mu"])
    flat_nu = treedef.flatten_up_to(opt_state["nu"])
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, n, p) for g, m, n, p in zip(flat_g, flat_mu, flat_nu, flat_p)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_mu = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_nu = jax.tree.unflatten(treedef, [o[2] for o in out])
    new_state = {"mu": new_mu, "nu": new_nu, "count": count}
    return new_p, new_state, {"grad_norm": gnorm}
