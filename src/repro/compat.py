"""Cross-version JAX API aliases.

The repo targets the 0.5+ names; the installed 0.4.x exposes some of
them elsewhere.  Import the alias from here instead of feature-detecting
at each call site (see also kernels/_compat.py for the Pallas-TPU names
and launch/mesh.py for AxisType).
"""
from __future__ import annotations

import jax

# True when shard_map supports partial-manual regions (axis_names=...,
# remaining axes auto-sharded by GSPMD).  On 0.4.x the compat wrapper
# below falls back to FULL manual, so code inside such regions must not
# emit sharding constraints over the would-be-auto axes.
SHARD_MAP_PARTIAL_AUTO = hasattr(jax, "shard_map")

try:
    shard_map = jax.shard_map                      # jax >= 0.5
except AttributeError:
    from jax.experimental.shard_map import shard_map as _shard_map  # 0.4.x

    def shard_map(f, *args, **kwargs):
        if "check_vma" in kwargs:                  # 0.5 name for check_rep
            kwargs["check_rep"] = kwargs.pop("check_vma")
        # 0.5 lists the manual axes (axis_names=); 0.4's equivalent
        # (auto= the complement) hits NotImplementedError when lowered, so
        # fall back to FULL manual: unmentioned axes replicate compute
        # inside the region instead of auto-sharding it — identical
        # numbers, less intra-region parallelism (fine for tests).
        kwargs.pop("axis_names", None)
        return _shard_map(f, *args, **kwargs)


def axis_size(name):
    """Static size of a named mesh axis inside shard_map."""
    if hasattr(jax.lax, "axis_size"):              # jax >= 0.5
        return jax.lax.axis_size(name)
    return jax.lax.psum(1, name)                   # folds to a constant
