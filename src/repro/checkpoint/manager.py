"""Striped, async, elastic checkpointing (the Lustre-store analogue).

Layout (paper §2.3: DDN ES400NVX2 OST striping -> per-leaf byte stripes):

    <root>/step_<N>.tmp/          # staged writes
        ost0/<leaf>.stripe0
        ost1/<leaf>.stripe1 ...
    <root>/step_<N>/              # committed by atomic os.replace
        ...
        MANIFEST.json             # written + fsync'd LAST

Commit protocol: write all stripes -> fsync -> write manifest -> fsync ->
atomic directory rename.  A crash at any point leaves either the previous
complete checkpoint or a .tmp that restore ignores — no torn states.

Elastic restore: leaves are loaded to host, then ``jax.device_put`` with
the *current* mesh's shardings — so a job can restart on a different device
count / mesh shape than it saved from (node-failure recovery, DESIGN.md §8).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

MANIFEST = "MANIFEST.json"


def _flatten_with_paths(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((name, leaf))
    return out


class CheckpointManager:
    def __init__(self, root: str, *, stripes: int = 4, keep: int = 3):
        self.root = root
        self.stripes = stripes
        self.keep = keep
        os.makedirs(root, exist_ok=True)
        self._pool = ThreadPoolExecutor(max_workers=stripes)
        self._pending: Optional[Any] = None
        self._lock = threading.Lock()

    # -- save ---------------------------------------------------------------

    def _write_leaf(self, stage: str, name: str, arr: np.ndarray) -> Dict:
        data = arr.tobytes()
        n = max(1, min(self.stripes, len(data) or 1))
        chunk = (len(data) + n - 1) // n if data else 0
        files = []
        for i in range(n):
            ost = os.path.join(stage, f"ost{i}")
            os.makedirs(ost, exist_ok=True)
            fname = os.path.join(ost, f"{name.replace('/', '.')}.stripe{i}")
            with open(fname, "wb") as f:
                f.write(data[i * chunk:(i + 1) * chunk])
                f.flush()
                os.fsync(f.fileno())
            files.append(os.path.relpath(fname, stage))
        return {"name": name, "shape": list(arr.shape),
                "dtype": str(arr.dtype), "files": files}

    def save(self, step: int, tree, *, extra: Optional[Dict] = None) -> str:
        """Blocking save. Returns the committed directory."""
        stage = os.path.join(self.root, f"step_{step}.tmp")
        final = os.path.join(self.root, f"step_{step}")
        shutil.rmtree(stage, ignore_errors=True)
        os.makedirs(stage)
        leaves = _flatten_with_paths(tree)
        host_leaves = [(n, np.asarray(jax.device_get(l))) for n, l in leaves]
        records = list(self._pool.map(
            lambda nl: self._write_leaf(stage, nl[0], nl[1]), host_leaves))
        manifest = {"step": step, "leaves": records, "extra": extra or {}}
        mpath = os.path.join(stage, MANIFEST)
        with open(mpath, "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        shutil.rmtree(final, ignore_errors=True)
        os.replace(stage, final)                     # atomic commit
        self._gc()
        return final

    def save_async(self, step: int, tree, *, extra: Optional[Dict] = None):
        """Non-blocking save (device->host copy happens before returning so
        training can mutate params immediately)."""
        leaves = _flatten_with_paths(tree)
        host = [(n, np.asarray(jax.device_get(l))) for n, l in leaves]
        treedef = jax.tree.structure(tree)

        def run():
            rebuilt = jax.tree.unflatten(treedef, [a for _, a in host])
            return self.save(step, rebuilt, extra=extra)

        with self._lock:
            self.wait()
            self._pending = ThreadPoolExecutor(max_workers=1).submit(run)
        return self._pending

    def wait(self):
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    # -- restore ------------------------------------------------------------

    def steps(self) -> List[int]:
        out = []
        for d in os.listdir(self.root):
            if d.startswith("step_") and not d.endswith(".tmp"):
                if os.path.exists(os.path.join(self.root, d, MANIFEST)):
                    out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, treedef_like, step: Optional[int] = None,
                shardings=None) -> Tuple[int, Any]:
        """Restore into the structure of `treedef_like`. If `shardings` (a
        matching pytree of NamedSharding) is given, leaves are placed onto
        the current mesh — independently of the mesh that saved them."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.root}")
        cdir = os.path.join(self.root, f"step_{step}")
        with open(os.path.join(cdir, MANIFEST)) as f:
            manifest = json.load(f)
        by_name = {r["name"]: r for r in manifest["leaves"]}
        names = [n for n, _ in _flatten_with_paths(treedef_like)]
        treedef = jax.tree.structure(treedef_like)

        def load(name) -> np.ndarray:
            rec = by_name[name]
            data = b"".join(
                open(os.path.join(cdir, f), "rb").read() for f in rec["files"])
            return np.frombuffer(data, dtype=rec["dtype"]).reshape(rec["shape"])

        arrays = list(self._pool.map(load, names))
        tree = jax.tree.unflatten(treedef, arrays)
        if shardings is not None:
            tree = jax.tree.map(jax.device_put, tree, shardings)
        return step, tree

    # -- gc -----------------------------------------------------------------

    def _gc(self):
        steps = self.steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.root, f"step_{s}"),
                          ignore_errors=True)
