"""Deterministic synthetic token pipeline (shard-aware, restart-safe).

Production data loading for LLM training has two properties this module
reproduces without external datasets: (1) determinism keyed by (step,
position) so a restarted/rescaled job resumes the exact stream (elastic
restore replays from the checkpointed step), and (2) per-shard generation —
each host materializes only its addressable slice via
``jax.make_array_from_callback`` so no host ever holds the global batch.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _tokens_for_slice(step: int, lo: int, hi: int, seq: int, vocab: int,
                      salt: int = 0, noise: float = 0.15) -> np.ndarray:
    """Deterministic tokens for global batch rows [lo, hi).

    The stream is a noisy affine-recurrence Markov chain
    (``next = (a·prev + c) mod V``, flipped to uniform noise w.p. `noise`)
    — deterministic AND learnable, so end-to-end training demos show real
    loss movement instead of fitting unigram statistics of pure noise.
    """
    rows = []
    a, c = 31, 17
    for r in range(lo, hi):
        rng = np.random.Generator(
            np.random.Philox(key=[(step << 32) | (salt & 0xFFFFFFFF), r]))
        toks = np.empty(seq + 1, dtype=np.int64)
        toks[0] = rng.integers(0, vocab)
        flips = rng.random(seq) < noise
        rand = rng.integers(0, vocab, size=seq)
        for t in range(seq):
            toks[t + 1] = rand[t] if flips[t] else (a * toks[t] + c) % vocab
        rows.append(toks)
    arr = np.stack(rows)
    return arr


class TokenPipeline:
    """get_batch(step) -> {'tokens','labels'} global jax.Arrays."""

    def __init__(self, vocab_size: int, seq_len: int, global_batch: int,
                 mesh: Optional[Mesh] = None, batch_spec: P = P()):
        self.vocab = vocab_size
        self.seq = seq_len
        self.batch = global_batch
        self.mesh = mesh
        self.spec = batch_spec

    def _global(self, step: int) -> np.ndarray:
        return _tokens_for_slice(step, 0, self.batch, self.seq, self.vocab)

    def get_batch(self, step: int) -> Dict[str, jax.Array]:
        if self.mesh is None:
            arr = self._global(step)
            return {"tokens": jnp.asarray(arr[:, :-1], jnp.int32),
                    "labels": jnp.asarray(arr[:, 1:], jnp.int32)}
        sharding = NamedSharding(self.mesh, self.spec)

        def cb_tokens(index):
            lo, hi = index[0].start or 0, index[0].stop or self.batch
            sl = _tokens_for_slice(step, lo, hi, self.seq, self.vocab)
            return sl[:, :-1][:, index[1]].astype(np.int32)

        def cb_labels(index):
            lo, hi = index[0].start or 0, index[0].stop or self.batch
            sl = _tokens_for_slice(step, lo, hi, self.seq, self.vocab)
            return sl[:, 1:][:, index[1]].astype(np.int32)

        shape = (self.batch, self.seq)
        return {
            "tokens": jax.make_array_from_callback(shape, sharding, cb_tokens),
            "labels": jax.make_array_from_callback(shape, sharding, cb_labels),
        }
