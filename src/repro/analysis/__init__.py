"""repro-lint: AST-based static analysis for the serving hot path.

The serving stack keeps re-fixing the same classes of latent JAX hazard
by hand — a full ``(max_seats, vocab)`` host pull inside the decode
sampler, retrace churn before the ``(max_seats,)`` shape pin, fp8
structural ops silently legalizing through whole-pool f16 round trips.
This package catches them mechanically, in CI, with no third-party
dependencies (it never imports jax — the lint job runs on a bare
Python):

    RL001  implicit host<->device transfer/sync in a declared hot path
    RL002  retrace hazard at a ``jax.jit`` boundary
    RL003  donated buffer referenced after the jitted call
    RL004  PRNG key reuse without split/fold_in
    RL005  host side effects (print/open/clock) inside a traced function
    RL006  structural ops on float8 arrays (travel as uint8 bit patterns)

Hot-path scope is declared in the checked-in manifest
``hotpaths.toml`` (next to this file); findings honor inline
``# repro-lint: disable=RLxxx`` suppressions and the committed
``baseline.json`` so adoption only ever ratchets down.  Run it as::

    python -m repro.analysis                 # lint the declared scan roots
    python -m repro.analysis --format=github # CI annotations
    python -m repro.analysis --docs          # markdown link check (one driver)

See docs/static_analysis.md for the rule catalog (each rule's motivating
incident), the suppression/baseline workflow, and how to declare a new
hot path.
"""
from repro.analysis.engine import AnalysisResult, Finding, analyze_paths
from repro.analysis.manifest import Manifest, ModuleDecl, load_manifest
from repro.analysis.rules import RULES, rule_ids

__all__ = [
    "AnalysisResult", "Finding", "Manifest", "ModuleDecl", "RULES",
    "analyze_paths", "load_manifest", "rule_ids",
]
