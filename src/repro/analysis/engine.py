"""Analysis engine: parsing, taint inference, suppressions, traversal.

The engine turns each Python source file into a :class:`ModuleContext`
— the parsed AST plus everything the rules need to reason locally:

* an import-alias map so ``jnp.asarray`` and ``jax.numpy.asarray``
  canonicalize to the same dotted name,
* a registry of jit-wrapped callables (``self._step_fn = jax.jit(...)``
  assignments and ``@jax.jit`` / ``@partial(jax.jit, ...)`` decorators)
  with their ``static_*`` / ``donate_argnums`` facts,
* per-function *taint* inference classifying expressions as DEVICE
  (jax array), HOST (numpy / Python scalar) or UNKNOWN, in statement
  order with no cross-branch merging — deliberately simple and local,
  which is what keeps the rules explainable,
* suppression pragmas (``# repro-lint: disable=RL001,RL002``, bare
  ``disable``, and file-level ``disable-file``).

Rules (see :mod:`repro.analysis.rules`) are pure functions from a
context to findings; :func:`analyze_paths` applies them over the scan
roots and filters suppressed findings.
"""
from __future__ import annotations

import ast
import dataclasses
import re
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Set, Tuple

from repro.analysis.manifest import Manifest, ModuleDecl

DEVICE = "device"
HOST = "host"
UNKNOWN = "unknown"

# Call roots whose results are jax arrays living on device.
_DEVICE_ROOTS = ("jax.numpy.", "jax.lax.", "jax.nn.", "jax.random.",
                 "jax.scipy.", "jax.device_put", "jax.tree_util.")
# Call roots whose results live on host.
_HOST_ROOTS = ("numpy.",)
_HOST_BUILTINS = {"int", "float", "bool", "len", "min", "max", "sum",
                  "range", "list", "tuple", "sorted", "enumerate", "zip",
                  "abs", "round", "str"}

_PRAGMA_RE = re.compile(
    r"#\s*repro-lint:\s*(disable-file|disable)\s*(?:=\s*([A-Z0-9,\s]+))?")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One diagnostic, keyed ``file:line RLxxx`` for reporting."""
    rule: str
    file: str            # repo-relative posix path
    line: int
    col: int
    symbol: str          # enclosing function qualname, or "<module>"
    message: str
    snippet: str = ""    # stripped source line (baseline identity)

    def baseline_key(self) -> Tuple[str, str, str, str]:
        """Line-number-independent identity used by the baseline file,
        so unrelated edits above a baselined finding don't break CI."""
        return (self.rule, self.file, self.symbol, self.snippet)

    def sort_key(self):
        return (self.file, self.line, self.col, self.rule)


@dataclasses.dataclass
class AnalysisResult:
    findings: List[Finding]
    files_scanned: int
    suppressed: int      # count silenced by inline pragmas


# -- suppressions ------------------------------------------------------------

def parse_suppressions(source: str):
    """Map line number -> set of suppressed rule ids (``{"*"}`` for a
    bare ``disable``).  Returns ``(per_line, file_wide)`` where
    ``file_wide`` is the set suppressed for the whole file."""
    per_line: Dict[int, Set[str]] = {}
    file_wide: Set[str] = set()
    for lineno, line in enumerate(source.splitlines(), start=1):
        m = _PRAGMA_RE.search(line)
        if not m:
            continue
        rules = ({r.strip() for r in m.group(2).split(",") if r.strip()}
                 if m.group(2) else {"*"})
        if m.group(1) == "disable-file":
            file_wide |= rules
        else:
            per_line.setdefault(lineno, set()).update(rules)
    return per_line, file_wide


def is_suppressed(finding: Finding, per_line, file_wide) -> bool:
    if "*" in file_wide or finding.rule in file_wide:
        return True
    rules = per_line.get(finding.line, ())
    return "*" in rules or finding.rule in rules


# -- jit registry ------------------------------------------------------------

@dataclasses.dataclass
class JitDecl:
    """Facts about one jit-wrapped callable usable at its call sites."""
    name: str                      # call pattern, e.g. "self._step_fn"
    line: int
    has_static: bool = False       # static_argnums/static_argnames given
    donate: Tuple[int, ...] = ()   # donated positional indices
    donate_conditional: bool = False


def _int_constants(node: ast.AST) -> Tuple[int, ...]:
    """All integer literals inside ``node`` — resolves plain tuples and,
    best effort, conditionals like ``(0,) if backend != 'cpu' else ()``
    (analyzing as-if-donated is the conservative read: the code must be
    safe on the backend that does donate)."""
    out = []
    for sub in ast.walk(node):
        if isinstance(sub, ast.Constant) and isinstance(sub.value, int) \
                and not isinstance(sub.value, bool):
            out.append(sub.value)
    return tuple(sorted(set(out)))


class ModuleContext:
    """Everything rules need about one source file."""

    def __init__(self, path: Path, relpath: str, source: str,
                 tree: ast.Module, manifest: Manifest):
        self.path = path
        self.relpath = relpath
        self.source = source
        self.lines = source.splitlines()
        self.tree = tree
        self.manifest = manifest
        self.decl: ModuleDecl = manifest.decl(relpath)
        self.aliases = self._collect_aliases(tree)
        self.functions = self._collect_functions(tree)
        self.jits = self._collect_jits(tree)

    # -- names ---------------------------------------------------------------

    @staticmethod
    def _collect_aliases(tree: ast.Module) -> Dict[str, str]:
        aliases: Dict[str, str] = {}
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    aliases[a.asname or a.name.split(".")[0]] = (
                        a.name if a.asname else a.name.split(".")[0])
            elif isinstance(node, ast.ImportFrom) and node.module:
                for a in node.names:
                    aliases[a.asname or a.name] = f"{node.module}.{a.name}"
        return aliases

    def dotted(self, node: ast.AST) -> str:
        """Raw dotted path of a Name/Attribute chain ("" otherwise)."""
        parts = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if isinstance(node, ast.Name):
            parts.append(node.id)
        elif isinstance(node, ast.Call):
            parts.append("()")       # keep chains like f(x).block_until_ready
        else:
            return ""
        return ".".join(reversed(parts))

    def canon(self, node: ast.AST) -> str:
        """Canonical dotted name with import aliases resolved at the
        root (``jnp.asarray`` -> ``jax.numpy.asarray``)."""
        raw = self.dotted(node)
        if not raw:
            return ""
        head, _, rest = raw.partition(".")
        head = self.aliases.get(head, head)
        return f"{head}.{rest}" if rest else head

    # -- functions -----------------------------------------------------------

    @staticmethod
    def _collect_functions(tree: ast.Module):
        """[(qualname, FunctionDef)] for every def, nested by class."""
        out: List[Tuple[str, ast.FunctionDef]] = []

        def visit(node, prefix):
            for child in ast.iter_child_nodes(node):
                if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    qual = f"{prefix}{child.name}"
                    out.append((qual, child))
                    visit(child, f"{qual}.")
                elif isinstance(child, ast.ClassDef):
                    visit(child, f"{prefix}{child.name}.")

        visit(tree, "")
        return out

    def is_hot(self, qualname: str) -> bool:
        return qualname in self.decl.hot

    def is_traced(self, qualname: str, node: ast.FunctionDef) -> bool:
        if qualname in self.decl.traced:
            return True
        return self._jit_decorated(node)

    def _jit_decorated(self, node: ast.FunctionDef) -> bool:
        for dec in node.decorator_list:
            target = dec.func if isinstance(dec, ast.Call) else dec
            name = self.canon(target)
            if name == "jax.jit":
                return True
            if name in ("functools.partial", "partial") and \
                    isinstance(dec, ast.Call) and dec.args and \
                    self.canon(dec.args[0]) == "jax.jit":
                return True
        return False

    # -- jit registry --------------------------------------------------------

    def _collect_jits(self, tree: ast.Module) -> Dict[str, JitDecl]:
        jits: Dict[str, JitDecl] = {}
        # simple name -> RHS map so donate_argnums=donate resolves when
        # the tuple (often conditional on the backend) was bound earlier
        bindings: Dict[str, ast.AST] = {}
        for node in ast.walk(tree):
            if isinstance(node, ast.Assign):
                for t in node.targets:
                    if isinstance(t, ast.Name):
                        bindings[t.id] = node.value
        for node in ast.walk(tree):
            call: Optional[ast.Call] = None
            names: List[str] = []
            if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
                call = node.value
                names = [self.dotted(t) for t in node.targets]
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for dec in node.decorator_list:
                    target = dec.func if isinstance(dec, ast.Call) else dec
                    tname = self.canon(target)
                    if tname == "jax.jit" and isinstance(dec, ast.Call):
                        call, names = dec, [node.name]
                    elif tname in ("functools.partial", "partial") and \
                            isinstance(dec, ast.Call) and dec.args and \
                            self.canon(dec.args[0]) == "jax.jit":
                        call, names = dec, [node.name]
                    elif tname == "jax.jit":
                        jits[node.name] = JitDecl(node.name, node.lineno)
            if call is None or self.canon(call.func) not in (
                    "jax.jit", "functools.partial", "partial"):
                continue
            if self.canon(call.func) in ("functools.partial", "partial") and \
                    not (call.args and self.canon(call.args[0]) == "jax.jit"):
                continue
            has_static = False
            donate: Tuple[int, ...] = ()
            conditional = False
            for kw in call.keywords:
                if kw.arg in ("static_argnums", "static_argnames"):
                    has_static = True
                elif kw.arg == "donate_argnums":
                    value = kw.value
                    if isinstance(value, ast.Name) and \
                            value.id in bindings:
                        value = bindings[value.id]
                    donate = _int_constants(value)
                    conditional = not isinstance(value, (ast.Tuple,
                                                         ast.Constant))
            for name in names:
                if name:
                    jits[name] = JitDecl(name, node.lineno, has_static,
                                         donate, conditional)
        return jits

    def line_at(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1].strip()
        return ""

    def finding(self, rule: str, node: ast.AST, symbol: str,
                message: str) -> Finding:
        return Finding(rule=rule, file=self.relpath,
                       line=getattr(node, "lineno", 1),
                       col=getattr(node, "col_offset", 0),
                       symbol=symbol, message=message,
                       snippet=self.line_at(getattr(node, "lineno", 1)))


# -- taint inference ---------------------------------------------------------

class TaintEnv:
    """Statement-ordered expression-taint environment for one function.

    Keys are ``ast.unparse`` strings of assignment targets (names and
    ``self.x`` attribute chains).  There is no branch merging: bodies of
    ``if``/``for`` are processed in textual order, which matches how the
    hot paths are actually written (straight-line steady state) and
    keeps every classification explainable from the source alone.
    """

    def __init__(self, ctx: ModuleContext):
        self.ctx = ctx
        self.env: Dict[str, str] = {}
        self.versions: Dict[str, int] = {}

    # taint lattice: DEVICE dominates (jax promotes mixed ops to device)
    @staticmethod
    def combine(*taints: str) -> str:
        if DEVICE in taints:
            return DEVICE
        if all(t == HOST for t in taints) and taints:
            return HOST
        if HOST in taints and all(t in (HOST, UNKNOWN) for t in taints):
            return UNKNOWN
        return UNKNOWN if taints else HOST

    def taint_of(self, node: ast.AST) -> str:
        ctx = self.ctx
        if isinstance(node, ast.Constant):
            return HOST
        if isinstance(node, (ast.List, ast.Tuple, ast.Set)):
            # a literal container is host construction unless it holds
            # a device value (then jax promotes the whole thing)
            elts = [self.taint_of(e) for e in node.elts]
            return DEVICE if DEVICE in elts else HOST
        if isinstance(node, (ast.Name, ast.Attribute)):
            key = ast.unparse(node)
            if key in self.env:
                return self.env[key]
            if any(key == p or key.startswith(p + "[")
                   for p in ctx.decl.host_state):
                return HOST
            return UNKNOWN
        if isinstance(node, ast.Subscript):
            return self.taint_of(node.value)
        if isinstance(node, ast.BinOp):
            return self.combine(self.taint_of(node.left),
                                self.taint_of(node.right))
        if isinstance(node, (ast.BoolOp,)):
            return self.combine(*[self.taint_of(v) for v in node.values])
        if isinstance(node, ast.UnaryOp):
            return self.taint_of(node.operand)
        if isinstance(node, ast.Compare):
            return self.combine(self.taint_of(node.left),
                                *[self.taint_of(c) for c in node.comparators])
        if isinstance(node, ast.IfExp):
            return self.combine(self.taint_of(node.body),
                                self.taint_of(node.orelse))
        if isinstance(node, ast.Call):
            return self._call_taint(node)
        if isinstance(node, ast.Starred):
            return self.taint_of(node.value)
        return UNKNOWN

    def _call_taint(self, node: ast.Call) -> str:
        ctx = self.ctx
        name = ctx.canon(node.func)
        raw = ctx.dotted(node.func)
        if name.startswith(_DEVICE_ROOTS) or name == "jax.jit":
            return DEVICE
        if any(raw == p or raw.startswith(p + "(")
               for p in ctx.manifest.device_producers):
            return DEVICE
        if raw in ctx.jits or (raw.split(".")[-1] in ctx.jits and "." not in raw):
            return DEVICE
        if name.startswith(_HOST_ROOTS):
            return HOST
        if isinstance(node.func, ast.Name) and \
                node.func.id in _HOST_BUILTINS:
            return HOST
        # method on a value keeps its residency: x.astype(...), x.at[i].set()
        if isinstance(node.func, ast.Attribute):
            if node.func.attr in ("item", "tolist"):
                return HOST
            return self.taint_of(node.func.value)
        return UNKNOWN

    # -- statement processing ------------------------------------------------

    def assign(self, target: ast.AST, taint: str):
        if isinstance(target, (ast.Tuple, ast.List)):
            for elt in target.elts:
                self.assign(elt, taint)
            return
        if isinstance(target, ast.Starred):
            target = target.value
        if isinstance(target, (ast.Name, ast.Attribute)):
            key = ast.unparse(target)
            self.env[key] = taint
            self.versions[key] = self.versions.get(key, 0) + 1
        elif isinstance(target, ast.Subscript):
            # x[i] = v leaves x's residency unchanged
            pass

    def process(self, stmt: ast.stmt):
        """Update the environment for one statement (call this in
        textual order; rules interleave their checks between calls)."""
        if isinstance(stmt, ast.Assign):
            value_taint = self.taint_of(stmt.value)
            for target in stmt.targets:
                if isinstance(target, (ast.Tuple, ast.List)) and \
                        isinstance(stmt.value, ast.Tuple) and \
                        len(target.elts) == len(stmt.value.elts):
                    for t, v in zip(target.elts, stmt.value.elts):
                        self.assign(t, self.taint_of(v))
                else:
                    self.assign(target, value_taint)
        elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
            self.assign(stmt.target, self.taint_of(stmt.value))
        elif isinstance(stmt, ast.AugAssign):
            self.assign(stmt.target,
                        self.combine(self.taint_of(stmt.target),
                                     self.taint_of(stmt.value)))
        elif isinstance(stmt, (ast.For, ast.AsyncFor)):
            self.assign(stmt.target, UNKNOWN)
        elif isinstance(stmt, ast.With):
            for item in stmt.items:
                if item.optional_vars is not None:
                    self.assign(item.optional_vars, UNKNOWN)


def iter_statements(fn: ast.FunctionDef) -> Iterable[ast.stmt]:
    """Every statement in the function in textual order, descending
    into compound bodies but not into nested function definitions."""
    def walk(body):
        for stmt in body:
            yield stmt
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                continue
            for field in ("body", "orelse", "finalbody"):
                yield from walk(getattr(stmt, field, []) or [])
            for handler in getattr(stmt, "handlers", []) or []:
                yield from walk(handler.body)
    yield from walk(fn.body)


def statement_expressions(stmt: ast.stmt) -> Iterable[ast.AST]:
    """All expression nodes inside one statement (not descending into
    nested statements — those are visited by iter_statements)."""
    compound = (ast.For, ast.AsyncFor, ast.While, ast.If, ast.With,
                ast.Try, ast.FunctionDef, ast.AsyncFunctionDef,
                ast.ClassDef)
    if isinstance(stmt, compound):
        # only the header expressions belong to this statement
        headers = []
        for attr in ("test", "iter", "target"):
            sub = getattr(stmt, attr, None)
            if sub is not None:
                headers.append(sub)
        for item in getattr(stmt, "items", []) or []:
            headers.append(item.context_expr)
        for h in headers:
            yield from ast.walk(h)
        return
    yield from ast.walk(stmt)


# -- driver ------------------------------------------------------------------

def iter_source_files(root: Path, scan_paths: Iterable[str]):
    for rel in scan_paths:
        base = root / rel
        if base.is_file():
            yield base
            continue
        for path in sorted(base.rglob("*.py")):
            if "__pycache__" in path.parts:
                continue
            yield path


def analyze_source(source: str, relpath: str, manifest: Manifest,
                   path: Optional[Path] = None,
                   rules: Optional[Iterable] = None) -> AnalysisResult:
    """Analyze one in-memory source blob (the unit the fixture tests
    drive)."""
    from repro.analysis.rules import RULES
    tree = ast.parse(source, filename=relpath)
    ctx = ModuleContext(path or Path(relpath), relpath, source, tree,
                        manifest)
    per_line, file_wide = parse_suppressions(source)
    findings: List[Finding] = []
    suppressed = 0
    for rule in (rules if rules is not None else RULES):
        for finding in rule.check(ctx):
            if is_suppressed(finding, per_line, file_wide):
                suppressed += 1
            else:
                findings.append(finding)
    findings.sort(key=Finding.sort_key)
    return AnalysisResult(findings=findings, files_scanned=1,
                          suppressed=suppressed)


def analyze_paths(root: Path, manifest: Manifest,
                  rules: Optional[Iterable] = None) -> AnalysisResult:
    """Analyze every file under the manifest's scan roots."""
    findings: List[Finding] = []
    suppressed = 0
    count = 0
    for path in iter_source_files(root, manifest.scan_paths):
        relpath = path.relative_to(root).as_posix()
        try:
            source = path.read_text()
        except (OSError, UnicodeDecodeError):
            continue
        try:
            result = analyze_source(source, relpath, manifest, path, rules)
        except SyntaxError as e:
            findings.append(Finding(
                rule="RL000", file=relpath, line=e.lineno or 1, col=0,
                symbol="<module>", message=f"syntax error: {e.msg}"))
            count += 1
            continue
        findings.extend(result.findings)
        suppressed += result.suppressed
        count += 1
    findings.sort(key=Finding.sort_key)
    return AnalysisResult(findings=findings, files_scanned=count,
                          suppressed=suppressed)
