"""Command line driver for ``python -m repro.analysis``.

Exit status: 0 when there are no non-baselined findings (and, with
``--docs``, no broken links); 1 otherwise.  ``--write-baseline``
records the current findings and exits 0 — use it only after fixing,
never to admit new debt.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path
from typing import List, Optional

from repro.analysis import baseline as baseline_mod
from repro.analysis import report
from repro.analysis.docscheck import run_docs_check
from repro.analysis.engine import analyze_paths
from repro.analysis.manifest import load_manifest
from repro.analysis.rules import RULES, get_rules


def default_root() -> Path:
    """Repo root when running from a checkout: src/repro/analysis/cli.py
    -> up four levels."""
    return Path(__file__).resolve().parents[3]


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repro-lint: AST-based JAX-hazard analysis for the "
                    "serving hot path (RL001-RL006), plus the markdown "
                    "link check (--docs).")
    p.add_argument("--root", type=Path, default=None,
                   help="repo root to analyze (default: the checkout "
                        "containing this package)")
    p.add_argument("--manifest", type=Path, default=None,
                   help="hot-path manifest (default: the checked-in "
                        "analysis/hotpaths.toml)")
    p.add_argument("--baseline", type=Path, default=None,
                   help="baseline file (default: the checked-in "
                        "analysis/baseline.json)")
    p.add_argument("--format", choices=("text", "json", "github"),
                   default="text", help="report format")
    p.add_argument("--rules", default=None, metavar="RL001,RL002",
                   help="comma-separated rule ids to run (default: all)")
    p.add_argument("--list-rules", action="store_true",
                   help="print the rule catalog and exit")
    p.add_argument("--write-baseline", action="store_true",
                   help="record current findings as the baseline and "
                        "exit 0 (only after fixing — the count must "
                        "only ratchet down)")
    p.add_argument("--docs", action="store_true",
                   help="run the markdown link check instead of the "
                        "lint rules")
    return p


def main(argv: Optional[List[str]] = None) -> int:
    args = build_parser().parse_args(argv)
    root = (args.root or default_root()).resolve()

    if args.list_rules:
        for rule in RULES:
            print(f"{rule.id}  {rule.title}")
            print(f"       {rule.brief}")
        return 0

    if args.docs:
        return run_docs_check(root)

    try:
        manifest = load_manifest(args.manifest)
    except (OSError, ValueError) as e:
        print(f"repro-lint: cannot load manifest: {e}", file=sys.stderr)
        return 2
    rules = RULES
    if args.rules:
        try:
            rules = get_rules({r.strip() for r in args.rules.split(",")
                               if r.strip()})
        except ValueError as e:
            print(f"repro-lint: {e}", file=sys.stderr)
            return 2

    result = analyze_paths(root, manifest, rules)

    baseline_path = args.baseline or baseline_mod.default_baseline_path()
    if args.write_baseline:
        n = baseline_mod.write_baseline(baseline_path, result.findings)
        print(f"repro-lint: wrote {n} finding(s) to {baseline_path}")
        return 0

    try:
        known = baseline_mod.load_baseline(baseline_path)
    except ValueError as e:
        print(f"repro-lint: bad baseline: {e}", file=sys.stderr)
        return 2
    new, baselined = baseline_mod.split_baselined(result.findings, known)
    report.emit(args.format, new, baselined, result, sys.stdout)
    return 1 if new else 0
