"""Hot-path manifest: which functions the serving tick actually runs.

``hotpaths.toml`` (checked in next to this module) declares, per
module, the *hot* functions (steady-state per-tick work — RL001 flags
implicit transfers only there), the *traced* functions (bodies that run
under ``jax.jit`` — RL005 forbids host side effects in them), and
*host_state* attribute patterns (names like ``self.page_table`` that
are host mirrors by contract, so uploading them from a hot path is a
churn hazard).  Global sections name *device_producers* (call patterns
whose results live on device, e.g. ``self._fused_fn``) and the default
*scan* roots.

Parsing prefers the stdlib ``tomllib`` (Python 3.11+, what CI runs) and
falls back to a built-in parser covering the subset this manifest uses
(tables, arrays of tables, string and string-list values) — the
analyzer must work on a bare Python with no third-party installs.
"""
from __future__ import annotations

import dataclasses
import re
from pathlib import Path
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModuleDecl:
    """Per-file analysis scope from one ``[[module]]`` manifest block."""
    file: str                          # repo-relative posix path
    hot: Tuple[str, ...] = ()          # qualnames: "Class.method" | "func"
    traced: Tuple[str, ...] = ()       # qualnames traced under jit
    host_state: Tuple[str, ...] = ()   # attr chains that are host mirrors


@dataclasses.dataclass(frozen=True)
class Manifest:
    """Parsed hot-path manifest (see module docstring)."""
    modules: Dict[str, ModuleDecl]
    device_producers: Tuple[str, ...] = ()
    scan_paths: Tuple[str, ...] = ("src/repro",)
    path: Optional[Path] = None

    def decl(self, relpath: str) -> ModuleDecl:
        """The declaration for ``relpath`` (empty scope when absent)."""
        return self.modules.get(relpath, ModuleDecl(file=relpath))


def default_manifest_path() -> Path:
    return Path(__file__).resolve().parent / "hotpaths.toml"


# -- TOML subset fallback ----------------------------------------------------

_KEY_RE = re.compile(r"^([A-Za-z0-9_-]+)\s*=\s*(.*)$")


def _strip_comment(line: str) -> str:
    """Drop a trailing comment (this manifest never puts '#' in strings
    outside of suppression examples, which live in docs, not here)."""
    out = []
    in_str = False
    for ch in line:
        if ch == '"':
            in_str = not in_str
        if ch == "#" and not in_str:
            break
        out.append(ch)
    return "".join(out).strip()


def _parse_value(text: str, lines, i: int):
    """Parse a string or (possibly multi-line) string array value.
    Returns (value, next_line_index)."""
    text = text.strip()
    if text.startswith('"'):
        return text.strip('"'), i
    if not text.startswith("["):
        raise ValueError(f"unsupported TOML value: {text!r}")
    buf = text
    while "]" not in buf:
        i += 1
        if i >= len(lines):
            raise ValueError("unterminated TOML array")
        buf += " " + _strip_comment(lines[i])
    inner = buf[buf.index("[") + 1:buf.rindex("]")]
    items = [s.strip().strip('"') for s in inner.split(",")]
    return [s for s in items if s], i


def parse_toml_subset(text: str) -> Dict[str, object]:
    """Parse the manifest's TOML subset into the same shape tomllib
    produces: ``[[name]]`` accumulates a list of dicts, ``[name]`` a
    dict, root keys go to the top level."""
    root: Dict[str, object] = {}
    current: Dict[str, object] = root
    lines = text.splitlines()
    i = 0
    while i < len(lines):
        line = _strip_comment(lines[i])
        if not line:
            i += 1
            continue
        if line.startswith("[["):
            name = line.strip("[]").strip()
            current = {}
            root.setdefault(name, [])
            root[name].append(current)          # type: ignore[union-attr]
        elif line.startswith("["):
            name = line.strip("[]").strip()
            current = {}
            root[name] = current
        else:
            m = _KEY_RE.match(line)
            if not m:
                raise ValueError(f"unparseable manifest line: {line!r}")
            value, i = _parse_value(m.group(2), lines, i)
            current[m.group(1)] = value
        i += 1
    return root


def _load_toml(path: Path) -> Dict[str, object]:
    try:
        import tomllib                           # Python 3.11+
    except ModuleNotFoundError:
        try:
            import tomli as tomllib              # type: ignore[no-redef]
        except ModuleNotFoundError:
            return parse_toml_subset(path.read_text())
    with open(path, "rb") as f:
        return tomllib.load(f)


def load_manifest(path: Optional[Path] = None) -> Manifest:
    """Load ``hotpaths.toml`` (the checked-in default when ``path`` is
    None).

    Raises:
      FileNotFoundError: the manifest file does not exist.
      ValueError: a ``[[module]]`` block is missing its ``file`` key.
    """
    path = Path(path) if path is not None else default_manifest_path()
    data = _load_toml(path)
    modules: Dict[str, ModuleDecl] = {}
    for block in data.get("module", []):         # type: ignore[union-attr]
        file = block.get("file")
        if not file:
            raise ValueError(f"{path}: [[module]] block without a 'file' key")
        modules[file] = ModuleDecl(
            file=file,
            hot=tuple(block.get("hot", [])),
            traced=tuple(block.get("traced", [])),
            host_state=tuple(block.get("host_state", [])),
        )
    producers: List[str] = list(
        data.get("device_producers", {}).get("patterns", []))
    scan: List[str] = list(data.get("scan", {}).get("paths", ["src/repro"]))
    return Manifest(modules=modules, device_producers=tuple(producers),
                    scan_paths=tuple(scan), path=path)
