"""The repro-lint rules: RL001-RL006.

Each rule is a pure function ``ModuleContext -> [Finding]`` wrapped in
a :class:`Rule` record carrying its catalog metadata.  The rules encode
hazards this repo has actually shipped and then fixed by hand (see
docs/static_analysis.md for the incident behind each one):

RL001  implicit host<->device transfer in a declared hot-path function
RL002  retrace hazard: Python scalars into a jit without static_*
RL003  donated buffer referenced after the donating call
RL004  PRNG key consumed twice without split/fold_in
RL005  host side effects inside a traced function
RL006  structural ops on float8 arrays (must travel as uint8 bits)
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Callable, Dict, List, Optional, Set, Tuple

from repro.analysis.engine import (
    DEVICE, HOST, UNKNOWN, Finding, ModuleContext, TaintEnv,
    iter_statements, statement_expressions,
)


@dataclasses.dataclass(frozen=True)
class Rule:
    id: str
    title: str
    brief: str
    check: Callable[[ModuleContext], List[Finding]]


# -- RL001: implicit transfers in hot paths ----------------------------------

_D2H_CALLS = ("numpy.asarray", "numpy.array", "numpy.copy")
_H2D_CALLS = ("jax.numpy.asarray", "jax.numpy.array", "jax.device_put")
_SYNC_BUILTINS = ("int", "float", "bool")


def _check_rl001(ctx: ModuleContext) -> List[Finding]:
    findings: List[Finding] = []
    for qual, fn in ctx.functions:
        if not ctx.is_hot(qual):
            continue
        env = TaintEnv(ctx)
        for stmt in iter_statements(fn):
            for node in statement_expressions(stmt):
                if isinstance(node, ast.Call):
                    _rl001_call(ctx, env, qual, node, findings)
            if isinstance(stmt, (ast.For, ast.AsyncFor)) and \
                    env.taint_of(stmt.iter) == DEVICE:
                findings.append(ctx.finding(
                    "RL001", stmt.iter, qual,
                    f"iterating over device array "
                    f"`{ast.unparse(stmt.iter)}` pulls it to host "
                    f"element by element — pull once with a batched "
                    f"np.asarray, or keep the loop on device"))
            env.process(stmt)
    return findings


def _rl001_call(ctx: ModuleContext, env: TaintEnv, qual: str,
                node: ast.Call, findings: List[Finding]):
    name = ctx.canon(node.func)
    arg = node.args[0] if node.args else None
    if name in _D2H_CALLS and arg is not None:
        taint = env.taint_of(arg)
        src = ast.unparse(arg)
        if taint == DEVICE:
            findings.append(ctx.finding(
                "RL001", node, qual,
                f"implicit device->host transfer: np.asarray on device "
                f"value `{src}` in hot path — every call blocks on the "
                f"device; batch transfers or keep the value on device"))
        elif taint == UNKNOWN:
            findings.append(ctx.finding(
                "RL001", node, qual,
                f"possible device->host transfer: np.asarray on "
                f"`{src}` whose residency this hot path cannot prove "
                f"is host — if it is a jax array this blocks every "
                f"call (reduce on device, pull only the result)"))
    elif name in _H2D_CALLS and arg is not None:
        if env.taint_of(arg) == HOST:
            findings.append(ctx.finding(
                "RL001", node, qual,
                f"per-call host->device upload "
                f"`{ast.unparse(node)}` in hot path — hoist the "
                f"upload out of the steady state or cache the device "
                f"copy and re-upload only when it changes"))
    elif isinstance(node.func, ast.Name) and \
            node.func.id in _SYNC_BUILTINS and arg is not None:
        if env.taint_of(arg) == DEVICE:
            findings.append(ctx.finding(
                "RL001", node, qual,
                f"`{node.func.id}()` on device value "
                f"`{ast.unparse(arg)}` forces a blocking device->host "
                f"sync in hot path — keep the scalar on device or "
                f"batch the pull"))
    elif isinstance(node.func, ast.Attribute) and \
            node.func.attr in ("item", "tolist") and not node.args:
        taint = env.taint_of(node.func.value)
        if taint in (DEVICE, UNKNOWN):
            sev = ("" if taint == DEVICE else "possible ")
            findings.append(ctx.finding(
                "RL001", node, qual,
                f"{sev}device->host sync: `.{node.func.attr}()` on "
                f"`{ast.unparse(node.func.value)}` in hot path — each "
                f"call is a blocking transfer"))


# -- RL002: retrace hazards at jit call sites --------------------------------

def _is_scalar_literal(node: ast.AST) -> bool:
    if isinstance(node, ast.Constant):
        return isinstance(node.value, (int, float, bool))
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
        return _is_scalar_literal(node.operand)
    return False


def _is_shape_dependent(ctx: ModuleContext, node: ast.AST) -> bool:
    """Expressions whose value changes with data shape: ``x.shape[i]``,
    ``len(x)``, ``int(...)`` — passing them as traced args retraces on
    every distinct value."""
    if isinstance(node, ast.Subscript) and \
            isinstance(node.value, ast.Attribute) and \
            node.value.attr == "shape":
        return True
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) and \
            node.func.id in ("int", "len"):
        return True
    return False


def _check_rl002(ctx: ModuleContext) -> List[Finding]:
    findings: List[Finding] = []
    for qual, fn in ctx.functions:
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            raw = ctx.dotted(node.func)
            decl = ctx.jits.get(raw)
            if decl is None or decl.has_static:
                continue
            if raw in ("jax.jit", "partial"):
                continue
            for i, arg in enumerate(list(node.args) +
                                    [k.value for k in node.keywords]):
                if _is_scalar_literal(arg):
                    findings.append(ctx.finding(
                        "RL002", arg, qual,
                        f"Python scalar `{ast.unparse(arg)}` passed to "
                        f"jitted `{raw}` (arg {i}) with no "
                        f"static_argnums/static_argnames — every "
                        f"distinct value triggers a retrace; pass a "
                        f"device array pinned to a fixed shape, or "
                        f"declare the arg static"))
                elif _is_shape_dependent(ctx, arg):
                    findings.append(ctx.finding(
                        "RL002", arg, qual,
                        f"data-dependent value `{ast.unparse(arg)}` "
                        f"passed to jitted `{raw}` (arg {i}) with no "
                        f"static_argnums/static_argnames — shape churn "
                        f"retraces on every new value; pad to a fixed "
                        f"shape (the (max_seats,) pin) or declare it "
                        f"static"))
    return findings


# -- RL003: donation-after-use -----------------------------------------------

def _stores_in(stmt: ast.stmt) -> Set[str]:
    out: Set[str] = set()
    targets: List[ast.AST] = []
    if isinstance(stmt, ast.Assign):
        targets = list(stmt.targets)
    elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
        targets = [stmt.target]
    elif isinstance(stmt, (ast.For, ast.AsyncFor)):
        targets = [stmt.target]
    for t in targets:
        for sub in ast.walk(t):
            if isinstance(sub, (ast.Name, ast.Attribute)):
                out.add(ast.unparse(sub))
    return out


def _loads_in(stmt: ast.stmt, key: str) -> List[ast.AST]:
    skip: Set[int] = set()
    if isinstance(stmt, ast.Assign):
        for t in stmt.targets:
            for sub in ast.walk(t):
                skip.add(id(sub))
    elif isinstance(stmt, ast.AnnAssign):
        for sub in ast.walk(stmt.target):
            skip.add(id(sub))
    out = []
    for node in statement_expressions(stmt):
        if id(node) in skip:
            continue
        if isinstance(node, (ast.Name, ast.Attribute)) and \
                ast.unparse(node) == key:
            out.append(node)
    return out


def _check_rl003(ctx: ModuleContext) -> List[Finding]:
    findings: List[Finding] = []
    for qual, fn in ctx.functions:
        stmts = list(iter_statements(fn))
        for idx, stmt in enumerate(stmts):
            for node in statement_expressions(stmt):
                if not isinstance(node, ast.Call):
                    continue
                decl = ctx.jits.get(ctx.dotted(node.func))
                if decl is None or not decl.donate:
                    continue
                donated = []
                for pos in decl.donate:
                    if pos < len(node.args):
                        key = ast.unparse(node.args[pos])
                        if isinstance(node.args[pos],
                                      (ast.Name, ast.Attribute)):
                            donated.append((pos, key))
                if not donated:
                    continue
                # stores on the call's own statement (unpack targets)
                # land after the call returns, so they re-bind safely
                live = {key: pos for pos, key in donated
                        if key not in _stores_in(stmt)}
                for later in stmts[idx + 1:]:
                    if not live:
                        break
                    for key in list(live):
                        loads = _loads_in(later, key)
                        if loads:
                            findings.append(ctx.finding(
                                "RL003", loads[0], qual,
                                f"`{key}` was donated to jitted "
                                f"`{ctx.dotted(node.func)}` (arg "
                                f"{live[key]}, donate_argnums) at line "
                                f"{stmt.lineno} and is read here — the "
                                f"buffer may already be reused; rebind "
                                f"the name from the call's result "
                                f"before any further use"))
                            del live[key]
                    for key in _stores_in(later):
                        live.pop(key, None)
    return findings


# -- RL004: PRNG key reuse ---------------------------------------------------

_KEY_PRODUCERS = ("jax.random.PRNGKey", "jax.random.key",
                  "jax.random.fold_in", "jax.random.split",
                  "jax.random.clone")
_KEY_SAFE_CONSUMERS = {"split", "fold_in", "PRNGKey", "key", "clone",
                       "wrap_key_data", "key_data"}
_KEY_PARAM_NAMES = {"key", "rng", "rng_key", "prng_key"}


def _check_rl004(ctx: ModuleContext) -> List[Finding]:
    findings: List[Finding] = []
    for qual, fn in ctx.functions:
        tracked: Set[str] = set()
        uses: Dict[str, List[Tuple[int, str]]] = {}
        for a in list(fn.args.args) + list(fn.args.kwonlyargs):
            if a.arg in _KEY_PARAM_NAMES:
                tracked.add(a.arg)
        for stmt in iter_statements(fn):
            # consumers first: the RHS runs before the LHS rebinds
            for node in statement_expressions(stmt):
                if not isinstance(node, ast.Call):
                    continue
                name = ctx.canon(node.func)
                if not name.startswith("jax.random."):
                    continue
                if name.rsplit(".", 1)[-1] in _KEY_SAFE_CONSUMERS:
                    continue
                for arg in list(node.args) + \
                        [k.value for k in node.keywords]:
                    expr = ast.unparse(arg) if isinstance(
                        arg, (ast.Name, ast.Attribute, ast.Subscript)) \
                        else ""
                    base = expr.split("[")[0].split(".")[0]
                    if not expr or base not in tracked:
                        continue
                    history = uses.setdefault(expr, [])
                    if history:
                        first_line, first_fn = history[0]
                        findings.append(ctx.finding(
                            "RL004", arg, qual,
                            f"PRNG key `{expr}` consumed by "
                            f"`{name}` but already consumed by "
                            f"`{first_fn}` at line {first_line} — "
                            f"reusing a key correlates the streams; "
                            f"jax.random.split it, or fold_in a "
                            f"distinct stream id per consumer (the "
                            f"sampler's (seed, rid, step) discipline)"))
                    history.append((node.lineno, name))
            # rebinding a tracked name starts a fresh key lineage
            rebound: Set[str] = set()
            if isinstance(stmt, ast.Assign):
                value_is_key = isinstance(stmt.value, ast.Call) and \
                    ctx.canon(stmt.value.func) in _KEY_PRODUCERS
                for t in stmt.targets:
                    elts = t.elts if isinstance(t, (ast.Tuple, ast.List)) \
                        else [t]
                    for elt in elts:
                        if isinstance(elt, ast.Name):
                            rebound.add(elt.id)
                            if value_is_key:
                                tracked.add(elt.id)
            elif isinstance(stmt, (ast.For, ast.AsyncFor)):
                elts = stmt.target.elts if isinstance(
                    stmt.target, (ast.Tuple, ast.List)) else [stmt.target]
                for elt in elts:
                    if isinstance(elt, ast.Name):
                        rebound.add(elt.id)
                        if isinstance(stmt.iter, ast.Call) and \
                                ctx.canon(stmt.iter.func) in _KEY_PRODUCERS:
                            tracked.add(elt.id)
            for name in rebound:
                for expr in list(uses):
                    if expr == name or expr.startswith((f"{name}[",
                                                        f"{name}.")):
                        del uses[expr]
    return findings


# -- RL005: host side effects under trace ------------------------------------

_EFFECT_CALLS = {
    "print": "jax.debug.print (formats on host without breaking the "
             "trace)",
    "input": "nothing — traced functions cannot block on host input",
    "breakpoint": "jax.debug.breakpoint",
    "open": "jax.debug.callback / io_callback for host I/O",
    "time.time": "jax.debug.callback, or time outside the jit boundary",
    "time.perf_counter": "jax.debug.callback, or time outside the jit "
                         "boundary",
    "time.monotonic": "jax.debug.callback, or time outside the jit "
                      "boundary",
}


def _check_rl005(ctx: ModuleContext) -> List[Finding]:
    findings: List[Finding] = []
    for qual, fn in ctx.functions:
        if not ctx.is_traced(qual, fn):
            continue
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            name = ctx.canon(node.func)
            suggestion = _EFFECT_CALLS.get(name)
            if suggestion is None and name.startswith("logging."):
                suggestion = "jax.debug.print"
            if suggestion is None:
                continue
            findings.append(ctx.finding(
                "RL005", node, qual,
                f"`{name}` inside jit-traced `{qual}` runs once at "
                f"trace time, then never again (or forces a host "
                f"callback) — use {suggestion}"))
    return findings


# -- RL006: structural ops on float8 -----------------------------------------

_STRUCTURAL_CALLS = (
    "jax.numpy.take", "jax.numpy.take_along_axis",
    "jax.numpy.concatenate", "jax.numpy.pad", "jax.numpy.roll",
    "jax.numpy.stack", "jax.lax.gather", "jax.lax.scatter",
    "jax.lax.dynamic_slice", "jax.lax.dynamic_update_slice",
    "jax.lax.dynamic_index_in_dim", "jax.lax.dynamic_slice_in_dim",
)
_AT_METHODS = ("set", "add", "max", "min", "mul", "get")


def _static_index(node: ast.AST) -> bool:
    if isinstance(node, ast.Constant):
        return True
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
        return _static_index(node.operand)
    if isinstance(node, ast.Slice):
        return all(p is None or _static_index(p)
                   for p in (node.lower, node.upper, node.step))
    if isinstance(node, ast.Tuple):
        return all(_static_index(e) for e in node.elts)
    return False


class _Fp8Env:
    """Tracks which expressions currently hold float8-typed arrays."""

    def __init__(self, ctx: ModuleContext):
        self.ctx = ctx
        self.fp8: Set[str] = set()

    def is_fp8(self, node: ast.AST) -> bool:
        if isinstance(node, ast.Call):
            func = node.func
            if isinstance(func, ast.Attribute) and func.attr == "astype":
                if node.args and "float8" in ast.unparse(node.args[0]):
                    return True
                return False          # astype to a wider dtype clears fp8
            name = self.ctx.canon(func)
            if name == "jax.lax.bitcast_convert_type":
                args = list(node.args) + [k.value for k in node.keywords]
                return any("float8" in ast.unparse(a) for a in args[1:])
            if name.startswith(("jax.numpy.", "jax.lax.")) and \
                    "float8" in ast.unparse(node):
                return True           # jnp.zeros(..., dtype=f8) etc.
            if isinstance(func, ast.Attribute):
                # x.at[i].set(v), x.reshape(...) keep x's dtype
                return self.is_fp8(func.value)
            return False
        if isinstance(node, (ast.Name, ast.Attribute)):
            return ast.unparse(node) in self.fp8
        if isinstance(node, ast.Subscript):
            return self.is_fp8(node.value)
        if isinstance(node, ast.IfExp):
            return self.is_fp8(node.body) or self.is_fp8(node.orelse)
        return False

    def process(self, stmt: ast.stmt):
        if isinstance(stmt, ast.Assign):
            fp8 = self.is_fp8(stmt.value)
            for t in stmt.targets:
                elts = t.elts if isinstance(t, (ast.Tuple, ast.List)) \
                    else [t]
                for elt in elts:
                    if isinstance(elt, (ast.Name, ast.Attribute)):
                        key = ast.unparse(elt)
                        (self.fp8.add if fp8 else
                         self.fp8.discard)(key)
        elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
            if isinstance(stmt.target, (ast.Name, ast.Attribute)):
                key = ast.unparse(stmt.target)
                (self.fp8.add if self.is_fp8(stmt.value) else
                 self.fp8.discard)(key)


_RL006_FIX = ("float8 must travel as uint8 bit patterns through "
              "structural ops: bitcast_convert_type to uint8, run the "
              "op, bitcast back (XLA CPU otherwise legalizes it "
              "through a whole-array f16 round trip)")


def _check_rl006(ctx: ModuleContext) -> List[Finding]:
    findings: List[Finding] = []
    for qual, fn in ctx.functions:
        env = _Fp8Env(ctx)
        for stmt in iter_statements(fn):
            for node in statement_expressions(stmt):
                if isinstance(node, ast.Subscript) and \
                        isinstance(node.ctx, ast.Load) and \
                        env.is_fp8(node.value) and \
                        not _static_index(node.slice):
                    if isinstance(node.value, ast.Attribute) and \
                            node.value.attr == "at":
                        continue      # handled as scatter below
                    findings.append(ctx.finding(
                        "RL006", node, qual,
                        f"dynamic gather "
                        f"`{ast.unparse(node)}` on a float8 array — "
                        f"{_RL006_FIX}"))
                elif isinstance(node, ast.Call):
                    _rl006_call(ctx, env, qual, node, findings)
            env.process(stmt)
    return findings


def _rl006_call(ctx: ModuleContext, env: _Fp8Env, qual: str,
                node: ast.Call, findings: List[Finding]):
    name = ctx.canon(node.func)
    if name in _STRUCTURAL_CALLS:
        args = list(node.args) + [k.value for k in node.keywords]
        if any(env.is_fp8(a) for a in args):
            findings.append(ctx.finding(
                "RL006", node, qual,
                f"`{name}` on a float8 array — {_RL006_FIX}"))
        return
    if name == "jax.lax.scan":
        # carry (2nd positional arg) slicing runs a structural op per step
        if len(node.args) >= 2 and env.is_fp8(node.args[1]):
            findings.append(ctx.finding(
                "RL006", node, qual,
                f"float8 array in a jax.lax.scan carry — each step "
                f"slices the carry structurally; {_RL006_FIX}"))
        return
    func = node.func
    if isinstance(func, ast.Attribute) and func.attr in _AT_METHODS and \
            isinstance(func.value, ast.Subscript) and \
            isinstance(func.value.value, ast.Attribute) and \
            func.value.value.attr == "at":
        base = func.value.value.value
        if env.is_fp8(base) and not _static_index(func.value.slice):
            findings.append(ctx.finding(
                "RL006", node, qual,
                f"dynamic scatter `.at[...].{func.attr}` on float8 "
                f"array `{ast.unparse(base)}` — {_RL006_FIX}"))


# -- registry ----------------------------------------------------------------

RULES: Tuple[Rule, ...] = (
    Rule("RL001", "implicit transfer in hot path",
         "device->host sync (np.asarray/int()/.item()/iteration) or "
         "per-call host->device upload inside a manifest-declared hot "
         "function", _check_rl001),
    Rule("RL002", "retrace hazard at jit boundary",
         "Python scalar or data-dependent shape passed to a jitted "
         "callable with no static_argnums/static_argnames", _check_rl002),
    Rule("RL003", "donated buffer used after call",
         "a buffer named in donate_argnums is read after the donating "
         "call without being rebound", _check_rl003),
    Rule("RL004", "PRNG key reuse",
         "the same key expression flows into two jax.random consumers "
         "without a split/fold_in between", _check_rl004),
    Rule("RL005", "host side effect under trace",
         "print/open/clock inside a jit-traced function (use "
         "jax.debug.print / callbacks)", _check_rl005),
    Rule("RL006", "structural op on float8",
         "gather/scatter/concat/scan-carry on a float8 array that must "
         "travel as uint8 bit patterns", _check_rl006),
)


def rule_ids() -> Tuple[str, ...]:
    return tuple(r.id for r in RULES)


def get_rules(only: Optional[Set[str]] = None) -> Tuple[Rule, ...]:
    """The rule set, optionally filtered to ``only`` ids.

    Raises:
      ValueError: ``only`` names an unknown rule id.
    """
    if only is None:
        return RULES
    unknown = only - set(rule_ids())
    if unknown:
        raise ValueError(f"unknown rule ids: {sorted(unknown)}")
    return tuple(r for r in RULES if r.id in only)
