"""Reporters: text (humans), json (tooling), github (CI annotations).

Every format keys findings ``file:line RLxxx`` so a report line, a
baseline entry, and a suppression comment all talk about the same
thing.  The github format emits workflow commands
(``::error file=...``) that the Actions runner turns into PR
annotations, and appends a summary table to ``$GITHUB_STEP_SUMMARY``
when that file is available.
"""
from __future__ import annotations

import json
import os
from typing import List, Optional, TextIO

from repro.analysis.engine import AnalysisResult, Finding


def format_text(new: List[Finding], baselined: List[Finding],
                result: AnalysisResult) -> str:
    lines: List[str] = []
    for f in new:
        lines.append(f"{f.file}:{f.line} {f.rule} [{f.symbol}] "
                     f"{f.message}")
    lines.append(
        f"repro-lint: {len(new)} finding(s) "
        f"({len(baselined)} baselined, {result.suppressed} suppressed) "
        f"across {result.files_scanned} file(s)")
    return "\n".join(lines)


def format_json(new: List[Finding], baselined: List[Finding],
                result: AnalysisResult) -> str:
    def encode(f: Finding, is_baselined: bool):
        return {
            "rule": f.rule, "file": f.file, "line": f.line,
            "col": f.col, "symbol": f.symbol, "message": f.message,
            "snippet": f.snippet, "baselined": is_baselined,
        }
    doc = {
        "findings": ([encode(f, False) for f in new] +
                     [encode(f, True) for f in baselined]),
        "new": len(new),
        "baselined": len(baselined),
        "suppressed": result.suppressed,
        "files_scanned": result.files_scanned,
    }
    return json.dumps(doc, indent=2)


def _escape_gh(text: str) -> str:
    """Workflow-command data escaping per the Actions runner rules."""
    return (text.replace("%", "%25").replace("\r", "%0D")
            .replace("\n", "%0A"))


def format_github(new: List[Finding], baselined: List[Finding],
                  result: AnalysisResult) -> str:
    lines: List[str] = []
    for f in new:
        lines.append(
            f"::error file={f.file},line={f.line},"
            f"col={f.col + 1},title=repro-lint {f.rule}::"
            f"{_escape_gh(f.message)}")
    for f in baselined:
        lines.append(
            f"::notice file={f.file},line={f.line},"
            f"col={f.col + 1},title=repro-lint {f.rule} (baselined)::"
            f"{_escape_gh(f.message)}")
    lines.append(
        f"repro-lint: {len(new)} new finding(s), "
        f"{len(baselined)} baselined, {result.suppressed} suppressed")
    return "\n".join(lines)


def step_summary(new: List[Finding], baselined: List[Finding],
                 result: AnalysisResult) -> str:
    """Markdown for $GITHUB_STEP_SUMMARY: the ratchet at a glance."""
    lines = ["### repro-lint", ""]
    lines.append(f"| new findings | baselined | suppressed inline "
                 f"| files scanned |")
    lines.append("|---|---|---|---|")
    lines.append(f"| **{len(new)}** | {len(baselined)} "
                 f"| {result.suppressed} | {result.files_scanned} |")
    if new:
        lines += ["", "| finding | symbol | message |", "|---|---|---|"]
        for f in new[:50]:
            msg = f.message if len(f.message) <= 120 else \
                f.message[:117] + "..."
            lines.append(f"| `{f.file}:{f.line}` {f.rule} "
                         f"| `{f.symbol}` | {msg} |")
    lines.append("")
    lines.append(f"baseline count: **{len(baselined)}** — this number "
                 f"only ratchets down (fix, then `--write-baseline`).")
    return "\n".join(lines)


def emit(fmt: str, new: List[Finding], baselined: List[Finding],
         result: AnalysisResult, out: TextIO,
         summary_path: Optional[str] = None) -> None:
    """Write the report; for github also append the step summary.

    Raises:
      ValueError: unknown format name.
    """
    formats = {"text": format_text, "json": format_json,
               "github": format_github}
    if fmt not in formats:
        raise ValueError(f"unknown format {fmt!r} "
                         f"(choose from {sorted(formats)})")
    print(formats[fmt](new, baselined, result), file=out)
    if fmt == "github":
        path = summary_path or os.environ.get("GITHUB_STEP_SUMMARY")
        if path:
            with open(path, "a") as f:
                f.write(step_summary(new, baselined, result) + "\n")
