"""Markdown link checking — the ``--docs`` mode of the analysis driver.

Formerly ``scripts/check_doc_links.py`` (that script is now a thin shim
over this module so there is exactly one analysis entry point).  Every
``[text](target)`` in README.md and docs/*.md whose target is a
relative path must resolve to a file in the repo; anchors are stripped
and external schemes skipped.  Also enforces the docs-set contract:
README.md must link the required docs pages.
"""
from __future__ import annotations

import re
from pathlib import Path
from typing import List

LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
EXTERNAL = ("http://", "https://", "mailto:")
REQUIRED_README_LINKS = ("docs/serving.md", "docs/benchmarks.md",
                         "docs/static_analysis.md", "docs/observability.md")


def md_files(root: Path) -> List[Path]:
    files = [root / "README.md"]
    files += sorted((root / "docs").glob("*.md"))
    return [f for f in files if f.exists()]


def check_docs(root: Path) -> List[str]:
    """All broken doc references under ``root`` (empty when green)."""
    errors: List[str] = []
    readme_targets = set()
    for f in md_files(root):
        for m in LINK.finditer(f.read_text()):
            target = m.group(1).split("#")[0]
            if not target or target.startswith(EXTERNAL):
                continue
            resolved = (f.parent / target).resolve()
            if f.name == "README.md":
                readme_targets.add(target)
            if not resolved.exists():
                errors.append(f"{f.relative_to(root)}: broken link "
                              f"-> {m.group(1)}")
    missing = {r for r in REQUIRED_README_LINKS
               if not any(t.endswith(r.split("/")[-1])
                          for t in readme_targets)}
    for r in sorted(missing):
        errors.append(f"README.md: missing required link to {r}")
    if not (root / "README.md").exists():
        errors.append("README.md does not exist")
    return errors


def run_docs_check(root: Path) -> int:
    """CLI body for ``python -m repro.analysis --docs``."""
    errors = check_docs(root)
    if errors:
        print(f"{len(errors)} broken doc reference(s):")
        for e in errors:
            print(f"  {e}")
        return 1
    print(f"doc links ok across {len(md_files(root))} markdown file(s)")
    return 0
