"""Baseline file: known findings that don't fail the build (yet).

The baseline makes adoption incremental and monotonic: findings present
when a rule lands get recorded once, CI fails only on *new* findings,
and the count can only ratchet down (regenerate with
``--write-baseline`` after fixing, never to admit new debt).

Entries are keyed ``(rule, file, symbol, snippet)`` — deliberately
line-number-free so edits elsewhere in a file don't invalidate the
baseline — and stored as a multiset: two identical hot-path pulls on
identical source lines need two entries.
"""
from __future__ import annotations

import json
from collections import Counter
from pathlib import Path
from typing import List, Tuple

from repro.analysis.engine import Finding

_FIELDS = ("rule", "file", "symbol", "snippet")


def default_baseline_path() -> Path:
    return Path(__file__).resolve().parent / "baseline.json"


def load_baseline(path: Path) -> Counter:
    """The baseline as a multiset of finding keys (empty if the file
    doesn't exist — a missing baseline means nothing is grandfathered).

    Raises:
      ValueError: the file exists but is not a valid baseline document.
    """
    if not path.exists():
        return Counter()
    data = json.loads(path.read_text())
    if not isinstance(data, dict) or "findings" not in data:
        raise ValueError(f"{path}: expected {{'findings': [...]}}")
    keys = Counter()
    for entry in data["findings"]:
        keys[tuple(entry.get(f, "") for f in _FIELDS)] += 1
    return keys


def write_baseline(path: Path, findings: List[Finding]) -> int:
    """Record ``findings`` as the new baseline; returns the count."""
    entries = [dict(zip(_FIELDS, f.baseline_key()))
               for f in sorted(findings, key=Finding.sort_key)]
    doc = {
        "comment": "repro-lint baseline — regenerate with "
                   "`python -m repro.analysis --write-baseline` only "
                   "after FIXING findings, never to admit new ones",
        "findings": entries,
    }
    path.write_text(json.dumps(doc, indent=2) + "\n")
    return len(entries)


def split_baselined(findings: List[Finding],
                    baseline: Counter) -> Tuple[List[Finding],
                                                List[Finding]]:
    """Partition into (new, baselined).  Multiset semantics: each
    baseline entry absolves at most one finding."""
    budget = Counter(baseline)
    new: List[Finding] = []
    old: List[Finding] = []
    for f in findings:
        key = f.baseline_key()
        if budget[key] > 0:
            budget[key] -= 1
            old.append(f)
        else:
            new.append(f)
    return new, old
