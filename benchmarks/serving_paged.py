"""Serving benchmark: paged-KV engine vs fixed-slot engine, equal KV budget.

Both engines get the SAME KV memory budget (in cache tokens) and the same
skewed request stream (mostly short requests, a tail of long ones — the
distribution that hurts fixed slots most: every slot is provisioned for
the longest request, so short requests strand most of their slot).

  fixed : slots = budget // max_len          (max_len fits the longest)
  paged : pages = budget // page_size        (each request holds only
                                              ceil(len/page_size) pages)

Prints ``name,tokens_per_s,detail`` CSV rows plus the paged/fixed
throughput ratio.  Run:

  PYTHONPATH=src python -m benchmarks.serving_paged [--requests 16]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced_config
from repro.models import model as M
from repro.runtime.serving import PagedServingEngine, ServingEngine


def make_workload(n: int, *, seed: int = 0, short_frac: float = 0.75,
                  max_len: int = 96):
    """Skewed lengths: ~short_frac short chats, the rest long-context."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        if rng.random() < short_frac:
            plen, gen = int(rng.integers(4, 11)), int(rng.integers(4, 9))
        else:
            plen, gen = int(rng.integers(40, 57)), int(rng.integers(24, 33))
        assert plen + gen <= max_len
        toks = rng.integers(0, 250, plen).astype(np.int32)
        reqs.append((toks, gen))
    return reqs


def run_engine(eng, reqs):
    for toks, gen in reqs:
        eng.submit(toks, max_new_tokens=gen)
    t0 = time.perf_counter()
    done = eng.run()
    wall = time.perf_counter() - t0
    toks = sum(len(r.generated) for r in done)
    return {"requests": len(done), "tokens": toks, "wall_s": wall,
            "tokens_per_s": toks / max(wall, 1e-9)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--budget-tokens", type=int, default=384,
                    help="KV cache budget shared by both engines")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=96)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = reduced_config(get_config(args.arch))
    params = M.init_params(M.param_specs(cfg), jax.random.PRNGKey(0),
                           dtype=jnp.float32)
    reqs = make_workload(args.requests, seed=args.seed,
                         max_len=args.max_len)
    n_short = sum(1 for t, g in reqs if len(t) + g <= 32)
    print(f"# workload: {len(reqs)} requests ({n_short} short), "
          f"budget={args.budget_tokens} KV tokens")

    slots = max(1, args.budget_tokens // args.max_len)
    fixed = ServingEngine(cfg, params, slots=slots, max_len=args.max_len)
    rf = run_engine(fixed, reqs)
    print(f"fixed_slot[{slots}x{args.max_len}],"
          f"{rf['tokens_per_s']:.2f},"
          f"tokens={rf['tokens']};wall_s={rf['wall_s']:.2f}")

    num_pages = args.budget_tokens // args.page_size + 1  # +1: scratch page
    paged = PagedServingEngine(
        cfg, params, page_size=args.page_size, num_pages=num_pages,
        max_seats=4 * slots, max_seq_len=args.max_len,
        prefill_chunk=args.max_len)
    rp = run_engine(paged, reqs)
    m = paged.metrics.snapshot()
    print(f"paged[{num_pages - 1}x{args.page_size}],"
          f"{rp['tokens_per_s']:.2f},"
          f"tokens={rp['tokens']};wall_s={rp['wall_s']:.2f};"
          f"peak_page_util={m['peak_page_utilization']:.2f};"
          f"ttft_avg_s={m['ttft_avg_s']:.3f}")

    ratio = rp["tokens_per_s"] / max(rf["tokens_per_s"], 1e-9)
    print(f"speedup,{ratio:.2f},paged_vs_fixed_tokens_per_s")
    assert rp["tokens"] == rf["tokens"], "engines generated different counts"


if __name__ == "__main__":
    main()
