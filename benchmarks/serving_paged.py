"""Serving benchmark: paged vs fixed-slot engines, and prefix caching
on vs off, at equal KV budget.  Emits machine-readable BENCH_serving.json.

Workload 1 (skewed): both engines get the SAME KV memory budget (in
cache tokens) and the same skewed request stream (mostly short requests,
a tail of long ones — the distribution that hurts fixed slots most:
every slot is provisioned for the longest request, so short requests
strand most of their slot).

  fixed : slots = budget // max_len          (max_len fits the longest)
  paged : pages = budget // page_size        (each request holds only
                                              ceil(len/page_size) pages)

Workload 2 (shared prefix): every request starts with the same long
system-prompt prefix plus a short unique suffix — the dominant shape in
real single-tenant LLM traffic.  The paged engine runs twice at the SAME
page budget, prefix caching off vs on; with caching, later requests
point their leading page-table entries at the already-cached prefix
pages (refcount++) and skip prefilling them, so TTFT and aggregate
tokens/s improve while outputs stay token-identical.

Workload 3 (oversubscribed early-eos): every request declares the full
``max_new`` generation budget but most stop early at eos — the bursty,
stop-early shape where up-front reservation strands the pages an eos'd
request never touched.  The paged engine runs twice at the SAME page
budget, ``lazy_pages`` off (reserve ``ceil((prompt+max_new)/page)`` at
admission) vs on (reserve prompt pages, grow on demand, preempt the
youngest decoding request under pressure); eos is discovered from an
uncontended probe run, so it fires at the same step in both engines and
outputs stay token-identical while lazy admits strictly more concurrent
requests.

Workload 4 (mixed SLO classes): an oversubscribed stream where a convoy
of ``batch`` requests is submitted ahead of late-arriving ``premium``
ones — the multi-tenant shape where FCFS admission destroys premium
TTFT.  The paged engine runs twice at the SAME page budget and seat
count, ``--admission fcfs`` vs ``slo`` (priority + EDF admission,
priority-aware preemption): premium mean TTFT must strictly improve
while batch throughput stays within 20% and outputs stay
token-identical per request (scheduling never changes tokens).

Workload 5 (multi-model fleet): two models served from ONE process by a
``runtime.router.ModelFleet`` under one total page budget, with skewed
per-model load (the heavy model gets 7 of every 8 requests, each a long
generation; the light model serves occasional short chats).
The fleet runs twice at the SAME total budget: *shared* (small
per-model floors, the surplus redistributed at admission time by the
``HostBudget``) vs a *static 50/50 split* (each model's floor is half
the budget, zero surplus — the partitioning a per-model deployment
would hard-code).  The busy model borrows the idle model's headroom in
the shared configuration, so aggregate fleet tokens/s must stay within
10% of — and typically beat — the best static split, while per-rid
outputs stay token-identical (fleet rids are global, so routing and
budget policy never change tokens).  Note the budget governs *live*
pages: each shared-mode engine's physical pool is sized to absorb the
whole surplus (see docs/serving.md).

Workload 6 (fused-tick scaling): B equal-length prompts decode
concurrently through the fused one-dispatch tick at several seat
counts (prefix cache off, per-engine jit warmup excluded).  Because the
tick is one jitted call — device-resident state, batched on-device
sampling, one token vector back per tick — per-token cost must FALL as
seats grow; ``flat_cost_ratio`` (per-token cost at max seats / at 1
seat) is gated in CI, and the max-seat run must be token-identical to
the pre-fusion ``fused=False`` engine.

Workload 7 (quantized KV pages): the oversubscribed early-eos stream
of workload 3, served twice at the SAME **byte** budget — once with
full-precision ``f32`` pages, once with ``fp8`` pages (one f32 scale
per (token, head) d-vector, dequantized inside the decode kernel).
fp8 pages are ~3x smaller at the reduced head dim, so the same bytes
hold ~3x the pages and the quantized engine preempts far less; the CI
gate requires ``tokens_per_s_ratio >= 1.5``.  Quantized outputs are
exact *within* a precision (each engine's contended outputs must equal
its own uncontended probe run truncated at eos) but only approximate
*across* precisions, so fidelity is scored separately: a
teacher-forced loop feeds both pools the identical token stream and
compares per-step greedy top-1 choices.  The gated number counts only
*decided* positions — where the full-precision top-2 logit gap exceeds
that position's measured fp8 logit perturbation — because on a
random-init model the remaining positions are near-ties that any lossy
storage resolves by coin flip (see docs/benchmarks.md); the
unconditional agreement is recorded alongside.

Workload 9 (telemetry overhead): the fused-tick steady state of
workload 6 at one seat count, run twice — telemetry off
(``telemetry=None``) vs on (flight recorder + SLO burn monitor, the
always-on plane; the opt-in tick profiler pays for its own
perf_counter calls and sits outside the gate).  The telemetry plane
must be effectively free:
``tokens_per_s_ratio`` = on/off is gated ``>= --telemetry-gate``
(default 0.98) in CI, and outputs must be token-identical (telemetry
observes the schedule, never perturbs it — tests/test_telemetry.py
pins the trace-level version of the same claim).

Prints ``name,tokens_per_s,detail`` CSV rows plus ratio lines, and
writes tokens/s, TTFT, page utilization and prefix-hit rate for every
engine run to ``--json-out`` (default BENCH_serving.json).  Run:

  PYTHONPATH=src python -m benchmarks.serving_paged [--requests 16]

Methodology (why medians of interleaved reps, what the CI gates mean):
docs/benchmarks.md.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced_config
from repro.models import model as M
from repro.parallel.sharding import SINGLE_DEVICE_RULES
from repro.runtime.router import FleetModel, ModelFleet
from repro.runtime.serving import PagedServingEngine, ServingEngine
from repro.runtime.telemetry import Telemetry


def make_workload(n: int, *, seed: int = 0, short_frac: float = 0.75,
                  max_len: int = 96):
    """Skewed lengths: ~short_frac short chats, the rest long-context."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        if rng.random() < short_frac:
            plen, gen = int(rng.integers(4, 11)), int(rng.integers(4, 9))
        else:
            plen, gen = int(rng.integers(40, 57)), int(rng.integers(24, 33))
        assert plen + gen <= max_len
        toks = rng.integers(0, 250, plen).astype(np.int32)
        reqs.append((toks, gen))
    return reqs


def make_shared_prefix_workload(n: int, *, prefix_len: int = 64,
                                suffix_max: int = 8, gen: int = 8,
                                seed: int = 0):
    """One shared system-prompt prefix + short unique suffixes."""
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, 250, prefix_len).astype(np.int32)
    reqs = []
    for _ in range(n):
        slen = int(rng.integers(1, suffix_max + 1))
        suffix = rng.integers(0, 250, slen).astype(np.int32)
        reqs.append((np.concatenate([prefix, suffix]),
                     int(rng.integers(max(2, gen - 2), gen + 1))))
    return prefix, reqs


def run_engine(eng, reqs):
    for toks, gen in reqs:
        eng.submit(toks, max_new_tokens=gen)
    t0 = time.perf_counter()
    done = eng.run()
    wall = time.perf_counter() - t0
    toks = sum(len(r.generated) for r in done)
    return {"requests": len(done), "tokens": toks, "wall_s": wall,
            "tokens_per_s": toks / max(wall, 1e-9)}


def engine_record(name, run, metrics=None):
    rec = {"name": name, "tokens_per_s": run["tokens_per_s"],
           "tokens": run["tokens"], "wall_s": run["wall_s"],
           "requests": run["requests"]}
    if metrics is not None:
        rec.update({
            "ttft_avg_s": metrics["ttft_avg_s"],
            "ttft_max_s": metrics["ttft_max_s"],
            "peak_page_utilization": metrics["peak_page_utilization"],
            "kv_occupancy": metrics["kv_occupancy"],
            "prefix_hit_rate": metrics["prefix_hit_rate"],
            "prefill_tokens": metrics["prefill_tokens"],
            "cached_prompt_tokens": metrics["cached_prompt_tokens"],
            "cached_pages": metrics["cached_pages"],
            "evictions": metrics["evictions"],
            "ticks": metrics["ticks"],
        })
    return rec


def bench_skewed(cfg, params, args):
    reqs = make_workload(args.requests, seed=args.seed, max_len=args.max_len)
    n_short = sum(1 for t, g in reqs if len(t) + g <= 32)
    print(f"# workload: {len(reqs)} requests ({n_short} short), "
          f"budget={args.budget_tokens} KV tokens")

    slots = max(1, args.budget_tokens // args.max_len)
    fixed = ServingEngine(cfg, params, slots=slots, max_len=args.max_len)
    rf = run_engine(fixed, reqs)
    print(f"fixed_slot[{slots}x{args.max_len}],"
          f"{rf['tokens_per_s']:.2f},"
          f"tokens={rf['tokens']};wall_s={rf['wall_s']:.2f}")

    num_pages = args.budget_tokens // args.page_size + 1  # +1: scratch page
    paged = PagedServingEngine(
        cfg, params, page_size=args.page_size, num_pages=num_pages,
        max_seats=4 * slots, max_seq_len=args.max_len,
        prefill_chunk=args.max_len)
    rp = run_engine(paged, reqs)
    m = paged.metrics.snapshot()
    print(f"paged[{num_pages - 1}x{args.page_size}],"
          f"{rp['tokens_per_s']:.2f},"
          f"tokens={rp['tokens']};wall_s={rp['wall_s']:.2f};"
          f"peak_page_util={m['peak_page_utilization']:.2f};"
          f"ttft_avg_s={m['ttft_avg_s']:.3f}")

    ratio = rp["tokens_per_s"] / max(rf["tokens_per_s"], 1e-9)
    print(f"speedup,{ratio:.2f},paged_vs_fixed_tokens_per_s")
    assert rp["tokens"] == rf["tokens"], "engines generated different counts"
    return {"fixed": engine_record("fixed_slot", rf),
            "paged": engine_record("paged", rp, m),
            "tokens_per_s_ratio": ratio}


def bench_shared_prefix(cfg, params, args):
    """Prefix caching on vs off on the paged engine, equal page budget.

    The cache is warmed with one request carrying the shared prefix
    (steady-state serving: the system prompt is resident from earlier
    traffic), then the measured stream runs.  Both configurations process
    the identical warmup + stream."""
    prefix, reqs = make_shared_prefix_workload(
        args.prefix_requests, prefix_len=args.prefix_len, seed=args.seed)
    max_seq = args.prefix_len + 8 + 10
    num_pages = args.prefix_budget_tokens // args.page_size + 1

    results, outputs = {}, {}
    for cached in (False, True):
        eng = PagedServingEngine(
            cfg, params, page_size=args.page_size, num_pages=num_pages,
            max_seats=args.prefix_requests, max_seq_len=max_seq,
            prefill_chunk=args.page_size, prefix_cache=cached)
        warm = np.concatenate([prefix, np.asarray([1], np.int32)])
        eng.submit(warm, max_new_tokens=2)
        eng.run()
        warm_m = eng.metrics.snapshot()         # exclude warmup (jit compile,
        warm_n = len(eng.finished)              # full prefix prefill) below

        for toks, gen in reqs:
            eng.submit(toks, max_new_tokens=gen)
        t0 = time.perf_counter()
        done = eng.run()[warm_n:]
        wall = time.perf_counter() - t0
        m = eng.metrics.snapshot()
        ttfts = [q.t_first_token - q.t_submit for q in done]
        toks = sum(len(q.generated) for q in done)
        prefill = m["prefill_tokens"] - warm_m["prefill_tokens"]
        cached_toks = (m["cached_prompt_tokens"]
                       - warm_m["cached_prompt_tokens"])
        rec = {
            "name": f"paged_prefix_{'cache' if cached else 'nocache'}",
            "tokens_per_s": toks / max(wall, 1e-9),
            "tokens": toks, "wall_s": wall, "requests": len(done),
            "ttft_avg_s": sum(ttfts) / len(ttfts),
            "ttft_max_s": max(ttfts),
            "peak_page_utilization": m["peak_page_utilization"],
            "kv_occupancy": m["kv_occupancy"],
            "prefix_hit_rate": cached_toks / max(prefill + cached_toks, 1),
            "prefill_tokens": prefill,
            "cached_prompt_tokens": cached_toks,
            "cached_pages": m["cached_pages"],
            "evictions": m["evictions"] - warm_m["evictions"],
            "ticks": m["ticks"] - warm_m["ticks"],
        }
        key = "cache" if cached else "nocache"
        results[key] = rec
        outputs[key] = [q.generated for q in sorted(done, key=lambda q: q.rid)]
        print(f"{rec['name']}[{num_pages - 1}x{args.page_size}],"
              f"{rec['tokens_per_s']:.2f},"
              f"tokens={rec['tokens']};wall_s={rec['wall_s']:.2f};"
              f"ttft_avg_s={rec['ttft_avg_s']:.4f};"
              f"prefix_hit_rate={rec['prefix_hit_rate']:.2f};"
              f"peak_page_util={rec['peak_page_utilization']:.2f}")

    assert outputs["cache"] == outputs["nocache"], \
        "prefix caching changed the generated tokens"
    tps = results["cache"]["tokens_per_s"] / \
        max(results["nocache"]["tokens_per_s"], 1e-9)
    ttft = results["nocache"]["ttft_avg_s"] / \
        max(results["cache"]["ttft_avg_s"], 1e-9)
    print(f"speedup,{tps:.2f},prefix_cache_vs_nocache_tokens_per_s")
    print(f"speedup,{ttft:.2f},prefix_cache_vs_nocache_ttft")
    return {"nocache": results["nocache"], "cache": results["cache"],
            "tokens_per_s_ratio": tps, "ttft_ratio": ttft,
            "token_identical": True}


def bench_lazy_growth(cfg, params, args):
    """Lazy on-demand paging vs up-front reservation at equal page budget
    on an oversubscribed early-eos stream (workload 3).

    A probe run on an uncontended pool yields the greedy outputs; for 3
    of every 4 requests a token drawn from the head of its own output
    becomes that request's eos (so it deterministically stops after a
    few tokens), the rest decode their full budget and supply sustained
    growth pressure.  Outputs are scheduling-invariant, so both engines
    see identical streams and must produce identical tokens — lazy just
    packs more of them per tick."""
    rng = np.random.default_rng(args.seed)
    ps = args.page_size
    max_new = args.lazy_max_new
    n = args.lazy_requests
    prompts = []
    for i in range(n):
        if i % 3 == 0:      # page-aligned prompts grow at the first decode
            plen = ps
        else:               # short chat prompts: ~1 page, big declared budget
            plen = int(rng.integers(4, ps + 1))
        prompts.append(rng.integers(0, 250, plen).astype(np.int32))
    max_seq = ps + max_new
    num_pages = args.lazy_budget_tokens // ps + 1       # +1: scratch page
    n_tables = -(-max_seq // ps)

    probe = PagedServingEngine(cfg, params, page_size=ps,
                               num_pages=1 + n * n_tables, max_seats=n,
                               max_seq_len=max_seq, prefill_chunk=ps)
    for p in prompts:
        probe.submit(p, max_new_tokens=max_new)
    probe_out = {r.rid: r.generated for r in probe.run()}
    # eos from each early request's own probe output: it fires at that
    # token's first occurrence (a few steps in), identically in every
    # engine below, stranding most of the declared reservation
    eos_ids = []
    for i in range(n):
        if i % 8 == 7:
            eos_ids.append(None)            # full-budget decoder
        else:
            stop = min(int(rng.integers(2, 5)), len(probe_out[i]) - 1)
            eos_ids.append(int(probe_out[i][stop]))
    n_early = sum(e is not None for e in eos_ids)
    print(f"# workload3: {n} requests, budget={args.lazy_budget_tokens} KV "
          f"tokens, declared max_new={max_new}, {n_early} early-eos, "
          f"median of {args.lazy_reps} interleaved reps")

    def one_rep(lazy):
        eng = PagedServingEngine(cfg, params, page_size=ps,
                                 num_pages=num_pages, max_seats=n,
                                 max_seq_len=max_seq, prefill_chunk=ps,
                                 lazy_pages=lazy)
        # warm the engine's jit caches (prefill chunk, batched decode,
        # and — via the repeat's prefix hit — the CoW copy) so the timed
        # window measures serving, not per-engine compilation; counters
        # are reported as deltas past this snapshot
        wp = np.full(ps, 251, np.int32)     # disjoint from workload tokens
        n_warm = 2
        for _ in range(n_warm):
            eng.submit(wp, max_new_tokens=2)
            eng.run()
        warm_m = eng.metrics.snapshot()
        warm_grows = eng.bm.grows
        for p, e in zip(prompts, eos_ids):
            eng.submit(p, max_new_tokens=max_new, eos_id=e)
        t0 = time.perf_counter()
        eng.run()
        wall = time.perf_counter() - t0
        done = eng.finished[n_warm:]
        toks = sum(len(r.generated) for r in done)
        m = eng.metrics.snapshot()
        ttfts = [q.t_first_token - q.t_submit for q in done]
        prefill = m["prefill_tokens"] - warm_m["prefill_tokens"]
        cached = m["cached_prompt_tokens"] - warm_m["cached_prompt_tokens"]
        rec = {
            "name": f"paged_{'lazy' if lazy else 'reserved'}",
            "tokens_per_s": toks / max(wall, 1e-9),
            "tokens": toks, "wall_s": wall, "requests": len(done),
            "ttft_avg_s": sum(ttfts) / len(ttfts),
            "ttft_max_s": max(ttfts),
            "peak_page_utilization": m["peak_page_utilization"],
            "kv_occupancy": m["kv_occupancy"],
            "prefix_hit_rate": cached / max(prefill + cached, 1),
            "prefill_tokens": prefill,
            "cached_prompt_tokens": cached,
            "cached_pages": m["cached_pages"],
            "evictions": m["evictions"] - warm_m["evictions"],
            "ticks": m["ticks"] - warm_m["ticks"],
            "peak_active": m["peak_active"],
            "preemptions": m["preemptions"],
            "grown_pages": eng.bm.grows - warm_grows,
        }
        outs = [r.generated for r in sorted(done, key=lambda r: r.rid)]
        return eng, rec, outs

    # interleave reps and score the median so one CPU hiccup cannot
    # decide the comparison either way
    reps = {False: [], True: []}
    for _ in range(args.lazy_reps):
        for lazy in (False, True):
            reps[lazy].append(one_rep(lazy))
    results, outputs = {}, {}
    for lazy in (False, True):
        runs = sorted(reps[lazy], key=lambda er: er[1]["tokens_per_s"])
        _, rec, outs = runs[len(runs) // 2]              # median rep
        key = "lazy" if lazy else "reserved"
        rec["tokens_per_s_reps"] = [r[1]["tokens_per_s"] for r in reps[lazy]]
        results[key] = rec
        outputs[key] = outs
        if lazy:
            assert all(any(k == "preempt" for _, k, _ in e.trace)
                       for e, _, _ in reps[lazy]), \
                "lazy run exercised no preemption — shrink the page budget"
        print(f"{rec['name']}[{num_pages - 1}x{ps}],"
              f"{rec['tokens_per_s']:.2f},"
              f"tokens={rec['tokens']};wall_s={rec['wall_s']:.2f};"
              f"peak_active={rec['peak_active']};"
              f"preemptions={rec['preemptions']};"
              f"ttft_avg_s={rec['ttft_avg_s']:.3f};"
              f"peak_page_util={rec['peak_page_utilization']:.2f}")

    assert outputs["lazy"] == outputs["reserved"], \
        "lazy paging changed the generated tokens"
    assert results["lazy"]["peak_active"] > results["reserved"]["peak_active"], \
        "lazy paging should admit more concurrent requests"
    ratio = results["lazy"]["tokens_per_s"] / \
        max(results["reserved"]["tokens_per_s"], 1e-9)
    print(f"speedup,{ratio:.2f},lazy_vs_reserved_tokens_per_s")
    print(f"gain,{results['lazy']['peak_active']}"
          f"/{results['reserved']['peak_active']},"
          f"lazy_vs_reserved_peak_concurrency")
    return {"reserved": results["reserved"], "lazy": results["lazy"],
            "tokens_per_s_ratio": ratio,
            "peak_active_reserved": results["reserved"]["peak_active"],
            "peak_active_lazy": results["lazy"]["peak_active"],
            "preemptions": results["lazy"]["preemptions"],
            "token_identical": True}


def make_mixed_class_workload(n, *, page_size, seed=0):
    """Mixed SLO classes, batch-heavy with premium arriving late: the
    submit order puts a convoy of long ``batch`` generations ahead of
    short ``premium`` requests, so FCFS admission makes premium wait
    behind the convoy while SLO admission does not.  Returns
    (prompt, max_new, class) triples in submit order."""
    pattern = ["batch", "batch", "standard", "batch", "premium", "batch",
               "batch", "premium", "standard", "batch", "premium", "batch"]
    gens = {"premium": 8, "standard": 12, "batch": 20}
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        cls = pattern[i % len(pattern)]
        plen = int(rng.integers(4, page_size + 1))
        reqs.append((rng.integers(0, 250, plen).astype(np.int32),
                     gens[cls], cls))
    return reqs


def bench_slo_classes(cfg, params, args):
    """SLO admission vs FCFS on a mixed-class oversubscribed stream at
    equal page budget AND equal seat count (workload 4).

    Both runs submit the identical stream (same rids, same prompts,
    greedy sampling), so per-request outputs must be token-identical —
    admission and preemption order decide only *when* each request
    runs.  Premium requests carry a generous TTFT deadline to exercise
    the deadline plumbing without making a wall-clock assertion."""
    ps = args.page_size
    reqs = make_mixed_class_workload(args.slo_requests, page_size=ps,
                                     seed=args.seed)
    max_seq = ps + max(g for _, g, _ in reqs)
    num_pages = args.slo_budget_tokens // ps + 1        # +1: scratch page
    by_cls = {}
    for _, g, c in reqs:
        by_cls[c] = by_cls.get(c, 0) + 1
    if "premium" not in by_cls or "batch" not in by_cls:
        raise SystemExit(
            f"--slo-requests {args.slo_requests} too small: the "
            "mixed-class workload must contain at least one premium and "
            "one batch request (the class pattern reaches premium at "
            "index 4 — use --slo-requests >= 5)")
    print(f"# workload4: {len(reqs)} requests "
          f"({', '.join(f'{v} {k}' for k, v in sorted(by_cls.items()))}), "
          f"budget={args.slo_budget_tokens} KV tokens, "
          f"{args.slo_seats} seats, median of {args.slo_reps} "
          f"interleaved reps")

    def one_rep(admission):
        eng = PagedServingEngine(cfg, params, page_size=ps,
                                 num_pages=num_pages,
                                 max_seats=args.slo_seats,
                                 max_seq_len=max_seq, prefill_chunk=ps,
                                 admission=admission,
                                 aging_ticks=10_000)  # aging off-scale here;
        # its un-starving behavior is pinned by tests/test_slo_scheduling.py
        wp = np.full(ps, 251, np.int32)     # disjoint from workload tokens
        n_warm = 2
        for _ in range(n_warm):             # jit warmup (prefill + decode
            eng.submit(wp, max_new_tokens=2)  # + prefix-hit CoW path)
            eng.run()
        warm_m = eng.metrics.snapshot()
        for p, g, c in reqs:
            eng.submit(p, max_new_tokens=g, priority=c,
                       deadline_ms=60_000 if c == "premium" else None)
        t0 = time.perf_counter()
        eng.run()
        wall = time.perf_counter() - t0
        done = eng.finished[n_warm:]
        m = eng.metrics.snapshot()
        toks = sum(len(r.generated) for r in done)
        cls_ttft, cls_toks = {}, {}
        for r in done:
            cls_ttft.setdefault(r.priority, []).append(
                r.t_first_token - r.t_submit)
            cls_toks[r.priority] = cls_toks.get(r.priority, 0) \
                + len(r.generated)
        rec = {
            "name": f"paged_slo_{admission}",
            "admission": admission,
            "tokens_per_s": toks / max(wall, 1e-9),
            "tokens": toks, "wall_s": wall, "requests": len(done),
            "peak_page_utilization": m["peak_page_utilization"],
            "preemptions": m["preemptions"],
            "ticks": m["ticks"] - warm_m["ticks"],
            "classes": {
                c: {"requests": len(ts),
                    "ttft_mean_s": sum(ts) / len(ts),
                    "ttft_max_s": max(ts),
                    "tokens": cls_toks[c],
                    "tokens_per_s": cls_toks[c] / max(wall, 1e-9)}
                for c, ts in sorted(cls_ttft.items())},
            # (the engine's own snapshot()["classes"] is deliberately
            # NOT recorded: it is cumulative and would fold the jit
            # warmup requests' compile-time TTFTs into the standard
            # class; the "classes" block above is computed from the
            # measured requests only)
        }
        outs = [r.generated for r in sorted(done, key=lambda r: r.rid)]
        return rec, outs

    # interleave reps and score the median premium TTFT so one CPU
    # hiccup cannot decide the comparison either way
    reps = {"fcfs": [], "slo": []}
    for _ in range(args.slo_reps):
        for adm in ("fcfs", "slo"):
            reps[adm].append(one_rep(adm))
    results, outputs = {}, {}
    for adm in ("fcfs", "slo"):
        runs = sorted(reps[adm],
                      key=lambda ro: ro[0]["classes"]["premium"]["ttft_mean_s"])
        rec, outs = runs[len(runs) // 2]                 # median rep
        rec["premium_ttft_reps_s"] = [
            r[0]["classes"]["premium"]["ttft_mean_s"] for r in reps[adm]]
        results[adm] = rec
        outputs[adm] = outs
        prem = rec["classes"]["premium"]
        bat = rec["classes"]["batch"]
        print(f"{rec['name']}[{num_pages - 1}x{ps}],"
              f"{rec['tokens_per_s']:.2f},"
              f"tokens={rec['tokens']};wall_s={rec['wall_s']:.2f};"
              f"premium_ttft_s={prem['ttft_mean_s']:.3f};"
              f"batch_tokens_per_s={bat['tokens_per_s']:.2f};"
              f"preemptions={rec['preemptions']}")

    assert outputs["fcfs"] == outputs["slo"], \
        "admission policy changed the generated tokens"
    prem_ratio = results["fcfs"]["classes"]["premium"]["ttft_mean_s"] / \
        max(results["slo"]["classes"]["premium"]["ttft_mean_s"], 1e-9)
    batch_ratio = results["slo"]["classes"]["batch"]["tokens_per_s"] / \
        max(results["fcfs"]["classes"]["batch"]["tokens_per_s"], 1e-9)
    print(f"speedup,{prem_ratio:.2f},slo_vs_fcfs_premium_ttft")
    print(f"ratio,{batch_ratio:.2f},slo_vs_fcfs_batch_tokens_per_s")
    return {"fcfs": results["fcfs"], "slo": results["slo"],
            "premium_ttft_ratio": prem_ratio,
            "batch_tokens_per_s_ratio": batch_ratio,
            "token_identical": True}


def bench_fleet(cfg, params, args):
    """Multi-model fleet, shared HostBudget vs static 50/50 split, at
    equal total page budget on a skewed per-model stream (workload 5).

    The heavy model (``--arch``) receives 7 of every 8 requests, each
    decoding a long generation; the light model (``--fleet-arch2``)
    gets the rest as short chats.  Both fleet
    configurations submit the identical interleaved stream with
    identical fleet-global rids, so per-rid outputs must be
    token-identical — the budget split decides only how many requests
    decode concurrently.  Shared mode must land within 10% of the
    static split's aggregate tokens/s (and typically beats it: the
    heavy model borrows the light model's idle pages)."""
    ps = args.page_size
    max_new = args.fleet_max_new
    max_seq = ps + max_new          # prompts span at most one page
    n_tables = -(-max_seq // ps)
    total = args.fleet_budget_tokens // ps
    if total < 2 * n_tables:
        raise SystemExit(
            f"--fleet-budget-tokens {args.fleet_budget_tokens} too small: "
            f"the budget must cover one max-length request per model "
            f"({2 * n_tables} pages of {ps} tokens)")
    if args.fleet_light_gen > max_new:
        raise SystemExit(
            f"--fleet-light-gen {args.fleet_light_gen} exceeds "
            f"--fleet-max-new {max_new}")
    cfg2 = reduced_config(get_config(args.fleet_arch2))
    params2 = M.init_params(M.param_specs(cfg2), jax.random.PRNGKey(1),
                            dtype=jnp.float32)
    names = (args.arch, args.fleet_arch2)
    # skewed per-model load in volume AND shape: the heavy model gets 7
    # of every 8 requests, each a long generation that must grow far
    # past its prompt page; the light model's occasional requests are
    # short chats that fit comfortably inside its floor, so its engine
    # idles early and its headroom is genuinely idle
    rng = np.random.default_rng(args.seed)
    reqs = []        # (model, prompt, max_new) in submit order
    for i in range(args.fleet_requests):
        name = names[0] if i % 8 != 7 else names[1]
        gen = max_new if name == names[0] else args.fleet_light_gen
        plen = int(rng.integers(4, ps + 1))
        reqs.append((name, rng.integers(0, 250, plen).astype(np.int32),
                     gen))
    n_heavy = sum(1 for n, _, _ in reqs if n == names[0])
    print(f"# workload5: {len(reqs)} requests ({n_heavy} {names[0]}, "
          f"{len(reqs) - n_heavy} {names[1]}), budget={total} pages "
          f"shared by both models, median of {args.fleet_reps} "
          f"interleaved reps")

    def one_rep(shared):
        if shared:
            floors = (n_tables, n_tables)   # minimum floors, max surplus
        else:
            floors = (total - total // 2, total // 2)   # static 50/50
        fleet = ModelFleet(
            [FleetModel(names[0], cfg, params, floor=floors[0]),
             FleetModel(names[1], cfg2, params2, floor=floors[1])],
            total_pages=total, page_size=ps, max_seats=args.fleet_seats,
            max_seq_len=max_seq, prefill_chunk=ps)
        wp = np.full(ps, 251, np.int32)     # disjoint from workload tokens
        warm_rids = []
        for name in names:                  # jit warmup per model (prefill
            for _ in range(2):              # + decode + prefix-hit CoW)
                warm_rids.append(fleet.submit(model=name, prompt=wp,
                                              max_new_tokens=2))
        fleet.run()
        for _, _, eng in fleet._engines():
            # warmup requests take pages of their own; restart the peak
            # high-water mark from the (now idle) pool so the
            # surplus-borrow sentinel below measures the workload, not
            # the warmup (all warm requests have finished: live = 0)
            eng.metrics.peak_pages_in_use = eng.policy.pages_in_use()
        for name, p, g in reqs:
            fleet.submit(model=name, prompt=p, max_new_tokens=g)
        t0 = time.perf_counter()
        fleet.run()
        wall = time.perf_counter() - t0
        done = {rid: r for rid, r in fleet.finished().items()
                if rid not in warm_rids}
        toks = sum(len(r.generated) for r in done.values())
        per_model = {}
        for rid, r in sorted(done.items()):
            name, _ = fleet.route(rid)
            pm = per_model.setdefault(
                name, {"requests": 0, "tokens": 0, "ttft_s": []})
            pm["requests"] += 1
            pm["tokens"] += len(r.generated)
            pm["ttft_s"].append(r.t_first_token - r.t_submit)
        m = fleet.metrics_snapshot()
        heavy_eng = fleet.group(names[0]).engines[0]
        rec = {
            "name": f"fleet_{'shared' if shared else 'static'}",
            "tokens_per_s": toks / max(wall, 1e-9),
            "tokens": toks, "wall_s": wall, "requests": len(done),
            "preemptions": m["fleet"]["preemptions"],
            "heavy_floor": floors[0],
            "heavy_peak_pages": heavy_eng.metrics.peak_pages_in_use,
            "models": {
                name: {"requests": pm["requests"], "tokens": pm["tokens"],
                       "tokens_per_s": pm["tokens"] / max(wall, 1e-9),
                       "ttft_mean_s": sum(pm["ttft_s"]) / len(pm["ttft_s"])}
                for name, pm in per_model.items()},
        }
        outs = {rid: done[rid].generated for rid in done}
        return rec, outs

    # interleave reps and score the median aggregate tokens/s so one
    # CPU hiccup cannot decide the comparison either way
    reps = {False: [], True: []}
    for _ in range(args.fleet_reps):
        for shared in (False, True):
            reps[shared].append(one_rep(shared))
    results, outputs = {}, {}
    for shared in (False, True):
        runs = sorted(reps[shared], key=lambda ro: ro[0]["tokens_per_s"])
        rec, outs = runs[len(runs) // 2]                 # median rep
        rec["tokens_per_s_reps"] = [r[0]["tokens_per_s"]
                                    for r in reps[shared]]
        key = "shared" if shared else "static"
        results[key] = rec
        outputs[key] = outs
        print(f"{rec['name']}[{total}x{ps}],"
              f"{rec['tokens_per_s']:.2f},"
              f"tokens={rec['tokens']};wall_s={rec['wall_s']:.2f};"
              f"heavy_peak_pages={rec['heavy_peak_pages']}"
              f"/floor={rec['heavy_floor']};"
              f"preemptions={rec['preemptions']:.0f}")

    assert outputs["shared"] == outputs["static"], \
        "the budget split changed the generated tokens"
    assert results["shared"]["heavy_peak_pages"] > \
        results["shared"]["heavy_floor"], \
        "shared mode never borrowed surplus — raise the load skew"
    ratio = results["shared"]["tokens_per_s"] / \
        max(results["static"]["tokens_per_s"], 1e-9)
    print(f"speedup,{ratio:.2f},fleet_shared_vs_static_tokens_per_s")
    return {"static": results["static"], "shared": results["shared"],
            "tokens_per_s_ratio": ratio,
            "heavy_model": names[0], "light_model": names[1],
            "budget_pages": total,
            "token_identical": True}


def bench_tick_scaling(cfg, params, args):
    """Fused-tick scaling: tokens/s and per-token cost vs active-seat
    count (workload 6).

    Each configuration seats ``B`` equal-length single-page prompts
    concurrently (prefix cache off — no sharing, every seat does full
    work) and decodes ``--tick-gen`` tokens per request, so the steady
    state is ``B`` active seats stepping through the fused one-dispatch
    tick.  Because the tick is ONE jitted call whose cost is dominated
    by dispatch + the batched model step — not by per-seat host work —
    per-token cost must FALL as seats grow (B tokens per tick for near
    the price of one): ``flat_cost_ratio`` = per-token cost at max
    seats / at 1 seat, gated ≤ ``--tick-gate`` in CI.  Per-engine jit
    warmup is excluded (each seat count traces its own
    ``(max_seats,)``-shaped fused fn) and the median of
    ``--tick-reps`` interleaved reps is scored.  The max-seat
    configuration also runs once with ``fused=False`` (the pre-fusion
    per-tick engine) and outputs must be token-identical per rid."""
    ps = args.page_size
    gen = args.tick_gen
    seat_counts = sorted(args.tick_seats)
    max_b = seat_counts[-1]
    max_seq = ps + gen
    n_tables = -(-max_seq // ps)
    rng = np.random.default_rng(args.seed)
    prompts = [rng.integers(0, 250, ps).astype(np.int32)
               for _ in range(max_b)]
    print(f"# workload6: seat counts {seat_counts}, {gen} tokens per "
          f"request, prompts of {ps} tokens, median of {args.tick_reps} "
          f"interleaved reps")

    def one_rep(B, fused=True):
        eng = PagedServingEngine(
            cfg, params, page_size=ps, num_pages=1 + (B + 1) * n_tables,
            max_seats=B, max_seq_len=max_seq, prefill_chunk=ps,
            prefix_cache=False, fused=fused)
        wp = np.full(ps, 251, np.int32)
        for _ in range(2):                  # jit warmup: prefill chunk +
            eng.submit(wp, max_new_tokens=2)  # (fused) decode tick
            eng.run()
        n_warm = len(eng.finished)
        warm_m = eng.metrics.snapshot()
        for p in prompts[:B]:
            eng.submit(p, max_new_tokens=gen)
        t0 = time.perf_counter()
        eng.run()
        wall = time.perf_counter() - t0
        done = eng.finished[n_warm:]
        toks = sum(len(r.generated) for r in done)
        ticks = eng.metrics.snapshot()["ticks"] - warm_m["ticks"]
        rec = {"seats": B, "tokens": toks, "wall_s": wall,
               "ticks": ticks,
               "tokens_per_s": toks / max(wall, 1e-9),
               "per_token_cost_s": wall / max(toks, 1),
               "per_tick_cost_s": wall / max(ticks, 1)}
        outs = [r.generated for r in sorted(done, key=lambda r: r.rid)]
        return rec, outs

    reps = {B: [] for B in seat_counts}
    for _ in range(args.tick_reps):         # interleave: CPU noise hits
        for B in seat_counts:               # every seat count equally
            reps[B].append(one_rep(B))
    per_seat, outputs = [], {}
    for B in seat_counts:
        runs = sorted(reps[B], key=lambda ro: ro[0]["per_token_cost_s"])
        rec, outs = runs[len(runs) // 2]                 # median rep
        rec["per_token_cost_reps_s"] = [r[0]["per_token_cost_s"]
                                        for r in reps[B]]
        assert all(o == outs for _, o in reps[B]), \
            f"nondeterministic outputs at {B} seats"
        per_seat.append(rec)
        outputs[B] = outs
        print(f"fused_tick[{B}seats],{rec['tokens_per_s']:.2f},"
              f"tokens={rec['tokens']};wall_s={rec['wall_s']:.3f};"
              f"per_token_cost_ms={rec['per_token_cost_s'] * 1e3:.2f};"
              f"per_tick_cost_ms={rec['per_tick_cost_s'] * 1e3:.2f}")

    # the pre-fusion engine is the token oracle at the largest batch
    _, oracle = one_rep(max_b, fused=False)
    token_identical = outputs[max_b] == oracle
    assert token_identical, \
        "fused tick changed the generated tokens vs the per-tick engine"
    ratio = per_seat[-1]["per_token_cost_s"] / \
        max(per_seat[0]["per_token_cost_s"], 1e-9)
    print(f"ratio,{ratio:.3f},per_token_cost_{max_b}seats_vs_1seat")
    assert ratio <= args.tick_gate, \
        (f"per-token cost at {max_b} seats is {ratio:.2f}x the 1-seat "
         f"cost (gate {args.tick_gate}): the tick is serializing "
         "per-seat work instead of batching it")
    return {"seat_counts": seat_counts, "per_seat": per_seat,
            "flat_cost_ratio": ratio, "gate": args.tick_gate,
            "token_identical": token_identical}


def bench_telemetry_overhead(cfg, params, args):
    """Telemetry-on vs telemetry-off throughput on the fused tick
    (workload 9).

    Both sides run the workload-6 steady state — ``--telemetry-seats``
    equal-length single-page prompts decoding ``--telemetry-gen``
    tokens each through the fused one-dispatch tick, prefix cache off —
    differing ONLY in whether a :class:`Telemetry` plane (flight
    recorder + SLO burn monitor — the always-on serving configuration;
    the opt-in ``--profile-ticks`` diagnostic pays for its own
    perf_counter calls and is deliberately outside this gate) is
    attached.  Per-engine jit warmup is excluded and
    the median of ``--telemetry-reps`` interleaved reps is scored.
    ``tokens_per_s_ratio`` = on/off is gated ``>= --telemetry-gate``;
    outputs must be token-identical (telemetry never touches the
    schedule or the device — the emit path is declared hot in
    hotpaths.toml so repro-lint rejects implicit syncs there)."""
    ps = args.page_size
    B = args.telemetry_seats
    gen = args.telemetry_gen
    max_seq = ps + gen
    n_tables = -(-max_seq // ps)
    rng = np.random.default_rng(args.seed)
    prompts = [rng.integers(0, 250, ps).astype(np.int32)
               for _ in range(B)]
    print(f"# workload9: {B} seats, {gen} tokens per request, telemetry "
          f"on vs off, median of {args.telemetry_reps} interleaved reps")

    def one_rep(tel_on):
        tel = Telemetry(ring=4096) if tel_on else None
        eng = PagedServingEngine(
            cfg, params, page_size=ps, num_pages=1 + (B + 1) * n_tables,
            max_seats=B, max_seq_len=max_seq, prefill_chunk=ps,
            prefix_cache=False, fused=True, telemetry=tel)
        wp = np.full(ps, 251, np.int32)
        for _ in range(2):                  # jit warmup: prefill chunk +
            eng.submit(wp, max_new_tokens=2)  # fused decode tick
            eng.run()
        n_warm = len(eng.finished)
        for p in prompts:
            eng.submit(p, max_new_tokens=gen)
        t0 = time.perf_counter()
        eng.run()
        wall = time.perf_counter() - t0
        done = eng.finished[n_warm:]
        toks = sum(len(r.generated) for r in done)
        rec = {"telemetry": tel_on, "tokens": toks, "wall_s": wall,
               "tokens_per_s": toks / max(wall, 1e-9)}
        if tel is not None:
            rec["events_recorded"] = tel.recorder.total
        outs = [r.generated for r in sorted(done, key=lambda r: r.rid)]
        return rec, outs

    reps = {False: [], True: []}
    for _ in range(args.telemetry_reps):    # interleave: CPU noise hits
        for tel_on in (False, True):        # both configurations equally
            reps[tel_on].append(one_rep(tel_on))
    recs, outputs = {}, {}
    for tel_on in (False, True):
        runs = sorted(reps[tel_on], key=lambda ro: ro[0]["tokens_per_s"])
        rec, outs = runs[len(runs) // 2]                 # median rep
        rec["tokens_per_s_reps"] = [r[0]["tokens_per_s"]
                                    for r in reps[tel_on]]
        assert all(o == outs for _, o in reps[tel_on]), \
            f"nondeterministic outputs (telemetry={tel_on})"
        recs[tel_on], outputs[tel_on] = rec, outs
        name = "telemetry_on" if tel_on else "telemetry_off"
        print(f"{name},{rec['tokens_per_s']:.2f},"
              f"tokens={rec['tokens']};wall_s={rec['wall_s']:.3f}")

    token_identical = outputs[True] == outputs[False]
    assert token_identical, \
        "attaching telemetry changed the generated tokens"
    ratio = recs[True]["tokens_per_s"] / \
        max(recs[False]["tokens_per_s"], 1e-9)
    print(f"ratio,{ratio:.3f},telemetry_on_vs_off_tokens_per_s")
    assert ratio >= args.telemetry_gate, \
        (f"telemetry-on throughput is {ratio:.3f}x telemetry-off "
         f"(gate {args.telemetry_gate}): the observability plane is "
         "taxing the hot path")
    return {"seats": B, "gen": gen, "off": recs[False], "on": recs[True],
            "tokens_per_s_ratio": ratio, "gate": args.telemetry_gate,
            "token_identical": token_identical}


def bench_kv_quant(cfg, params, args):
    """Quantized fp8 KV pages vs full-precision f32 pages at equal BYTE
    budget on the oversubscribed early-eos stream (workload 7).

    Both engines run lazy paging over the identical request stream; the
    only difference is the pool's storage precision, so the page count
    each side gets from the shared byte budget decides how many
    requests decode concurrently.  Per-precision probe runs on
    uncontended pools derive each request's eos from its own output (at
    stop indices drawn once, so eos fires at the same step of either
    stream), and every contended output must equal its probe stream
    truncated at eos — quantization is exact within a precision.

    Cross-precision fidelity is a separate, teacher-forced measurement:
    f32 and fp8 pools are fed the identical (f32-greedy) token stream
    and per-step top-1 choices are compared.  ``greedy_agreement``
    counts only *decided* positions (f32 top-2 logit gap > that
    position's measured fp8 logit perturbation); the unconditional
    number is recorded as ``greedy_agreement_all``."""
    rng = np.random.default_rng(args.seed)
    ps = args.page_size
    max_new = args.kvq_max_new
    n = args.kvq_requests
    prompts = []
    for i in range(n):
        if i % 3 == 0:      # page-aligned prompts grow at the first decode
            plen = ps
        else:               # short chat prompts: ~1 page, big declared budget
            plen = int(rng.integers(4, ps + 1))
        prompts.append(rng.integers(0, 250, plen).astype(np.int32))
    max_seq = ps + max_new
    n_tables = -(-max_seq // ps)
    dtypes = ("f32", "fp8")
    page_bytes = {dt: M.paged_page_bytes(cfg, ps, dt) for dt in dtypes}
    # equal BYTES, not equal pages: the f32 side's page count converts
    # the token budget, the fp8 side gets however many of its smaller
    # pages fit in the same bytes
    budget_bytes = (args.kvq_budget_tokens // ps) * page_bytes["f32"]
    pages = {dt: int(budget_bytes // page_bytes[dt]) for dt in dtypes}
    if pages["f32"] < n_tables:
        raise SystemExit(
            f"--kvq-budget-tokens {args.kvq_budget_tokens} too small: the "
            f"f32 pool must hold one max-length request ({n_tables} pages "
            f"of {ps} tokens)")
    # stop indices drawn once so each precision's eos (a token from its
    # OWN probe stream) fires at the same step of either stream; half
    # the stream decodes its full budget so steady-state page demand
    # genuinely exceeds the f32 pool and the comparison measures
    # preemption thrash, not prefill overhead
    stop_at = [None if i % 2 == 1 else int(rng.integers(2, 5))
               for i in range(n)]

    def truncate(stream, eos_id):
        if eos_id is None:
            return list(stream)
        out = []
        for t in stream:
            out.append(t)
            if t == eos_id:
                break
        return out

    probe_out, eos_ids, expected = {}, {}, {}
    for dt in dtypes:
        probe = PagedServingEngine(cfg, params, page_size=ps,
                                   num_pages=1 + n * n_tables, max_seats=n,
                                   max_seq_len=max_seq, prefill_chunk=ps,
                                   kv_dtype=dt)
        for p in prompts:
            probe.submit(p, max_new_tokens=max_new)
        probe_out[dt] = {r.rid: r.generated for r in probe.run()}
        eos_ids[dt] = [
            None if s is None else
            int(probe_out[dt][i][min(s, len(probe_out[dt][i]) - 1)])
            for i, s in enumerate(stop_at)]
        expected[dt] = [truncate(probe_out[dt][i], e)
                        for i, e in enumerate(eos_ids[dt])]
    n_early = sum(s is not None for s in stop_at)
    print(f"# workload7: {n} requests, budget={budget_bytes} KV bytes "
          f"({pages['f32']}x{page_bytes['f32']:.0f}B f32 pages vs "
          f"{pages['fp8']}x{page_bytes['fp8']:.0f}B fp8 pages), declared "
          f"max_new={max_new}, {n_early} early-eos, median of "
          f"{args.kvq_reps} interleaved reps")

    def one_rep(dt):
        eng = PagedServingEngine(cfg, params, page_size=ps,
                                 num_pages=pages[dt] + 1,   # +1: scratch
                                 max_seats=n, max_seq_len=max_seq,
                                 prefill_chunk=ps, lazy_pages=True,
                                 kv_dtype=dt)
        wp = np.full(ps, 251, np.int32)     # disjoint from workload tokens
        n_warm = 2
        for _ in range(n_warm):
            eng.submit(wp, max_new_tokens=2)
            eng.run()
        warm_m = eng.metrics.snapshot()
        for p, e in zip(prompts, eos_ids[dt]):
            eng.submit(p, max_new_tokens=max_new, eos_id=e)
        t0 = time.perf_counter()
        eng.run()
        wall = time.perf_counter() - t0
        done = eng.finished[n_warm:]
        toks = sum(len(r.generated) for r in done)
        m = eng.metrics.snapshot()
        ttfts = [q.t_first_token - q.t_submit for q in done]
        rec = {
            "name": f"paged_kv_{dt}",
            "kv_dtype": dt,
            "tokens_per_s": toks / max(wall, 1e-9),
            "tokens": toks, "wall_s": wall, "requests": len(done),
            "ttft_avg_s": sum(ttfts) / len(ttfts),
            "ttft_max_s": max(ttfts),
            "num_pages": pages[dt],
            "page_bytes": page_bytes[dt],
            "peak_page_utilization": m["peak_page_utilization"],
            "ticks": m["ticks"] - warm_m["ticks"],
            "peak_active": m["peak_active"],
            "preemptions": m["preemptions"],
        }
        outs = [r.generated for r in sorted(done, key=lambda r: r.rid)]
        return rec, outs

    reps = {dt: [] for dt in dtypes}
    for _ in range(args.kvq_reps):          # interleave: CPU noise hits
        for dt in dtypes:                   # both precisions equally
            reps[dt].append(one_rep(dt))
    results = {}
    for dt in dtypes:
        runs = sorted(reps[dt], key=lambda ro: ro[0]["tokens_per_s"])
        rec, _ = runs[len(runs) // 2]                    # median rep
        rec["tokens_per_s_reps"] = [r[0]["tokens_per_s"] for r in reps[dt]]
        results[dt] = rec
        for _, outs in reps[dt]:
            assert outs == expected[dt], \
                f"{dt} contended outputs diverged from the probe run"
        print(f"{rec['name']}[{pages[dt]}x{ps}],"
              f"{rec['tokens_per_s']:.2f},"
              f"tokens={rec['tokens']};wall_s={rec['wall_s']:.2f};"
              f"peak_active={rec['peak_active']};"
              f"preemptions={rec['preemptions']};"
              f"ttft_avg_s={rec['ttft_avg_s']:.3f}")

    assert results["f32"]["preemptions"] > 0, \
        "the f32 pool never came under pressure — shrink the byte budget"
    assert results["fp8"]["peak_active"] > results["f32"]["peak_active"], \
        "fp8 pages should admit more concurrent requests from equal bytes"
    ratio = results["fp8"]["tokens_per_s"] / \
        max(results["f32"]["tokens_per_s"], 1e-9)
    print(f"speedup,{ratio:.2f},fp8_vs_f32_tokens_per_s_equal_bytes")

    # -- teacher-forced greedy agreement (cross-precision fidelity) ----
    A = min(8, n)
    T = args.kvq_agree_steps
    arng = np.random.default_rng(args.seed + 1)
    aprompts = np.stack([arng.integers(0, 250, ps).astype(np.int32)
                         for _ in range(A)])
    a_tables = -(-(ps + T) // ps)
    pt = np.zeros((A, a_tables), np.int32)
    nxt = 1
    for a in range(A):
        for i in range(a_tables):
            pt[a, i] = nxt
            nxt += 1
    pt = jnp.asarray(pt)
    opts = M.RunOptions(mesh=None)
    step = jax.jit(lambda p, c, t, q, ptb, nv: M.paged_decode_step(
        p, cfg, c, t, q, ptb, nv, SINGLE_DEVICE_RULES, opts))

    def prefill(dt):
        cache = M.init_paged_cache(cfg, 1 + A * a_tables, ps, kv_dtype=dt)
        return step(params, cache, jnp.asarray(aprompts),
                    jnp.zeros((A,), jnp.int32), pt,
                    jnp.full((A,), ps, jnp.int32))

    l32, c32 = prefill("f32")
    lq, cq = prefill("fp8")
    gaps, noise, match = [], [], []

    def collect(l32s, lqs):
        lz = np.asarray(l32s[:, -1], np.float32)
        lq_ = np.asarray(lqs[:, -1], np.float32)
        a32 = lz.argmax(-1)
        top2 = np.partition(lz, -2, axis=-1)
        gaps.extend((top2[:, -1] - top2[:, -2]).tolist())
        noise.extend(np.abs(lz - lq_).max(-1).tolist())
        match.extend((a32 == lq_.argmax(-1)).tolist())
        return a32

    nxt_tok = collect(l32, lq)
    for t in range(T - 1):
        t32 = jnp.asarray(nxt_tok, jnp.int32)[:, None]
        pos = jnp.full((A,), ps + t, jnp.int32)
        nv = jnp.ones((A,), jnp.int32)
        l32s, c32 = step(params, c32, t32, pos, pt, nv)
        lqs, cq = step(params, cq, t32, pos, pt, nv)
        nxt_tok = collect(l32s, lqs)
    gaps, noise, match = map(np.asarray, (gaps, noise, match))
    decided = gaps > noise
    agree = float(match[decided].mean()) if decided.any() else 1.0
    agree_all = float(match.mean())
    print(f"agreement,{agree:.4f},fp8_vs_f32_greedy_top1_decided "
          f"(all={agree_all:.4f}, decided {int(decided.sum())}/"
          f"{len(match)}, median_noise={float(np.median(noise)):.3f})")

    return {"f32": results["f32"], "fp8": results["fp8"],
            "tokens_per_s_ratio": ratio,
            "budget_bytes": budget_bytes,
            "page_bytes": page_bytes,
            "num_pages": pages,
            "capacity_ratio": pages["fp8"] / max(pages["f32"], 1),
            "greedy_agreement": agree,
            "greedy_agreement_all": agree_all,
            "decided_frac": float(decided.mean()),
            "agree_seats": A, "agree_steps": T,
            "token_identical": True}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--budget-tokens", type=int, default=384,
                    help="KV cache budget shared by both engines (skewed)")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=96)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--prefix-requests", type=int, default=12)
    ap.add_argument("--prefix-len", type=int, default=64,
                    help="shared system-prompt length (shared-prefix bench)")
    ap.add_argument("--prefix-budget-tokens", type=int, default=384,
                    help="KV budget for the shared-prefix comparison")
    ap.add_argument("--lazy-requests", type=int, default=16,
                    help="request count for the early-eos lazy-paging bench")
    ap.add_argument("--lazy-max-new", type=int, default=48,
                    help="declared generation budget per request (workload 3)")
    ap.add_argument("--lazy-budget-tokens", type=int, default=112,
                    help="KV budget for the lazy-vs-reserved comparison")
    ap.add_argument("--lazy-reps", type=int, default=3,
                    help="interleaved repetitions per engine; the median "
                         "tokens/s is scored (CPU noise control)")
    ap.add_argument("--slo-requests", type=int, default=12,
                    help="request count for the mixed-class SLO bench")
    ap.add_argument("--slo-budget-tokens", type=int, default=112,
                    help="KV budget for the fcfs-vs-slo comparison")
    ap.add_argument("--slo-seats", type=int, default=3,
                    help="seat count for the mixed-class SLO bench "
                         "(oversubscription: requests >> seats)")
    ap.add_argument("--slo-reps", type=int, default=3,
                    help="interleaved repetitions per admission policy; "
                         "the median premium TTFT is scored")
    ap.add_argument("--fleet-arch2", default="llama3-8b",
                    help="second (lightly loaded) model for the fleet "
                         "bench; --arch is the heavy one")
    ap.add_argument("--fleet-requests", type=int, default=24,
                    help="request count for the multi-model fleet bench "
                         "(7 of every 8 go to the heavy model)")
    ap.add_argument("--fleet-budget-tokens", type=int, default=320,
                    help="TOTAL KV budget shared by both fleet models")
    ap.add_argument("--fleet-max-new", type=int, default=32,
                    help="heavy-model generation budget per request "
                         "(workload 5)")
    ap.add_argument("--fleet-light-gen", type=int, default=6,
                    help="light-model generation budget per request "
                         "(workload 5)")
    ap.add_argument("--fleet-seats", type=int, default=8,
                    help="seats per fleet engine (workload 5)")
    ap.add_argument("--fleet-reps", type=int, default=3,
                    help="interleaved repetitions per budget split; the "
                         "median aggregate tokens/s is scored")
    ap.add_argument("--tick-seats", type=lambda s: [int(x) for x in
                                                    s.split(",")],
                    default=[1, 2, 4, 8],
                    help="comma-separated active-seat counts for the "
                         "fused-tick scaling bench (workload 6)")
    ap.add_argument("--tick-gen", type=int, default=24,
                    help="decode tokens per request (workload 6)")
    ap.add_argument("--tick-reps", type=int, default=3,
                    help="interleaved repetitions per seat count; the "
                         "median per-token cost is scored")
    ap.add_argument("--tick-gate", type=float, default=0.9,
                    help="max allowed flat_cost_ratio: per-token cost at "
                         "max seats / at 1 seat (workload 6 CI gate)")
    ap.add_argument("--kvq-requests", type=int, default=16,
                    help="request count for the quantized-KV bench "
                         "(workload 7)")
    ap.add_argument("--kvq-max-new", type=int, default=48,
                    help="declared generation budget per request "
                         "(workload 7)")
    ap.add_argument("--kvq-budget-tokens", type=int, default=80,
                    help="KV byte budget for the fp8-vs-f32 comparison, "
                         "expressed as f32 cache tokens (both pools get "
                         "the same BYTES)")
    ap.add_argument("--kvq-reps", type=int, default=3,
                    help="interleaved repetitions per precision; the "
                         "median tokens/s is scored")
    ap.add_argument("--kvq-agree-steps", type=int, default=32,
                    help="teacher-forced decode steps for the greedy "
                         "agreement measurement (workload 7)")
    ap.add_argument("--telemetry-seats", type=int, default=4,
                    help="active-seat count for the telemetry-overhead "
                         "bench (workload 9)")
    ap.add_argument("--telemetry-gen", type=int, default=24,
                    help="decode tokens per request (workload 9)")
    ap.add_argument("--telemetry-reps", type=int, default=3,
                    help="interleaved repetitions per configuration; "
                         "the median tokens/s is scored")
    ap.add_argument("--telemetry-gate", type=float, default=0.98,
                    help="min allowed tokens/s ratio telemetry-on / "
                         "telemetry-off (workload 9 CI gate)")
    ap.add_argument("--json-out", default="BENCH_serving.json")
    args = ap.parse_args()

    cfg = reduced_config(get_config(args.arch))
    params = M.init_params(M.param_specs(cfg), jax.random.PRNGKey(0),
                           dtype=jnp.float32)

    skewed = bench_skewed(cfg, params, args)
    shared = bench_shared_prefix(cfg, params, args)
    lazy = bench_lazy_growth(cfg, params, args)
    slo = bench_slo_classes(cfg, params, args)
    fleet = bench_fleet(cfg, params, args)
    tick = bench_tick_scaling(cfg, params, args)
    kvq = bench_kv_quant(cfg, params, args)
    telemetry = bench_telemetry_overhead(cfg, params, args)

    out = {"arch": args.arch, "seed": args.seed,
           "budget_tokens": args.budget_tokens,
           "page_size": args.page_size,
           "skewed": skewed, "shared_prefix": shared,
           "lazy_growth": lazy, "slo_classes": slo, "fleet": fleet,
           "tick_scaling": tick, "kv_quant": kvq,
           "telemetry_overhead": telemetry}
    with open(args.json_out, "w") as f:
        json.dump(out, f, indent=2)
    print(f"# wrote {args.json_out}")


if __name__ == "__main__":
    main()
