"""Benchmark harness — one function per paper table.

Prints ``name,us_per_call,derived`` CSV rows (derived = the table's own
headline metric).  Sizes are scaled to the CPU container; on a real TPU
slice the same functions run the paper-scale problems.

  PYTHONPATH=src python -m benchmarks.run [table7|table8|table9|table10|
                                           interconnect|kernels|roofline|all]
"""
from __future__ import annotations

import json
import os
import sys
import time

ROWS = []


def emit(name: str, us_per_call: float, derived: str):
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}")


def table7_hpl():
    """Paper Table 7: HPL (high-precision blocked LU)."""
    from repro.core.hpl import run_hpl
    r = run_hpl(n=768, nb=128)
    emit("table7.hpl_fp32", r["time_s"] * 1e6,
         f"gflops={r['gflops']:.2f};residual={r['residual']:.2e};"
         f"passed={r['passed']}")


def table8_hpcg():
    """Paper Table 8: HPCG (27-pt stencil preconditioned CG)."""
    from repro.core.hpcg import run_hpcg
    r = run_hpcg(48, 48, 48, max_iters=90)
    emit("table8.hpcg", r["time_s"] * 1e6,
         f"gflops={r['gflops']:.2f};bw_gbs={r['bandwidth_gbs']:.2f};"
         f"rel_resid={r['rel_residual']:.2e};converged={r['converged']}")


def table9_hplmxp():
    """Paper Table 9: HPL-MxP (low-precision LU + iterative refinement).
    Reports the low-vs-high precision speed ratio (paper: 10× FP8 vs FP64;
    CPU container has no MXU so the ratio here only shows structure)."""
    from repro.core.hpl import run_hpl
    from repro.core.hplmxp import run_hplmxp
    hi = run_hpl(n=768, nb=128)
    for prec in ("bf16", "fp8"):
        r = run_hplmxp(n=768, nb=128, lowprec=prec, ir_iters=4)
        # NOTE: CPU has no low-precision compute units, so the paper's 10×
        # FP8 speedup cannot appear here; the structural claims (same O(n³)
        # factor work, O(n²) IR overhead, validation passes) are the test.
        emit(f"table9.hplmxp_{prec}", r["time_s"] * 1e6,
             f"gflops={r['gflops']:.2f};lu_only_gflops={r['gflops_lu_only']:.2f};"
             f"lu_speedup_vs_fp32={hi['time_s'] / r['lu_time_s']:.2f};"
             f"residual={r['residual']:.2e};passed={r['passed']}")


def table10_io500():
    """Paper Table 10: IO500 phases, few-worker vs many-worker (the paper's
    10-node vs 96-node scaling observation)."""
    from repro.core.io500 import run_io500
    for nproc in (2, 8):
        r = run_io500(nproc=nproc, mb_per_proc=16, files_per_proc=150)
        emit(f"table10.io500_np{nproc}", 0.0,
             f"score={r['total_score']:.2f};bw_gibs={r['bandwidth_score_gibs']:.2f};"
             f"kiops={r['iops_score_kiops']:.2f};"
             f"easy_w={r['ior_easy']['write_gibs']:.2f};"
             f"hard_w={r['ior_hard']['write_gibs']:.3f};"
             f"stat_kiops={r['mdtest']['stat_kiops']:.1f}")


def interconnect_table():
    """Paper §2.2 (Tables 3-4 context): rail-optimized vs flat collectives
    on the topology cost model, for the production gradient sizes."""
    from repro.core import topology
    for gb, label in ((0.5e9, "0.5GB"), (4e9, "4GB"), (16e9, "16GB")):
        per_chip = gb / 512
        hier, parts = topology.hierarchical_allreduce_cost(per_chip, 16, 2)
        flat = topology.flat_allreduce_cost(per_chip, 16, 2)
        comp = (parts["reduce_scatter"] + parts["all_gather"]
                + parts["cross_pod"] / 4)          # int8 cross-pod payload
        emit(f"interconnect.allreduce_{label}", hier * 1e6,
             f"flat_us={flat * 1e6:.1f};hier_us={hier * 1e6:.1f};"
             f"hier_int8_us={comp * 1e6:.1f};speedup={flat / hier:.1f}x")


def kernels_table():
    """Kernel wrappers vs oracles (CPU: correctness-bench; timings are the
    jnp reference path — Pallas timings need a TPU)."""
    import jax
    import jax.numpy as jnp
    from repro.kernels import ref
    from repro.core.mixed_precision import fp8_matmul as fp8_jnp

    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (512, 512), jnp.float32)
    b = jax.random.normal(jax.random.fold_in(key, 1), (512, 512), jnp.float32)

    f32 = jax.jit(lambda x, y: x @ y)
    f8 = jax.jit(fp8_jnp)
    for name, fn in (("kernels.matmul_f32", f32), ("kernels.matmul_fp8", f8)):
        fn(a, b).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(10):
            out = fn(a, b)
        out.block_until_ready()
        us = (time.perf_counter() - t0) / 10 * 1e6
        flops = 2 * 512 ** 3
        emit(name, us, f"gflops={flops / us / 1e3:.2f}")

    # fresh stream: `key` itself already seeded the matmul operand `a`
    q = jax.random.normal(jax.random.fold_in(key, 2), (8, 256, 64),
                          jnp.bfloat16)
    att = jax.jit(lambda q: ref.attention_ref(q, q, q, causal=True))
    att(q).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(10):
        out = att(q)
    out.block_until_ready()
    emit("kernels.attention_ref", (time.perf_counter() - t0) / 10 * 1e6,
         "oracle-path")


def roofline_table():
    """Deliverable (g): per-cell roofline terms from the dry-run artifacts
    (run `python -m repro.launch.dryrun --all` first)."""
    d = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")
    if not os.path.isdir(d):
        emit("roofline.missing", 0.0, "run repro.launch.dryrun first")
        return
    for fn in sorted(os.listdir(d)):
        if not fn.endswith(".json"):
            continue
        r = json.load(open(os.path.join(d, fn)))
        if not r.get("supported", False):
            emit(f"roofline.{fn[:-5]}", 0.0, f"skipped:{r.get('skip_reason','')[:40]}")
            continue
        if "roofline" not in r:
            continue
        rt = r["roofline"]
        emit(f"roofline.{fn[:-5]}", rt["step_s"] * 1e6,
             f"dominant={rt['dominant']};compute_s={rt['compute_s']:.4f};"
             f"memory_s={rt['memory_s']:.4f};collective_s={rt['collective_s']:.4f}")


TABLES = {
    "table7": table7_hpl,
    "table8": table8_hpcg,
    "table9": table9_hplmxp,
    "table10": table10_io500,
    "interconnect": interconnect_table,
    "kernels": kernels_table,
    "roofline": roofline_table,
}


def main() -> None:
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    names = TABLES if which == "all" else {which: TABLES[which]}
    print("name,us_per_call,derived")
    for name, fn in names.items():
        fn()


if __name__ == "__main__":
    main()
